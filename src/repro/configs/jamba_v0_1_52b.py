"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other layer
[arXiv:2403.19887].

Period-8 block: attention at position 3 (1 attn : 7 mamba), MoE on odd layers.
DESIGN.md note: Jamba's SSM layers are Mamba-1 (S6); we realize them with the
Mamba-2 SSD form (d_state 16 as in the paper) — same state size and
interleave, TPU-friendlier compute."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    mixer_pattern=("ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm", "ssm"),
    mlp_pattern=("dense", "moe"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    rules_override={"fsdp": "data"},
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    d_model=64, n_layers=8, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    mixer_pattern=("ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm", "ssm"),
    mlp_pattern=("dense", "moe"),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, capacity_factor=8.0),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=32),
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=52.0, active_params_b=12.0, train_microbatch=16,
                long_500k=True,
                long_500k_note="hybrid: SSM state + 4 attn layers' 524k KV "
                               "(seq-sharded) — long_500k RUNS")
