"""Assigned-architecture registry: one module per arch exposing
CONFIG (full, dry-run only), SMOKE (reduced, CPU-runnable) and META
(per-shape microbatching, long_500k applicability, notes).

Shapes (assignment): every LM arch pairs with all four; decode/long lower
`serve_step`, train_4k lowers `train_step`, prefill_32k lowers `prefill_step`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

ARCHS = [
    "deepseek_7b",
    "internlm2_1_8b",
    "phi3_medium_14b",
    "qwen2_5_14b",
    "musicgen_large",
    "mamba2_130m",
    "jamba_v0_1_52b",
    "mixtral_8x7b",
    "deepseek_v3_671b",
    "internvl2_26b",
]

# public ids (assignment sheet) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "deepseek-7b": "deepseek_7b",
    "internlm2-1.8b": "internlm2_1_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2.5-14b": "qwen2_5_14b",
    "musicgen-large": "musicgen_large",
    "mamba2-130m": "mamba2_130m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "internvl2-26b": "internvl2_26b",
})

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class ArchMeta:
    params_b: float                      # approx parameter count (billions)
    active_params_b: float               # activated params (MoE) else == params_b
    train_microbatch: int = 1            # grad-accum steps for train_4k
    long_500k: bool = False              # sub-quadratic decode applicable?
    long_500k_note: str = ""
    notes: str = ""


def _mod(name: str):
    key = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str, smoke: bool = False):
    m = _mod(name)
    return m.SMOKE if smoke else m.CONFIG


def get_meta(name: str) -> ArchMeta:
    return _mod(name).META


def input_specs(cfg, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of a shape cell.
    No allocation — exactly what .lower() consumes."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32

    def tok(*shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if sh["kind"] in ("train", "prefill"):
        if cfg.frontend == "codebooks":
            return {"tokens": tok(B, S, cfg.n_codebooks)}
        if cfg.frontend == "patches":
            P = cfg.vision_tokens
            return {"tokens": tok(B, S - P),
                    "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), cfg.dtype)}
        return {"tokens": tok(B, S)}
    # decode: one new token against a cache of S
    if cfg.frontend == "codebooks":
        return {"tokens": tok(B, cfg.n_codebooks)}
    return {"tokens": tok(B)}
