"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
8 experts top-2, sliding-window attention (W=4096) [arXiv:2401.04088].

8 experts < 16-way model axis: EP would pad 2x, so experts map to TP-within-
expert instead (rules_override: experts->None, expert_ffn->model). SWA makes
long_500k decode sub-quadratic (rolling 4096 KV buffer) — it RUNS."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    d_model=4096, n_layers=32, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, rope_theta=1e6, swa_window=4096,
    mixer_pattern=("attn",), mlp_pattern=("moe",),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    rules_override={"experts": None, "expert_ffn": "model", "fsdp": "data"},
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=1e6, swa_window=64,
    mixer_pattern=("attn",), mlp_pattern=("moe",),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, capacity_factor=8.0),
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=46.7, active_params_b=12.9, train_microbatch=8,
                long_500k=True,
                long_500k_note="SWA rolling KV (W=4096): decode state is O(W) "
                               "not O(S) — long_500k RUNS")
