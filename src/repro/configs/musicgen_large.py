"""musicgen-large [audio]: 48L d_model=2048 32H (MHA) d_ff=8192 vocab=2048 —
decoder-only over EnCodec tokens [arXiv:2306.05284]. Modality frontend is a
STUB per assignment: inputs are 4 parallel EnCodec codebook token streams
(delay pattern applied upstream); embeddings are summed, one head per
codebook."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    d_model=2048, n_layers=48, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, rope_theta=1e4,
    frontend="codebooks", n_codebooks=4,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64, rope_theta=1e4,
    frontend="codebooks", n_codebooks=4,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=3.3, active_params_b=3.3, train_microbatch=4, long_500k=False,
                long_500k_note="pure full attention — skipped")
