"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297]."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    d_model=2048, n_layers=24, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92544, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=1e6,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=1.8, active_params_b=1.8, train_microbatch=2, long_500k=False,
                long_500k_note="pure full attention — skipped")
