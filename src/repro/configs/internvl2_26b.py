"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2-20B backbone [arXiv:2404.16821].

Per assignment the spec covers the LLM BACKBONE only; the InternViT frontend
is a STUB: input_specs provides 256 precomputed patch embeddings (B, 256,
d_model) prepended to the text tokens. Causal mask over the concatenated
sequence (simplification of prefix-LM masking; DESIGN.md)."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    d_model=6144, n_layers=48, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, rope_theta=1e6,
    frontend="patches", vision_tokens=256,
    rules_override={"fsdp": "data"},
)

SMOKE = ModelConfig(
    name="internvl2-26b-smoke",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=1e6,
    frontend="patches", vision_tokens=8,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=25.5, active_params_b=25.5, train_microbatch=8,
                long_500k=False, long_500k_note="pure full attention — skipped")
