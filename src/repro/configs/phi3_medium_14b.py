"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA [arXiv:2404.14219]. 40 heads / 10 kv heads are
not 16-divisible: GSPMD pads the head dim on the 16-way model axis (noted in
EXPERIMENTS.md roofline as padding overhead)."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    d_model=5120, n_layers=40, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352, rope_theta=1e4,
    rules_override={"fsdp": "data"},
)

SMOKE = ModelConfig(
    name="phi3-medium-14b-smoke",
    d_model=80, n_layers=2, n_heads=5, n_kv_heads=5, head_dim=16,
    d_ff=160, vocab_size=256, rope_theta=1e4,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=14.0, active_params_b=14.0, train_microbatch=8,
                long_500k=False, long_500k_note="pure full attention — skipped")
