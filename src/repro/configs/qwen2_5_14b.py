"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias [hf:Qwen/Qwen2.5]."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    d_model=5120, n_layers=48, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, rope_theta=1e6, qkv_bias=True,
    rules_override={"fsdp": "data"},
)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=1e6, qkv_bias=True,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=14.8, active_params_b=14.8, train_microbatch=8,
                long_500k=False, long_500k_note="pure full attention — skipped")
