"""mamba2-130m [ssm]: 24L d_model=768, attention-free, ssm_state=128,
vocab=50280 — SSD state-space duality [arXiv:2405.21060]. Pure Mamba blocks:
no MLP (mlp_pattern = "none"); d_inner = 2*768, head_dim 64 -> 24 SSD heads."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig
from repro.models.ssm import SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    d_model=768, n_layers=24, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    mixer_pattern=("ssm",), mlp_pattern=("none",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    d_model=64, n_layers=2, n_heads=1, n_kv_heads=1, head_dim=16,
    d_ff=0, vocab_size=256,
    mixer_pattern=("ssm",), mlp_pattern=("none",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=32),
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=0.13, active_params_b=0.13,
                long_500k=True,
                long_500k_note="SSM: O(1) state decode — long_500k RUNS")
