"""deepseek-v3-671b [moe]: 61L d_model=7168 128H MLA d_ff(expert)=2048
vocab=129280, 1 shared + 256 routed experts top-8, MTP [arXiv:2412.19437].

First 3 layers dense (d_ff 18432), remaining 58 MoE. MLA: q_lora 1536,
kv_lora 512, qk_nope 128, qk_rope 64, v 128. mtp_depth=1 adds the paper's
depth-1 multi-token-prediction module to train_step. Router here is softmax
top-k (V3's sigmoid + aux-free bias router approximated; DESIGN.md). Weights
2D-sharded (TP on model axis x FSDP on data axis) — required to fit 671B."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.mla import MLAConfig
from repro.models.model import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    d_model=7168, n_layers=61, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab_size=129280, rope_theta=1e4,
    mixer_pattern=("mla",), mlp_pattern=("moe",),
    dense_prefix=3, d_ff_dense=18432,
    mla=MLAConfig(n_heads=128, q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared=1, d_ff_shared=2048),
    mtp_depth=1,
    rules_override={"fsdp": "data", "expert_fsdp": "data"},
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke",
    d_model=64, n_layers=3, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, vocab_size=256,
    mixer_pattern=("mla",), mlp_pattern=("moe",),
    dense_prefix=1, d_ff_dense=128,
    mla=MLAConfig(n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                  n_shared=1, d_ff_shared=96, capacity_factor=8.0),
    mtp_depth=1,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=671.0, active_params_b=37.0, train_microbatch=16,
                long_500k=False,
                long_500k_note="full (latent) attention — skipped; MLA cache "
                               "is 576B/token so 500k would fit, but scores "
                               "remain O(S) per step")
