"""deepseek-7b [dense]: 30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954]."""
import jax.numpy as jnp

from repro.configs import ArchMeta
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    d_model=4096, n_layers=30, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="deepseek-7b-smoke",
    d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=1e4,
    dtype=jnp.float32, param_dtype=jnp.float32,
)

META = ArchMeta(params_b=6.9, active_params_b=6.9, train_microbatch=4,
                long_500k=False,
                long_500k_note="pure full attention: O(S) KV + O(S) score per "
                               "step is fine, but 500k full-softmax decode is "
                               "assigned only to sub-quadratic archs — skipped")
