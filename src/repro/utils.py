"""Shared small utilities: pytree helpers, dtype helpers, timing."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def has_nan(tree: Any) -> bool:
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree) if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return False
    return bool(jax.device_get(jnp.any(jnp.stack(leaves))))


def block_until_ready(tree: Any) -> Any:
    return jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, tree)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kwargs) -> tuple[float, Any]:
    """Wall-clock a jitted fn; returns (best seconds, last output)."""
    out = None
    for _ in range(warmup):
        out = block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best, out


def pretty_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"
