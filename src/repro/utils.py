"""Shared small utilities: pytree helpers, dtype helpers, timing, and the
tiny on-disk JSON cache used by kernel autotuning and routing calibration."""
from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def has_nan(tree: Any) -> bool:
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree) if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return False
    return bool(jax.device_get(jnp.any(jnp.stack(leaves))))


def block_until_ready(tree: Any) -> Any:
    return jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, tree)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kwargs) -> tuple[float, Any]:
    """Wall-clock a jitted fn; returns (best seconds, last output)."""
    out = None
    for _ in range(warmup):
        out = block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best, out


# -- on-disk JSON cache -----------------------------------------------------
#
# Both the kernel tile autotuner (kernels/autotune.py) and the routing
# calibration (core/routing.py) measure machine facts that outlive the
# process. They persist here: ${REPRO_CACHE_DIR:-~/.cache/repro-sven}/
# <kind>.json. Every entry key embeds whatever invalidates it (platform,
# device count, jax version, shape bucket) so one flat file per kind
# suffices. All failures — read-only HOME, corrupt JSON, races — degrade to
# "no cache", never to an exception on the solve path.

def cache_dir() -> Optional[Path]:
    """The persistent cache directory, or None when unwritable."""
    root = os.environ.get("REPRO_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-sven")
    try:
        p = Path(root)
        p.mkdir(parents=True, exist_ok=True)
        return p
    except OSError:
        return None


def disk_cache_load(kind: str) -> dict:
    """Read `<cache_dir>/<kind>.json`; {} on any failure."""
    d = cache_dir()
    if d is None:
        return {}
    try:
        with open(d / f"{kind}.json", encoding="utf-8") as f:
            out = json.load(f)
        return out if isinstance(out, dict) else {}
    except (OSError, ValueError):
        return {}


def disk_cache_update(kind: str, entries: dict) -> bool:
    """Merge `entries` into `<cache_dir>/<kind>.json` atomically
    (write-temp + rename, so concurrent processes see old or new, never
    torn). Returns False when persistence is unavailable."""
    d = cache_dir()
    if d is None:
        return False
    merged = disk_cache_load(kind)
    merged.update(entries)
    try:
        fd, tmp = tempfile.mkstemp(dir=d, prefix=f".{kind}-", suffix=".json")
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, d / f"{kind}.json")
        return True
    except OSError:
        return False


def pretty_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"
