"""Gap-safe feature screening for the Elastic Net (beyond-paper optimization).

Before running the SVM reduction, provably-inactive features can be discarded
(Ndiaye et al., "Gap Safe screening rules", JMLR 2017), shrinking the
constructed SVM problem from 2p to 2p_kept samples — a direct multiplier on
the Gram/Newton cost that the paper leaves on the table.

Derivation under this repo's scaling (P(b) = ||Xb-y||^2 + l2||b||^2 + l1|b|_1):
the ridge term folds into an augmented Lasso via A = [X; sqrt(l2) I],
b = [y; 0]: P = 2*(1/2||b-Ab||^2 + (l1/2)|b|_1). With lam = l1/2 and any
primal point beta:

    resid   = [y - X beta ; -sqrt(l2) beta]
    corr_j  = x_j^T (y - X beta) - l2 beta_j              (= a_j^T resid)
    theta   = resid / max(lam, ||corr||_inf)              (dual feasible)
    gap     = P_half(beta) - D(theta) >= 0
    DISCARD j  if  |corr_j| / scale + sqrt(2 gap) / lam * ||a_j|| < 1,
    ||a_j|| = sqrt(||x_j||^2 + l2)

Safe: a discarded j provably has beta*_j = 0 (tested: the rule never removes
the CD solution's support, for any warm point).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ScreenResult(NamedTuple):
    keep: jax.Array        # (p,) bool — features that MAY be active
    gap: jax.Array         # duality gap at (beta, theta)
    n_kept: jax.Array


def gap_safe_screen(X: jax.Array, y: jax.Array, beta: jax.Array,
                    lambda1: float, lambda2: float,
                    slack: float = 1e-6) -> ScreenResult:
    """`slack` is a pure-numerics guard on the discard boundary: at a warm
    point that is already (near-)optimal the duality gap underflows toward 0
    and ACTIVE coordinates sit exactly on |corr_j|/scale = 1, where f64
    roundoff (O(1e-8) observed) can push them to the discard side. Keeping a
    1e-6 band around the boundary costs a few extra kept columns and keeps
    the rule safe for the serving runtime's repeat-traffic warm starts,
    which screen at exactly such converged points."""
    lam = lambda1 / 2.0
    # lambda1 = 0 (pure ridge) has no L1 dual ball: nothing is safely
    # discardable, and every lam division below would produce NaNs that
    # silently discard EVERYTHING (beta = 0 instead of the ridge solution).
    # Guard the divisions and force keep-everything on that edge.
    lam_pos = jnp.asarray(lam > 0)   # jnp: `~` on a Python bool is -2
    lam_s = jnp.where(lam_pos, lam, 1.0)
    r = y - X @ beta
    corr = X.T @ r - lambda2 * beta                        # (p,)
    scale = jnp.maximum(lam_s, jnp.max(jnp.abs(corr)))

    # P_half and D(theta) in the augmented-Lasso convention
    res_sq = r @ r + lambda2 * (beta @ beta)               # ||b - A beta||^2
    p_half = 0.5 * res_sq + lam * jnp.sum(jnp.abs(beta))
    b_sq = y @ y
    btheta = (y @ r) / scale
    theta_sq = res_sq / (scale * scale)
    # D = 1/2||b||^2 - lam^2/2 ||theta - b/lam||^2
    d_val = 0.5 * b_sq - 0.5 * lam_s * lam_s * (
        theta_sq - 2.0 * btheta / lam_s + b_sq / (lam_s * lam_s))
    gap = jnp.maximum(p_half - d_val, 0.0)

    radius = jnp.sqrt(2.0 * gap) / lam_s
    col_norm = jnp.sqrt(jnp.sum(X * X, axis=0) + lambda2)
    keep = (jnp.abs(corr) / scale + radius * col_norm) >= 1.0 - slack
    keep = jnp.logical_or(keep, jnp.logical_not(lam_pos))
    return ScreenResult(keep=keep, gap=gap, n_kept=jnp.sum(keep))


def sven_with_screening(X, y, t, lambda2, *, warm_beta=None, config=None):
    """Screen-then-solve: estimate lambda1 from a warm beta (or a few FISTA
    steps), drop provably-inactive columns, run SVEN on the survivors and
    scatter beta back to p dims. Exactness is preserved (safe rule)."""
    from repro.baselines.fista import elastic_net_fista
    from repro.core import elastic_net as en
    from repro.core.sven import SvenConfig, sven

    config = config or SvenConfig()
    p = X.shape[1]
    if warm_beta is None:
        # cheap warm start at the lambda1 implied by a rough path position
        l1_guess = 0.2 * float(en.lambda1_max(X, y))
        warm_beta = elastic_net_fista(X, y, l1_guess, lambda2, max_iters=400).beta
    # lambda1 consistent with the constrained-form multiplier at warm_beta
    lam1 = float(en.kkt_multiplier(X, y, warm_beta, lambda2))
    lam1 = max(lam1, 1e-8)
    scr = gap_safe_screen(X, y, warm_beta, lam1, lambda2)
    idx = jnp.where(scr.keep, size=p, fill_value=-1)[0]
    n_kept = int(scr.n_kept)
    idx = idx[:n_kept]
    X_red = X[:, idx]
    sol = sven(X_red, y, t, lambda2, config)
    beta = jnp.zeros((p,), X.dtype).at[idx].set(sol.beta)
    return beta, sol, scr
