"""The paper's reduction: Elastic Net -> squared-hinge SVM (Algorithm 1).

Given (X in R^{n x p}, y in R^n, t > 0, lambda2 > 0) construct a binary
classification problem with m = 2p samples in d = n dimensions:

    Xhat_1 = X - (1/t) y 1^T    (columns are the +1 class)
    Xhat_2 = X + (1/t) y 1^T    (columns are the -1 class)
    Xhat   = [Xhat_1, Xhat_2]   as columns; SVM sample i is the i-th column
    yhat   = [+1_p ; -1_p],  C  = 1 / (2 lambda2)

If alpha* solves the SVM dual (3), the Elastic Net solution is

    beta* = t * (alpha*[:p] - alpha*[p:]) / |alpha*|_1.

NOTE on the paper's MATLAB listing: line 3 uses "[A; B]'" (vertical concat)
which would produce a (p x 2n) matrix — inconsistent with the math (m = 2p
samples of dimension n). We follow the math: Xnew = [Xhat_1, Xhat_2]^T of
shape (2p, n), samples as rows.

This module provides BOTH an explicit construction (reference, used by tests
and the paper-faithful baseline) and matrix-free operators that never
materialize the (2p, n) matrix — the TPU-native path (see DESIGN.md §2): all
solver mat-vecs reduce to ops on the original (n, p) X plus rank-1 terms,
halving FLOPs and removing a full HBM materialization.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Explicit construction (paper-faithful)
# --------------------------------------------------------------------------

def build_svm_dataset(X: jax.Array, y: jax.Array, t: float) -> Tuple[jax.Array, jax.Array]:
    """Return (Xhat, yhat): Xhat (2p, n) rows = SVM samples, yhat (2p,) labels."""
    shift = (y / t)[None, :]          # (1, n) broadcast over the p columns
    Xt = X.T                          # (p, n): row j = original feature j
    Xhat = jnp.concatenate([Xt - shift, Xt + shift], axis=0)  # (2p, n)
    p = X.shape[1]
    yhat = jnp.concatenate([jnp.ones((p,), X.dtype), -jnp.ones((p,), X.dtype)])
    return Xhat, yhat


#: Default Lasso-limit floor on lambda2 (C capped at 1/(2*floor)). The single
#: source of truth for the clamp — SvenConfig.lambda2_floor defaults to it.
LAMBDA2_FLOOR = 1e-12


def svm_C(lambda2, floor: float = LAMBDA2_FLOOR) -> jax.Array:
    """C = 1/(2 lambda2); capped for the Lasso limit lambda2 -> 0.

    Accepts Python floats and traced scalars alike — the one clamping rule
    used by both the explicit reduction and the sven() driver.
    """
    lam2 = jnp.maximum(jnp.asarray(lambda2, jnp.result_type(float, lambda2)), floor)
    return 1.0 / (2.0 * lam2)


def recover_beta(alpha: jax.Array, t: float) -> jax.Array:
    """beta = t (alpha_top - alpha_bot) / sum(alpha); Algorithm 1 line 11."""
    p = alpha.shape[0] // 2
    s = jnp.sum(alpha)
    # Degenerate |alpha|_1 = 0 (no support vectors) is meaningless per the
    # paper's footnote 1; guard to avoid NaN and return beta = 0.
    safe = jnp.where(s > 0, s, 1.0)
    return jnp.where(s > 0, t * (alpha[:p] - alpha[p:]) / safe, jnp.zeros((p,), alpha.dtype))


def alpha_from_primal(Xhat: jax.Array, yhat: jax.Array, w: jax.Array, C: float) -> jax.Array:
    """Dual from primal solution: alpha_i = C max(0, 1 - yhat_i x_i^T w)."""
    return C * jnp.maximum(1.0 - yhat * (Xhat @ w), 0.0)


# --------------------------------------------------------------------------
# Matrix-free operators (TPU-native; beyond-paper optimization)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SvenOperator:
    """Matrix-free Xhat / Zhat operators built from the original (X, y, t).

    With a = X^T w (p,), b = y^T w / t (scalar):
        Xhat @ w          = [a - b ; a + b]
        Xhat^T @ v        = X (v_top + v_bot) + (y/t) (sum(v_bot) - sum(v_top))
        Zhat @ v          = X (v_top - v_bot) - (y/t) sum(v)          (n,)
        Zhat^T @ u        = [X^T u - (y^T u/t) 1 ; -X^T u - (y^T u/t) 1]
    where Zhat = [Xhat_1, -Xhat_2] (n x 2p) is the label-scaled data of the
    dual (3). Every product is O(np) on the original X — the (2p, n) matrix
    never exists.
    """

    X: jax.Array   # (n, p)
    y: jax.Array   # (n,)
    t: float

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]

    @property
    def m(self) -> int:
        return 2 * self.X.shape[1]

    def xhat_matvec(self, w: jax.Array) -> jax.Array:
        a = self.X.T @ w
        b = (self.y @ w) / self.t
        return jnp.concatenate([a - b, a + b])

    def xhat_rmatvec(self, v: jax.Array) -> jax.Array:
        p = self.p
        vt, vb = v[:p], v[p:]
        return self.X @ (vt + vb) + (self.y / self.t) * (jnp.sum(vb) - jnp.sum(vt))

    def zhat_matvec(self, v: jax.Array) -> jax.Array:
        p = self.p
        vt, vb = v[:p], v[p:]
        return self.X @ (vt - vb) - (self.y / self.t) * jnp.sum(v)

    def zhat_rmatvec(self, u: jax.Array) -> jax.Array:
        a = self.X.T @ u
        b = (self.y @ u) / self.t
        return jnp.concatenate([a - b, -a - b])

    def kernel_matvec(self, v: jax.Array) -> jax.Array:
        """K v with K = Zhat^T Zhat (2p x 2p), in O(np)."""
        return self.zhat_rmatvec(self.zhat_matvec(v))

    def margins(self, w: jax.Array) -> jax.Array:
        """yhat * (Xhat @ w) as used by the squared hinge."""
        p = self.p
        o = self.xhat_matvec(w)
        return jnp.concatenate([o[:p], -o[p:]])


def gram_from_stats(G: jax.Array, u: jax.Array, s) -> jax.Array:
    """K = Zhat^T Zhat (2p x 2p) from the sufficient statistics
    G = X^T X (p, p), u = X^T y / t (p,), s = y^T y / t^2 (scalar):

        K = [[ G - u1' - 1u' + s ,  -G - u1' + 1u' + s ],
             [ -G + u1' - 1u' + s,   G + u1' + 1u' + s ]]

    Split out from `gram_blocks` because the statistics are one-shot
    maintainable under streaming rows: a new (x, y) sample is a rank-1
    update G += x x^T, X^T y += y x, y^T y += y^2 — the serving runtime's
    online layer (`repro.runtime.online`) rebuilds K from the updated stats
    in O(p^2), never re-touching the n accumulated rows.
    """
    u1 = u[:, None]
    u2 = u[None, :]
    top = jnp.concatenate([G - u1 - u2 + s, -G - u1 + u2 + s], axis=1)
    bot = jnp.concatenate([-G + u1 - u2 + s, G + u1 + u2 + s], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def gram_blocks(X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """Assemble K = Zhat^T Zhat (2p x 2p) from p x p blocks.

    Beyond-paper optimization: built via `gram_from_stats`, costing one
    p x p Gram (np^2 MACs) instead of the naive (2p)^2 n — a 4x FLOP
    reduction over materializing Zhat (what the MATLAB/GPU code pays).
    """
    return gram_from_stats(X.T @ X, (X.T @ y) / t, (y @ y) / (t * t))


def gram_reference(X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """Paper-faithful K: materialize Zhat then Zhat^T Zhat."""
    Xhat, yhat = build_svm_dataset(X, y, t)
    Zhat = (yhat[:, None] * Xhat).T   # (n, 2p)
    return Zhat.T @ Zhat
