"""Batched K-fold cross-validation for the penalized Elastic Net (DESIGN.md §7).

glmnet's `cv.glmnet` loops folds sequentially; here the K held-out training
problems are stacked through `core.batch.cv_folds` and the whole (lambda
grid x fold) surface runs as ONE jitted `lax.scan` over the grid whose body
vmaps the screening-fused penalized point solver (`core.api._enet_point`)
over the fold axis — K solver machines advance in lockstep, each carrying
its own warm (beta, alpha, w, t, nu) state down the path. Under an active
`repro.dist.mesh_context` the fold axis is exactly the "batch" axis the rule
table shards, so CV fans out across the data-parallel mesh like any other
batched workload.

`cross_validate` selects lambda by mean held-out MSE and refits on the full
data: the entire driver costs exactly two traces — `enet_cv_scan` (the CV
surface) + `enet` (the refit) — asserted via `trace_counts()` in tests.
`cross_validate_reference` keeps the glmnet-style sequential per-fold loop
as the testable reference (identical fold splits, identical grid).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.batch import cv_folds
from repro.core.sven import _bump_trace


def _auto_fold_chunk(k: int) -> int:
    """Right-size the scan-of-vmap: how many folds advance in vmap lockstep.

    A vmapped `while_loop` costs the MAX trip count across lanes at every
    nesting level (Illinois evals x Newton iters x CG), so on a single CPU
    device the k-wide lockstep runs ~1.6x SLOWER than solving folds one
    after another (BENCH_path.json's cv section tracks this). chunk=1 keeps
    everything inside ONE executable — an outer `lax.scan` over folds, no
    per-fold dispatch — which is what beats the host-side per-fold loop on
    CPU; with real batch parallelism (accelerator backends or a multi-device
    mesh feeding the "batch" rule-table axis) the full-width vmap wins.
    """
    if jax.default_backend() != "cpu" or jax.device_count() > 1:
        return k
    return 1


@partial(jax.jit, static_argnames=("config", "fold_chunk"))
def _enet_cv_scan(Xtr, ytr, Xva, yva, lambda1s, lambda2,
                  config: api.PathConfig, fold_chunk: Optional[int] = None):
    """(L,) grid scan of fold-chunked vmaps; returns per-point CV diagnostics.

    Folds are processed `fold_chunk` at a time (None = all k at once, the
    pure vmap): an outer scan over k/fold_chunk chunks, each chunk scanning
    the lambda grid with a fold_chunk-wide vmapped `_enet_point` body and
    its own warm state carried down the path. Results are identical for any
    chunking (tested); see `_auto_fold_chunk` for why the size matters.
    """
    _bump_trace("enet_cv_scan")
    k = Xtr.shape[0]
    c = k if fold_chunk is None else fold_chunk
    if k % c:
        raise ValueError(f"_enet_cv_scan: fold_chunk={c} must divide k={k}")
    chunked = jax.tree.map(lambda a: a.reshape(k // c, c, *a.shape[1:]),
                           (Xtr, ytr, Xva, yva))

    def chunk_body(_, xs):
        Xt, yt, Xv, yv = xs                            # (c, n_tr, p) ...
        if c == 1:
            # skip the inner vmap: even at width 1 it rewrites every nested
            # while_loop into its masked batched form, which runs ~2.4x
            # slower than the plain loops on CPU
            Xf, yf, Xv1, yv1 = Xt[0], yt[0], Xv[0], yv[0]

            def lam_body1(carry, lam1):
                carry2, pt = api._enet_point(Xf, yf, lam1, lambda2, carry,
                                             config)
                resid = Xv1 @ pt.beta - yv1
                return carry2, (jnp.mean(resid * resid)[None],
                                pt.n_kept[None], pt.evals[None])

            _, out = jax.lax.scan(lam_body1, api.cold_carry(Xf, yf), lambda1s)
            return None, out                           # each (L, 1)

        init = jax.vmap(api.cold_carry)(Xt, yt)

        def lam_body(carry, lam1):
            def one(Xf, yf, cf):
                return api._enet_point(Xf, yf, lam1, lambda2, cf, config)

            carry2, pts = jax.vmap(one)(Xt, yt, carry)
            resid = jnp.einsum("kif,kf->ki", Xv, pts.beta) - yv
            mse = jnp.mean(resid * resid, axis=1)      # (c,)
            return carry2, (mse, pts.n_kept, pts.evals)

        _, out = jax.lax.scan(lam_body, init, lambda1s)
        return None, out                               # each (L, c)

    _, (mse, n_kept, evals) = jax.lax.scan(chunk_body, None, chunked)

    def reorder(a):                                    # (g, L, c) -> (L, k)
        return jnp.moveaxis(a, 0, 1).reshape(a.shape[1], k)

    return reorder(mse), reorder(n_kept), reorder(evals)


class CVResult(NamedTuple):
    lambda1s: jax.Array     # (L,) descending grid
    lambda2: float
    mse_path: jax.Array     # (L, k) held-out MSE per grid point and fold
    mean_mse: jax.Array     # (L,)
    lambda_min: float       # grid point minimizing mean CV MSE
    index_min: int
    beta: jax.Array         # (p,) full-data refit at lambda_min (orig scale)
    intercept: jax.Array
    n_kept: jax.Array       # (L, k) screened problem sizes
    evals: jax.Array        # (L, k) SVEN solves per (lambda, fold)


def cross_validate(X, y, *, k: int = 5, lambda1s=None, n_lambdas: int = 40,
                   eps: Optional[float] = None, lambda2=1.0,
                   standardize: bool = True, fit_intercept: bool = True,
                   fold_chunk: Optional[int] = None,
                   config: api.PathConfig = api.PathConfig()) -> CVResult:
    """K-fold CV over the lambda grid, batched across folds; refit at the min.

    Standardization statistics and the grid are computed once on the full
    data (so every fold sees the same grid, as cv.glmnet does); held-out MSE
    is measured in the centered space, which equals original-space MSE
    because the scaler is global.

    `fold_chunk` sets how many folds advance in vmap lockstep (must divide
    k); the default picks per backend — all k on accelerators / multi-device
    meshes, 1 (a pure scan, still one executable) on a single CPU device,
    where lockstep loses (see `_auto_fold_chunk`).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, scaler = api.standardize_fit(X, y, standardize=standardize,
                                         fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = api.lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    lam2 = jnp.asarray(lambda2, X.dtype)

    if fold_chunk is None:
        fold_chunk = _auto_fold_chunk(k)
    if k % fold_chunk:
        raise ValueError(f"cross_validate: fold_chunk={fold_chunk} must "
                         f"divide k={k}")
    Xtr, ytr, Xva, yva = cv_folds(Xs, ys, k)
    mse, n_kept, evals = _enet_cv_scan(Xtr, ytr, Xva, yva, lambda1s, lam2,
                                       config, fold_chunk)
    mean_mse = jnp.mean(mse, axis=1)
    i_min = int(jnp.argmin(mean_mse))
    lambda_min = float(lambda1s[i_min])

    _, pt = api._enet_jit(Xs, ys, jnp.asarray(lambda_min, X.dtype), lam2,
                          api.cold_carry(Xs, ys), config)
    beta, intercept = api.unscale_coef(pt.beta, scaler)
    return CVResult(lambda1s=lambda1s, lambda2=float(lambda2), mse_path=mse,
                    mean_mse=mean_mse, lambda_min=lambda_min, index_min=i_min,
                    beta=beta, intercept=intercept, n_kept=n_kept, evals=evals)


def cross_validate_reference(X, y, *, k: int = 5, lambda1s=None,
                             n_lambdas: int = 40, eps: Optional[float] = None,
                             lambda2=1.0, standardize: bool = True,
                             fit_intercept: bool = True,
                             config: api.PathConfig = api.PathConfig()):
    """Sequential per-fold loop (cv.glmnet's shape): the batched CV's oracle.

    Same splits, same full-data grid and scaler; each fold runs its own
    `_enet_path_scan`. Returns (lambda1s, mse_path (L, k)).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, _ = api.standardize_fit(X, y, standardize=standardize,
                                    fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = api.lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    lam2 = jnp.asarray(lambda2, X.dtype)

    Xtr, ytr, Xva, yva = cv_folds(Xs, ys, k)
    cols = []
    for i in range(k):
        pts = api._enet_path_scan(Xtr[i], ytr[i], lambda1s, lam2, config)
        resid = pts.beta @ Xva[i].T - yva[i][None, :]   # (L, fold)
        cols.append(jnp.mean(resid * resid, axis=1))
    return lambda1s, jnp.stack(cols, axis=1)


class ElasticNetCV:
    """sklearn-style K-fold CV estimator over the batched SVEN front-end.

    After `fit`: `coef_`, `intercept_`, `lambda_min_`, `lambda1s_`,
    `mse_path_` (L, k), `mean_mse_`.
    """

    def __init__(self, k: int = 5, n_lambdas: int = 40,
                 eps: Optional[float] = None, lambda2: float = 1.0, *,
                 standardize: bool = True, fit_intercept: bool = True,
                 config: api.PathConfig = api.PathConfig()):
        self.k = k
        self.n_lambdas = n_lambdas
        self.eps = eps
        self.lambda2 = lambda2
        self.standardize = standardize
        self.fit_intercept = fit_intercept
        self.config = config

    def fit(self, X, y):
        res = cross_validate(X, y, k=self.k, n_lambdas=self.n_lambdas,
                             eps=self.eps, lambda2=self.lambda2,
                             standardize=self.standardize,
                             fit_intercept=self.fit_intercept,
                             config=self.config)
        self.coef_ = res.beta
        self.intercept_ = res.intercept
        self.lambda_min_ = res.lambda_min
        self.lambda1s_ = res.lambda1s
        self.mse_path_ = res.mse_path
        self.mean_mse_ = res.mean_mse
        self.cv_result_ = res
        return self

    def predict(self, X):
        return jnp.asarray(X) @ self.coef_ + self.intercept_
