"""Batched K-fold cross-validation for the penalized Elastic Net (DESIGN.md §7).

glmnet's `cv.glmnet` loops folds sequentially; here the K held-out training
problems are stacked through `core.batch.cv_folds` and the whole (lambda
grid x fold) surface runs as ONE jitted `lax.scan` over the grid whose body
vmaps the screening-fused penalized point solver (`core.api._enet_point`)
over the fold axis — K solver machines advance in lockstep, each carrying
its own warm (beta, alpha, w, t, nu) state down the path. Under an active
`repro.dist.mesh_context` the fold axis is exactly the "batch" axis the rule
table shards, so CV fans out across the data-parallel mesh like any other
batched workload.

`cross_validate` selects lambda by mean held-out MSE and refits on the full
data: the entire driver costs exactly two traces — `enet_cv_scan` (the CV
surface) + `enet` (the refit) — asserted via `trace_counts()` in tests.
`cross_validate_reference` keeps the glmnet-style sequential per-fold loop
as the testable reference (identical fold splits, identical grid).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.batch import cv_folds
from repro.core.sven import _bump_trace


@partial(jax.jit, static_argnames=("config",))
def _enet_cv_scan(Xtr, ytr, Xva, yva, lambda1s, lambda2,
                  config: api.PathConfig):
    """(L,) grid scan of a (k,)-fold vmap; returns per-point CV diagnostics."""
    _bump_trace("enet_cv_scan")

    init = jax.vmap(api.cold_carry)(Xtr, ytr)

    def body(carry, lam1):
        def one(Xf, yf, cf):
            return api._enet_point(Xf, yf, lam1, lambda2, cf, config)

        carry2, pts = jax.vmap(one)(Xtr, ytr, carry)
        resid = jnp.einsum("kif,kf->ki", Xva, pts.beta) - yva
        mse = jnp.mean(resid * resid, axis=1)          # (k,)
        return carry2, (mse, pts.n_kept, pts.evals)

    _, (mse, n_kept, evals) = jax.lax.scan(body, init, lambda1s)
    return mse, n_kept, evals                          # each (L, k)


class CVResult(NamedTuple):
    lambda1s: jax.Array     # (L,) descending grid
    lambda2: float
    mse_path: jax.Array     # (L, k) held-out MSE per grid point and fold
    mean_mse: jax.Array     # (L,)
    lambda_min: float       # grid point minimizing mean CV MSE
    index_min: int
    beta: jax.Array         # (p,) full-data refit at lambda_min (orig scale)
    intercept: jax.Array
    n_kept: jax.Array       # (L, k) screened problem sizes
    evals: jax.Array        # (L, k) SVEN solves per (lambda, fold)


def cross_validate(X, y, *, k: int = 5, lambda1s=None, n_lambdas: int = 40,
                   eps: Optional[float] = None, lambda2=1.0,
                   standardize: bool = True, fit_intercept: bool = True,
                   config: api.PathConfig = api.PathConfig()) -> CVResult:
    """K-fold CV over the lambda grid, batched across folds; refit at the min.

    Standardization statistics and the grid are computed once on the full
    data (so every fold sees the same grid, as cv.glmnet does); held-out MSE
    is measured in the centered space, which equals original-space MSE
    because the scaler is global.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, scaler = api.standardize_fit(X, y, standardize=standardize,
                                         fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = api.lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    lam2 = jnp.asarray(lambda2, X.dtype)

    Xtr, ytr, Xva, yva = cv_folds(Xs, ys, k)
    mse, n_kept, evals = _enet_cv_scan(Xtr, ytr, Xva, yva, lambda1s, lam2,
                                       config)
    mean_mse = jnp.mean(mse, axis=1)
    i_min = int(jnp.argmin(mean_mse))
    lambda_min = float(lambda1s[i_min])

    _, pt = api._enet_jit(Xs, ys, jnp.asarray(lambda_min, X.dtype), lam2,
                          api.cold_carry(Xs, ys), config)
    beta, intercept = api.unscale_coef(pt.beta, scaler)
    return CVResult(lambda1s=lambda1s, lambda2=float(lambda2), mse_path=mse,
                    mean_mse=mean_mse, lambda_min=lambda_min, index_min=i_min,
                    beta=beta, intercept=intercept, n_kept=n_kept, evals=evals)


def cross_validate_reference(X, y, *, k: int = 5, lambda1s=None,
                             n_lambdas: int = 40, eps: Optional[float] = None,
                             lambda2=1.0, standardize: bool = True,
                             fit_intercept: bool = True,
                             config: api.PathConfig = api.PathConfig()):
    """Sequential per-fold loop (cv.glmnet's shape): the batched CV's oracle.

    Same splits, same full-data grid and scaler; each fold runs its own
    `_enet_path_scan`. Returns (lambda1s, mse_path (L, k)).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, _ = api.standardize_fit(X, y, standardize=standardize,
                                    fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = api.lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    lam2 = jnp.asarray(lambda2, X.dtype)

    Xtr, ytr, Xva, yva = cv_folds(Xs, ys, k)
    cols = []
    for i in range(k):
        pts = api._enet_path_scan(Xtr[i], ytr[i], lambda1s, lam2, config)
        resid = pts.beta @ Xva[i].T - yva[i][None, :]   # (L, fold)
        cols.append(jnp.mean(resid * resid, axis=1))
    return lambda1s, jnp.stack(cols, axis=1)


class ElasticNetCV:
    """sklearn-style K-fold CV estimator over the batched SVEN front-end.

    After `fit`: `coef_`, `intercept_`, `lambda_min_`, `lambda1s_`,
    `mse_path_` (L, k), `mean_mse_`.
    """

    def __init__(self, k: int = 5, n_lambdas: int = 40,
                 eps: Optional[float] = None, lambda2: float = 1.0, *,
                 standardize: bool = True, fit_intercept: bool = True,
                 config: api.PathConfig = api.PathConfig()):
        self.k = k
        self.n_lambdas = n_lambdas
        self.eps = eps
        self.lambda2 = lambda2
        self.standardize = standardize
        self.fit_intercept = fit_intercept
        self.config = config

    def fit(self, X, y):
        res = cross_validate(X, y, k=self.k, n_lambdas=self.n_lambdas,
                             eps=self.eps, lambda2=self.lambda2,
                             standardize=self.standardize,
                             fit_intercept=self.fit_intercept,
                             config=self.config)
        self.coef_ = res.beta
        self.intercept_ = res.intercept
        self.lambda_min_ = res.lambda_min
        self.lambda1s_ = res.lambda1s
        self.mse_path_ = res.mse_path
        self.mean_mse_ = res.mean_mse
        self.cv_result_ = res
        return self

    def predict(self, X):
        return jnp.asarray(X) @ self.coef_ + self.intercept_
