"""Batched K-fold cross-validation for the penalized Elastic Net (DESIGN.md §7).

glmnet's `cv.glmnet` loops folds sequentially; here the K held-out training
problems are stacked through `core.batch.cv_folds` and the whole (lambda
grid x fold) surface runs as ONE jitted `lax.scan` over the grid whose body
vmaps the screening-fused penalized point solver (`core.api._enet_point`)
over the fold axis — K solver machines advance in lockstep, each carrying
its own warm (beta, alpha, w, t, nu) state down the path. Under an active
`repro.dist.mesh_context` the fold axis is exactly the "batch" axis the rule
table shards, so CV fans out across the data-parallel mesh like any other
batched workload.

`cross_validate` selects lambda by mean held-out MSE and refits on the full
data: the entire driver costs exactly two traces — `enet_cv_scan` (the CV
surface) + `enet` (the refit) — asserted via `trace_counts()` in tests.
`cross_validate_reference` keeps the glmnet-style sequential per-fold loop
as the testable reference (identical fold splits, identical grid).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import dist
from repro.core import api
from repro.core.batch import cv_folds
from repro.core.sven import _bump_trace


def _auto_fold_chunk(k: int, mesh) -> int:
    """Right-size the scan-of-vmap: how many folds advance in vmap lockstep.

    A vmapped `while_loop` costs the MAX trip count across lanes at every
    nesting level (Illinois evals x Newton iters x CG), so on a single CPU
    device the k-wide lockstep runs ~1.6x SLOWER than solving folds one
    after another (BENCH_path.json's cv section tracks this). chunk=1 keeps
    everything inside ONE executable — an outer `lax.scan` over folds, no
    per-fold dispatch — which is what beats the host-side per-fold loop on
    CPU.

    The decision keys on where the FOLDS ARE PLACED, not on process-global
    device counts: with a (>1)-device `mesh` carrying the fold axis, every
    device advances its own fold subset and the full-width vmap wins; the
    mere existence of extra devices the folds don't live on (the old
    heuristic) buys nothing. Non-CPU backends keep the full-width vmap
    even on one device (batch parallelism in the hardware).

    `mesh` is REQUIRED and must be the RESOLVED placement (the mesh the
    folds actually shard over, or None for single-device) — an optional
    default here once let a caller inside an outer `mesh_context` with a
    single-device resolution fall back to process-global state and pick the
    wrong lockstep width. Every call path resolves first, then asks.
    """
    if mesh is not None and mesh.size > 1:
        return k
    if jax.default_backend() != "cpu":
        return k
    return 1


def _resolve_cv_mesh(mesh, k: int, n_tr: Optional[int] = None,
                     p: Optional[int] = None, points: int = 1):
    """mesh="auto" -> the innermost dist context, else a device-spanning
    data mesh, else None; any mesh whose size does not divide k falls back
    to None (replicated folds would just pay collective overhead).

    An auto-resolved mesh is an OFFER, so with the fold-problem shape
    (`n_tr`, `p`, `points` grid points per lane) given it is also priced by
    the `core.routing` cost model and declined when a single device would
    finish the CV surface sooner. An EXPLICIT mesh pins the placement —
    the caller said where the folds live, routing does not second-guess it.
    """
    auto = mesh == "auto"
    if auto:
        ctx = dist.current_context()
        if ctx is not None:
            mesh = ctx[0]
        elif jax.device_count() > 1:
            mesh = dist.data_mesh()
        else:
            mesh = None
    if mesh is not None and (mesh.size <= 1 or k % mesh.size != 0):
        return None
    if auto and mesh is not None and n_tr is not None and p is not None:
        from repro.core import routing
        decision = routing.route_batch(n_tr, p, k, mesh, form="penalized",
                                       points=points)
        if decision.path != "batch":
            return None
    return mesh


def _place_folds(mesh, *arrays):
    """Shard the leading (fold) axis of each stacked array over `mesh` via
    the one batch-axis placement implementation (`_maybe_shard_batch`);
    rules come from the active context when it carries this mesh."""
    from repro.core.batch import _maybe_shard_batch

    ctx = dist.current_context()
    rules = (ctx[1] if ctx is not None and ctx[0] is mesh
             else dict(dist.DEFAULT_RULES))
    return tuple(_maybe_shard_batch(a, True, (mesh, rules)) for a in arrays)


@partial(jax.jit, static_argnames=("config", "fold_chunk", "mesh"))
def _enet_cv_scan_sharded(Xtr, ytr, Xva, yva, lambda1s, lambda2,
                          config: api.PathConfig, fold_chunk: int, mesh):
    """Device-parallel CV: the fold axis shard_mapped over the mesh.

    Each device runs `_enet_cv_scan` on ITS OWN fold block with zero
    collectives — in particular the solver while_loops never synchronize
    across devices (a fold-sharded vmap under the partitioner would
    all-reduce every loop condition, orders of magnitude slower).
    `fold_chunk` is the PER-DEVICE lockstep width; with one fold per device
    it is 1, which `_enet_cv_scan` special-cases to the plain un-vmapped
    loops: full device parallelism AND no masked-lockstep penalty.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)

    def local(Xt, yt, Xv, yv, l1, l2):
        return _enet_cv_scan(Xt, yt, Xv, yv, l1, l2, config, fold_chunk)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes), P(axes), P(axes), P(axes), P(), P()),
                     out_specs=(P(None, axes),) * 3, check_rep=False)(
                         Xtr, ytr, Xva, yva, lambda1s, lambda2)


@partial(jax.jit, static_argnames=("config", "fold_chunk"))
def _enet_cv_scan(Xtr, ytr, Xva, yva, lambda1s, lambda2,
                  config: api.PathConfig, fold_chunk: Optional[int] = None):
    """(L,) grid scan of fold-chunked vmaps; returns per-point CV diagnostics.

    Folds are processed `fold_chunk` at a time (None = all k at once, the
    pure vmap): an outer scan over k/fold_chunk chunks, each chunk scanning
    the lambda grid with a fold_chunk-wide vmapped `_enet_point` body and
    its own warm state carried down the path. Results are identical for any
    chunking (tested); see `_auto_fold_chunk` for why the size matters.
    """
    _bump_trace("enet_cv_scan")
    k = Xtr.shape[0]
    c = k if fold_chunk is None else fold_chunk
    if k % c:
        raise ValueError(f"_enet_cv_scan: fold_chunk={c} must divide k={k}")
    chunked = jax.tree.map(lambda a: a.reshape(k // c, c, *a.shape[1:]),
                           (Xtr, ytr, Xva, yva))

    def chunk_body(_, xs):
        Xt, yt, Xv, yv = xs                            # (c, n_tr, p) ...
        if c == 1:
            # skip the inner vmap: even at width 1 it rewrites every nested
            # while_loop into its masked batched form, which runs ~2.4x
            # slower than the plain loops on CPU
            Xf, yf, Xv1, yv1 = Xt[0], yt[0], Xv[0], yv[0]

            def lam_body1(carry, lam1):
                carry2, pt = api._enet_point(Xf, yf, lam1, lambda2, carry,
                                             config)
                resid = Xv1 @ pt.beta - yv1
                return carry2, (jnp.mean(resid * resid)[None],
                                pt.n_kept[None], pt.evals[None])

            _, out = jax.lax.scan(lam_body1, api.cold_carry(Xf, yf), lambda1s)
            return None, out                           # each (L, 1)

        init = jax.vmap(api.cold_carry)(Xt, yt)

        def lam_body(carry, lam1):
            def one(Xf, yf, cf):
                return api._enet_point(Xf, yf, lam1, lambda2, cf, config)

            carry2, pts = jax.vmap(one)(Xt, yt, carry)
            resid = jnp.einsum("kif,kf->ki", Xv, pts.beta) - yv
            mse = jnp.mean(resid * resid, axis=1)      # (c,)
            return carry2, (mse, pts.n_kept, pts.evals)

        _, out = jax.lax.scan(lam_body, init, lambda1s)
        return None, out                               # each (L, c)

    _, (mse, n_kept, evals) = jax.lax.scan(chunk_body, None, chunked)

    def reorder(a):                                    # (g, L, c) -> (L, k)
        return jnp.moveaxis(a, 0, 1).reshape(a.shape[1], k)

    return reorder(mse), reorder(n_kept), reorder(evals)


class CVResult(NamedTuple):
    lambda1s: jax.Array     # (L,) descending grid
    lambda2: float
    mse_path: jax.Array     # (L, k) held-out MSE per grid point and fold
    mean_mse: jax.Array     # (L,)
    lambda_min: float       # grid point minimizing mean CV MSE
    index_min: int
    beta: jax.Array         # (p,) full-data refit at lambda_min (orig scale)
    intercept: jax.Array
    n_kept: jax.Array       # (L, k) screened problem sizes
    evals: jax.Array        # (L, k) SVEN solves per (lambda, fold)


def cross_validate(X, y, *, k: int = 5, lambda1s=None, n_lambdas: int = 40,
                   eps: Optional[float] = None, lambda2=1.0,
                   standardize: bool = True, fit_intercept: bool = True,
                   fold_chunk: Optional[int] = None, mesh="auto",
                   config: api.PathConfig = api.PathConfig()) -> CVResult:
    """K-fold CV over the lambda grid, batched across folds; refit at the min.

    Standardization statistics and the grid are computed once on the full
    data (so every fold sees the same grid, as cv.glmnet does); held-out MSE
    is measured in the centered space, which equals original-space MSE
    because the scaler is global.

    `fold_chunk` sets how many folds advance in vmap lockstep (must divide
    k); the default picks per PLACEMENT — all k when the folds are sharded
    over a multi-device mesh or on accelerator backends, 1 (a pure scan,
    still one executable) on a single CPU device, where lockstep loses
    (see `_auto_fold_chunk`). On the sharded path the knob applies PER
    DEVICE (each holds k/mesh.size folds); an explicit chunk the local
    fold block cannot honor exactly disables the mesh rather than being
    silently overridden.

    `mesh` places the stacked fold axis: "auto" resolves the innermost
    `dist.mesh_context`, else a data mesh over the visible devices, else
    single-device; a mesh whose size does not divide k falls back to
    single-device placement (results are identical either way — tested).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, scaler = api.standardize_fit(X, y, standardize=standardize,
                                         fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = api.lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    lam2 = jnp.asarray(lambda2, X.dtype)

    n_tr = (Xs.shape[0] // k) * (k - 1)          # rows per training fold
    mesh = _resolve_cv_mesh(mesh, k, n_tr, Xs.shape[1],
                            points=int(lambda1s.shape[0]))
    explicit_chunk = fold_chunk is not None
    if fold_chunk is None:
        fold_chunk = _auto_fold_chunk(k, mesh)
    if k % fold_chunk:
        raise ValueError(f"cross_validate: fold_chunk={fold_chunk} must "
                         f"divide k={k}")
    chunk_local = fold_chunk
    if mesh is not None:
        # the lockstep knob applies PER DEVICE on the sharded path: each
        # device holds k/mesh.size folds, advanced `chunk_local` at a time.
        # An explicit chunk the local block cannot honor EXACTLY disables
        # the mesh (single-device placement) — never silently overridden;
        # the auto default simply takes the full local width (1 fold per
        # device => the plain un-vmapped loops).
        k_local = k // mesh.size
        if explicit_chunk:
            if fold_chunk <= k_local and k_local % fold_chunk == 0:
                chunk_local = fold_chunk
            else:
                mesh = None
        else:
            chunk_local = k_local
    config = api.resolve_path_config(config, Xs, ys)
    Xtr, ytr, Xva, yva = cv_folds(Xs, ys, k)
    if mesh is not None:
        Xtr, ytr, Xva, yva = _place_folds(mesh, Xtr, ytr, Xva, yva)
        mse, n_kept, evals = _enet_cv_scan_sharded(Xtr, ytr, Xva, yva,
                                                   lambda1s, lam2, config,
                                                   chunk_local, mesh)
    else:
        mse, n_kept, evals = _enet_cv_scan(Xtr, ytr, Xva, yva, lambda1s,
                                           lam2, config, fold_chunk)
    mean_mse = jnp.mean(mse, axis=1)
    i_min = int(jnp.argmin(mean_mse))
    lambda_min = float(lambda1s[i_min])

    _, pt = api._enet_jit(Xs, ys, jnp.asarray(lambda_min, X.dtype), lam2,
                          api.cold_carry(Xs, ys), config)
    beta, intercept = api.unscale_coef(pt.beta, scaler)
    return CVResult(lambda1s=lambda1s, lambda2=float(lambda2), mse_path=mse,
                    mean_mse=mean_mse, lambda_min=lambda_min, index_min=i_min,
                    beta=beta, intercept=intercept, n_kept=n_kept, evals=evals)


def cross_validate_reference(X, y, *, k: int = 5, lambda1s=None,
                             n_lambdas: int = 40, eps: Optional[float] = None,
                             lambda2=1.0, standardize: bool = True,
                             fit_intercept: bool = True,
                             config: api.PathConfig = api.PathConfig()):
    """Sequential per-fold loop (cv.glmnet's shape): the batched CV's oracle.

    Same splits, same full-data grid and scaler; each fold runs its own
    `_enet_path_scan`. Returns (lambda1s, mse_path (L, k)).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, _ = api.standardize_fit(X, y, standardize=standardize,
                                    fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = api.lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    lam2 = jnp.asarray(lambda2, X.dtype)

    Xtr, ytr, Xva, yva = cv_folds(Xs, ys, k)
    cols = []
    for i in range(k):
        pts = api._enet_path_scan(Xtr[i], ytr[i], lambda1s, lam2, config)
        resid = pts.beta @ Xva[i].T - yva[i][None, :]   # (L, fold)
        cols.append(jnp.mean(resid * resid, axis=1))
    return lambda1s, jnp.stack(cols, axis=1)


class ElasticNetCV:
    """sklearn-style K-fold CV estimator over the batched SVEN front-end.

    After `fit`: `coef_`, `intercept_`, `lambda_min_`, `lambda1s_`,
    `mse_path_` (L, k), `mean_mse_`.
    """

    def __init__(self, k: int = 5, n_lambdas: int = 40,
                 eps: Optional[float] = None, lambda2: float = 1.0, *,
                 standardize: bool = True, fit_intercept: bool = True,
                 mesh="auto", config: api.PathConfig = api.PathConfig()):
        self.k = k
        self.n_lambdas = n_lambdas
        self.eps = eps
        self.lambda2 = lambda2
        self.standardize = standardize
        self.fit_intercept = fit_intercept
        self.mesh = mesh
        self.config = config

    def fit(self, X, y):
        res = cross_validate(X, y, k=self.k, n_lambdas=self.n_lambdas,
                             eps=self.eps, lambda2=self.lambda2,
                             standardize=self.standardize,
                             fit_intercept=self.fit_intercept,
                             mesh=self.mesh, config=self.config)
        self.coef_ = res.beta
        self.intercept_ = res.intercept
        self.lambda_min_ = res.lambda_min
        self.lambda1s_ = res.lambda1s
        self.mse_path_ = res.mse_path
        self.mean_mse_ = res.mean_mse
        self.cv_result_ = res
        return self

    def predict(self, X):
        return jnp.asarray(X) @ self.coef_ + self.intercept_
