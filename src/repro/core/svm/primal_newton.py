"""Squared-hinge linear SVM, primal Newton-CG (Chapelle 2007), no bias.

    min_w f(w) = 1/2 ||w||^2 + C sum_i max(0, 1 - yhat_i w^T xhat_i)^2

Newton system at the current support-vector set SV = {i : margin_i < 1}:

    H = I + 2C Xhat_SV^T Xhat_SV
    H d = grad,   grad = w + 2C Xhat^T (act * (Xhat w - yhat))

solved matrix-free with conjugate gradients (the H mat-vec is two Xhat
products masked by `act`), followed by a backtracking line search. For a
fixed SV set f is quadratic, so the method takes full steps near the
solution and terminates in a handful of iterations — all heavy work is
BLAS-3-shaped, which is the property the paper's GPU claim rests on.

The solver is a `SolverState` init/step/run machine (state.py, DESIGN.md
§6): hyperparameters (C, tol) are traced scalars, the carry is fixed-shape,
and everything is jax.lax control flow — so one trace serves a whole
(t, lambda2) grid under `lax.scan` and stacked problems under `vmap`, and
the mat-vec callables may close over pjit-sharded arrays or shard_map
collectives.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.svm.state import (Hyper, SolverMachine, SolverState,
                                  initial_state, make_hyper, run_machine)


class PrimalResult(NamedTuple):
    w: jax.Array
    iters: jax.Array
    grad_norm: jax.Array
    objective: jax.Array


def _cg(matvec: Callable, b: jax.Array, maxiter: int, tol) -> jax.Array:
    """Plain CG on SPD `matvec`; fixed-shape while_loop, early exit on tol."""

    def body(state):
        x, r, pvec, rs, it = state
        Ap = matvec(pvec)
        denom = pvec @ Ap
        alpha = rs / jnp.where(denom > 0, denom, 1.0)
        x = x + alpha * pvec
        r = r - alpha * Ap
        rs_new = r @ r
        beta = rs_new / jnp.where(rs > 0, rs, 1.0)
        pvec = r + beta * pvec
        return x, r, pvec, rs_new, it + 1

    def cond(state):
        _, _, _, rs, it = state
        return (rs > tol * tol) & (it < maxiter)

    x0 = jnp.zeros_like(b)
    state = (x0, b, b, b @ b, jnp.zeros((), jnp.int32))
    x, *_ = jax.lax.while_loop(cond, body, state)
    return x


def _primal_obj(matvec: Callable, yhat: jax.Array, w: jax.Array, C) -> jax.Array:
    """f(w) = 1/2 ||w||^2 + C sum_i max(0, 1 - yhat_i (Xhat w)_i)^2."""
    o = matvec(w)
    act = (yhat * o) < 1.0
    xi = jnp.where(act, 1.0 - yhat * o, 0.0)
    return 0.5 * (w @ w) + C * (xi @ xi)


def primal_newton_machine(
    matvec: Callable[[jax.Array], jax.Array],     # w (d,) -> Xhat @ w (m,)
    rmatvec: Callable[[jax.Array], jax.Array],    # v (m,) -> Xhat^T v (d,)
    yhat: jax.Array,                              # (m,) labels in {+1,-1}
    d: int,
    *,
    max_newton: int = 50,
    cg_iters: int = 250,
    hess_matvec: Callable | None = None,          # (v, act, C) -> H v override (Pallas)
) -> SolverMachine:
    """Newton-CG as a SolverState machine; `hyper.C`/`hyper.tol` are traced."""
    dtype = yhat.dtype

    def f_value(w, C):
        return _primal_obj(matvec, yhat, w, C)

    def init(hyper: Hyper, x0: jax.Array | None = None) -> SolverState:
        del hyper
        w0 = jnp.zeros((d,), dtype) if x0 is None else x0.astype(dtype)
        return initial_state(w0)

    def step(state: SolverState, hyper: Hyper) -> SolverState:
        w, C = state.x, hyper.C
        o = matvec(w)
        act = ((yhat * o) < 1.0).astype(dtype)
        grad = w + 2.0 * C * rmatvec(act * (o - yhat))

        if hess_matvec is None:
            def hess_mv(v):
                return v + 2.0 * C * rmatvec(act * matvec(v))
        else:
            def hess_mv(v):
                return hess_matvec(v, act, C)

        dstep = _cg(hess_mv, grad, cg_iters, hyper.tol * 1e-2)

        # Backtracking (Armijo) line search on f along -dstep, LINEARIZED:
        # matvec is linear, so Xhat (w - s d) = o - s (Xhat d) — one extra
        # matvec (od) per Newton step and every f evaluation becomes pure
        # replicated vector math. This hoists the per-evaluation matvec out
        # of the search loop: in the row-sharded primal machine each matvec
        # is a psum, so the old form paid one collective per backtracking
        # halving (plus one for f0) that the replicated operands make
        # redundant; on one device it saves the O(np) GEMV per halving.
        od = matvec(dstep)
        ww_ = w @ w
        wd = w @ dstep
        dd = dstep @ dstep

        def f_line(s):
            m = yhat * (o - s * od)
            xi = jnp.where(m < 1.0, 1.0 - m, 0.0)
            return (0.5 * (ww_ - 2.0 * s * wd + s * s * dd) + C * (xi @ xi))

        f0 = f_line(jnp.asarray(0.0, dtype))
        gd = grad @ dstep

        def ls_body(ls):
            s, _ = ls
            return s * 0.5, f_line(s * 0.5)

        def ls_cond(ls):
            s, fv = ls
            return (fv > f0 - 1e-4 * s * gd) & (s > 1e-10)

        s, _ = jax.lax.while_loop(
            ls_cond, ls_body, (jnp.asarray(1.0, dtype),
                               f_line(jnp.asarray(1.0, dtype))))
        gnorm = jnp.max(jnp.abs(grad))
        # ~(> tol) rather than (<= tol): a NaN residual counts as terminal,
        # so a diverged solve exits instead of spinning to max_iters.
        return SolverState(x=w - s * dstep, aux=state.aux, iters=state.iters + 1,
                           residual=gnorm, converged=~(gnorm > hyper.tol))

    def run(hyper: Hyper, x0: jax.Array | None = None) -> SolverState:
        return run_machine(step, init(hyper, x0), hyper, max_newton)

    return SolverMachine(init=init, step=step, run=run)


def solve_primal_newton(
    matvec: Callable[[jax.Array], jax.Array],
    rmatvec: Callable[[jax.Array], jax.Array],
    yhat: jax.Array,
    C,
    d: int,
    *,
    tol=1e-8,
    max_newton: int = 50,
    cg_iters: int = 250,
    w0: jax.Array | None = None,
    hess_matvec: Callable | None = None,
) -> PrimalResult:
    """Classic-signature wrapper over the machine (C/tol may be traced)."""
    dtype = yhat.dtype
    machine = primal_newton_machine(matvec, rmatvec, yhat, d,
                                    max_newton=max_newton, cg_iters=cg_iters,
                                    hess_matvec=hess_matvec)
    hyper = make_hyper(C, tol, dtype)
    st = machine.run(hyper, w0)
    return PrimalResult(w=st.x, iters=st.iters, grad_norm=st.residual,
                        objective=_primal_obj(matvec, yhat, st.x, hyper.C))
