"""Squared-hinge linear SVM, primal Newton-CG (Chapelle 2007), no bias.

    min_w f(w) = 1/2 ||w||^2 + C sum_i max(0, 1 - yhat_i w^T xhat_i)^2

Newton system at the current support-vector set SV = {i : margin_i < 1}:

    H = I + 2C Xhat_SV^T Xhat_SV
    H d = grad,   grad = w + 2C Xhat^T (act * (Xhat w - yhat))

solved matrix-free with conjugate gradients (the H mat-vec is two Xhat
products masked by `act`), followed by a backtracking line search. For a
fixed SV set f is quadratic, so the method takes full steps near the
solution and terminates in a handful of iterations — all heavy work is
BLAS-3-shaped, which is the property the paper's GPU claim rests on.

The solver is expressed entirely with jax.lax control flow so it jits and
shards (the mat-vec callables may close over pjit-sharded arrays or
shard_map collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class PrimalResult(NamedTuple):
    w: jax.Array
    iters: jax.Array
    grad_norm: jax.Array
    objective: jax.Array


def _cg(matvec: Callable, b: jax.Array, maxiter: int, tol: float) -> jax.Array:
    """Plain CG on SPD `matvec`; fixed-shape while_loop, early exit on tol."""

    def body(state):
        x, r, pvec, rs, it = state
        Ap = matvec(pvec)
        denom = pvec @ Ap
        alpha = rs / jnp.where(denom > 0, denom, 1.0)
        x = x + alpha * pvec
        r = r - alpha * Ap
        rs_new = r @ r
        beta = rs_new / jnp.where(rs > 0, rs, 1.0)
        pvec = r + beta * pvec
        return x, r, pvec, rs_new, it + 1

    def cond(state):
        _, _, _, rs, it = state
        return (rs > tol * tol) & (it < maxiter)

    x0 = jnp.zeros_like(b)
    state = (x0, b, b, b @ b, jnp.zeros((), jnp.int32))
    x, *_ = jax.lax.while_loop(cond, body, state)
    return x


def solve_primal_newton(
    matvec: Callable[[jax.Array], jax.Array],     # w (d,) -> Xhat @ w (m,)
    rmatvec: Callable[[jax.Array], jax.Array],    # v (m,) -> Xhat^T v (d,)
    yhat: jax.Array,                              # (m,) labels in {+1,-1}
    C: float,
    d: int,
    *,
    tol: float = 1e-8,
    max_newton: int = 50,
    cg_iters: int = 250,
    w0: jax.Array | None = None,
    hess_matvec: Callable | None = None,          # (v, act) -> H v override (Pallas path)
) -> PrimalResult:
    dtype = yhat.dtype
    C = jnp.asarray(C, dtype)

    def f_value(w):
        o = matvec(w)
        act = (yhat * o) < 1.0
        xi = jnp.where(act, 1.0 - yhat * o, 0.0)
        return 0.5 * (w @ w) + C * (xi @ xi)

    def newton_body(state):
        w, it, _ = state
        o = matvec(w)
        act = ((yhat * o) < 1.0).astype(dtype)
        grad = w + 2.0 * C * rmatvec(act * (o - yhat))

        if hess_matvec is None:
            def hess_mv(v):
                return v + 2.0 * C * rmatvec(act * matvec(v))
        else:
            def hess_mv(v):
                return hess_matvec(v, act)

        step = _cg(hess_mv, grad, cg_iters, tol * 1e-2)

        # Backtracking (Armijo) line search on f along -step.
        f0 = f_value(w)
        gd = grad @ step

        def ls_body(ls):
            s, _ = ls
            return s * 0.5, f_value(w - s * 0.5 * step)

        def ls_cond(ls):
            s, fv = ls
            return (fv > f0 - 1e-4 * s * gd) & (s > 1e-10)

        s, _ = jax.lax.while_loop(ls_cond, ls_body, (jnp.asarray(1.0, dtype), f_value(w - step)))
        w_new = w - s * step
        gnorm = jnp.max(jnp.abs(grad))
        return w_new, it + 1, gnorm

    def newton_cond(state):
        _, it, gnorm = state
        return (gnorm > tol) & (it < max_newton)

    w_init = jnp.zeros((d,), dtype) if w0 is None else w0.astype(dtype)
    state = (w_init, jnp.zeros((), jnp.int32), jnp.asarray(jnp.inf, dtype))
    w, iters, gnorm = jax.lax.while_loop(newton_cond, newton_body, state)
    return PrimalResult(w=w, iters=iters, grad_norm=gnorm, objective=f_value(w))
