"""Projected FISTA on the SVM dual — a robust first-order fallback.

Same bound-constrained QP as dual_newton; accelerated projected gradient with
step 1/L, L = lambda_max(2K + I/C) estimated by power iteration. Linear
convergence via strong convexity 1/C. Used (a) as an independent check of the
Newton solvers in tests, (b) as the solver of last resort for ill-conditioned
problems.

Expressed as a `SolverState` init/step/run machine (state.py, DESIGN.md §6):
the momentum pair (z, tk) and the 1/L step size live in `state.aux`, computed
once at init from the traced C.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.svm.dual_newton import DualResult, _dual_obj
from repro.core.svm.state import (Hyper, SolverMachine, SolverState,
                                  initial_state, make_hyper, run_machine)


def _power_iter_L(hess_mv: Callable, m: int, dtype, iters: int = 30) -> jax.Array:
    v = jnp.ones((m,), dtype) / jnp.sqrt(m)

    def body(_, v):
        w = hess_mv(v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return v @ hess_mv(v)


def dual_fista_machine(
    kernel_matvec: Callable[[jax.Array], jax.Array],
    m: int,
    *,
    dtype=jnp.float64,
    max_iters: int = 5000,
) -> SolverMachine:
    """Projected FISTA as a SolverState machine; aux = (z, tk, step)."""
    two = jnp.asarray(2.0, dtype)

    def grad_fn(a, C):
        return two * kernel_matvec(a) + a / C - two

    def init(hyper: Hyper, x0: jax.Array | None = None) -> SolverState:
        a0 = jnp.zeros((m,), dtype) if x0 is None else x0.astype(dtype)

        def hess_mv(v):
            return two * kernel_matvec(v) + v / hyper.C

        L = _power_iter_L(hess_mv, m, dtype) * 1.02
        aux = (a0, jnp.asarray(1.0, dtype), 1.0 / L)   # (z, tk, step)
        return initial_state(a0, aux=aux)

    def step(state: SolverState, hyper: Hyper) -> SolverState:
        a = state.x
        z, tk, stepsz = state.aux
        g = grad_fn(z, hyper.C)
        a_new = jnp.maximum(z - stepsz * g, 0.0)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
        z_new = a_new + ((tk - 1.0) / t_new) * (a_new - a)
        g_new = grad_fn(a_new, hyper.C)
        pg = jnp.max(jnp.abs(jnp.where(a_new > 0, g_new, jnp.minimum(g_new, 0.0))))
        # ~(> tol): NaN residual is terminal (diverged), not "keep iterating"
        return SolverState(x=a_new, aux=(z_new, t_new, stepsz),
                           iters=state.iters + 1, residual=pg,
                           converged=~(pg > hyper.tol))

    def run(hyper: Hyper, x0: jax.Array | None = None) -> SolverState:
        return run_machine(step, init(hyper, x0), hyper, max_iters)

    return SolverMachine(init=init, step=step, run=run)


def solve_dual_fista(
    kernel_matvec: Callable[[jax.Array], jax.Array],
    m: int,
    C,
    *,
    dtype=jnp.float64,
    tol=1e-7,
    max_iters: int = 5000,
    alpha0: jax.Array | None = None,
) -> DualResult:
    """Classic-signature wrapper over the machine (C/tol may be traced)."""
    machine = dual_fista_machine(kernel_matvec, m, dtype=dtype, max_iters=max_iters)
    hyper = make_hyper(C, tol, dtype)
    st = machine.run(hyper, alpha0)
    return DualResult(alpha=st.x, iters=st.iters, pg_norm=st.residual,
                      objective=_dual_obj(kernel_matvec, st.x, hyper.C))
