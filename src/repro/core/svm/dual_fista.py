"""Projected FISTA on the SVM dual — a robust first-order fallback.

Same bound-constrained QP as dual_newton; accelerated projected gradient with
step 1/L, L = lambda_max(2K + I/C) estimated by power iteration. Linear
convergence via strong convexity 1/C. Used (a) as an independent check of the
Newton solvers in tests, (b) as the solver of last resort for ill-conditioned
problems.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.svm.dual_newton import DualResult


def _power_iter_L(hess_mv: Callable, m: int, dtype, iters: int = 30) -> jax.Array:
    v = jnp.ones((m,), dtype) / jnp.sqrt(m)

    def body(_, v):
        w = hess_mv(v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return v @ hess_mv(v)


def solve_dual_fista(
    kernel_matvec: Callable[[jax.Array], jax.Array],
    m: int,
    C: float,
    *,
    dtype=jnp.float64,
    tol: float = 1e-7,
    max_iters: int = 5000,
    alpha0: jax.Array | None = None,
) -> DualResult:
    C = jnp.asarray(C, dtype)
    two = jnp.asarray(2.0, dtype)

    def grad_fn(a):
        return two * kernel_matvec(a) + a / C - two

    def obj_fn(a):
        return a @ kernel_matvec(a) + (a @ a) / (two * C) - two * jnp.sum(a)

    def hess_mv(v):
        return two * kernel_matvec(v) + v / C

    L = _power_iter_L(hess_mv, m, dtype) * 1.02
    step = 1.0 / L

    def body(state):
        a, z, tk, it, _ = state
        g = grad_fn(z)
        a_new = jnp.maximum(z - step * g, 0.0)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
        z_new = a_new + ((tk - 1.0) / t_new) * (a_new - a)
        g_new = grad_fn(a_new)
        pg = jnp.where(a_new > 0, g_new, jnp.minimum(g_new, 0.0))
        return a_new, z_new, t_new, it + 1, jnp.max(jnp.abs(pg))

    def cond(state):
        _, _, _, it, pg = state
        return (pg > tol) & (it < max_iters)

    a0 = jnp.zeros((m,), dtype) if alpha0 is None else alpha0.astype(dtype)
    one = jnp.asarray(1.0, dtype)
    a, _, _, iters, pg = jax.lax.while_loop(cond, body, (a0, a0, one, jnp.zeros((), jnp.int32), jnp.asarray(jnp.inf, dtype)))
    return DualResult(alpha=a, iters=iters, pg_norm=pg, objective=obj_fn(a))
