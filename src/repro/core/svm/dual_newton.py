"""Squared-hinge SVM dual: projected Newton with active sets.

    min_{alpha >= 0} D(alpha) = alpha^T K alpha + 1/(2C) ||alpha||^2
                                - 2 sum(alpha)                     (paper eq. 3)

with K = Zhat^T Zhat. grad = 2 K alpha + alpha/C - 2; the Hessian
H = 2K + I/C is constant and PD, so a projected Newton method with a
free/clamped split converges in finitely many outer iterations:

    F   = {i : alpha_i > 0  or  grad_i < 0}        (free set)
    solve (H d)_F = grad_F, d_{F^c} = 0 via masked CG
    alpha <- max(0, alpha - s d), backtracking on D

The kernel mat-vec is supplied as a callable: either `lambda v: K @ v` with a
cached kernel matrix (the paper's d >> m regime — "remaining running time
independent of the dimensionality") or the matrix-free O(np) SvenOperator
product. All compute is matmul/matvec-shaped for MXU/BLAS execution.

Expressed as a `SolverState` init/step/run machine (state.py, DESIGN.md §6)
with traced (C, tol) so one trace serves scan-compiled paths and vmapped
problem batches.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.svm.state import (Hyper, SolverMachine, SolverState,
                                  initial_state, make_hyper, run_machine)


class DualResult(NamedTuple):
    alpha: jax.Array
    iters: jax.Array
    pg_norm: jax.Array      # projected-gradient sup-norm
    objective: jax.Array


def _masked_cg(matvec: Callable, b: jax.Array, mask: jax.Array, maxiter: int, tol) -> jax.Array:
    """CG restricted to coordinates where mask==1 (others pinned to 0)."""

    def mv(v):
        return mask * matvec(mask * v)

    b = mask * b

    def body(state):
        x, r, pvec, rs, it = state
        Ap = mv(pvec)
        denom = pvec @ Ap
        alpha = rs / jnp.where(denom > 0, denom, 1.0)
        x = x + alpha * pvec
        r = r - alpha * Ap
        rs_new = r @ r
        beta = rs_new / jnp.where(rs > 0, rs, 1.0)
        return x, r, r + beta * pvec, rs_new, it + 1

    def cond(state):
        _, _, _, rs, it = state
        return (rs > tol * tol) & (it < maxiter)

    x0 = jnp.zeros_like(b)
    x, *_ = jax.lax.while_loop(cond, body, (x0, b, b, b @ b, jnp.zeros((), jnp.int32)))
    return x


def _dual_obj(kernel_matvec, alpha, C):
    two = jnp.asarray(2.0, alpha.dtype)
    return (alpha @ kernel_matvec(alpha)
            + (alpha @ alpha) / (two * C) - two * jnp.sum(alpha))


def dual_newton_machine(
    kernel_matvec: Callable[[jax.Array], jax.Array],   # v (m,) -> K v (m,)
    m: int,
    *,
    dtype=jnp.float64,
    max_newton: int = 100,
    cg_iters: int = 250,
) -> SolverMachine:
    """Projected Newton as a SolverState machine; `hyper.C`/`hyper.tol` traced."""
    two = jnp.asarray(2.0, dtype)

    def grad_fn(alpha, C):
        return two * kernel_matvec(alpha) + alpha / C - two

    def init(hyper: Hyper, x0: jax.Array | None = None) -> SolverState:
        del hyper
        a0 = jnp.zeros((m,), dtype) if x0 is None else x0.astype(dtype)
        return initial_state(a0)

    def step(state: SolverState, hyper: Hyper) -> SolverState:
        alpha, C = state.x, hyper.C
        g = grad_fn(alpha, C)
        free = ((alpha > 0) | (g < 0)).astype(dtype)

        def hess_mv(v):
            return two * kernel_matvec(v) + v / C

        d = _masked_cg(hess_mv, g, free, cg_iters, hyper.tol * 1e-2)

        f0 = _dual_obj(kernel_matvec, alpha, C)

        def proj(s):
            return jnp.maximum(alpha - s * d, 0.0)

        def ls_cond(ls):
            s, fv = ls
            return (fv > f0 - 1e-12 * jnp.abs(f0)) & (s > 1e-12)

        def ls_body(ls):
            s, _ = ls
            s = s * 0.5
            return s, _dual_obj(kernel_matvec, proj(s), C)

        s, _ = jax.lax.while_loop(
            ls_cond, ls_body,
            (jnp.asarray(1.0, dtype), _dual_obj(kernel_matvec, proj(1.0), C)))
        alpha_new = proj(s)
        # projected gradient: optimality measure for the bound-constrained QP
        g_new = grad_fn(alpha_new, C)
        pg = jnp.max(jnp.abs(jnp.where(alpha_new > 0, g_new, jnp.minimum(g_new, 0.0))))
        # ~(> tol): NaN residual is terminal (diverged), not "keep iterating"
        return SolverState(x=alpha_new, aux=state.aux, iters=state.iters + 1,
                           residual=pg, converged=~(pg > hyper.tol))

    def run(hyper: Hyper, x0: jax.Array | None = None) -> SolverState:
        return run_machine(step, init(hyper, x0), hyper, max_newton)

    return SolverMachine(init=init, step=step, run=run)


def solve_dual_newton(
    kernel_matvec: Callable[[jax.Array], jax.Array],
    m: int,
    C,
    *,
    dtype=jnp.float64,
    tol=1e-8,
    max_newton: int = 100,
    cg_iters: int = 250,
    alpha0: jax.Array | None = None,
) -> DualResult:
    """Classic-signature wrapper over the machine (C/tol may be traced)."""
    machine = dual_newton_machine(kernel_matvec, m, dtype=dtype,
                                  max_newton=max_newton, cg_iters=cg_iters)
    hyper = make_hyper(C, tol, dtype)
    st = machine.run(hyper, alpha0)
    return DualResult(alpha=st.x, iters=st.iters, pg_norm=st.residual,
                      objective=_dual_obj(kernel_matvec, st.x, hyper.C))
