from repro.core.svm.primal_newton import solve_primal_newton, PrimalResult
from repro.core.svm.dual_newton import solve_dual_newton, DualResult
from repro.core.svm.dual_fista import solve_dual_fista

__all__ = [
    "solve_primal_newton",
    "solve_dual_newton",
    "solve_dual_fista",
    "PrimalResult",
    "DualResult",
]
