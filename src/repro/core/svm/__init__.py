from repro.core.svm.state import (Hyper, SolverMachine, SolverState,
                                  make_hyper, run_machine)
from repro.core.svm.primal_newton import (PrimalResult, primal_newton_machine,
                                          solve_primal_newton)
from repro.core.svm.dual_newton import (DualResult, dual_newton_machine,
                                        solve_dual_newton)
from repro.core.svm.dual_fista import dual_fista_machine, solve_dual_fista

__all__ = [
    "Hyper",
    "SolverMachine",
    "SolverState",
    "make_hyper",
    "run_machine",
    "primal_newton_machine",
    "dual_newton_machine",
    "dual_fista_machine",
    "solve_primal_newton",
    "solve_dual_newton",
    "solve_dual_fista",
    "PrimalResult",
    "DualResult",
]
