"""The SolverState protocol — fixed-shape init/step/run state machines.

Every SVM solver in this package (primal Newton-CG, projected dual Newton,
projected dual FISTA) is expressed as the same three pure functions
(DESIGN.md §6):

    init(hyper, x0=None) -> SolverState     fixed-shape starting carry
    step(state, hyper)   -> SolverState     one outer iteration
    run(hyper, x0=None)  -> SolverState     while_loop(step) to convergence

with one common carry:

    SolverState(x, aux, iters, residual, converged)

`x` is the solver's iterate (primal w or dual alpha), `aux` holds any
solver-private fixed-shape extras (FISTA momentum), `residual` is the
solver's own optimality measure and `converged` its tolerance flag. Because
the carry is a fixed-shape pytree and the hyperparameters (`Hyper.C`,
`Hyper.tol`) enter as *traced scalars* — never Python floats baked into the
trace — a machine composes directly with `jax.jit`, `jax.lax.scan`
(regularization paths re-use one trace for the whole t-grid) and `jax.vmap`
(`core/batch.py` stacks whole problems). Loop bounds (`max_iters`,
`cg_iters`) stay static: they size the computation, not the trace inputs.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Hyper(NamedTuple):
    """Traced solver hyperparameters (regular jnp scalars under jit/scan/vmap)."""

    C: jax.Array     # SVM cost 1/(2*lambda2), clamped (reduction.svm_C)
    tol: jax.Array   # outer-loop optimality tolerance


class SolverState(NamedTuple):
    """Common fixed-shape carry shared by all SVM solver machines."""

    x: jax.Array          # iterate: primal w (n,) or dual alpha (2p,)
    aux: Any              # solver-private extras (fixed-shape pytree, often ())
    iters: jax.Array      # int32 outer-iteration count
    residual: jax.Array   # solver's optimality measure (sup-norm)
    converged: jax.Array  # bool: residual <= tol reached

    def telemetry(self) -> dict:
        """Host-side scalar summary of where the solve ended (DESIGN.md
        §12.5): plain Python numbers for solve logs and event records.
        Call OUTSIDE jit only — it materializes device scalars."""
        return {"iters": int(self.iters),
                "residual": float(self.residual),
                "converged": bool(self.converged)}


class SolverMachine(NamedTuple):
    """An init/step/run triple closed over the problem operators."""

    init: Callable[..., SolverState]
    step: Callable[[SolverState, Hyper], SolverState]
    run: Callable[..., SolverState]


def make_hyper(C, tol, dtype) -> Hyper:
    """Coerce (possibly Python-float) hyperparameters to traced scalars."""
    return Hyper(C=jnp.asarray(C, dtype), tol=jnp.asarray(tol, dtype))


def initial_state(x0: jax.Array, aux: Any = ()) -> SolverState:
    return SolverState(
        x=x0,
        aux=aux,
        iters=jnp.zeros((), jnp.int32),
        residual=jnp.asarray(jnp.inf, x0.dtype),
        converged=jnp.zeros((), bool),
    )


def run_machine(step: Callable[[SolverState, Hyper], SolverState],
                state: SolverState, hyper: Hyper, max_iters: int) -> SolverState:
    """Drive `step` to convergence with a fixed-shape while_loop."""

    def cond(s: SolverState):
        return (~s.converged) & (s.iters < max_iters)

    return jax.lax.while_loop(cond, lambda s: step(s, hyper), state)
