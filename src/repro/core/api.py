"""glmnet-parity penalized front-end for the SVEN engine (DESIGN.md §7).

The paper's headline comparison is against glmnet, which solves the
*penalized* Elastic Net along a lambda grid; the SVEN reduction solves the
*constrained* form (t, lambda2). This module closes that gap so the
comparison is actually reproducible:

  - `lambda_grid` builds the standard glmnet grid: `n_lambdas` points
    geometrically spaced from lambda1_max (smallest lambda with beta = 0)
    down to eps * lambda1_max.
  - `penalized_from_glmnet` / `penalized_from_sklearn` convert those
    libraries' (lambda, alpha) / (alpha, l1_ratio) parameters into this
    repo's paper-scaled (lambda1, lambda2) — see the conventions table in
    DESIGN.md §7.
  - `standardize_fit` / `unscale_coef` handle glmnet-style column
    standardization and intercept centering with exact round-trip
    un-scaling (the penalty never touches the intercept).
  - `enet` / `enet_path` map each penalized (lambda1, lambda2) onto the
    constrained engine through the `t = |beta*|_1` equivalence
    (`core/elastic_net.py`): at the constrained optimum the L1 multiplier
    nu(t) = max_j |g_j(beta(t))| is piecewise linear and decreasing in t,
    so the t* with nu(t*) = lambda1 is found by a guarded Illinois
    (modified regula falsi) iteration whose every evaluation is one
    warm-started `_sven_core` solve. The bracket is analytic — nu(0) =
    lambda1_max and nu(|beta_ridge|_1) = 0 — so no extra solves are spent
    bracketing, and on the piecewise-linear nu the secant step is exact as
    soon as both endpoints share a segment.
  - `gap_safe_screen` (core/screening.py) is fused into every point as a
    fixed-size (p,) keep mask carried into `_sven_core` — columns that are
    provably inactive at the *current* lambda1 are zeroed and their
    coefficients scattered back as exact zeros, preserving compile-once.
  - `enet_path` runs the whole grid as ONE jitted `lax.scan` carrying
    (beta, alpha, w, t, nu) warm state; `trace_counts()["enet_path_scan"]`
    asserts the single-trace property. `enet_batch` vmaps the same point
    solver over stacked problems for the serving layer.
  - `ElasticNet` is the thin sklearn-style fit/predict wrapper
    (`core/cv.py` adds `ElasticNetCV`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import elastic_net as en
from repro.core.screening import gap_safe_screen
from repro.core.sven import SvenConfig, _bump_trace, _sven_core, resolve_backend


# ---------------------------------------------------------------------------
# Scaling conventions (DESIGN.md §7): paper <-> glmnet <-> sklearn
# ---------------------------------------------------------------------------

def penalized_from_glmnet(lam, alpha, n: int) -> Tuple[float, float]:
    """glmnet (lambda, alpha) -> paper-scaled (lambda1, lambda2).

    glmnet minimizes 1/(2n) ||y - X b||^2 + lam * (alpha |b|_1
    + (1-alpha)/2 ||b||^2); multiplying by 2n (argmin-invariant) gives the
    paper objective with lambda1 = 2 n lam alpha, lambda2 = n lam (1-alpha).
    """
    return 2.0 * n * lam * alpha, n * lam * (1.0 - alpha)


def penalized_to_glmnet(lambda1, lambda2, n: int) -> Tuple[float, float]:
    """Inverse of `penalized_from_glmnet` (lambda1 + lambda2 must be > 0)."""
    la, lr = lambda1 / (2.0 * n), lambda2 / n
    lam = la + lr
    return lam, la / lam


def penalized_from_sklearn(alpha, l1_ratio, n: int) -> Tuple[float, float]:
    """sklearn ElasticNet (alpha, l1_ratio) -> paper-scaled (lambda1, lambda2).

    sklearn's objective is glmnet's with lambda = alpha, alpha = l1_ratio.
    """
    return penalized_from_glmnet(alpha, l1_ratio, n)


def lambda_grid(X: jax.Array, y: jax.Array, n_lambdas: int = 40,
                eps: Optional[float] = None) -> jax.Array:
    """The standard glmnet grid: geometric from lambda1_max to eps*lambda1_max.

    eps defaults to glmnet's: 1e-2 when p > n, else 1e-4. The first point is
    exactly lambda1_max, where the solution is identically zero.
    """
    n, p = X.shape
    if eps is None:
        eps = 1e-2 if p > n else 1e-4
    l1max = en.lambda1_max(X, y)
    return l1max * jnp.geomspace(1.0, eps, n_lambdas).astype(X.dtype)


# ---------------------------------------------------------------------------
# Standardization / intercept round trip
# ---------------------------------------------------------------------------

class Scaler(NamedTuple):
    """Column/response statistics needed to un-scale a standardized fit."""

    x_mean: jax.Array   # (p,)
    x_scale: jax.Array  # (p,)
    y_mean: jax.Array   # ()


def standardize_fit(X: jax.Array, y: jax.Array, *, standardize: bool = True,
                    fit_intercept: bool = True):
    """Center/scale (X, y) glmnet-style; returns (Xs, ys, Scaler).

    With fit_intercept, columns and the response are mean-centered so the
    (unpenalized) intercept drops out of the optimization entirely; with
    standardize, columns are scaled to unit 1/n-variance (constant columns
    keep scale 1). The solvers then see (Xs, ys); `unscale_coef` maps the
    standardized coefficients back.
    """
    dtype = X.dtype
    p = X.shape[1]
    if fit_intercept:
        x_mean = jnp.mean(X, axis=0)
        y_mean = jnp.mean(y)
    else:
        x_mean = jnp.zeros((p,), dtype)
        y_mean = jnp.zeros((), dtype)
    Xc = X - x_mean
    if standardize:
        sd = jnp.sqrt(jnp.mean(Xc * Xc, axis=0))
        x_scale = jnp.where(sd > 0, sd, 1.0)
    else:
        x_scale = jnp.ones((p,), dtype)
    return Xc / x_scale, y - y_mean, Scaler(x_mean, x_scale, y_mean)


def unscale_coef(beta_std: jax.Array, scaler: Scaler):
    """Standardized-space coefficients -> original-scale (beta, intercept).

    Works for a single (p,) vector or a stacked (L, p) path.
    """
    beta = beta_std / scaler.x_scale
    intercept = scaler.y_mean - beta @ scaler.x_mean
    return beta, intercept


# ---------------------------------------------------------------------------
# The penalized point solver: multiplier root-find over the constrained engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PathConfig:
    """Static configuration of the penalized front-end (hashable: jit key)."""

    solver: SvenConfig = SvenConfig(tol=1e-10)
    screen: bool = True        # fuse gap_safe_screen keep-masks into each point
    max_evals: int = 30        # Illinois iterations == SVEN solves per point
    t_floor_rel: float = 1e-7  # smallest bracketed t, relative to |ridge|_1
    f_rtol: float = 1e-9       # |nu - lambda1| stop, relative to lambda1_max


def resolve_path_config(config: PathConfig, *arrays) -> PathConfig:
    """Pin the nested SvenConfig's Pallas interpret choice before tracing
    (see `core.sven.resolve_backend`); a no-op for the XLA backend."""
    solver = resolve_backend(config.solver, *arrays)
    if solver is config.solver:
        return config
    return dataclasses.replace(config, solver=solver)


class EnetCarry(NamedTuple):
    """Warm state threaded across lambda points (and across CV-fold vmaps)."""

    beta: jax.Array   # (p,)  last solution (screening warm point)
    alpha: jax.Array  # (2p,) dual warm start
    w: jax.Array      # (n,)  primal warm start
    t: jax.Array      # ()    L1 budget of the last solution
    nu: jax.Array     # ()    multiplier measured at (t, beta)


class EnetPoint(NamedTuple):
    """Per-lambda solve result (standardized space), stackable under scan."""

    beta: jax.Array       # (p,)
    t: jax.Array          # |beta|_1 — the constrained budget this maps to
    nu: jax.Array         # measured L1 multiplier (== lambda1 at the root)
    kkt: jax.Array        # Elastic Net KKT violation at beta
    keep: jax.Array       # (p,) gap-safe mask used for this point
    n_kept: jax.Array     # surviving columns
    gap: jax.Array        # duality gap at the screening warm point
    evals: jax.Array      # Illinois iterations spent (== SVEN solves)
    sven_iters: jax.Array # total inner solver iterations across evals


class _Illinois(NamedTuple):
    t_lo: jax.Array
    f_lo: jax.Array
    t_hi: jax.Array
    f_hi: jax.Array
    side: jax.Array       # +1: last eval replaced lo, -1: hi, 0: fresh
    beta: jax.Array
    alpha: jax.Array
    w: jax.Array
    nu: jax.Array         # nu at the last evaluated point
    f: jax.Array          # nu - lambda1 at the last evaluated point
    evals: jax.Array
    iters: jax.Array


def cold_carry(X: jax.Array, y: jax.Array) -> EnetCarry:
    """Zero warm state; nu(0) = lambda1_max is the exact multiplier at 0."""
    n, p = X.shape
    dtype = X.dtype
    return EnetCarry(beta=jnp.zeros((p,), dtype), alpha=jnp.zeros((2 * p,), dtype),
                     w=jnp.zeros((n,), dtype), t=jnp.zeros((), dtype),
                     nu=jnp.asarray(en.lambda1_max(X, y), dtype))


def _ridge_l1(X: jax.Array, y: jax.Array, lambda2) -> jax.Array:
    """|beta_ridge(lambda2)|_1 — the analytic top of the t bracket.

    For t >= this, the L1 constraint is slack so nu(t) = 0. Solved in the
    cheaper of the (p, p) primal or (n, n) dual normal equations; lambda2 is
    floored so the Lasso limit returns the min-norm least-squares point.
    """
    n, p = X.shape
    dtype = X.dtype
    lam = jnp.maximum(jnp.asarray(lambda2, dtype), 1e-8)
    if p <= n:
        b = jnp.linalg.solve(X.T @ X + lam * jnp.eye(p, dtype=dtype), X.T @ y)
    else:
        b = X.T @ jnp.linalg.solve(X @ X.T + lam * jnp.eye(n, dtype=dtype), y)
    return jnp.sum(jnp.abs(b))


def _enet_point(X: jax.Array, y: jax.Array, lambda1, lambda2,
                carry: EnetCarry, config: PathConfig):
    """Solve one penalized (lambda1, lambda2) point on the constrained engine.

    Pure traced function: lambda1/lambda2/warm state are operands, config is
    static — usable directly under jit, lax.scan (paths) and vmap (CV folds,
    serving batches). Returns (next_carry, EnetPoint).
    """
    n, p = X.shape
    dtype = X.dtype
    lambda1 = jnp.asarray(lambda1, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)

    if config.screen:
        scr = gap_safe_screen(X, y, carry.beta, lambda1, lambda2)
        keep, gap = scr.keep, scr.gap
    else:
        keep = jnp.ones((p,), bool)
        gap = jnp.zeros((), dtype)
    keepf = keep.astype(dtype)
    Xm = X * keepf[None, :]

    l1max_m = 2.0 * jnp.max(jnp.abs(Xm.T @ y))
    t_ridge = _ridge_l1(Xm, y, lambda2)
    t_floor = config.t_floor_rel * t_ridge + jnp.asarray(1e-30, dtype)
    ftol = config.f_rtol * jnp.maximum(l1max_m, 1e-30)
    wtol = 1e-12 * t_ridge
    has_root = l1max_m > lambda1          # else beta* = 0 (top of the path)

    # Bracket f(t) = nu(t) - lambda1: analytic endpoints nu(0) = l1max_m and
    # nu(t_ridge) = 0; the warm (t, nu) from the previous (larger) lambda is a
    # tighter lower endpoint whenever it is on the correct side.
    f_warm = carry.nu - lambda1
    warm_ok = (f_warm > 0) & (carry.t > 0) & (carry.t < t_ridge)
    state0 = _Illinois(
        t_lo=jnp.where(warm_ok, carry.t, 0.0),
        f_lo=jnp.where(warm_ok, f_warm, l1max_m - lambda1),
        t_hi=t_ridge,
        f_hi=-lambda1,
        side=jnp.zeros((), jnp.int32),
        beta=carry.beta * keepf,
        alpha=carry.alpha * jnp.concatenate([keepf, keepf]),
        w=carry.w,
        nu=carry.nu,
        f=jnp.where(warm_ok, f_warm, l1max_m - lambda1),
        evals=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32),
    )

    def cond(s: _Illinois):
        return ((s.evals < config.max_evals) & has_root
                & (s.t_hi - s.t_lo > wtol) & (jnp.abs(s.f) > ftol))

    def body(s: _Illinois):
        frac = s.f_lo / jnp.maximum(s.f_lo - s.f_hi, 1e-30)
        frac = jnp.clip(frac, 0.05, 0.95)   # never stall on an endpoint
        t_c = jnp.maximum(s.t_lo + frac * (s.t_hi - s.t_lo), t_floor)
        arrs = _sven_core(Xm, y, t_c, lambda2, s.alpha, s.w, config.solver)
        g = en.smooth_grad(Xm, y, arrs.beta, lambda2)
        nu_c = jnp.max(jnp.abs(g) * keepf)
        f_c = nu_c - lambda1
        went_lo = f_c >= 0
        # Illinois: replacing the same endpoint twice halves the stale side's
        # f, forcing the secant off that endpoint (superlinear on kinks).
        f_hi = jnp.where(went_lo,
                         jnp.where(s.side == 1, 0.5 * s.f_hi, s.f_hi), f_c)
        t_hi = jnp.where(went_lo, s.t_hi, t_c)
        f_lo = jnp.where(went_lo, f_c,
                         jnp.where(s.side == -1, 0.5 * s.f_lo, s.f_lo))
        t_lo = jnp.where(went_lo, t_c, s.t_lo)
        side = jnp.where(went_lo, 1, -1).astype(jnp.int32)
        return _Illinois(t_lo, f_lo, t_hi, f_hi, side, arrs.beta, arrs.alpha,
                         arrs.w, nu_c, f_c, s.evals + 1,
                         s.iters + arrs.iters.astype(jnp.int32))

    s = jax.lax.while_loop(cond, body, state0)

    ok = has_root.astype(dtype)
    beta = s.beta * keepf * ok
    t_out = jnp.sum(jnp.abs(beta))
    nu_out = jnp.where(has_root, s.nu, l1max_m)
    next_carry = EnetCarry(beta=beta, alpha=s.alpha * ok, w=s.w * ok,
                           t=t_out, nu=nu_out)
    point = EnetPoint(beta=beta, t=t_out, nu=nu_out,
                      kkt=en.kkt_violation(X, y, beta, lambda2),
                      keep=keep, n_kept=jnp.sum(keep), gap=gap,
                      evals=s.evals, sven_iters=s.iters)
    return next_carry, point


# ---------------------------------------------------------------------------
# jitted entry points: single solve, scan path, vmapped batch
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("config",))
def _enet_jit(X, y, lambda1, lambda2, carry, config: PathConfig):
    _bump_trace("enet")
    return _enet_point(X, y, lambda1, lambda2, carry, config)


@partial(jax.jit, static_argnames=("config",))
def _enet_path_scan(X, y, lambda1s, lambda2, config: PathConfig) -> EnetPoint:
    _bump_trace("enet_path_scan")

    def body(carry, lam1):
        return _enet_point(X, y, lam1, lambda2, carry, config)

    _, points = jax.lax.scan(body, cold_carry(X, y), lambda1s)
    return points


def _enet_solve_one(config: PathConfig):
    def one(X_, y_, l1_, l2_, warm_, hw_):
        carry = cold_carry(X_, y_)
        if warm_ is not None:
            # hw_ selects per problem: a cache hit rides its stored warm
            # state, a miss stays exactly cold — one executable either way.
            carry = jax.tree.map(
                lambda w, c: jnp.where(hw_, w.astype(c.dtype), c), warm_, carry)
        return _enet_point(X_, y_, l1_, l2_, carry, config)
    return one


@partial(jax.jit, static_argnames=("config", "axes"))
def _enet_batch_jit(X, y, lambda1, lambda2, warm, has_warm,
                    config: PathConfig, axes) -> EnetPoint:
    from repro.core.batch import solve_lanes

    _bump_trace("enet_batch")
    return solve_lanes(_enet_solve_one(config),
                       (X, y, lambda1, lambda2, warm, has_warm), axes)


@partial(jax.jit, static_argnames=("config", "axes", "mesh"))
def _enet_batch_sharded_jit(X, y, lambda1, lambda2, warm, has_warm,
                            config: PathConfig, axes, mesh) -> EnetPoint:
    """Penalized stack over the batch axis via `batch.shard_map_lanes`:
    each device runs its local lanes' whole multiplier root-find with ZERO
    collectives — solver while_loops never synchronize across devices."""
    from repro.core.batch import shard_map_lanes, solve_lanes

    _bump_trace("enet_batch")

    def local(*ops):
        return solve_lanes(_enet_solve_one(config), ops, axes)

    return shard_map_lanes(mesh, axes, local,
                           (X, y, lambda1, lambda2, warm, has_warm))


def enet_batch(X, y, lambda1s, lambda2s,
               config: PathConfig = PathConfig(), *,
               warm: Optional[EnetCarry] = None,
               has_warm: Optional[jax.Array] = None,
               return_carry: bool = False,
               route: str = "auto"):
    """Stacked penalized solves in one vmapped executable (serving layer).

    Batch axes by rank, as in `core.batch.sven_batch`: X (B, n, p) or (n, p)
    shared; y (B, n) or (n,); lambda1/lambda2 (B,) or scalar. Every field of
    the returned EnetPoint carries a leading (B,) axis. Under an active
    `repro.dist.mesh_context` the stacked operands take the rule table's
    "batch" axis placement when the `core.routing` cost model prefers the
    fan-out for this shape, exactly as `sven_batch` does; `route=` pins the
    layout ("batch" / "single").

    `warm` is an optional stacked EnetCarry (every field with a leading (B,)
    axis) and `has_warm` a (B,) bool selecting, per problem, the warm state
    over a cold start — the serving runtime's cache feeds adjacent-lambda
    solutions back through this without splitting the executable. With
    `return_carry` the final stacked EnetCarry comes back alongside the
    points (the state the runtime stores for the NEXT adjacent request);
    default is points only.
    """
    from repro.core.batch import _maybe_shard_batch, batch_mesh

    X = jnp.asarray(X)
    dtype = X.dtype
    y = jnp.asarray(y, dtype)
    lambda1s = jnp.asarray(lambda1s, dtype)
    lambda2s = jnp.asarray(lambda2s, dtype)
    axes = (0 if X.ndim == 3 else None,
            0 if y.ndim == 2 else None,
            0 if lambda1s.ndim == 1 else None,
            0 if lambda2s.ndim == 1 else None,
            0 if warm is not None else None,
            0 if warm is not None else None)
    sizes = {op.shape[0] for op, ax in zip((X, y, lambda1s, lambda2s), axes)
             if ax == 0}
    if not sizes:
        raise ValueError("enet_batch: no batched operand (use enet())")
    if (warm is None) != (has_warm is None):
        raise ValueError("enet_batch: warm and has_warm must be given together")
    if has_warm is not None:
        has_warm = jnp.asarray(has_warm, bool)
        sizes.update(jnp.asarray(f).shape[0] for f in warm)
        sizes.add(has_warm.shape[0])
    if len(sizes) != 1:
        raise ValueError(f"enet_batch: inconsistent batch sizes {sorted(sizes)}")
    # route BEFORE placing (see sven_batch): the penalized lane runs the
    # whole multiplier root-find, priced via form="penalized".
    mesh = batch_mesh(next(iter(sizes)), X.shape[-2], X.shape[-1],
                      form="penalized", route=route)
    if mesh is not None:
        X, y, lambda1s, lambda2s = (
            _maybe_shard_batch(op, ax == 0)
            for op, ax in zip((X, y, lambda1s, lambda2s), axes[:4]))
        if warm is not None:
            warm = EnetCarry(*(_maybe_shard_batch(jnp.asarray(f), True)
                               for f in warm))
            has_warm = _maybe_shard_batch(has_warm, True)
    config = resolve_path_config(config, X, y)
    if mesh is not None:
        carry, points = _enet_batch_sharded_jit(X, y, lambda1s, lambda2s,
                                                warm, has_warm, config, axes,
                                                mesh)
    else:
        carry, points = _enet_batch_jit(X, y, lambda1s, lambda2s, warm,
                                        has_warm, config, axes)
    return (points, carry) if return_carry else points


# ---------------------------------------------------------------------------
# Public penalized API (original scale)
# ---------------------------------------------------------------------------

class EnetResult(NamedTuple):
    beta: jax.Array        # (p,) original-scale coefficients
    intercept: jax.Array   # ()
    lambda1: float
    lambda2: float
    t: jax.Array           # |beta_std|_1 — the constrained-form budget
    nu: jax.Array          # measured multiplier (== lambda1 at convergence)
    n_kept: jax.Array      # columns surviving the gap-safe screen
    evals: jax.Array       # SVEN solves spent on the multiplier root-find
    sven_iters: jax.Array


class EnetPath(NamedTuple):
    lambda1s: jax.Array    # (L,) descending grid
    lambda2: float
    betas: jax.Array       # (L, p) original-scale coefficients
    intercepts: jax.Array  # (L,)
    ts: jax.Array          # (L,) constrained budgets |beta*|_1
    nus: jax.Array         # (L,) measured multipliers
    kkts: jax.Array        # (L,) Elastic Net KKT violations
    n_kept: jax.Array      # (L,) columns surviving the screen
    evals: jax.Array       # (L,) SVEN solves per point
    sven_iters: jax.Array  # (L,)


def enet(X, y, lambda1, lambda2, *, standardize: bool = False,
         fit_intercept: bool = False,
         config: PathConfig = PathConfig()) -> EnetResult:
    """Solve one penalized Elastic Net (paper scaling) via the SVEN engine."""
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, scaler = standardize_fit(X, y, standardize=standardize,
                                     fit_intercept=fit_intercept)
    config = resolve_path_config(config, Xs, ys)
    _, pt = _enet_jit(Xs, ys, jnp.asarray(lambda1, X.dtype),
                      jnp.asarray(lambda2, X.dtype), cold_carry(Xs, ys), config)
    beta, intercept = unscale_coef(pt.beta, scaler)
    return EnetResult(beta=beta, intercept=intercept, lambda1=float(lambda1),
                      lambda2=float(lambda2), t=pt.t, nu=pt.nu,
                      n_kept=pt.n_kept, evals=pt.evals,
                      sven_iters=pt.sven_iters)


def enet_path(X, y, *, lambda1s=None, n_lambdas: int = 40,
              eps: Optional[float] = None, lambda2=1.0,
              standardize: bool = False, fit_intercept: bool = False,
              config: PathConfig = PathConfig()) -> EnetPath:
    """glmnet-style regularization path: ONE jitted scan over the lambda grid.

    The grid is computed on the standardized problem (as glmnet does); the
    whole path — screening, bracketing and every warm-started SVEN solve —
    compiles to a single executable per (shape, grid length, config), so
    re-solving with new data or a rescaled grid never retraces
    (`trace_counts()["enet_path_scan"]`).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    Xs, ys, scaler = standardize_fit(X, y, standardize=standardize,
                                     fit_intercept=fit_intercept)
    if lambda1s is None:
        lambda1s = lambda_grid(Xs, ys, n_lambdas=n_lambdas, eps=eps)
    lambda1s = jnp.asarray(lambda1s, X.dtype)
    config = resolve_path_config(config, Xs, ys)
    pts = _enet_path_scan(Xs, ys, lambda1s, jnp.asarray(lambda2, X.dtype), config)
    betas, intercepts = unscale_coef(pts.beta, scaler)
    return EnetPath(lambda1s=lambda1s, lambda2=float(lambda2), betas=betas,
                    intercepts=intercepts, ts=pts.t, nus=pts.nu, kkts=pts.kkt,
                    n_kept=pts.n_kept, evals=pts.evals,
                    sven_iters=pts.sven_iters)


class ElasticNet:
    """sklearn-style estimator over the penalized SVEN front-end.

    Parameters are in the paper's scaling (no 1/2, no 1/n — see DESIGN.md §7
    for conversions from glmnet/sklearn). After `fit`: `coef_`, `intercept_`,
    `t_` (the constrained budget the fit mapped to), `n_kept_`.
    """

    def __init__(self, lambda1: float, lambda2: float = 1.0, *,
                 standardize: bool = True, fit_intercept: bool = True,
                 config: PathConfig = PathConfig()):
        self.lambda1 = lambda1
        self.lambda2 = lambda2
        self.standardize = standardize
        self.fit_intercept = fit_intercept
        self.config = config

    def fit(self, X, y):
        res = enet(X, y, self.lambda1, self.lambda2,
                   standardize=self.standardize,
                   fit_intercept=self.fit_intercept, config=self.config)
        self.coef_ = res.beta
        self.intercept_ = res.intercept
        self.t_ = res.t
        self.nu_ = res.nu
        self.n_kept_ = res.n_kept
        return self

    def predict(self, X):
        return jnp.asarray(X) @ self.coef_ + self.intercept_
