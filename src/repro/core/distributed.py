"""Distributed SVEN: the paper's solver on the production mesh.

The paper parallelizes the squared-hinge SVM on one GPU via BLAS; here the
same matrix-op structure shards over a TPU pod with shard_map:

  * features (the 2p constructed SVM samples <-> original p features) shard
    over the FLATTENED mesh (all axes) — at (16,16) that is 256-way feature
    parallelism;
  * the primal Newton-CG Hessian mat-vec needs, per iteration,
        c_loc = X_loc^T v        (local GEMV over the feature shard)
        d_loc = mask epilogue    (local)
        Hv    = psum(X_loc d_loc) + rank-1 terms   (ONE all-reduce of an
                n-vector per CG iteration)
  * the dual Gram build computes block-rows K_loc = Z_loc^T Z against an
    all-gathered Z panel (one all-gather of X per solve, amortized over all
    Newton iterations — the "kernel caching" regime of the paper).

Distribution-by-construction: every collective is explicit, so the dry-run
HLO for the sven_* cells shows exactly one psum per CG step + one gather per
Gram build (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.svm.primal_newton import solve_primal_newton


def _flat_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def feature_sharding(mesh: Mesh) -> NamedSharding:
    """X (n, p) with p sharded over every mesh axis."""
    return NamedSharding(mesh, P(None, _flat_axes(mesh)))


def dual_sample_sharding(mesh: Mesh) -> NamedSharding:
    """K (2p, 2p) row-sharded over the full mesh."""
    return NamedSharding(mesh, P(_flat_axes(mesh), None))


def distributed_gram(mesh: Mesh, X: jax.Array, y: jax.Array, t: float,
                     row_shard_out: bool = True) -> jax.Array:
    """K = Zhat^T Zhat (2p, 2p) with SAMPLES (n) sharded over the full mesh.

    The n >> p dual regime: each device reduces its sample shard
        G_loc = X_loc^T X_loc  (p,p),  u_loc = X_loc^T y_loc / t,
        s_loc = y_loc^T y_loc / t^2
    followed by ONE psum of (p^2 + p + 1) floats; the 4 block quadrants of K
    (the kernels/gram.py identity) assemble locally with zero additional
    communication. Contrast: the paper-faithful path would all-gather the
    (2p, n) constructed matrix — n/p times more wire bytes.
    """
    axes = _flat_axes(mesh)
    p = X.shape[1]

    def local(X_loc, y_loc):
        G = jax.lax.psum(X_loc.T @ X_loc, axes)                 # (p, p)
        u = jax.lax.psum((X_loc.T @ y_loc) / t, axes)           # (p,)
        s = jax.lax.psum((y_loc @ y_loc) / (t * t), axes)
        a = u[:, None]
        b = u[None, :]
        top = jnp.concatenate([G - a - b + s, -G - a + b + s], axis=1)
        bot = jnp.concatenate([-G + a - b + s, G + a + b + s], axis=1)
        K = jnp.concatenate([top, bot], axis=0)                 # (2p, 2p) replicated
        if row_shard_out:
            rank = jax.lax.axis_index(axes)
            n_dev = jax.lax.psum(1, axes)
            rows = (2 * p) // n_dev
            K = jax.lax.dynamic_slice_in_dim(K, rank * rows, rows, axis=0)
        return K

    out_spec = P(axes, None) if row_shard_out else P()
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes)),
        out_specs=out_spec,
        check_rep=False,
    )(X, y)


def distributed_gram_rs(mesh: Mesh, X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """Reduce-scatter Gram (§Perf iteration on distributed_gram).

    all-reduce(G) gives every device all of G (2(n-1)/n x p^2 wire) but a
    device only assembles its own K row block. psum_scatter hands device r
    just its p/n_dev G rows (half the wire, 1/n_dev the G memory); the K rows
    emitted are the feature-interleaved permutation [ +rows_r ; -rows_r ] —
    labels via interleaved_labels(), solvers are permutation-equivariant."""
    axes = _flat_axes(mesh)
    p = X.shape[1]

    def local(X_loc, y_loc):
        n_dev = jax.lax.psum(1, axes)
        G_part = X_loc.T @ X_loc                               # (p, p) partial
        G_rows = jax.lax.psum_scatter(G_part, axes, scatter_dimension=0,
                                      tiled=True)              # (p/n_dev, p)
        u = jax.lax.psum((X_loc.T @ y_loc) / t, axes)          # (p,)
        s = jax.lax.psum((y_loc @ y_loc) / (t * t), axes)
        rank = jax.lax.axis_index(axes)
        rows = p // n_dev
        u_loc = jax.lax.dynamic_slice_in_dim(u, rank * rows, rows)
        a = u_loc[:, None]
        b = u[None, :]
        top = jnp.concatenate([G_rows - a - b + s, -G_rows - a + b + s], axis=1)
        bot = jnp.concatenate([-G_rows + a - b + s, G_rows + a + b + s], axis=1)
        return jnp.concatenate([top, bot], axis=0)             # (2 p/n_dev, 2p)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes)),
                     out_specs=P(axes, None), check_rep=False)(X, y)


def distributed_gram_rs_syrk(mesh: Mesh, X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """distributed_gram_rs + level-1 SYRK blocking: G = X^T X is symmetric, so
    with X = [X1 X2] only (G11, G12, G22) are computed — 3/4 of the MACs; G21
    is a local transpose. (Recursive halving would approach 1/2.)"""
    axes = _flat_axes(mesh)
    p = X.shape[1]
    h = p // 2

    def local(X_loc, y_loc):
        n_dev = jax.lax.psum(1, axes)
        X1, X2 = X_loc[:, :h], X_loc[:, h:]
        G11 = X1.T @ X1
        G12 = X1.T @ X2
        G22 = X2.T @ X2
        G_part = jnp.concatenate([
            jnp.concatenate([G11, G12], axis=1),
            jnp.concatenate([G12.T, G22], axis=1)], axis=0)
        G_rows = jax.lax.psum_scatter(G_part, axes, scatter_dimension=0, tiled=True)
        u = jax.lax.psum((X_loc.T @ y_loc) / t, axes)
        s = jax.lax.psum((y_loc @ y_loc) / (t * t), axes)
        rank = jax.lax.axis_index(axes)
        rows = p // n_dev
        u_loc = jax.lax.dynamic_slice_in_dim(u, rank * rows, rows)
        a = u_loc[:, None]
        b = u[None, :]
        top = jnp.concatenate([G_rows - a - b + s, -G_rows - a + b + s], axis=1)
        bot = jnp.concatenate([-G_rows + a - b + s, G_rows + a + b + s], axis=1)
        return jnp.concatenate([top, bot], axis=0)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes)),
                     out_specs=P(axes, None), check_rep=False)(X, y)


def interleaved_labels(p: int, n_dev: int, dtype) -> jax.Array:
    """Labels matching distributed_gram_rs's row permutation."""
    rows = p // n_dev
    one = jnp.ones((rows,), dtype)
    return jnp.tile(jnp.concatenate([one, -one]), n_dev)


def distributed_gram_paper(mesh: Mesh, X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """PAPER-FAITHFUL baseline for the §Perf hillclimb: materialize the
    constructed (n_loc, 2p) matrix Zhat per sample shard (exactly what the
    MATLAB listing does before calling the SVM) and reduce K = psum(Z^T Z):
    4x the MACs and 2x the HBM reads of distributed_gram's block identity."""
    axes = _flat_axes(mesh)
    p = X.shape[1]

    def local(X_loc, y_loc):
        shift = (y_loc / t)[:, None]
        Z_loc = jnp.concatenate([X_loc - shift, -(X_loc + shift)], axis=1)  # (n_loc, 2p)
        return jax.lax.psum(Z_loc.T @ Z_loc, axes)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes)),
                     out_specs=P(), check_rep=False)(X, y)


def make_distributed_hessian_matvec(mesh: Mesh, X: jax.Array, y: jax.Array,
                                    t: float, C: float):
    """Primal-mode H v mat-vec with ONE psum per call.

    v (n,) replicated; features sharded. act masks (2p,) live feature-sharded
    as (act_top_loc, act_bot_loc). Returns a closure for solve_primal_newton's
    hess_matvec hook (act supplied per Newton iteration, replicated (2p,) in
    shard order)."""
    axes = _flat_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    p = X.shape[1]
    p_loc = p // n_dev

    def local(X_loc, y_full, act, v, C_op):
        rank = jax.lax.axis_index(axes)
        a_t = jax.lax.dynamic_slice_in_dim(act, rank * p_loc, p_loc)
        a_b = jax.lax.dynamic_slice_in_dim(act, p + rank * p_loc, p_loc)
        c = X_loc.T @ v                                   # (p_loc,)
        byv = (y_full @ v) / t                            # scalar (replicated)
        u_t = a_t * (c - byv)
        u_b = a_b * (c + byv)
        d = u_t + u_b
        e_loc = jnp.sum(u_b) - jnp.sum(u_t)
        partial_hv = X_loc @ d + (y_full / t) * e_loc     # (n,)
        hv = jax.lax.psum(partial_hv, axes)               # ONE all-reduce
        return v + 2.0 * C_op * hv

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, axes), P(), P(), P(), P()),
                   out_specs=P(), check_rep=False)

    def hess_matvec(v, act, C_traced=None):
        C_op = C if C_traced is None else C_traced
        return fn(X, y, act.astype(v.dtype), v, jnp.asarray(C_op, v.dtype))

    return hess_matvec


# ---------------------------------------------------------------------------
# Data-parallel SVEN (DESIGN.md §9): rows of Zhat sharded over the mesh
# ---------------------------------------------------------------------------
#
# Zhat (n, 2p) is the label-scaled dual data matrix; its rows are the
# ORIGINAL samples, so row-sharding Zhat == row-sharding X — plain data
# parallelism. Every solver product then reduces to local O(n_loc p) work
# plus one small collective:
#
#     dual   K = Zhat^T Zhat       one psum of (G, u, s): p^2 + p + 1 floats
#                                  per SOLVE (kernel caching regime); the
#                                  projected-Newton solver runs replicated
#                                  on the assembled (2p, 2p) kernel.
#     primal Xhat @ w              one psum of (p + 1) floats per product
#            Xhat^T v              one all-gather of an n-vector
#            hinge stats           one psum of (p + 2) floats
#
# Rows pad with ZEROS to a multiple of the mesh size — a zero sample with a
# zero response adds nothing to the Elastic Net objective, to any Gram
# statistic, or to any matvec (the serve/engine.py padding argument), so
# padded parity is exact, not approximate.


def pad_rows(X: jax.Array, y: jax.Array, n_dev: int):
    """Zero-row pad (X, y) to a row count divisible by `n_dev` (exact)."""
    rem = (-X.shape[0]) % n_dev
    if rem == 0:
        return X, y
    return jnp.pad(X, ((0, rem), (0, 0))), jnp.pad(y, ((0, rem),))


def shard_rows(mesh: Mesh, X: jax.Array, y: jax.Array):
    """Place (X, y) row-sharded over the flattened mesh (zero-row padded)."""
    axes = _flat_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    Xp, yp = pad_rows(X, y, n_dev)
    Xs = jax.device_put(Xp, NamedSharding(mesh, P(axes, None)))
    ys = jax.device_put(yp, NamedSharding(mesh, P(axes)))
    return Xs, ys


@partial(jax.jit, static_argnames=("mesh",))
def sharded_stats(X, y, t, *, mesh: Mesh):
    """The ONE collective of the sharded dual solve, as its own executable:
    psum-reduced sufficient statistics (G = X^T X, u = X^T y / t,
    s = y^T y / t^2) of a row-sharded (X, y).

    Launched separately from the solve program ON PURPOSE: under JAX async
    dispatch the returned arrays are futures, so the device runs the
    all-reduce while the host traces/launches the (much larger) replicated
    Newton program that consumes them — the stats reduction overlaps the
    solver setup instead of serializing in front of it. Same op order per
    shard as `reduction.gram_blocks`'s inputs, so a 1-device mesh
    reproduces the single-device statistics bitwise.
    """
    from repro.core.sven import _bump_trace

    _bump_trace("sven_sharded_stats")
    axes = _flat_axes(mesh)

    def local(X_loc, y_loc, t_op):
        G = jax.lax.psum(X_loc.T @ X_loc, axes)
        u = jax.lax.psum(X_loc.T @ y_loc, axes) / t_op
        s = jax.lax.psum(y_loc @ y_loc, axes) / (t_op * t_op)
        return G, u, s

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes), P()),
                     out_specs=(P(), P(), P()), check_rep=False)(
                         X, y, jnp.asarray(t, X.dtype))


def sharded_gram_stats(mesh: Mesh, X: jax.Array, y: jax.Array, t) -> jax.Array:
    """K = Zhat^T Zhat from psum-reduced (G, u, s) statistics — the
    data-parallel twin of `reduction.gram_blocks` (same op order per shard,
    so a 1-device mesh reproduces the single-device kernel bitwise).

    Composition of the async `sharded_stats` launch and the replicated
    4-block assembly; callers that want the overlap harvest the stats
    futures inside their own program instead (`_sven_sharded_dual_jit`).
    """
    from repro.core import reduction as red

    G, u, s = sharded_stats(X, y, t, mesh=mesh)
    return red.gram_from_stats(G, u, s)


def sharded_hinge_stats(mesh: Mesh, X: jax.Array, y: jax.Array, t,
                        w: jax.Array, C):
    """`kernels.ref.hinge_stats_ref` on a row-sharded X: the fused Newton
    outer-step stats (margin, act, loss, galpha) from ONE psum of p + 2
    floats — X_loc^T w_loc, y_loc . w_loc and w_loc . w_loc.

    Standalone fused form, parity-tested against the jnp oracle; the
    primal solver machine (`_sven_sharded_primal`) composes its
    matvec/rmatvec closures instead, so this op serves stats-driven outer
    loops and diagnostics rather than the solve hot path."""
    from repro.kernels.ref import hinge_stats_from_moments

    axes = _flat_axes(mesh)
    p = X.shape[1]
    dtype = X.dtype

    def local(X_loc, y_loc, t_op, C_op, w_full):
        n_loc = X_loc.shape[0]
        rank = jax.lax.axis_index(axes)
        w_loc = jax.lax.dynamic_slice_in_dim(w_full, rank * n_loc, n_loc)
        stats = jax.lax.psum(jnp.concatenate([
            X_loc.T @ w_loc, (y_loc @ w_loc)[None], (w_loc @ w_loc)[None]]),
            axes)
        return hinge_stats_from_moments(stats[:p], stats[p] / t_op,
                                        stats[p + 1], C_op)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes), P(), P(), P()),
                     out_specs=(P(), P(), P(), P()), check_rep=False)(
                         X, y, jnp.asarray(t, dtype), jnp.asarray(C, dtype), w)


def _sven_sharded_primal(mesh: Mesh, X, y, t, C, warm_w, config):
    """Whole primal Newton-CG solve inside ONE shard_map region: w (n,)
    replicated, X rows sharded; each Xhat product costs one psum(p + 1),
    each Xhat^T product one all-gather of an n-vector."""
    from repro.core import reduction as red

    axes = _flat_axes(mesh)
    n, p = X.shape
    dtype = X.dtype
    yhat = jnp.concatenate([jnp.ones((p,), dtype), -jnp.ones((p,), dtype)])

    def local(X_loc, y_loc, t_op, C_op, w0):
        n_loc = X_loc.shape[0]
        rank = jax.lax.axis_index(axes)

        def matvec(w):                       # Xhat @ w -> (2p,) replicated
            w_loc = jax.lax.dynamic_slice_in_dim(w, rank * n_loc, n_loc)
            ab = jax.lax.psum(jnp.concatenate([X_loc.T @ w_loc,
                                               (y_loc @ w_loc)[None]]), axes)
            a, b = ab[:p], ab[p] / t_op
            return jnp.concatenate([a - b, a + b])

        def rmatvec(v):                      # Xhat^T v -> (n,) replicated
            vt, vb = v[:p], v[p:]
            out_loc = (X_loc @ (vt + vb)
                       + (y_loc / t_op) * (jnp.sum(vb) - jnp.sum(vt)))
            return jax.lax.all_gather(out_loc, axes, tiled=True)

        res = solve_primal_newton(matvec, rmatvec, yhat, C_op, n,
                                  tol=config.tol, max_newton=config.max_newton,
                                  cg_iters=config.cg_iters, w0=w0)
        alpha = C_op * jnp.maximum(1.0 - yhat * matvec(res.w), 0.0)
        beta = red.recover_beta(alpha, t_op)
        return beta, alpha, res.w, res.iters, res.grad_norm

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes), P(), P(), P()),
                     out_specs=(P(), P(), P(), P(), P()), check_rep=False)(
                         X, y, jnp.asarray(t, dtype), jnp.asarray(C, dtype),
                         warm_w)


@partial(jax.jit, static_argnames=("n_orig", "config"))
def _sven_sharded_dual_jit(stats, K, X, y, t, lambda2, warm_alpha, *,
                           n_orig: int, config):
    """Replicated dual solve consuming the async stats/K launch.

    Exactly one of `stats` (the (G, u, s) futures from `sharded_stats`) and
    `K` (the Pallas `sharded_shifted_gram` future) is non-None — harvested
    at first use, so by the time the device reaches the kernel assembly the
    overlapped reduction has usually already landed. Everything here is
    global ops: the partitioner keeps X's rows sharded for the w-recovery
    and KKT contractions (one all-reduce each), the Newton solve itself is
    replicated — no shard_map, hence no static mesh in the jit key.
    """
    from repro.core import elastic_net as en
    from repro.core import reduction as red
    from repro.core.svm import solve_dual_fista, solve_dual_newton
    from repro.core.sven import SvenArrays, _bump_trace

    _bump_trace("sven_sharded")
    p = X.shape[1]
    dtype = X.dtype
    C = red.svm_C(lambda2, floor=config.lambda2_floor).astype(dtype)
    kernel_K = K is not None          # static: pytree structure keys the jit
    if K is None:
        K = red.gram_from_stats(*stats)
    solver = (solve_dual_newton if config.solver == "newton"
              else solve_dual_fista)
    res = solver(lambda v: K @ v, 2 * p, C, dtype=dtype, tol=config.tol,
                 alpha0=warm_alpha)
    if kernel_K and config.precision != "f32":
        # iterative refinement, sharded flavor (DESIGN.md §10.3): re-solve
        # matrix-free at full precision from the low-precision alpha. All
        # global ops — the partitioner keeps X's rows sharded and inserts
        # the same one-psum-per-product collectives as the stats path.
        res = solver(red.SvenOperator(X=X, y=y, t=t).kernel_matvec, 2 * p, C,
                     dtype=dtype, tol=config.tol, alpha0=res.alpha)
    beta = red.recover_beta(res.alpha, t)
    # w = Zhat @ alpha on the row-sharded X: global ops, the partitioner
    # keeps the row dimension sharded and gathers the (n,) result.
    w = red.SvenOperator(X=X, y=y, t=t).zhat_matvec(res.alpha)
    kkt = en.kkt_violation(X, y, beta, lambda2)
    return SvenArrays(beta=beta, alpha=res.alpha, w=w[:n_orig],
                      iters=res.iters, opt_residual=res.pg_norm, kkt=kkt)


@partial(jax.jit, static_argnames=("mesh", "n_orig", "config"))
def _sven_sharded_primal_jit(X, y, t, lambda2, warm_w, *, mesh: Mesh,
                             n_orig: int, config):
    from repro.core import elastic_net as en
    from repro.core import reduction as red
    from repro.core.sven import SvenArrays, _bump_trace

    _bump_trace("sven_sharded")
    dtype = X.dtype
    C = red.svm_C(lambda2, floor=config.lambda2_floor).astype(dtype)
    beta, alpha, w, iters, opt = _sven_sharded_primal(
        mesh, X, y, t, C, warm_w, config)
    # KKT diagnostics on the (padded == original) problem; rows stay sharded
    # under the partitioner, one all-reduce for the X^T r contraction.
    kkt = en.kkt_violation(X, y, beta, lambda2)
    return SvenArrays(beta=beta, alpha=alpha, w=w[:n_orig], iters=iters,
                      opt_residual=opt, kkt=kkt)


def sven_sharded(X: jax.Array, y: jax.Array, t, lambda2, config=None, *,
                 mesh: Optional[Mesh] = None, warm_alpha=None, warm_w=None):
    """Data-parallel `sven()`: rows sharded over the mesh, same answers.

    The production multi-device solve path (DESIGN.md §9): X's rows (==
    Zhat's rows) are zero-padded to the mesh size and sharded over every
    mesh axis; the dual path assembles the kernel from one psum of its
    sufficient statistics, the primal path runs the whole Newton-CG machine
    inside one shard_map region with one psum + one all-gather per product.
    Parity with single-device `sven()` is exact to solver tolerance
    (<= 1e-10 tested on 8 forced host devices), and a 1-device mesh
    reproduces it bitwise.

    `mesh=None` resolves the innermost `dist.mesh_context`, then falls back
    to `dist.data_mesh()` over all visible devices — on a single-device
    process that is a 1-device mesh, i.e. the single-device path.

    This is the PINNED sharded layout: it always runs row-sharded on the
    resolved mesh. `core.routing.sven_routed` is the entry point that
    consults the cost model first and only comes here when sharding wins.
    """
    from repro import dist
    from repro.core.sven import (SvenConfig, SvenSolution, _pick_mode,
                                 resolve_backend)

    config = SvenConfig() if config is None else config
    X = jnp.asarray(X)
    y = jnp.asarray(y, X.dtype)
    n, p = X.shape
    if mesh is None:
        ctx = dist.current_context()
        mesh = ctx[0] if ctx is not None else dist.data_mesh()
    mode = _pick_mode(n, p, config)
    Xs, ys = shard_rows(mesh, X, y)
    config = resolve_backend(config, Xs, ys)
    dtype = X.dtype
    t_op = jnp.asarray(t, dtype)
    l2_op = jnp.asarray(lambda2, dtype)
    if mode == "dual":
        # Launch the one-psum stats reduction (or the Pallas Gram kernel)
        # as its OWN async program, then hand its output futures to the
        # replicated solve program — the device reduces while the host
        # traces/dispatches the Newton setup (collective/compute overlap).
        stats = K = None
        if config.backend != "xla":
            from repro.kernels.ops import sharded_shifted_gram
            K = sharded_shifted_gram(
                mesh, Xs.astype(jnp.float32), ys.astype(jnp.float32),
                jnp.asarray(t, jnp.float32), backend=config.backend,
                precision=config.precision).astype(dtype)
        else:
            stats = sharded_stats(Xs, ys, t_op, mesh=mesh)
        wa = (jnp.zeros((2 * p,), dtype) if warm_alpha is None
              else jnp.asarray(warm_alpha, dtype))
        arrs = _sven_sharded_dual_jit(stats, K, Xs, ys, t_op, l2_op, wa,
                                      n_orig=n, config=config)
    else:
        ww = (jnp.zeros((Xs.shape[0],), dtype) if warm_w is None
              else jnp.pad(jnp.asarray(warm_w, dtype),
                           ((0, Xs.shape[0] - n),)))
        arrs = _sven_sharded_primal_jit(Xs, ys, t_op, l2_op, ww, mesh=mesh,
                                        n_orig=n, config=config)
    return SvenSolution(beta=arrs.beta, alpha=arrs.alpha, mode=mode,
                        iters=arrs.iters, opt_residual=arrs.opt_residual,
                        kkt=arrs.kkt, w=arrs.w)


def sven_primal_distributed(mesh: Mesh, X: jax.Array, y: jax.Array, t: float,
                            lambda2: float, *, tol: float = 1e-8,
                            max_newton: int = 40, cg_iters: int = 200):
    """Full distributed primal SVEN solve; beta via Algorithm 1 recovery.

    Note: the act-mask layout here is the canonical [all +, all -] ordering —
    the gradient/margin path computes on the replicated implicit operator
    while the O(np) Hessian mat-vecs (the hot loop) run feature-sharded."""
    from repro.core.reduction import SvenOperator, recover_beta, svm_C

    n, p = X.shape
    C = svm_C(lambda2).astype(X.dtype)
    op = SvenOperator(X=X, y=y, t=t)
    yhat = jnp.concatenate([jnp.ones((p,), X.dtype), -jnp.ones((p,), X.dtype)])
    hess = make_distributed_hessian_matvec(mesh, X, y, t, C)
    res = solve_primal_newton(op.xhat_matvec, op.xhat_rmatvec, yhat, C, n,
                              tol=tol, max_newton=max_newton, cg_iters=cg_iters,
                              hess_matvec=hess)
    alpha = C * jnp.maximum(1.0 - yhat * op.xhat_matvec(res.w), 0.0)
    return recover_beta(alpha, t), res
