"""Distributed SVEN: the paper's solver on the production mesh.

The paper parallelizes the squared-hinge SVM on one GPU via BLAS; here the
same matrix-op structure shards over a TPU pod with shard_map:

  * features (the 2p constructed SVM samples <-> original p features) shard
    over the FLATTENED mesh (all axes) — at (16,16) that is 256-way feature
    parallelism;
  * the primal Newton-CG Hessian mat-vec needs, per iteration,
        c_loc = X_loc^T v        (local GEMV over the feature shard)
        d_loc = mask epilogue    (local)
        Hv    = psum(X_loc d_loc) + rank-1 terms   (ONE all-reduce of an
                n-vector per CG iteration)
  * the dual Gram build computes block-rows K_loc = Z_loc^T Z against an
    all-gathered Z panel (one all-gather of X per solve, amortized over all
    Newton iterations — the "kernel caching" regime of the paper).

Distribution-by-construction: every collective is explicit, so the dry-run
HLO for the sven_* cells shows exactly one psum per CG step + one gather per
Gram build (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.svm.primal_newton import solve_primal_newton


def _flat_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def feature_sharding(mesh: Mesh) -> NamedSharding:
    """X (n, p) with p sharded over every mesh axis."""
    return NamedSharding(mesh, P(None, _flat_axes(mesh)))


def dual_sample_sharding(mesh: Mesh) -> NamedSharding:
    """K (2p, 2p) row-sharded over the full mesh."""
    return NamedSharding(mesh, P(_flat_axes(mesh), None))


def distributed_gram(mesh: Mesh, X: jax.Array, y: jax.Array, t: float,
                     row_shard_out: bool = True) -> jax.Array:
    """K = Zhat^T Zhat (2p, 2p) with SAMPLES (n) sharded over the full mesh.

    The n >> p dual regime: each device reduces its sample shard
        G_loc = X_loc^T X_loc  (p,p),  u_loc = X_loc^T y_loc / t,
        s_loc = y_loc^T y_loc / t^2
    followed by ONE psum of (p^2 + p + 1) floats; the 4 block quadrants of K
    (the kernels/gram.py identity) assemble locally with zero additional
    communication. Contrast: the paper-faithful path would all-gather the
    (2p, n) constructed matrix — n/p times more wire bytes.
    """
    axes = _flat_axes(mesh)
    p = X.shape[1]

    def local(X_loc, y_loc):
        G = jax.lax.psum(X_loc.T @ X_loc, axes)                 # (p, p)
        u = jax.lax.psum((X_loc.T @ y_loc) / t, axes)           # (p,)
        s = jax.lax.psum((y_loc @ y_loc) / (t * t), axes)
        a = u[:, None]
        b = u[None, :]
        top = jnp.concatenate([G - a - b + s, -G - a + b + s], axis=1)
        bot = jnp.concatenate([-G + a - b + s, G + a + b + s], axis=1)
        K = jnp.concatenate([top, bot], axis=0)                 # (2p, 2p) replicated
        if row_shard_out:
            rank = jax.lax.axis_index(axes)
            n_dev = jax.lax.psum(1, axes)
            rows = (2 * p) // n_dev
            K = jax.lax.dynamic_slice_in_dim(K, rank * rows, rows, axis=0)
        return K

    out_spec = P(axes, None) if row_shard_out else P()
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes)),
        out_specs=out_spec,
        check_rep=False,
    )(X, y)


def distributed_gram_rs(mesh: Mesh, X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """Reduce-scatter Gram (§Perf iteration on distributed_gram).

    all-reduce(G) gives every device all of G (2(n-1)/n x p^2 wire) but a
    device only assembles its own K row block. psum_scatter hands device r
    just its p/n_dev G rows (half the wire, 1/n_dev the G memory); the K rows
    emitted are the feature-interleaved permutation [ +rows_r ; -rows_r ] —
    labels via interleaved_labels(), solvers are permutation-equivariant."""
    axes = _flat_axes(mesh)
    p = X.shape[1]

    def local(X_loc, y_loc):
        n_dev = jax.lax.psum(1, axes)
        G_part = X_loc.T @ X_loc                               # (p, p) partial
        G_rows = jax.lax.psum_scatter(G_part, axes, scatter_dimension=0,
                                      tiled=True)              # (p/n_dev, p)
        u = jax.lax.psum((X_loc.T @ y_loc) / t, axes)          # (p,)
        s = jax.lax.psum((y_loc @ y_loc) / (t * t), axes)
        rank = jax.lax.axis_index(axes)
        rows = p // n_dev
        u_loc = jax.lax.dynamic_slice_in_dim(u, rank * rows, rows)
        a = u_loc[:, None]
        b = u[None, :]
        top = jnp.concatenate([G_rows - a - b + s, -G_rows - a + b + s], axis=1)
        bot = jnp.concatenate([-G_rows + a - b + s, G_rows + a + b + s], axis=1)
        return jnp.concatenate([top, bot], axis=0)             # (2 p/n_dev, 2p)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes)),
                     out_specs=P(axes, None), check_rep=False)(X, y)


def distributed_gram_rs_syrk(mesh: Mesh, X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """distributed_gram_rs + level-1 SYRK blocking: G = X^T X is symmetric, so
    with X = [X1 X2] only (G11, G12, G22) are computed — 3/4 of the MACs; G21
    is a local transpose. (Recursive halving would approach 1/2.)"""
    axes = _flat_axes(mesh)
    p = X.shape[1]
    h = p // 2

    def local(X_loc, y_loc):
        n_dev = jax.lax.psum(1, axes)
        X1, X2 = X_loc[:, :h], X_loc[:, h:]
        G11 = X1.T @ X1
        G12 = X1.T @ X2
        G22 = X2.T @ X2
        G_part = jnp.concatenate([
            jnp.concatenate([G11, G12], axis=1),
            jnp.concatenate([G12.T, G22], axis=1)], axis=0)
        G_rows = jax.lax.psum_scatter(G_part, axes, scatter_dimension=0, tiled=True)
        u = jax.lax.psum((X_loc.T @ y_loc) / t, axes)
        s = jax.lax.psum((y_loc @ y_loc) / (t * t), axes)
        rank = jax.lax.axis_index(axes)
        rows = p // n_dev
        u_loc = jax.lax.dynamic_slice_in_dim(u, rank * rows, rows)
        a = u_loc[:, None]
        b = u[None, :]
        top = jnp.concatenate([G_rows - a - b + s, -G_rows - a + b + s], axis=1)
        bot = jnp.concatenate([-G_rows + a - b + s, G_rows + a + b + s], axis=1)
        return jnp.concatenate([top, bot], axis=0)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes)),
                     out_specs=P(axes, None), check_rep=False)(X, y)


def interleaved_labels(p: int, n_dev: int, dtype) -> jax.Array:
    """Labels matching distributed_gram_rs's row permutation."""
    rows = p // n_dev
    one = jnp.ones((rows,), dtype)
    return jnp.tile(jnp.concatenate([one, -one]), n_dev)


def distributed_gram_paper(mesh: Mesh, X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """PAPER-FAITHFUL baseline for the §Perf hillclimb: materialize the
    constructed (n_loc, 2p) matrix Zhat per sample shard (exactly what the
    MATLAB listing does before calling the SVM) and reduce K = psum(Z^T Z):
    4x the MACs and 2x the HBM reads of distributed_gram's block identity."""
    axes = _flat_axes(mesh)
    p = X.shape[1]

    def local(X_loc, y_loc):
        shift = (y_loc / t)[:, None]
        Z_loc = jnp.concatenate([X_loc - shift, -(X_loc + shift)], axis=1)  # (n_loc, 2p)
        return jax.lax.psum(Z_loc.T @ Z_loc, axes)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes)),
                     out_specs=P(), check_rep=False)(X, y)


def make_distributed_hessian_matvec(mesh: Mesh, X: jax.Array, y: jax.Array,
                                    t: float, C: float):
    """Primal-mode H v mat-vec with ONE psum per call.

    v (n,) replicated; features sharded. act masks (2p,) live feature-sharded
    as (act_top_loc, act_bot_loc). Returns a closure for solve_primal_newton's
    hess_matvec hook (act supplied per Newton iteration, replicated (2p,) in
    shard order)."""
    axes = _flat_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    p = X.shape[1]
    p_loc = p // n_dev

    def local(X_loc, y_full, act, v, C_op):
        rank = jax.lax.axis_index(axes)
        a_t = jax.lax.dynamic_slice_in_dim(act, rank * p_loc, p_loc)
        a_b = jax.lax.dynamic_slice_in_dim(act, p + rank * p_loc, p_loc)
        c = X_loc.T @ v                                   # (p_loc,)
        byv = (y_full @ v) / t                            # scalar (replicated)
        u_t = a_t * (c - byv)
        u_b = a_b * (c + byv)
        d = u_t + u_b
        e_loc = jnp.sum(u_b) - jnp.sum(u_t)
        partial_hv = X_loc @ d + (y_full / t) * e_loc     # (n,)
        hv = jax.lax.psum(partial_hv, axes)               # ONE all-reduce
        return v + 2.0 * C_op * hv

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, axes), P(), P(), P(), P()),
                   out_specs=P(), check_rep=False)

    def hess_matvec(v, act, C_traced=None):
        C_op = C if C_traced is None else C_traced
        return fn(X, y, act.astype(v.dtype), v, jnp.asarray(C_op, v.dtype))

    return hess_matvec


def sven_primal_distributed(mesh: Mesh, X: jax.Array, y: jax.Array, t: float,
                            lambda2: float, *, tol: float = 1e-8,
                            max_newton: int = 40, cg_iters: int = 200):
    """Full distributed primal SVEN solve; beta via Algorithm 1 recovery.

    Note: the act-mask layout here is the canonical [all +, all -] ordering —
    the gradient/margin path computes on the replicated implicit operator
    while the O(np) Hessian mat-vecs (the hot loop) run feature-sharded."""
    from repro.core.reduction import SvenOperator, recover_beta, svm_C

    n, p = X.shape
    C = svm_C(lambda2).astype(X.dtype)
    op = SvenOperator(X=X, y=y, t=t)
    yhat = jnp.concatenate([jnp.ones((p,), X.dtype), -jnp.ones((p,), X.dtype)])
    hess = make_distributed_hessian_matvec(mesh, X, y, t, C)
    res = solve_primal_newton(op.xhat_matvec, op.xhat_rmatvec, yhat, C, n,
                              tol=tol, max_newton=max_newton, cg_iters=cg_iters,
                              hess_matvec=hess)
    alpha = C * jnp.maximum(1.0 - yhat * op.xhat_matvec(res.w), 0.0)
    return recover_beta(alpha, t), res
