"""SVEN driver — the paper's Algorithm 1 as a jit-native JAX engine.

Dispatch (paper §3, "Implementation details"):
    2p > n  -> primal solver over w in R^n   (cost driven by n)
    else    -> dual solver over alpha in R^{2p}, kernel cached when it fits

`matrix_free=True` (default) uses the SvenOperator O(np) products and never
materializes the (2p, n) constructed dataset — the TPU-native path.
`matrix_free=False` is the paper-faithful baseline (explicit Xnew, as the
MATLAB listing does). Both return identical solutions (tested).

Engine architecture (DESIGN.md §6): `t` and `lambda2` are *traced* scalars,
so `sven()` compiles exactly once per (shape, dtype, warm-start structure,
config) — sweeping the regularization surface never retraces. `sven_path`
is a single jitted `lax.scan` over the t-grid that carries the warm dual
alpha AND primal w through the scan; `sven_path_reference` keeps the
host-side Python loop as the testable reference. `core/batch.py` vmaps the
same core over stacked problems and `serve/engine.py` buckets live request
queues onto these compiled executables. Trace counts are observable via
`trace_counts()` — tests assert the compile-once property.

Gap-safe screening (`core/screening.py`) plugs in through the optional
`keep` mask: a (p,) boolean operand that zeroes provably-inactive columns
and scatters their coefficients back as exact zeros — fixed shapes, so the
compile-once property survives. The glmnet-parity penalized front-end
(`core/api.py`: lambda grids, `enet_path`, estimators; `core/cv.py`:
batched `ElasticNetCV`) drives this core through the `t = |beta*|_1`
penalized<->constrained equivalence (DESIGN.md §7).

The returned diagnostics make the solve auditable at scale: iteration counts,
final KKT residuals of the *original* Elastic Net problem, and the objective.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import elastic_net as en
from repro.core import reduction as red
from repro.core.svm import solve_dual_fista, solve_dual_newton, solve_primal_newton

# ---------------------------------------------------------------------------
# Trace instrumentation: each jit-wrapped entry point bumps its counter ONCE
# per trace (the bump runs at trace time, not at execution time). Tests and
# benchmarks assert e.g. a 40-point path costs exactly one trace. The counts
# live on the process-wide obs registry (``solver_traces_total{entry=...}``,
# DESIGN.md §12.2) so they export beside router decisions; a `trace:<entry>`
# instant marks WHEN each (re)trace happened on the timeline — a nonzero
# steady-state count is the regression the zero-retrace CI gate catches.
# ---------------------------------------------------------------------------

def _trace_counter():
    from repro.obs.metrics import default_registry
    return default_registry().counter(
        "solver_traces_total", "jit traces per solver entry point", ("entry",))


def _bump_trace(name: str) -> None:
    _trace_counter().inc(entry=name)
    from repro.obs.trace import get_tracer
    get_tracer().instant(f"trace:{name}")


def trace_counts() -> dict:
    """Snapshot of {entry_point: times_traced} since the last reset."""
    return {entry: int(v)
            for (entry,), v in _trace_counter().series().items()}


def reset_trace_counts() -> None:
    from repro.obs.metrics import default_registry
    default_registry().reset_instrument("solver_traces_total")


class SvenArrays(NamedTuple):
    """Arrays-only solve result — the jit/scan/vmap-safe core payload."""

    beta: jax.Array
    alpha: jax.Array
    w: jax.Array              # primal iterate (dual mode: w = Zhat @ alpha)
    iters: jax.Array
    opt_residual: jax.Array
    kkt: jax.Array


class SvenSolution(NamedTuple):
    beta: jax.Array
    alpha: jax.Array
    mode: str                 # "primal" | "dual"
    iters: jax.Array
    opt_residual: jax.Array   # solver's own optimality measure
    kkt: jax.Array            # Elastic Net KKT violation at beta
    w: jax.Array              # primal SVM iterate — warm-start carrier


#: every accepted SvenConfig.backend spelling: "xla" = no kernels module at
#: all (pure-jnp matrix-free reduction); "auto" = kernel registry, body
#: resolved from the operands' platform; "pallas" = deprecated alias of
#: "auto" (the pre-enum spelling); the rest are RESOLVED kernel backends
#: (kernels/registry.py: body + execution mode).
BACKENDS = ("xla", "auto", "pallas",
            "tpu", "gpu", "tpu_interpret", "gpu_interpret", "ref")
PRECISIONS = ("f32", "bf16", "tf32")


@dataclasses.dataclass(frozen=True)
class SvenConfig:
    mode: str = "auto"            # "auto" | "primal" | "dual"
    matrix_free: bool = True      # SvenOperator vs explicit Xnew
    cache_kernel: str = "auto"    # "auto" | "blocks" | "never" (dual only)
    solver: str = "newton"        # "newton" | "fista" (dual only)
    backend: str = "xla"          # one of BACKENDS (DESIGN.md §10)
    # DEPRECATED two-flag-era Pallas interpret switch. None = unresolved:
    # `resolve_backend` folds any explicit value into the backend enum
    # (backend "auto" + interpret=True -> "<body>_interpret") and
    # normalizes this field back to None so equivalent spellings hash to
    # the SAME jit key. New code should pass a resolved backend instead.
    interpret: Optional[bool] = None
    # kernel MAC/storage precision: "f32" | "bf16" | "tf32". Applies to the
    # registry-backed kernel paths only ("xla" and the ref oracle always
    # compute at full input precision); low-precision dual solves get one
    # full-precision iterative-refinement re-solve (DESIGN.md §10.3) so the
    # <= 1e-10 parity gates still hold.
    precision: str = "f32"
    tol: float = 1e-8
    max_newton: int = 60
    cg_iters: int = 300
    kernel_cache_max_m: int = 8192   # cache K when 2p <= this
    lambda2_floor: float = red.LAMBDA2_FLOOR  # Lasso limit: C capped at 1/(2*floor)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"SvenConfig.backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"SvenConfig.precision must be one of "
                             f"{PRECISIONS}, got {self.precision!r}")


def _pick_mode(n: int, p: int, cfg: SvenConfig) -> str:
    if cfg.mode != "auto":
        return cfg.mode
    return "primal" if 2 * p > n else "dual"


def resolve_backend(config: SvenConfig, *arrays) -> SvenConfig:
    """Pin the kernel backend enum into the (static, jit-keyed) config.

    Resolution happens BEFORE tracing, against the devices the concrete
    input arrays are committed to (`kernels.registry.resolve_kernel_backend`
    — never the process default backend at trace time, DESIGN.md §9.3), so
    the compiled executable matches where the data actually lives; two
    placements that need different kernel bodies get different jit keys.

    The deprecated spellings fold in here: backend "pallas" is an alias of
    "auto", and an explicit `interpret=` flag is pushed into the backend
    value ("<body>_interpret") and then normalized to None — so e.g.
    `SvenConfig(backend="pallas")` and `SvenConfig(backend="pallas",
    interpret=True)` resolve to the SAME config (same jit key) on CPU. A
    no-op (same object) for the "xla" backend and for already-resolved
    configs, which `api.resolve_path_config` relies on.
    """
    if config.backend == "xla":
        if config.interpret is None:
            return config
        return dataclasses.replace(config, interpret=None)
    from repro.kernels import registry

    resolved = registry.resolve_kernel_backend(
        None if config.backend in ("auto", "pallas") else config.backend,
        *arrays)
    if config.interpret is not None and resolved != "ref":
        body, _ = registry.split_backend(resolved)
        resolved = body + ("_interpret" if config.interpret else "")
    if resolved == config.backend and config.interpret is None:
        return config
    return dataclasses.replace(config, backend=resolved, interpret=None)


def _sven_core(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array,
    lambda2: jax.Array,
    warm_alpha: Optional[jax.Array],
    warm_w: Optional[jax.Array],
    config: SvenConfig,
    keep: Optional[jax.Array] = None,
) -> SvenArrays:
    """Pure traced core: t/lambda2/warm starts are operands, config is static.

    `keep` is an optional (p,) screening mask (e.g. from `gap_safe_screen`):
    masked columns are zeroed — a fixed-shape form of feature screening that
    survives jit/scan/vmap — and the returned beta is scattered back to exact
    zeros on the discarded coordinates. Because a zero column provably carries
    beta_j = 0 through the reduction (see serve/engine.py padding argument),
    a *safe* mask leaves the solution unchanged.
    """
    n, p = X.shape
    dtype = X.dtype
    t = jnp.asarray(t, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)
    X_full = X    # KKT diagnostics stay on the ORIGINAL problem: an unsafe
    keepf = None  # mask must show up as a large kkt, not pass trivially
    if keep is not None:
        keepf = keep.astype(dtype)
        X = X * keepf[None, :]
        if warm_alpha is not None:
            # symmetrize masked duplicate pairs so dual warm starts can't
            # leave stale asymmetric mass on screened-out samples
            warm_alpha = warm_alpha * jnp.concatenate([keepf, keepf])
    C = red.svm_C(lambda2, floor=config.lambda2_floor).astype(dtype)
    mode = _pick_mode(n, p, config)
    op = red.SvenOperator(X=X, y=y, t=t)

    if mode == "primal":
        if config.matrix_free:
            matvec, rmatvec = op.xhat_matvec, op.xhat_rmatvec
        else:
            Xhat, _ = red.build_svm_dataset(X, y, t)
            matvec = lambda w: Xhat @ w
            rmatvec = lambda v: Xhat.T @ v
        yhat = jnp.concatenate([jnp.ones((p,), dtype), -jnp.ones((p,), dtype)])
        hess_matvec = None
        if config.backend != "xla":
            from repro.kernels.ops import hinge_hessian_matvec

            def hess_matvec(v, act, C_traced):  # noqa: F811 — Pallas fused H v
                hv = hinge_hessian_matvec(
                    X.astype(jnp.float32), y.astype(jnp.float32),
                    jnp.asarray(t, jnp.float32), jnp.asarray(C_traced, jnp.float32),
                    act[:p].astype(jnp.float32), act[p:].astype(jnp.float32),
                    v.astype(jnp.float32), backend=config.backend,
                    precision=config.precision)
                return hv.astype(dtype)

        res = solve_primal_newton(
            matvec, rmatvec, yhat, C, n,
            tol=config.tol, max_newton=config.max_newton, cg_iters=config.cg_iters,
            w0=warm_w, hess_matvec=hess_matvec,
        )
        alpha = C * jnp.maximum(1.0 - yhat * matvec(res.w), 0.0)  # Alg.1 line 7
        beta = red.recover_beta(alpha, t)
        if keepf is not None:
            beta = beta * keepf
        return SvenArrays(beta=beta, alpha=alpha, w=res.w, iters=res.iters,
                          opt_residual=res.grad_norm,
                          kkt=en.kkt_violation(X_full, y, beta, lambda2))

    # --- dual ---
    m = 2 * p
    cache = config.cache_kernel
    if cache == "auto":
        cache = "blocks" if m <= config.kernel_cache_max_m else "never"
    refine = False
    if cache == "blocks":
        if config.backend != "xla":
            from repro.kernels.ops import shifted_gram
            K = shifted_gram(X.astype(jnp.float32), y.astype(jnp.float32),
                             jnp.asarray(t, jnp.float32),
                             backend=config.backend,
                             precision=config.precision).astype(dtype)
            refine = config.precision != "f32"
        elif config.matrix_free:
            K = red.gram_blocks(X, y, t)
        else:
            K = red.gram_reference(X, y, t)
        kernel_matvec = lambda v: K @ v
    else:
        kernel_matvec = op.kernel_matvec

    solver = solve_dual_newton if config.solver == "newton" else solve_dual_fista
    res = solver(kernel_matvec, m, C, dtype=dtype, tol=config.tol, alpha0=warm_alpha)
    if refine:
        # one step of iterative refinement (DESIGN.md §10.3): the bf16/tf32
        # kernel bought the O(np^2) Gram pass cheap; re-solving MATRIX-FREE
        # at full input precision, warm-started from the low-precision
        # alpha, re-evaluates every Newton residual against exact Gram
        # statistics at O(np) per iteration and converges in a handful of
        # steps — restoring <= 1e-10 parity with the full-precision solve.
        res = solver(op.kernel_matvec, m, C, dtype=dtype, tol=config.tol,
                     alpha0=res.alpha)
    beta = red.recover_beta(res.alpha, t)
    if keepf is not None:
        beta = beta * keepf
    # w = Zhat @ alpha: the primal iterate this dual solution induces — carried
    # so a following primal-mode solve (or the scan) can warm-start from it.
    w = op.zhat_matvec(res.alpha)
    return SvenArrays(beta=beta, alpha=res.alpha, w=w, iters=res.iters,
                      opt_residual=res.pg_norm,
                      kkt=en.kkt_violation(X_full, y, beta, lambda2))


@partial(jax.jit, static_argnames=("config",))
def _sven_jit(X, y, t, lambda2, warm_alpha, warm_w, keep, config: SvenConfig) -> SvenArrays:
    _bump_trace("sven")
    return _sven_core(X, y, t, lambda2, warm_alpha, warm_w, config, keep)


def sven(
    X: jax.Array,
    y: jax.Array,
    t,
    lambda2,
    config: SvenConfig = SvenConfig(),
    *,
    warm_alpha: Optional[jax.Array] = None,
    warm_w: Optional[jax.Array] = None,
    keep: Optional[jax.Array] = None,
) -> SvenSolution:
    """Solve the Elastic Net (paper eq. 1) via the SVM reduction.

    `t` and `lambda2` are jit operands: repeated calls at new regularization
    settings on the same-shape problem reuse one compiled executable
    (assertable via `trace_counts()["sven"]`).

    `keep` is an optional (p,) safe screening mask (see `core/screening.py`
    and the penalized front-end in `core/api.py`): screened-out columns are
    zeroed and their coefficients scattered back as exact zeros, without
    changing the compiled shape.
    """
    config = resolve_backend(config, X, y)
    arrs = _sven_jit(X, y, jnp.asarray(t, X.dtype), jnp.asarray(lambda2, X.dtype),
                     warm_alpha, warm_w, keep, config)
    mode = _pick_mode(X.shape[0], X.shape[1], config)
    return SvenSolution(beta=arrs.beta, alpha=arrs.alpha, mode=mode,
                        iters=arrs.iters, opt_residual=arrs.opt_residual,
                        kkt=arrs.kkt, w=arrs.w)


@partial(jax.jit, static_argnames=("config",))
def _sven_path_scan(X, y, ts, lambda2, config: SvenConfig) -> jax.Array:
    _bump_trace("sven_path_scan")
    n, p = X.shape
    dtype = X.dtype

    def body(carry, t):
        warm_a, warm_w = carry
        arrs = _sven_core(X, y, t, lambda2, warm_a, warm_w, config)
        return (arrs.alpha, arrs.w), arrs.beta

    carry0 = (jnp.zeros((2 * p,), dtype), jnp.zeros((n,), dtype))
    _, betas = jax.lax.scan(body, carry0, ts)
    return betas


def sven_path(
    X: jax.Array,
    y: jax.Array,
    ts,
    lambda2,
    config: SvenConfig = SvenConfig(),
) -> jax.Array:
    """Regularization path over a grid of L1 budgets (Fig. 1), scan-compiled.

    One `lax.scan` over the t-grid: the whole path is a single trace / single
    executable (per grid *length*, not per grid *values*), and both warm
    starts — the dual alpha and the primal w — are genuinely carried from
    point to point. Warm-starting across the grid is a beyond-paper
    optimization (the paper solves each (t, lambda2) cold); it typically cuts
    total Newton iterations 2-4x along a 40-point path, and the scan removes
    the per-point dispatch/retrace cost on top.

    `sven_path_reference` is the host-side loop with identical warm-start
    semantics; the two are tested equal to 1e-6.
    """
    ts = jnp.asarray(ts, X.dtype)
    config = resolve_backend(config, X, y)
    return _sven_path_scan(X, y, ts, jnp.asarray(lambda2, X.dtype), config)


def sven_path_reference(
    X: jax.Array,
    y: jax.Array,
    ts,
    lambda2,
    config: SvenConfig = SvenConfig(),
) -> jax.Array:
    """Reference Python-loop path, warm-started like the scan (alpha AND w)."""
    betas = []
    warm_a, warm_w = None, None
    for t in list(ts):
        sol = sven(X, y, float(t), lambda2, config, warm_alpha=warm_a, warm_w=warm_w)
        betas.append(sol.beta)
        warm_a, warm_w = sol.alpha, sol.w
    return jnp.stack(betas)
