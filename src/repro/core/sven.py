"""SVEN driver — the paper's Algorithm 1 as a composable JAX module.

Dispatch (paper §3, "Implementation details"):
    2p > n  -> primal solver over w in R^n   (cost driven by n)
    else    -> dual solver over alpha in R^{2p}, kernel cached when it fits

`matrix_free=True` (default) uses the SvenOperator O(np) products and never
materializes the (2p, n) constructed dataset — the TPU-native path.
`matrix_free=False` is the paper-faithful baseline (explicit Xnew, as the
MATLAB listing does). Both return identical solutions (tested).

The returned diagnostics make the solve auditable at scale: iteration counts,
final KKT residuals of the *original* Elastic Net problem, and the objective.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import elastic_net as en
from repro.core import reduction as red
from repro.core.svm import solve_dual_fista, solve_dual_newton, solve_primal_newton


class SvenSolution(NamedTuple):
    beta: jax.Array
    alpha: jax.Array
    mode: str                 # "primal" | "dual"
    iters: jax.Array
    opt_residual: jax.Array   # solver's own optimality measure
    kkt: jax.Array            # Elastic Net KKT violation at beta


@dataclasses.dataclass(frozen=True)
class SvenConfig:
    mode: str = "auto"            # "auto" | "primal" | "dual"
    matrix_free: bool = True      # SvenOperator vs explicit Xnew
    cache_kernel: str = "auto"    # "auto" | "blocks" | "never" (dual only)
    solver: str = "newton"        # "newton" | "fista" (dual only)
    backend: str = "xla"          # "xla" | "pallas" (TPU-tiled hot ops)
    tol: float = 1e-8
    max_newton: int = 60
    cg_iters: int = 300
    kernel_cache_max_m: int = 8192   # cache K when 2p <= this
    lambda2_floor: float = 1e-12     # Lasso limit: C capped at 1/(2*floor)


def _pick_mode(n: int, p: int, cfg: SvenConfig) -> str:
    if cfg.mode != "auto":
        return cfg.mode
    return "primal" if 2 * p > n else "dual"


def sven(
    X: jax.Array,
    y: jax.Array,
    t: float,
    lambda2: float,
    config: SvenConfig = SvenConfig(),
    *,
    warm_alpha: Optional[jax.Array] = None,
    warm_w: Optional[jax.Array] = None,
) -> SvenSolution:
    """Solve the Elastic Net (paper eq. 1) via the SVM reduction."""
    n, p = X.shape
    dtype = X.dtype
    C = 1.0 / (2.0 * max(lambda2, config.lambda2_floor))
    mode = _pick_mode(n, p, config)
    op = red.SvenOperator(X=X, y=y, t=t)

    if mode == "primal":
        if config.matrix_free:
            matvec, rmatvec = op.xhat_matvec, op.xhat_rmatvec
        else:
            Xhat, _ = red.build_svm_dataset(X, y, t)
            matvec = lambda w: Xhat @ w
            rmatvec = lambda v: Xhat.T @ v
        yhat = jnp.concatenate([jnp.ones((p,), dtype), -jnp.ones((p,), dtype)])
        hess_matvec = None
        if config.backend == "pallas":
            from repro.kernels.ops import hinge_hessian_matvec

            def hess_matvec(v, act):  # noqa: F811 — Pallas fused H v
                hv = hinge_hessian_matvec(
                    X.astype(jnp.float32), y.astype(jnp.float32),
                    jnp.float32(t), jnp.float32(C),
                    act[:p].astype(jnp.float32), act[p:].astype(jnp.float32),
                    v.astype(jnp.float32))
                return hv.astype(dtype)

        res = solve_primal_newton(
            matvec, rmatvec, yhat, C, n,
            tol=config.tol, max_newton=config.max_newton, cg_iters=config.cg_iters,
            w0=warm_w, hess_matvec=hess_matvec,
        )
        alpha = C * jnp.maximum(1.0 - yhat * matvec(res.w), 0.0)  # Alg.1 line 7
        beta = red.recover_beta(alpha, t)
        return SvenSolution(beta=beta, alpha=alpha, mode="primal", iters=res.iters,
                            opt_residual=res.grad_norm,
                            kkt=en.kkt_violation(X, y, beta, lambda2))

    # --- dual ---
    m = 2 * p
    cache = config.cache_kernel
    if cache == "auto":
        cache = "blocks" if m <= config.kernel_cache_max_m else "never"
    if cache == "blocks":
        if config.backend == "pallas":
            from repro.kernels.ops import shifted_gram
            K = shifted_gram(X.astype(jnp.float32), y.astype(jnp.float32),
                             jnp.float32(t)).astype(dtype)
        elif config.matrix_free:
            K = red.gram_blocks(X, y, t)
        else:
            K = red.gram_reference(X, y, t)
        kernel_matvec = lambda v: K @ v
    else:
        kernel_matvec = op.kernel_matvec

    solver = solve_dual_newton if config.solver == "newton" else solve_dual_fista
    res = solver(kernel_matvec, m, C, dtype=dtype, tol=config.tol, alpha0=warm_alpha)
    beta = red.recover_beta(res.alpha, t)
    return SvenSolution(beta=beta, alpha=res.alpha, mode="dual", iters=res.iters,
                        opt_residual=res.pg_norm,
                        kkt=en.kkt_violation(X, y, beta, lambda2))


def sven_path(
    X: jax.Array,
    y: jax.Array,
    ts: jax.Array,
    lambda2: float,
    config: SvenConfig = SvenConfig(),
) -> jax.Array:
    """Regularization path over an increasing grid of L1 budgets (Fig. 1).

    Warm-starts alpha (dual) / w (primal) across the grid — a beyond-paper
    optimization (the paper solves each (t, lambda2) cold); typically cuts
    total Newton iterations 2-4x along a 40-point path.
    """
    betas = []
    warm_a, warm_w = None, None
    for t in list(ts):
        sol = sven(X, y, float(t), lambda2, config, warm_alpha=warm_a, warm_w=warm_w)
        betas.append(sol.beta)
        if sol.mode == "dual":
            warm_a = sol.alpha
        # primal warm start: w is t-dependent through the data; alpha-based
        # restarts are still effective since SV sets evolve slowly along the path.
    return jnp.stack(betas)
