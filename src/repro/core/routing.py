"""Adaptive execution routing: which device layout should run this solve?

PR 5 made the row-sharded `sven_sharded` path available everywhere a mesh
was in scope — and BENCH_path.json promptly recorded the cost of using it
unconditionally: a lone (768, 48) solve ran 10x SLOWER sharded than on one
device (`dist_solve.solve_speedup = 0.10`), because every collective pays
mesh latency and the replicated Newton solve competes with its own shards
for the simulated host devices' shared cores. The paper's claim is "as fast
as the hardware allows" (Zhou et al., AAAI 2015); GPU-SVM practice (Rgtsvm)
shows that only holds when the problem SHAPE picks the execution strategy.

This module is that picker. It routes every solve to one of three layouts:

    "single"   one device, the jit-native `sven` executable;
    "sharded"  rows of X/Zhat sharded over the mesh (`sven_sharded`,
               DESIGN.md §9.1) — wins when per-device GEMM savings beat
               collective latency + the replicated-solver tax;
    "batch"    batch-axis fan-out (`shard_map_lanes`, DESIGN.md §9.2) —
               each device vmaps its own lanes with zero collectives; wins
               whenever the per-device lane compute amortizes dispatch.

Decisions come from a COST MODEL, not hardcoded thresholds: a one-time
calibration microbenchmark (`calibrate`) measures, on the actual mesh,

    flops_per_s          single-device dense GEMM throughput,
    psum_latency_s       wall time of a small all-reduce (the per-collective
                         floor every sharded iteration pays),
    psum_per_byte_s      marginal cost per reduced byte (interconnect BW),
    fanout_speedup       measured speedup of shard_map'ing N independent
                         GEMMs vs one device doing all N (captures how much
                         of the mesh is REAL parallel hardware — simulated
                         host devices on shared cores score ~1, separate
                         chips score ~N),
    replicated_slowdown  the same GEMM run replicated on every device vs on
                         one (the oversubscription tax the sharded path's
                         replicated Newton solve pays on host-sim meshes),

    kernel_backend /     the RESOLVED kernel backend (kernels/registry.py)
    gram_flops_per_s     serving the dual Gram pass, and its measured
                         throughput — the data-pass term prices the real
                         kernel, not an assumed XLA GEMM,

and the router prices each layout's FLOPs + collectives with those numbers.
Calibration is cached per (backend, device-count) in-process AND persisted
to `<utils.cache_dir()>/calibration.json` keyed (platform, device count,
jax version), so repeat processes skip the microbenchmark entirely — the
knob: `calibrate(mesh, force=True)` re-measures (and overwrites the disk
entry), `clear_calibration()` drops the in-process caches (both exported;
see README "Multi-device").

Escape hatch: every routed entry point takes `route=` ("auto" | a pinned
path name) — `route="sharded"` forces the row-sharded layout regardless of
the model, which is also what the parity tests and benchmarks use to keep
exercising every path.
"""
from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# -- effective iteration counts for pricing a solve ------------------------
#
# The model prices RELATIVE layout costs, not absolute runtimes, so these
# only need to be the right order of magnitude (typical counts observed on
# the paper-scaled problems; tol=1e-8 Newton converges in ~10 outer steps).
DUAL_NEWTON_ITERS = 12      # projected-Newton outer steps (dual mode)
DUAL_CG_ITERS = 25          # masked-CG steps per outer step
PRIMAL_NEWTON_ITERS = 10    # Newton-CG outer steps (primal mode)
PRIMAL_CG_ITERS = 30        # CG steps per outer step
PENALIZED_EVALS = 8         # Illinois root-find SVEN evals per enet point

#: Fixed host-side overhead of launching any multi-device executable
#: (shard_map dispatch, sharded donation/placement) — keeps the router off
#: the mesh for solves too small for the timings above to even register.
MULTI_DEVICE_DISPATCH_S = 2e-4


class Calibration(NamedTuple):
    """Measured machine numbers the cost model prices layouts with."""

    devices: int
    backend: str
    flops_per_s: float
    psum_latency_s: float
    psum_per_byte_s: float
    fanout_speedup: float
    replicated_slowdown: float
    # the RESOLVED kernel backend the dual Gram pass will actually run on
    # (kernels/registry.py) and its measured throughput — the data-pass
    # term of the dual cost must price the real kernel, not assume an XLA
    # GEMM. On interpret/ref backends the kernel rate falls back to the
    # GEMM rate (interpret timings are pathological and the ref body IS an
    # XLA GEMM).
    kernel_backend: str = "ref"
    gram_flops_per_s: float = 0.0


class RouteDecision(NamedTuple):
    """One routing verdict: the chosen path and the model's price list."""

    path: str                 # "single" | "sharded" | "batch"
    costs: dict               # {path: predicted seconds} for every candidate
    calibration: Calibration
    reason: str


#: calibration cache, keyed on (backend, device_count) — mesh OBJECTS come
#: and go (tests build fresh ones constantly) but the hardware they name
#: does not, so the microbenchmark runs once per distinct device set.
_CALIBRATIONS: dict = {}
#: decision cache: routing must cost microseconds on the serving hot path,
#: so verdicts key on the (shape, mesh-size, backend) tuple that determined
#: them. Cleared with the calibrations.
_DECISIONS: dict = {}

_SINGLE_DEVICE = Calibration(devices=1, backend="any", flops_per_s=1e9,
                             psum_latency_s=0.0, psum_per_byte_s=0.0,
                             fanout_speedup=1.0, replicated_slowdown=1.0)


def clear_calibration() -> None:
    """Drop all in-process calibrations AND routing decisions (re-read the
    disk cache / re-measure next use) — the test/bench hook. To also force
    fresh MEASUREMENTS across processes, call `calibrate(mesh, force=True)`
    (which overwrites the disk entry) or delete
    `<utils.cache_dir()>/calibration.json`."""
    _CALIBRATIONS.clear()
    _DECISIONS.clear()


def _disk_key(backend: str, ndev: int) -> str:
    import jax as _jax
    return f"{backend}|{ndev}dev|jax{_jax.__version__}"


def _load_disk_calibration(backend: str, ndev: int):
    from repro import utils

    entry = utils.disk_cache_load("calibration").get(_disk_key(backend, ndev))
    if not isinstance(entry, dict) or set(entry) != set(Calibration._fields):
        return None
    try:
        return Calibration(**entry)
    except TypeError:
        return None


def _store_disk_calibration(cal: Calibration) -> None:
    from repro import utils

    utils.disk_cache_update(
        "calibration", {_disk_key(cal.backend, cal.devices): cal._asdict()})


def _gram_kernel_rate(flops_per_s: float) -> tuple[str, float]:
    """(resolved kernel backend, measured Gram-pass FLOPs/s) for this
    process's default platform. Compiled backends get a real measurement of
    `kernels.shifted_gram`; interpret/ref backends keep the GEMM rate."""
    from repro.kernels import ops as kops
    from repro.kernels import registry

    kb = registry.resolve_kernel_backend(None)
    body, interpret = registry.split_backend(kb)
    if interpret or body == "ref":
        return kb, flops_per_s
    n, p = 2048, 256
    X = jnp.ones((n, p), jnp.float32)
    y = jnp.ones((n,), jnp.float32)
    try:
        t = _best_of(lambda: kops.shifted_gram(X, y, 1.0, backend=kb))
    except Exception:  # noqa: BLE001 — no functional kernel: price as GEMM
        return kb, flops_per_s
    return kb, (2.0 * n * p * p) / max(t, 1e-9)


def _best_of(fn, reps: int = 3) -> float:
    jax.block_until_ready(fn())                  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate(mesh: Optional[Mesh], *, force: bool = False) -> Calibration:
    """Measure the mesh once; cached per (backend, device count).

    `mesh=None` or a 1-device mesh is the trivial calibration: no
    collectives exist, so only GEMM throughput is measured. The
    microbenchmark uses small fixed shapes (~1 MFLOP GEMMs, ~100 KB
    reductions) — enough to resolve latency-vs-bandwidth without the
    calibration itself costing more than the solves it routes.
    """
    from jax.experimental.shard_map import shard_map

    ndev = mesh.size if mesh is not None else 1
    backend = jax.default_backend()
    key = (backend, ndev)
    if not force and key in _CALIBRATIONS:
        return _CALIBRATIONS[key]
    if not force:
        # the repeat-process fast path: a prior run on this (platform,
        # device count, jax version) already paid for the microbenchmark —
        # BENCH showed the calibration overhead alone dragging routed
        # solves to 0.93x on the bit-identical "single" path.
        cal = _load_disk_calibration(backend, ndev)
        if cal is not None:
            _CALIBRATIONS[key] = cal
            return cal

    m = 192                                       # GEMM probe: 2*m^3 FLOPs
    A = jnp.ones((m, m), jnp.float32)
    gemm = jax.jit(lambda a: a @ a)
    t_gemm = _best_of(lambda: gemm(A))
    flops_per_s = (2.0 * m ** 3) / max(t_gemm, 1e-9)
    kernel_backend, gram_flops_per_s = _gram_kernel_rate(flops_per_s)

    if ndev <= 1:
        cal = Calibration(devices=ndev, backend=backend,
                          flops_per_s=flops_per_s, psum_latency_s=0.0,
                          psum_per_byte_s=0.0, fanout_speedup=1.0,
                          replicated_slowdown=1.0,
                          kernel_backend=kernel_backend,
                          gram_flops_per_s=gram_flops_per_s)
        _CALIBRATIONS[key] = cal
        _store_disk_calibration(cal)
        return cal

    axes = tuple(mesh.axis_names)

    def _psum_bench(rows: int):
        x = jax.device_put(jnp.ones((ndev, rows), jnp.float32),
                           NamedSharding(mesh, P(axes, None)))
        f = jax.jit(shard_map(lambda v: jax.lax.psum(v, axes), mesh=mesh,
                              in_specs=P(axes, None), out_specs=P(),
                              check_rep=False))
        return _best_of(lambda: f(x))

    t_small = _psum_bench(16)                     # latency-bound
    t_big = _psum_bench(32768)                    # bandwidth-bound (128 KB)
    psum_latency_s = t_small
    psum_per_byte_s = max(t_big - t_small, 0.0) / (32768 * 4)

    # fan-out probe: ndev independent GEMMs, shard_map'd one per device,
    # against a single device grinding through all of them as one batched
    # GEMM. On real parallel hardware this approaches ndev; on simulated
    # host devices sharing the same cores it hovers near 1 (or below).
    Ab = jnp.ones((ndev, m, m), jnp.float32)
    batched = jax.jit(lambda a: jnp.einsum("bij,bjk->bik", a, a))
    t_seq = _best_of(lambda: batched(Ab))
    Abs_ = jax.device_put(Ab, NamedSharding(mesh, P(axes, None, None)))
    fan = jax.jit(shard_map(lambda a: jnp.einsum("bij,bjk->bik", a, a),
                            mesh=mesh, in_specs=P(axes, None, None),
                            out_specs=P(axes, None, None), check_rep=False))
    t_fan = _best_of(lambda: fan(Abs_))
    fanout_speedup = max(t_seq / max(t_fan, 1e-9), 1e-3)

    # replication probe: the SAME GEMM executed by every device at once vs
    # by one — prices the sharded path's replicated Newton solve, which on
    # an oversubscribed host-sim mesh is several times slower than it looks.
    rep = jax.jit(shard_map(lambda a: a @ a, mesh=mesh, in_specs=P(),
                            out_specs=P(), check_rep=False))
    t_rep = _best_of(lambda: rep(A))
    replicated_slowdown = max(t_rep / max(t_gemm, 1e-9), 1.0)

    cal = Calibration(devices=ndev, backend=backend, flops_per_s=flops_per_s,
                      psum_latency_s=psum_latency_s,
                      psum_per_byte_s=psum_per_byte_s,
                      fanout_speedup=fanout_speedup,
                      replicated_slowdown=replicated_slowdown,
                      kernel_backend=kernel_backend,
                      gram_flops_per_s=gram_flops_per_s)
    _CALIBRATIONS[key] = cal
    _store_disk_calibration(cal)
    _DECISIONS.clear()
    return cal


# -- the cost model ---------------------------------------------------------

def _psum_cost(cal: Calibration, floats: float) -> float:
    return cal.psum_latency_s + floats * 8.0 * cal.psum_per_byte_s


def _solve_flops(n: int, p: int, mode: str) -> tuple:
    """(data-pass FLOPs over X, solver-iteration FLOPs) for one SVEN solve.

    dual: one Gram pass 2np^2 then Newton on the (2p, 2p) kernel — each
    outer step's masked CG does a K matvec, 2(2p)^2 FLOPs. primal: every
    Newton-CG product is a matvec + rmatvec pair over X, ~8np each.
    """
    if mode == "dual":
        data = 2.0 * n * p * p
        iters = DUAL_NEWTON_ITERS * (DUAL_CG_ITERS + 3) * 2.0 * (2 * p) ** 2
    else:
        data = 0.0
        iters = (PRIMAL_NEWTON_ITERS * (PRIMAL_CG_ITERS + 3)) * 8.0 * n * p
    return data, iters


def _solve_costs(n: int, p: int, mode: str, cal: Calibration) -> dict:
    """Predicted seconds for one solve under each layout."""
    F = cal.flops_per_s
    # the dual data pass runs on the RESOLVED kernel backend (Pallas Gram
    # on tpu/gpu, XLA GEMM otherwise) — price it at that kernel's measured
    # rate, not the generic GEMM rate
    G = cal.gram_flops_per_s or F
    data, iters = _solve_flops(n, p, mode)
    costs = {"single": data / G + iters / F}
    if cal.devices > 1:
        if mode == "dual":
            # data pass shards perfectly (one psum of G/u/s closes it); the
            # projected Newton runs REPLICATED on the assembled kernel, so
            # it pays the replication tax, not a 1/ndev discount.
            sharded = (data / (G * cal.fanout_speedup * cal.devices)
                       + _psum_cost(cal, p * p + p + 1)
                       + iters * cal.replicated_slowdown / F
                       + 2.0 * cal.psum_latency_s      # w recovery + kkt
                       + MULTI_DEVICE_DISPATCH_S)
        else:
            # every Newton-CG product: local O(np/ndev) work + one
            # psum(p + 1) + one all-gather of the n-vector.
            products = PRIMAL_NEWTON_ITERS * (PRIMAL_CG_ITERS + 3)
            per_product = (8.0 * n * p
                           / (F * cal.fanout_speedup * cal.devices)
                           + _psum_cost(cal, p + 1)
                           + _psum_cost(cal, n))
            sharded = products * per_product + MULTI_DEVICE_DISPATCH_S
        costs["sharded"] = sharded
    return costs


def _batch_costs(n: int, p: int, B: int, mode: str, cal: Calibration,
                 points: int) -> dict:
    """Predicted seconds for a B-problem stack: vmap on one device vs
    batch-axis fan-out (each device vmaps B/ndev lanes, zero collectives)."""
    data, iters = _solve_flops(n, p, mode)
    lane = points * (data + iters) / cal.flops_per_s
    costs = {"single": B * lane}
    if cal.devices > 1:
        costs["batch"] = (B * lane / cal.fanout_speedup
                          + MULTI_DEVICE_DISPATCH_S)
    return costs


def _decide(costs: dict, cal: Calibration, pinned: Optional[str]) -> RouteDecision:
    if pinned is not None:
        decision = RouteDecision(path=pinned, costs=costs, calibration=cal,
                                 reason=f"pinned route={pinned!r}")
    else:
        path = min(costs, key=costs.get)
        others = {k: v for k, v in costs.items() if k != path}
        margin = (min(others.values()) / max(costs[path], 1e-12)
                  if others else float("inf"))
        decision = RouteDecision(path=path, costs=costs, calibration=cal,
                                 reason=f"cost model: {path} wins {margin:.2f}x")
    # telemetry (DESIGN.md §12): each FRESH verdict (cached ones replay the
    # same decision) counts on the process registry and drops a trace
    # instant carrying the full price table the model compared.
    from repro.obs.metrics import default_registry
    from repro.obs.trace import get_tracer

    default_registry().counter(
        "route_decisions_total", "cost-model routing verdicts",
        ("path",)).inc(path=decision.path)
    get_tracer().instant("route", path=decision.path, costs=dict(costs),
                         reason=decision.reason)
    return decision


def _resolve_route_mesh(mesh):
    """None -> innermost dist context, else the process data mesh (matches
    `sven_sharded`'s resolution so routed and pinned calls agree)."""
    from repro import dist

    if mesh is None:
        ctx = dist.current_context()
        mesh = ctx[0] if ctx is not None else dist.data_mesh()
    return mesh


def route_solve(n: int, p: int, *, mesh: Optional[Mesh] = None,
                config=None, route: str = "auto") -> RouteDecision:
    """Price one (n, p) solve on `mesh` and pick single-device vs sharded.

    `route` pins the verdict ("single" / "sharded") while still reporting
    the model's prices — the escape hatch and the introspection hook.
    """
    if route not in ("auto", "single", "sharded"):
        raise ValueError(f"route_solve: route must be auto|single|sharded, "
                         f"got {route!r}")
    from repro.core.sven import SvenConfig, _pick_mode

    cfg = SvenConfig() if config is None else config
    mesh = _resolve_route_mesh(mesh)
    ndev = mesh.size if mesh is not None else 1
    mode = _pick_mode(n, p, cfg)
    if ndev <= 1:
        return RouteDecision(path="single",
                             costs={"single": 0.0},
                             calibration=_SINGLE_DEVICE,
                             reason="one device: nothing to route")
    cal = calibrate(mesh)
    key = ("solve", n, p, ndev, cal.backend, mode, route)
    if key not in _DECISIONS:
        _DECISIONS[key] = _decide(_solve_costs(n, p, mode, cal), cal,
                                  None if route == "auto" else route)
    return _DECISIONS[key]


def route_batch(n: int, p: int, batch_size: int, mesh: Optional[Mesh] = None,
                *, form: str = "constrained", points: int = 1,
                route: str = "auto") -> RouteDecision:
    """Price a stacked B-problem launch: single-device vmap vs batch-axis
    fan-out. `form="penalized"` scales each lane by the Illinois root-find's
    solve count; `points` further scales per-lane work (CV/path scans run
    `points` grid points per lane). Divisibility of B by the mesh is the
    CALLER's concern (`batch.batch_mesh` checks it) — the router prices
    layouts, it does not validate placements.
    """
    if route not in ("auto", "single", "batch"):
        raise ValueError(f"route_batch: route must be auto|single|batch, "
                         f"got {route!r}")
    from repro.core.sven import SvenConfig, _pick_mode

    mesh = _resolve_route_mesh(mesh)
    ndev = mesh.size if mesh is not None else 1
    mode = _pick_mode(n, p, SvenConfig())
    if ndev <= 1:
        return RouteDecision(path="single", costs={"single": 0.0},
                             calibration=_SINGLE_DEVICE,
                             reason="one device: nothing to route")
    cal = calibrate(mesh)
    pts = points * (PENALIZED_EVALS if form == "penalized" else 1)
    key = ("batch", n, p, batch_size, pts, ndev, cal.backend, mode, route)
    if key not in _DECISIONS:
        _DECISIONS[key] = _decide(_batch_costs(n, p, batch_size, mode, cal,
                                               pts), cal,
                                  None if route == "auto" else route)
    return _DECISIONS[key]


def estimate_batch_seconds(n: int, p: int, batch_size: int, *,
                           form: str = "constrained") -> float:
    """Modeled single-host seconds for a stacked B-problem (n, p) solve.

    The multi-host coordinator's placement signal: it needs RELATIVE prices
    (a (256, 128) x 8 batch must cost more than a (32, 16) x 2 one), not
    wall-clock accuracy, and it must never trigger a calibration
    microbenchmark on the admission path. So this prices the "single"
    layout with whatever calibration is already known — the in-process
    cache, then the disk cache, then the shape-only default — and never
    measures.
    """
    backend = jax.default_backend()
    cal = (_CALIBRATIONS.get((backend, 1))
           or _load_disk_calibration(backend, 1) or _SINGLE_DEVICE)
    from repro.core.sven import SvenConfig, _pick_mode

    mode = _pick_mode(n, p, SvenConfig())
    pts = PENALIZED_EVALS if form == "penalized" else 1
    return _batch_costs(n, p, batch_size, mode, cal, pts)["single"]


def sven_routed(X, y, t, lambda2, config=None, *, mesh: Optional[Mesh] = None,
                route: str = "auto", warm_alpha=None, warm_w=None):
    """`sven` with automatic layout choice — THE multi-device entry point.

    Routes through the cost model to single-device `sven` or row-sharded
    `sven_sharded` (results match to <= 1e-10 either way, tested);
    `route="single"`/`route="sharded"` pins the path. Mesh resolution
    matches `sven_sharded`: explicit mesh, else the innermost
    `dist.mesh_context`, else the process data mesh.
    """
    from repro.core.distributed import sven_sharded
    from repro.core.sven import SvenConfig, sven

    cfg = SvenConfig() if config is None else config
    # shape only — array conversion is the chosen entry point's job, and
    # an eager asarray here would tax every routed call
    n, p = jnp.shape(X)
    mesh = _resolve_route_mesh(mesh)
    decision = route_solve(n, p, mesh=mesh, config=cfg, route=route)
    if decision.path == "single":
        return sven(X, y, t, lambda2, cfg,
                    warm_alpha=warm_alpha, warm_w=warm_w)
    return sven_sharded(X, y, t, lambda2, cfg, mesh=mesh,
                        warm_alpha=warm_alpha, warm_w=warm_w)
