"""The paper's primary contribution: Elastic Net -> squared-hinge SVM (SVEN)."""
from repro.core.sven import sven, sven_path, SvenConfig, SvenSolution
from repro.core.reduction import (
    SvenOperator,
    build_svm_dataset,
    gram_blocks,
    gram_reference,
    recover_beta,
)
from repro.core import elastic_net
from repro.core.screening import gap_safe_screen, sven_with_screening

__all__ = [
    "sven",
    "sven_path",
    "SvenConfig",
    "SvenSolution",
    "SvenOperator",
    "build_svm_dataset",
    "gram_blocks",
    "gram_reference",
    "recover_beta",
    "elastic_net",
    "gap_safe_screen",
    "sven_with_screening",
]
