"""The paper's primary contribution: Elastic Net -> squared-hinge SVM (SVEN)."""
from repro.core.sven import (
    sven,
    sven_path,
    sven_path_reference,
    SvenConfig,
    SvenSolution,
    trace_counts,
    reset_trace_counts,
)
from repro.core.batch import SvenBatchSolution, cv_folds, en_grid, sven_batch
from repro.core.reduction import (
    SvenOperator,
    build_svm_dataset,
    gram_blocks,
    gram_reference,
    recover_beta,
    svm_C,
)
from repro.core import elastic_net
from repro.core.screening import gap_safe_screen, sven_with_screening

__all__ = [
    "sven",
    "sven_path",
    "sven_path_reference",
    "sven_batch",
    "SvenBatchSolution",
    "cv_folds",
    "en_grid",
    "SvenConfig",
    "SvenSolution",
    "trace_counts",
    "reset_trace_counts",
    "SvenOperator",
    "build_svm_dataset",
    "gram_blocks",
    "gram_reference",
    "recover_beta",
    "svm_C",
    "elastic_net",
    "gap_safe_screen",
    "sven_with_screening",
]
