"""The paper's primary contribution: Elastic Net -> squared-hinge SVM (SVEN).

Three tiers live here (DESIGN.md §6-§7):
  - constrained engine: `sven`/`sven_path`/`sven_batch` solve the paper's
    (t, lambda2) form, jit-native with optional gap-safe `keep` masks;
  - screening: `gap_safe_screen` + `sven_with_screening`;
  - glmnet-parity front-end: penalized (lambda1, lambda2) entry points
    (`enet`, `enet_path`, `lambda_grid`, scaling conversions), sklearn-style
    `ElasticNet`/`ElasticNetCV` estimators and batched `cross_validate`.
"""
from repro.core.sven import (
    sven,
    sven_path,
    sven_path_reference,
    SvenConfig,
    SvenSolution,
    trace_counts,
    reset_trace_counts,
)
from repro.core.batch import SvenBatchSolution, cv_folds, en_grid, sven_batch
from repro.core.reduction import (
    SvenOperator,
    build_svm_dataset,
    gram_blocks,
    gram_reference,
    recover_beta,
    svm_C,
)
from repro.core import elastic_net
from repro.core.distributed import (
    sharded_gram_stats,
    sharded_hinge_stats,
    sven_sharded,
)
from repro.core.routing import (
    Calibration,
    RouteDecision,
    calibrate,
    clear_calibration,
    route_batch,
    route_solve,
    sven_routed,
)
from repro.core.screening import gap_safe_screen, sven_with_screening
from repro.core.api import (
    ElasticNet,
    EnetPath,
    EnetResult,
    PathConfig,
    enet,
    enet_batch,
    enet_path,
    lambda_grid,
    penalized_from_glmnet,
    penalized_from_sklearn,
    penalized_to_glmnet,
    standardize_fit,
    unscale_coef,
)
from repro.core.cv import (
    CVResult,
    ElasticNetCV,
    cross_validate,
    cross_validate_reference,
)

__all__ = [
    "sven",
    "sven_path",
    "sven_path_reference",
    "sven_batch",
    "SvenBatchSolution",
    "cv_folds",
    "en_grid",
    "SvenConfig",
    "SvenSolution",
    "trace_counts",
    "reset_trace_counts",
    "SvenOperator",
    "build_svm_dataset",
    "gram_blocks",
    "gram_reference",
    "recover_beta",
    "svm_C",
    "elastic_net",
    "gap_safe_screen",
    "sven_with_screening",
    # data-parallel sharded solve path (core/distributed.py, DESIGN.md §9)
    "sven_sharded",
    "sharded_gram_stats",
    "sharded_hinge_stats",
    # adaptive layout routing (core/routing.py, DESIGN.md §9.5)
    "sven_routed",
    "route_solve",
    "route_batch",
    "calibrate",
    "clear_calibration",
    "Calibration",
    "RouteDecision",

    # glmnet-parity penalized front-end (core/api.py, core/cv.py)
    "ElasticNet",
    "ElasticNetCV",
    "EnetPath",
    "EnetResult",
    "PathConfig",
    "CVResult",
    "enet",
    "enet_batch",
    "enet_path",
    "lambda_grid",
    "penalized_from_glmnet",
    "penalized_from_sklearn",
    "penalized_to_glmnet",
    "standardize_fit",
    "unscale_coef",
    "cross_validate",
    "cross_validate_reference",
]
