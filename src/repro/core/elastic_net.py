"""Elastic Net problem specification, objectives and optimality diagnostics.

Conventions follow the paper (Zhou et al., AAAI 2015):

    constrained form:  min_beta ||X beta - y||_2^2 + lambda2 ||beta||_2^2
                       s.t. |beta|_1 <= t                                  (1)

    penalized form:    min_beta ||X beta - y||_2^2 + lambda2 ||beta||_2^2
                       + lambda1 |beta|_1                                  (pen)

with X in R^{n x p} (rows = samples), y in R^n. The two forms are equivalent:
if beta* solves (pen) with lambda1 > 0 then beta* solves (1) with
t = |beta*|_1 (the constraint is tight), and the KKT multiplier of (1)'s
L1 constraint equals lambda1. NOTE: no 1/2 or 1/n factors anywhere — this
matches the paper, not glmnet's internal scaling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ElasticNetProblem:
    """An Elastic Net instance in the paper's constrained form."""

    X: jax.Array  # (n, p) design matrix, rows = samples
    y: jax.Array  # (n,) centered response
    t: float      # L1 budget (> 0)
    lambda2: float  # L2 regularization (>= 0; 0 => Lasso)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]


def objective_constrained(X: jax.Array, y: jax.Array, beta: jax.Array, lambda2: float) -> jax.Array:
    """||X beta - y||^2 + lambda2 ||beta||^2 (the L1 part is a constraint)."""
    r = X @ beta - y
    return r @ r + lambda2 * (beta @ beta)


def objective_penalized(
    X: jax.Array, y: jax.Array, beta: jax.Array, lambda1: float, lambda2: float
) -> jax.Array:
    return objective_constrained(X, y, beta, lambda2) + lambda1 * jnp.sum(jnp.abs(beta))


def smooth_grad(X: jax.Array, y: jax.Array, beta: jax.Array, lambda2: float) -> jax.Array:
    """Gradient of the smooth part: 2 X^T (X beta - y) + 2 lambda2 beta."""
    return 2.0 * (X.T @ (X @ beta - y)) + 2.0 * lambda2 * beta


def kkt_multiplier(
    X: jax.Array, y: jax.Array, beta: jax.Array, lambda2: float, zero_tol: float = 1e-8
) -> jax.Array:
    """Estimate the L1-constraint multiplier nu >= 0 from active coordinates.

    At an optimum of (1) with a tight constraint there exists nu >= 0 with
        g_j = -nu * sign(beta_j)   for beta_j != 0
        |g_j| <= nu                for beta_j == 0
    where g = smooth_grad. We estimate nu as the mean of -g_j*sign(beta_j)
    over active coordinates (they should all agree).
    """
    g = smooth_grad(X, y, beta, lambda2)
    active = jnp.abs(beta) > zero_tol
    nu_each = -g * jnp.sign(beta)
    denom = jnp.maximum(jnp.sum(active), 1)
    return jnp.sum(jnp.where(active, nu_each, 0.0)) / denom


def kkt_violation_from_grad(
    g: jax.Array, beta: jax.Array, zero_tol: float = 1e-8
) -> jax.Array:
    """`kkt_violation` given a precomputed smooth gradient g at beta.

    The split exists for callers that never hold (X, y) explicitly: the
    online runtime keeps only the sufficient statistics (G = X^T X,
    X^T y), from which g = 2 (G beta - X^T y) + 2 lambda2 beta — so the
    same diagnostic applies to streamed data (runtime/online.py).
    """
    active = jnp.abs(beta) > zero_tol
    nu_each = -g * jnp.sign(beta)
    denom = jnp.maximum(jnp.sum(active), 1)
    nu = jnp.sum(jnp.where(active, nu_each, 0.0)) / denom
    act_res = jnp.where(active, jnp.abs(nu_each - nu), 0.0)
    inact_res = jnp.where(~active, jnp.maximum(jnp.abs(g) - nu, 0.0), 0.0)
    return jnp.maximum(jnp.max(act_res), jnp.max(inact_res)) / (1.0 + jnp.abs(nu))


def kkt_violation(
    X: jax.Array, y: jax.Array, beta: jax.Array, lambda2: float, zero_tol: float = 1e-8
) -> jax.Array:
    """Max KKT residual of (1) at beta (0 at an exact optimum).

    Checks (a) active coordinates agree on nu, (b) inactive coordinates
    satisfy |g_j| <= nu. Scale-free-ish: normalized by (1 + nu).
    """
    g = smooth_grad(X, y, beta, lambda2)
    return kkt_violation_from_grad(g, beta, zero_tol)


def lambda1_max(X: jax.Array, y: jax.Array) -> jax.Array:
    """Smallest lambda1 for which the penalized solution is beta = 0.

    From the (pen) KKT at 0: |2 x_j^T y| <= lambda1 for all j.
    """
    return 2.0 * jnp.max(jnp.abs(X.T @ y))
