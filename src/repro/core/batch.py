"""Batched multi-problem SVEN solves — vmap over the jit-native engine.

`sven_batch` stacks whole Elastic Net problems along a leading batch axis
and runs the same `_sven_core` trace for all of them at once (DESIGN.md §6).
Batching is where GPU/TPU SVM throughput actually comes from (cf. Rgtsvm,
Wang et al. 2017): one fat executable instead of B thin dispatches. The
three stacking patterns the serving layer needs all go through here:

    multi-response     X (n, p) shared,  y (B, n)
    (t, lambda2) grid  X, y shared,      t (B,), lambda2 (B,)   [en_grid]
    k-fold CV          X (B, n_tr, p), y (B, n_tr)              [cv_folds]

Any subset of {X, y, t, lambda2} may carry the batch axis; the rest
broadcast. Under an active `repro.dist.mesh_context` whose size divides the
batch, the solve runs as a shard_map over the batch axis (DESIGN.md §9.2):
each device vmaps its OWN local lanes with zero collectives — the same
rules that shard LM training batches shard solver workloads, without the
per-iteration while_loop synchronization a partitioner-sharded vmap would
pay. Any other mesh/batch combination falls back to the single-device
executable.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import dist
from repro.core.sven import (SvenArrays, SvenConfig, _bump_trace, _sven_core,
                             resolve_backend)


class SvenBatchSolution(NamedTuple):
    """Stacked per-problem solutions; every field has a leading (B,) axis."""

    beta: jax.Array           # (B, p)
    alpha: jax.Array          # (B, 2p)
    w: jax.Array              # (B, n)
    iters: jax.Array          # (B,)
    opt_residual: jax.Array   # (B,)
    kkt: jax.Array            # (B,)


def solve_lanes(solve_one, operands: tuple, axes: tuple):
    """Vmap `solve_one` over the stacked lanes of `operands` (pytrees; ax
    == 0 marks a batched operand). A width-1 stack skips vmap entirely —
    vmap rewrites every nested while_loop into its masked batched form,
    ~2.4x slower than the plain loops even at width 1. The ONE lane-solve
    implementation: both the constrained and the penalized batch entry
    points (and their shard_map bodies) route through here."""
    widths = {leaf.shape[0]
              for op, ax in zip(operands, axes) if ax == 0 and op is not None
              for leaf in jax.tree.leaves(op)}
    if widths == {1}:
        ops1 = tuple(jax.tree.map(lambda a: a[0], op) if ax == 0 else op
                     for op, ax in zip(operands, axes))
        return jax.tree.map(lambda a: jnp.expand_dims(a, 0),
                            solve_one(*ops1))
    return jax.vmap(solve_one, in_axes=axes)(*operands)


def shard_map_lanes(mesh, axes: tuple, local, operands: tuple):
    """shard_map a stacked solve over the batch axis (DESIGN.md §9.2).

    Problems are independent, so each device runs `local` on ITS OWN lane
    block with ZERO collectives — crucially the solver while_loops stay
    per-device (a batch-sharded vmap under the partitioner turns every
    while_loop condition into a cross-device all-reduce per iteration,
    orders of magnitude slower). Batched operands (ax == 0) shard dim 0
    over every mesh axis, the rest replicate; every output carries the
    leading batch axis.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    data_axes = tuple(mesh.axis_names)
    in_specs = tuple(P(data_axes) if ax == 0 else P() for ax in axes)
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=P(data_axes), check_rep=False)(*operands)


def _sven_solve_one(config: SvenConfig):
    def solve_one(X_, y_, t_, l2_, keep_, wa_, ww_):
        return _sven_core(X_, y_, t_, l2_, wa_, ww_, config, keep_)
    return solve_one


@partial(jax.jit, static_argnames=("config", "axes"))
def _sven_batch_jit(X, y, t, lambda2, keep, warm_alpha, warm_w,
                    config: SvenConfig, axes) -> SvenArrays:
    _bump_trace("sven_batch")
    return solve_lanes(_sven_solve_one(config),
                       (X, y, t, lambda2, keep, warm_alpha, warm_w), axes)


@partial(jax.jit, static_argnames=("config", "axes", "mesh"))
def _sven_batch_sharded_jit(X, y, t, lambda2, keep, warm_alpha, warm_w,
                            config: SvenConfig, axes, mesh) -> SvenArrays:
    _bump_trace("sven_batch")

    def local(*ops):
        return solve_lanes(_sven_solve_one(config), ops, axes)

    return shard_map_lanes(mesh, axes, local,
                           (X, y, t, lambda2, keep, warm_alpha, warm_w))


def batch_mesh(batch_size: int, n: Optional[int] = None,
               p: Optional[int] = None, *, form: str = "constrained",
               route: str = "auto"):
    """The mesh a stacked launch should fan its batch axis over, or None.

    Structural vetoes first (no context, 1-device mesh, mesh does not
    divide `batch_size` -> None: graceful single-device fallback), then the
    COST MODEL: with the problem shape (`n`, `p`) given, `core.routing`
    prices the fan-out against a single-device vmap on the calibrated mesh
    and returns None when single wins — an active mesh_context is an
    OFFER of devices, not an obligation to use them (the PR 6 regression
    fix). `route="batch"` pins the fan-out, `route="single"` pins one
    device; without a shape the offer is taken as-is (legacy behavior,
    the caller knows no better and neither do we).
    """
    ctx = dist.current_context()
    if ctx is None or route == "single":
        return None
    mesh = ctx[0]
    if mesh.size <= 1 or batch_size % mesh.size != 0:
        return None
    if route == "batch" or n is None or p is None:
        return mesh
    from repro.core import routing
    decision = routing.route_batch(n, p, batch_size, mesh, form=form,
                                   route=route)
    return mesh if decision.path == "batch" else None


def _maybe_shard_batch(arr: jax.Array, batched: bool, ctx=None) -> jax.Array:
    """Place a stacked operand with the rule table's "batch" axis (dim 0).

    `ctx` is an explicit (mesh, rules) pair; default is the innermost
    `dist.mesh_context` (no context, no placement). The one implementation
    of batch-axis placement — CV fold placement routes through here too.
    """
    if ctx is None:
        ctx = dist.current_context()
    if ctx is None or not batched:
        return arr
    mesh, rules = ctx
    names = ("batch",) + (None,) * (arr.ndim - 1)
    spec = dist.resolve_spec(names, arr.shape, mesh, rules)
    return jax.device_put(arr, NamedSharding(mesh, spec))


def sven_batch(
    X: jax.Array,
    y: jax.Array,
    t,
    lambda2,
    config: SvenConfig = SvenConfig(),
    *,
    keep: jax.Array | None = None,
    warm_alpha: jax.Array | None = None,
    warm_w: jax.Array | None = None,
    route: str = "auto",
) -> SvenBatchSolution:
    """Solve a stack of Elastic Net problems in one vmapped executable.

    Batch-axis detection by rank: X (B, n, p) vs (n, p); y (B, n) vs (n,);
    t / lambda2 (B,) vs scalar; optional screening mask keep (B, p) vs (p,)
    (see `sven`'s keep). At least one operand must be batched; all batched
    operands must agree on B. Results match a Python loop of per-problem
    `sven` calls to solver tolerance (tested).

    `warm_alpha` (B, 2p) / `warm_w` (B, n) warm-start every problem in the
    stack — the serving runtime's cache hands back neighbouring solutions
    through these (zero rows are exactly a cold start, so a mixed
    hit/miss batch stays a single executable).

    Under an active `dist.mesh_context` the batch axis fans out over the
    mesh only when the `core.routing` cost model says the mesh wins for
    this shape (see `batch_mesh`); `route="batch"`/`route="single"` pins
    the layout. Results are identical either way (tested to <= 1e-10).
    """
    X = jnp.asarray(X)
    dtype = X.dtype
    y = jnp.asarray(y, dtype)
    t = jnp.asarray(t, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)
    if keep is not None:
        keep = jnp.asarray(keep)
    if warm_alpha is not None:
        warm_alpha = jnp.asarray(warm_alpha, dtype)
    if warm_w is not None:
        warm_w = jnp.asarray(warm_w, dtype)

    axes = (0 if X.ndim == 3 else None,
            0 if y.ndim == 2 else None,
            0 if t.ndim == 1 else None,
            0 if lambda2.ndim == 1 else None,
            0 if keep is not None and keep.ndim == 2 else None,
            0 if warm_alpha is not None else None,
            0 if warm_w is not None else None)
    operands = (X, y, t, lambda2, keep, warm_alpha, warm_w)
    sizes = {op.shape[0] for op, ax in zip(operands, axes) if ax == 0}
    if not sizes:
        raise ValueError("sven_batch: no batched operand (add a leading batch "
                         "axis to X, y, t or lambda2, or call sven())")
    if len(sizes) != 1:
        raise ValueError(f"sven_batch: inconsistent batch sizes {sorted(sizes)}")

    # route BEFORE placing: once operands are batch-sharded, a vmapped
    # executable would run under the partitioner with a per-iteration
    # all-reduce on every while_loop — placement must follow the routing
    # decision, never precede it.
    pn, pp = X.shape[-2], X.shape[-1]
    mesh = batch_mesh(next(iter(sizes)), pn, pp, route=route)
    if mesh is not None:
        X, y, t, lambda2, keep, warm_alpha, warm_w = (
            _maybe_shard_batch(op, ax == 0) if op is not None else None
            for op, ax in zip(operands, axes))
    config = resolve_backend(config, X, y)
    if mesh is not None:
        arrs = _sven_batch_sharded_jit(X, y, t, lambda2, keep, warm_alpha,
                                       warm_w, config, axes, mesh)
    else:
        arrs = _sven_batch_jit(X, y, t, lambda2, keep, warm_alpha, warm_w,
                               config, axes)
    return SvenBatchSolution(beta=arrs.beta, alpha=arrs.alpha, w=arrs.w,
                             iters=arrs.iters, opt_residual=arrs.opt_residual,
                             kkt=arrs.kkt)


def en_grid(ts, lambda2s) -> Tuple[jax.Array, jax.Array]:
    """Flatten a (t, lambda2) product grid into batched (B,) operand pairs."""
    T, L = jnp.meshgrid(jnp.asarray(ts), jnp.asarray(lambda2s), indexing="ij")
    return T.ravel(), L.ravel()


def cv_folds(X: jax.Array, y: jax.Array, k: int):
    """Stack k leave-one-fold-out problems for `sven_batch` (equal-size folds).

    Uses the first k*(n//k) rows so every fold — and therefore every stacked
    training problem — has the same shape (a vmap requirement). Returns
    (X_train (k, n-f, p), y_train (k, n-f), X_val (k, f, p), y_val (k, f)).
    """
    n = X.shape[0]
    if k < 2 or k > n:
        raise ValueError(f"cv_folds: need 2 <= k <= n, got k={k}, n={n}")
    fold = n // k
    n_use = fold * k
    X, y = X[:n_use], y[:n_use]
    idx = jnp.arange(n_use)
    val_idx = idx.reshape(k, fold)
    train_idx = jnp.stack([
        jnp.concatenate([idx[: i * fold], idx[(i + 1) * fold:]]) for i in range(k)
    ])
    return X[train_idx], y[train_idx], X[val_idx], y[val_idx]
