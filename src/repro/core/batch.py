"""Batched multi-problem SVEN solves — vmap over the jit-native engine.

`sven_batch` stacks whole Elastic Net problems along a leading batch axis
and runs the same `_sven_core` trace for all of them at once (DESIGN.md §6).
Batching is where GPU/TPU SVM throughput actually comes from (cf. Rgtsvm,
Wang et al. 2017): one fat executable instead of B thin dispatches. The
three stacking patterns the serving layer needs all go through here:

    multi-response     X (n, p) shared,  y (B, n)
    (t, lambda2) grid  X, y shared,      t (B,), lambda2 (B,)   [en_grid]
    k-fold CV          X (B, n_tr, p), y (B, n_tr)              [cv_folds]

Any subset of {X, y, t, lambda2} may carry the batch axis; the rest
broadcast. Under an active `repro.dist.mesh_context` the stacked inputs are
placed with the rule table's "batch" axis before entering jit, so the
compiled executable fans problems out across the data-parallel mesh axis —
the same rules that shard LM training batches shard solver workloads.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import dist
from repro.core.sven import SvenArrays, SvenConfig, _bump_trace, _sven_core


class SvenBatchSolution(NamedTuple):
    """Stacked per-problem solutions; every field has a leading (B,) axis."""

    beta: jax.Array           # (B, p)
    alpha: jax.Array          # (B, 2p)
    w: jax.Array              # (B, n)
    iters: jax.Array          # (B,)
    opt_residual: jax.Array   # (B,)
    kkt: jax.Array            # (B,)


@partial(jax.jit, static_argnames=("config", "axes"))
def _sven_batch_jit(X, y, t, lambda2, keep, warm_alpha, warm_w,
                    config: SvenConfig, axes) -> SvenArrays:
    _bump_trace("sven_batch")

    def solve_one(X_, y_, t_, l2_, keep_, wa_, ww_):
        return _sven_core(X_, y_, t_, l2_, wa_, ww_, config, keep_)

    return jax.vmap(solve_one, in_axes=axes)(X, y, t, lambda2, keep,
                                             warm_alpha, warm_w)


def _maybe_shard_batch(arr: jax.Array, batched: bool) -> jax.Array:
    """Place a stacked operand with the rule table's "batch" axis (dim 0)."""
    ctx = dist.current_context()
    if ctx is None or not batched:
        return arr
    mesh, rules = ctx
    names = ("batch",) + (None,) * (arr.ndim - 1)
    spec = dist.resolve_spec(names, arr.shape, mesh, rules)
    return jax.device_put(arr, NamedSharding(mesh, spec))


def sven_batch(
    X: jax.Array,
    y: jax.Array,
    t,
    lambda2,
    config: SvenConfig = SvenConfig(),
    *,
    keep: jax.Array | None = None,
    warm_alpha: jax.Array | None = None,
    warm_w: jax.Array | None = None,
) -> SvenBatchSolution:
    """Solve a stack of Elastic Net problems in one vmapped executable.

    Batch-axis detection by rank: X (B, n, p) vs (n, p); y (B, n) vs (n,);
    t / lambda2 (B,) vs scalar; optional screening mask keep (B, p) vs (p,)
    (see `sven`'s keep). At least one operand must be batched; all batched
    operands must agree on B. Results match a Python loop of per-problem
    `sven` calls to solver tolerance (tested).

    `warm_alpha` (B, 2p) / `warm_w` (B, n) warm-start every problem in the
    stack — the serving runtime's cache hands back neighbouring solutions
    through these (zero rows are exactly a cold start, so a mixed
    hit/miss batch stays a single executable).
    """
    X = jnp.asarray(X)
    dtype = X.dtype
    y = jnp.asarray(y, dtype)
    t = jnp.asarray(t, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)
    if keep is not None:
        keep = jnp.asarray(keep)
    if warm_alpha is not None:
        warm_alpha = jnp.asarray(warm_alpha, dtype)
    if warm_w is not None:
        warm_w = jnp.asarray(warm_w, dtype)

    axes = (0 if X.ndim == 3 else None,
            0 if y.ndim == 2 else None,
            0 if t.ndim == 1 else None,
            0 if lambda2.ndim == 1 else None,
            0 if keep is not None and keep.ndim == 2 else None,
            0 if warm_alpha is not None else None,
            0 if warm_w is not None else None)
    operands = (X, y, t, lambda2, keep, warm_alpha, warm_w)
    sizes = {op.shape[0] for op, ax in zip(operands, axes) if ax == 0}
    if not sizes:
        raise ValueError("sven_batch: no batched operand (add a leading batch "
                         "axis to X, y, t or lambda2, or call sven())")
    if len(sizes) != 1:
        raise ValueError(f"sven_batch: inconsistent batch sizes {sorted(sizes)}")

    X, y, t, lambda2 = (_maybe_shard_batch(op, ax == 0)
                        for op, ax in zip(operands[:4], axes[:4]))
    arrs = _sven_batch_jit(X, y, t, lambda2, keep, warm_alpha, warm_w,
                           config, axes)
    return SvenBatchSolution(beta=arrs.beta, alpha=arrs.alpha, w=arrs.w,
                             iters=arrs.iters, opt_residual=arrs.opt_residual,
                             kkt=arrs.kkt)


def en_grid(ts, lambda2s) -> Tuple[jax.Array, jax.Array]:
    """Flatten a (t, lambda2) product grid into batched (B,) operand pairs."""
    T, L = jnp.meshgrid(jnp.asarray(ts), jnp.asarray(lambda2s), indexing="ij")
    return T.ravel(), L.ravel()


def cv_folds(X: jax.Array, y: jax.Array, k: int):
    """Stack k leave-one-fold-out problems for `sven_batch` (equal-size folds).

    Uses the first k*(n//k) rows so every fold — and therefore every stacked
    training problem — has the same shape (a vmap requirement). Returns
    (X_train (k, n-f, p), y_train (k, n-f), X_val (k, f, p), y_val (k, f)).
    """
    n = X.shape[0]
    if k < 2 or k > n:
        raise ValueError(f"cv_folds: need 2 <= k <= n, got k={k}, n={n}")
    fold = n // k
    n_use = fold * k
    X, y = X[:n_use], y[:n_use]
    idx = jnp.arange(n_use)
    val_idx = idx.reshape(k, fold)
    train_idx = jnp.stack([
        jnp.concatenate([idx[: i * fold], idx[(i + 1) * fold:]]) for i in range(k)
    ])
    return X[train_idx], y[train_idx], X[val_idx], y[val_idx]
