"""Render EXPERIMENTS.md tables from the dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report [--out experiments/artifacts]
prints markdown tables for §Dry-run and §Roofline.
"""
from __future__ import annotations

import argparse

from repro.launch.roofline import build_table, load_all


def _f(v, fmt="{:.3g}"):
    return fmt.format(v) if isinstance(v, (int, float)) else (v or "")


def dryrun_table(out_dir: str, mesh_tag: str) -> str:
    lines = ["| arch | shape | flops/dev (corr) | bytes/dev (corr) | peak GiB/dev | "
             "collective bytes/dev | compile s |",
             "|---|---|---|---|---|---|---|"]
    for rec in load_all(out_dir):
        if rec.get("mesh_tag") != mesh_tag:
            continue
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | SKIP | | | | |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | |")
            continue
        coll = rec.get("corrected_collectives") or rec.get("collectives") or {}
        cb = sum(e["bytes"] for e in coll.values())
        lines.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {_f(rec.get('corrected_flops') or rec.get('flops'), '{:.3e}')} "
            f"| {_f(rec.get('corrected_bytes') or rec.get('bytes_accessed'), '{:.3e}')} "
            f"| {_f((rec.get('peak_bytes_per_device') or 0) / 2**30, '{:.2f}')} "
            f"| {_f(cb, '{:.3e}')} | {_f(rec.get('compile_s'), '{:.1f}')} |")
    return "\n".join(lines)


def roofline_table(out_dir: str, mesh_tag: str) -> str:
    lines = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
             "useful (6ND/HLO) | MFU@roofline | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|"]
    for row in build_table(out_dir):
        if row.get("mesh") != mesh_tag:
            continue
        if row["status"] == "skipped":
            lines.append(f"| {row['arch']} | {row['shape']} | SKIP | | | | | | |")
            continue
        if row["status"] != "ok":
            lines.append(f"| {row['arch']} | {row['shape']} | ERR | | | | | | |")
            continue
        fits = "yes" if row.get("peak_gib", 1e9) <= 16 else f"NO ({row['peak_gib']:.0f}G)"
        lines.append(
            f"| {row['arch']} | {row['shape']} | {_f(row.get('t_compute_s'), '{:.2e}')} "
            f"| {_f(row.get('t_memory_s'), '{:.2e}')} | {_f(row.get('t_collective_s'), '{:.2e}')} "
            f"| {row.get('bottleneck', '')} | {_f(row.get('useful_ratio'), '{:.2f}')} "
            f"| {_f(row.get('mfu_at_roofline'), '{:.2f}')} | {fits} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    print("## Dry-run (" + args.mesh + ")\n")
    print(dryrun_table(args.out, args.mesh))
    print("\n## Roofline (" + args.mesh + ")\n")
    print(roofline_table(args.out, args.mesh))


if __name__ == "__main__":
    main()
