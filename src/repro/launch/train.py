"""Training launcher: mesh setup, sharded init, jit train_step with in/out
shardings, checkpoint/restart, supervised retry loop (fault tolerance) and a
per-step watchdog (straggler mitigation).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

Fault-tolerance model (designed for 1000+ nodes, exercised single-host):
  * every step is a pure function of (params, opt_state, step_index) and the
    deterministic data pipeline => restart-exactness;
  * the supervisor catches step failures (flaky node <-> injected fault),
    restores the latest checkpoint and resumes — bounded retries;
  * a wall-clock watchdog flags steps exceeding `watchdog_factor` x the
    rolling median step time (straggler detection; on a real pod this signals
    the controller to evict/replace the slow host — here it logs);
  * checkpoints are atomic + content-hashed; elastic restore re-shards onto
    whatever mesh the relaunch built (dist/zero.py + ckpt/checkpoint.py).
"""
from __future__ import annotations

import argparse
import statistics
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.ckpt import CheckpointManager
from repro.configs import get_config, get_meta
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.optim import adamw_init, warmup_cosine
from repro.train.step import make_train_step


def build_state(cfg, key, mesh=None):
    """Initialize params (+AdamW) with logical shardings applied via jit."""
    init_fn = partial(M.init_model, cfg=cfg)
    if mesh is None:
        params = init_fn(key)
    else:
        with dist.mesh_context(mesh, rules={**dist.DEFAULT_RULES, **cfg.rules_override}):
            params = jax.jit(init_fn)(key)
    opt = adamw_init(params)
    return params, opt


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-retries", type=int, default=3)
    ap.add_argument("--watchdog-factor", type=float, default=5.0)
    ap.add_argument("--inject-fault-at", type=int, default=-1,
                    help="test hook: raise at this step once (supervisor must recover)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_local_mesh()
    rules = {**dist.DEFAULT_RULES, **cfg.rules_override}

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch,
                      n_codebooks=cfg.n_codebooks if cfg.frontend == "codebooks" else 0,
                      vision_tokens=cfg.vision_tokens if cfg.frontend == "patches" else 0,
                      d_model=cfg.d_model)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    schedule = warmup_cosine(args.lr, max(10, args.steps // 20), args.steps)

    with dist.mesh_context(mesh, rules=rules):
        params, opt_state = build_state(cfg, jax.random.PRNGKey(0), mesh)
        start_step = 0
        if ckpt and ckpt.latest_step() is not None:
            (params, opt_state), start_step, _ = ckpt.restore((params, opt_state))
            print(f"[train] resumed from step {start_step}", flush=True)

        step_fn = jax.jit(make_train_step(
            cfg, microbatches=args.microbatches, lr_schedule=schedule))

        stream = SyntheticStream(dcfg, start_step=start_step)
        injected = {"done": False}
        retries = 0
        step = start_step
        times: list[float] = []
        while step < args.steps:
            batch = stream.__next__()
            try:
                if step == args.inject_fault_at and not injected["done"]:
                    injected["done"] = True
                    raise RuntimeError("injected node failure")
                t0 = time.perf_counter()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])  # sync point
                dt = time.perf_counter() - t0
                times.append(dt)
                if len(times) > 5:
                    med = statistics.median(times[-50:])
                    if dt > args.watchdog_factor * med:
                        print(f"[watchdog] step {step} took {dt:.3f}s "
                              f"(median {med:.3f}s) — straggler suspected", flush=True)
                if not np.isfinite(loss):
                    raise RuntimeError(f"non-finite loss at step {step}")
            except Exception as e:  # supervisor: restore + retry
                retries += 1
                print(f"[supervisor] step {step} failed ({e}); retry {retries}", flush=True)
                if retries > args.max_retries:
                    raise
                if ckpt and ckpt.latest_step() is not None:
                    (params, opt_state), step, _ = ckpt.restore((params, opt_state))
                    stream.step = step
                continue
            step += 1
            stream.step = step
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms)", flush=True)
            if ckpt and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state), extra={"arch": args.arch})
        if ckpt:
            ckpt.save(step, (params, opt_state), extra={"arch": args.arch})
        print(f"[train] done at step {step}, final loss {loss:.4f}", flush=True)
        return loss


if __name__ == "__main__":
    run()
