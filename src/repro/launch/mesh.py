"""Production mesh builders. FUNCTIONS, not module constants — importing this
module never touches jax device state (jax locks the device count on first
backend init, and only launch/dryrun.py may force 512 host devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (data, model); multi_pod prepends a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — used by tests/examples (usually 1 device)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
