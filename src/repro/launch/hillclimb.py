import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (same contract as dryrun.py: only launch entry points force host devices)

"""Perf hillclimb driver (EXPERIMENTS.md §Perf): lowers named VARIANTS of a
dry-run cell (config fields / sharding-rule / microbatch overrides), computes
the roofline terms of each, and appends the hypothesis->result record to
experiments/perf/<cell>__<variant>.json.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell deepseek_7b:train_4k \
        --variant dense_attn --set attn_dense_max=4096
"""
import argparse
import json
import time

from repro.launch.dryrun import lower_cell, lower_sven_cell, _write
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms


def run_variant(arch: str, shape: str, name: str, overrides: dict, out_dir: str,
                multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    rec = lower_cell(arch, shape, mesh, opt_overrides=overrides)
    rec["variant"] = name
    rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    rec["status"] = "ok"
    rec["wall_s"] = round(time.perf_counter() - t0, 1)
    rec.update(roofline_terms(rec))
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def summarize(rec: dict) -> str:
    return (f"t_comp={rec.get('t_compute_s', 0):.3g}s "
            f"t_mem={rec.get('t_memory_s', 0):.3g}s "
            f"t_coll={rec.get('t_collective_s', 0):.3g}s "
            f"bottleneck={rec.get('bottleneck')} "
            f"peak={rec.get('peak_bytes_per_device', 0) / 2**30:.1f}GiB")


def _parse_set(pairs: list[str]) -> dict:
    cfg_over = {}
    for pair in pairs:
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        cfg_over[k] = v
    return cfg_over


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--set", nargs="*", default=[], help="cfg field overrides k=v")
    ap.add_argument("--rule", nargs="*", default=[], help="sharding rule overrides k=v|none")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    overrides: dict = {"cfg": _parse_set(args.set)}
    if args.rule:
        overrides["rules"] = {k: (None if v == "none" else v)
                              for k, v in (r.split("=", 1) for r in args.rule)}
    if args.microbatches:
        overrides["microbatches"] = args.microbatches
    rec = run_variant(arch, shape, args.variant, overrides, args.out)
    print(f"[hillclimb] {args.cell} variant={args.variant}: {summarize(rec)}")


if __name__ == "__main__":
    main()
