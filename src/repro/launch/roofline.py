"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. Terms per (arch x shape x mesh) cell, from the corrected (scan-aware)
dry-run numbers — all "per device" quantities:

    t_compute = flops_dev / 197e12
    t_memory  = bytes_dev / 819e9
    t_coll    = sum_k  wire_bytes_k(dev) * hops_factor_k / 50e9

Collective wire-byte models (ring algorithms, result-shape R bytes recorded
by the dry-run's HLO scan, already per-device):
    all-gather:        R * (n-1)/n   (R = gathered result)
    reduce-scatter:    R * (n-1)     (R = scattered result; input n*R)
    all-reduce:        2R * (n-1)/n
    all-to-all:        R * (n-1)/n
    collective-permute R

MODEL_FLOPS = 6 * N_active * tokens (train; 3x for fwd-only cells x2... see
`model_flops`) — the useful-work yardstick; MODEL_FLOPS / HLO_FLOPS exposes
remat/padding/dispatch waste.

    PYTHONPATH=src python -m repro.launch.roofline --out experiments/artifacts
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1),
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def model_flops(arch_meta, shape: dict, kind: str) -> float:
    """6*N_active*D for train, 2*N_active*D for single forward (prefill),
    2*N_active*B for one decode token (D = tokens processed)."""
    n_act = arch_meta.active_params_b * 1e9
    if kind == "train":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 6 * n_act * tokens
    if kind == "prefill":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 2 * n_act * tokens
    # decode: one token per sequence
    return 2 * n_act * shape["global_batch"]


def roofline_terms(rec: dict, *, mesh_axis_for_coll: str = "model") -> dict:
    chips = rec["chips"]
    flops_dev = rec.get("corrected_flops") or rec.get("flops")
    bytes_dev = rec.get("corrected_bytes") or rec.get("bytes_accessed")
    colls = rec.get("corrected_collectives") or rec.get("collectives") or {}
    # collective ring size: LM cells collect along the model axis (16); the
    # sven cells' shard_map collectives span the FLAT mesh (all chips)
    if rec.get("kind") == "sven":
        n_ring = chips
    else:
        n_ring = rec.get("mesh", {}).get(mesh_axis_for_coll, 16)
    t_comp = flops_dev / PEAK_FLOPS if flops_dev else None
    t_mem = bytes_dev / HBM_BW if bytes_dev else None
    t_coll = 0.0
    coll_bytes = 0
    for kind, e in colls.items():
        f = _WIRE_FACTOR.get(kind, lambda n: 1.0)(n_ring)
        t_coll += e["bytes"] * f / ICI_BW
        coll_bytes += e["bytes"]
    out = {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "collective_bytes_dev": coll_bytes,
    }
    terms = {k: v for k, v in out.items() if k.startswith("t_") and v}
    if terms:
        dom = max(terms, key=lambda k: terms[k])
        out["bottleneck"] = dom.replace("t_", "").replace("_s", "")
        t_bound = max(terms.values())
        out["roofline_step_s"] = t_bound
        if t_comp:
            out["compute_fraction"] = t_comp / t_bound
    return out


def load_all(out_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def build_table(out_dir: str) -> list[dict]:
    from repro.configs import SHAPES, get_meta
    rows = []
    for rec in load_all(out_dir):
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh_tag"), "status": "skipped",
                         "note": rec.get("reason", "")})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh_tag"), "status": "error",
                         "note": rec.get("error", "")[:200]})
            continue
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "mesh": rec.get("mesh_tag"), "status": "ok",
               "chips": rec["chips"],
               "peak_gib": (rec.get("peak_bytes_per_device") or 0) / 2**30}
        row.update(roofline_terms(rec))
        if rec["shape"] in SHAPES and rec.get("kind") != "sven":
            try:
                meta = get_meta(rec["arch"])
                mf = model_flops(meta, SHAPES[rec["shape"]], rec["kind"])
                mf_dev = mf / rec["chips"]
                row["model_flops_dev"] = mf_dev
                hlo = rec.get("corrected_flops") or rec.get("flops")
                if hlo:
                    row["useful_ratio"] = mf_dev / hlo
                    row["mfu_at_roofline"] = (mf_dev / PEAK_FLOPS) / row["roofline_step_s"]
            except Exception:  # noqa: BLE001
                pass
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    rows = build_table(args.out)
    cols = ["arch", "shape", "mesh", "status", "t_compute_s", "t_memory_s",
            "t_collective_s", "bottleneck", "compute_fraction", "useful_ratio",
            "mfu_at_roofline", "peak_gib"]
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(",".join(cols) + "\n")
            for r in rows:
                f.write(",".join(_fmt(r.get(c)) for c in cols) + "\n")


def _fmt(v):
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


if __name__ == "__main__":
    main()
