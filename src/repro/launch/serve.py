"""Serving launcher: batched request loop over prefill + decode with
continuous greedy generation and per-request token accounting.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import make_decode_step, make_prefill_step


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen + cfg.vision_tokens + 4

    key = jax.random.PRNGKey(1)
    if cfg.frontend == "codebooks":
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len,
                                                    cfg.n_codebooks), 0, cfg.vocab_size)}
    elif cfg.frontend == "patches":
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size),
                 "patch_embeds": jax.random.normal(key, (args.batch, cfg.vision_tokens,
                                                         cfg.d_model), cfg.dtype)}
    else:
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, caches = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t0 = time.perf_counter()
    n = 0
    for _ in range(args.gen):
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        n += args.batch
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1e3:.0f} ms; decode {n} tokens in {t_decode * 1e3:.0f} ms "
          f"({n / t_decode:.0f} tok/s)")
    return n / t_decode


if __name__ == "__main__":
    run()
