import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# Only this module forces 512 host devices; tests/benches see the real 1.

"""Multi-pod dry-run: for every (architecture x input shape x mesh) cell,
AOT-lower + compile the step function on the production mesh and record
memory_analysis / cost_analysis / per-collective byte counts to
experiments/artifacts/<cell>.json (resumable; roofline.py consumes these).

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single --out experiments/artifacts

Cells:
  train_4k    -> train_step  (fwd+bwd+AdamW, microbatched, remat, ZeRO-1)
  prefill_32k -> prefill_step (logits + KV cache build)
  decode_32k / long_500k -> serve decode_step (1 token vs seq_len cache)
  sven_*      -> the paper's distributed solver hot ops (gram / hessian-mv)
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.configs import ARCHS, SHAPES, get_config, get_meta, input_specs
from repro.dist.shardings import (batch_shardings, cache_shardings,
                                  params_shardings, replicated)
from repro.dist.zero import zero1_shardings
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import model as M
from repro.optim.adamw import AdamWState
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")


_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind over the optimized HLO.

    Handles tuple-result ops (XLA's reduction combiner merges many psums into
    one `(...) all-reduce(...)`) and async start/done pairs (counts -start,
    skips -done)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        for kind in _COLL_KINDS:
            pos = -1
            for tok in (f" {kind}(", f" {kind}-start("):
                pos = rhs.find(tok)
                if pos != -1:
                    break
            if pos == -1:
                continue
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(rhs[:pos]):
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            e = out.setdefault(kind, {"count": 0, "bytes": 0})
            e["count"] += 1
            e["bytes"] += nbytes
            break
    return out


def analyze(compiled, lower_s: float, compile_s: float) -> dict:
    rec = {"lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["flops"] = float(ca.get("flops", -1))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
        rec["transcendentals"] = float(ca.get("transcendentals", -1))
    except Exception as e:  # noqa: BLE001
        rec["cost_analysis_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            rec[k] = int(getattr(ma, k))
        rec["peak_bytes_per_device"] = (
            rec["argument_size_in_bytes"] + rec["output_size_in_bytes"]
            + rec["temp_size_in_bytes"] - rec.get("alias_size_in_bytes", 0))
    except Exception as e:  # noqa: BLE001
        rec["memory_analysis_error"] = str(e)
    try:
        rec["collectives"] = collective_bytes(compiled.as_text())
    except Exception as e:  # noqa: BLE001
        rec["collectives_error"] = str(e)
    return rec


def _combine_probes(rec: dict, recA: dict, recB: dict, n_periods: int, mb: int):
    """XLA's cost model counts while/scan bodies ONCE, so a scanned L-layer
    model under-reports by ~L/period x. Correction: lower 1-period and
    2-period probes (at microbatch scale), diff to get per-period cost, then
    total = mb * (A + (n_periods - 1) * per_period). Slight overcount of the
    optimizer epilogue (x mb, elementwise, <1-2% of flops) — documented in
    EXPERIMENTS.md. The probes share the real cell's shapes per microbatch."""

    def corr(field):
        a, b = recA.get(field), recB.get(field)
        if a is None or b is None or a < 0 or b < 0:
            return None
        pp = b - a
        return mb * (a + (n_periods - 1) * pp)

    rec["corrected_flops"] = corr("flops")
    rec["corrected_bytes"] = corr("bytes_accessed")
    colls = {}
    ka = recA.get("collectives", {})
    kb = recB.get("collectives", {})
    for kind in set(ka) | set(kb):
        ca = ka.get(kind, {"count": 0, "bytes": 0})
        cb = kb.get(kind, {"count": 0, "bytes": 0})
        colls[kind] = {
            "count": mb * (ca["count"] + (n_periods - 1) * (cb["count"] - ca["count"])),
            "bytes": mb * (ca["bytes"] + (n_periods - 1) * (cb["bytes"] - ca["bytes"])),
        }
    rec["corrected_collectives"] = colls
    rec["probe_A"] = {k: recA.get(k) for k in ("flops", "bytes_accessed", "collectives")}
    rec["probe_B"] = {k: recB.get(k) for k in ("flops", "bytes_accessed", "collectives")}


def _rules_for(cfg, shape_name: str) -> dict:
    rules = dict(dist.DEFAULT_RULES)
    rules.update(cfg.rules_override)
    if shape_name == "prefill_32k":
        # cache written seq-sharded over model; compute stays heads-sharded
        rules["seq_kv"] = "model"
        rules["kv_heads"] = None
    if shape_name == "decode_32k":
        # flash-decoding layout: batch over data, cache seq over model, heads
        # UNSHARDED in compute (a heads-sharded q against a seq-sharded cache
        # makes GSPMD replicate the cache — involuntary full remat). Weights
        # take FSDP over data instead of head-TP.
        rules["seq_kv"] = "model"
        rules["kv_heads"] = None
        rules["heads"] = None
        rules["fsdp"] = "data"
    if shape_name == "long_500k":
        # batch=1: seq shards over DATA, heads keep model TP — disjoint axes,
        # so scores (B, H@model, 1, S@data) compose without resharding.
        rules["batch"] = None
        rules["seq_kv"] = "data"
        rules["kv_heads"] = None
        rules["fsdp"] = None
    return rules


def _lower_one(cfg, shape_name: str, mesh, rules, *, microbatches: int,
               global_batch: int | None = None) -> dict:
    """Lower + compile one step artifact for `cfg` at a shape; returns analysis."""
    sh = dict(SHAPES[shape_name])
    if global_batch is not None:
        sh["global_batch"] = global_batch

    with dist.mesh_context(mesh, rules=rules):
        import repro.configs as C
        saved = C.SHAPES[shape_name]
        C.SHAPES[shape_name] = sh
        try:
            specs = input_specs(cfg, shape_name)
        finally:
            C.SHAPES[shape_name] = saved
        params_shape = jax.eval_shape(lambda: M.init_model(jax.random.PRNGKey(0), cfg))
        p_sh = params_shardings(params_shape)
        t0 = time.perf_counter()

        if sh["kind"] == "train":
            step_fn = make_train_step(cfg, microbatches=microbatches, learning_rate=1e-3,
                                      grad_shardings=p_sh)
            opt_shape = jax.eval_shape(partial_adamw_init, params_shape)
            m_sh = zero1_shardings(p_sh, params_shape)
            o_sh = AdamWState(m=m_sh, v=m_sh, count=replicated(opt_shape.count))
            b_sh = batch_shardings(specs)
            jf = jax.jit(step_fn,
                         in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            lowered = jf.lower(params_shape, opt_shape, specs)
        elif sh["kind"] == "prefill":
            step_fn = make_prefill_step(cfg, max_len=sh["seq_len"])
            b_sh = batch_shardings(specs)
            jf = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
            lowered = jf.lower(params_shape, specs)
        else:  # decode
            B, S = sh["global_batch"], sh["seq_len"]
            cache_shape = jax.eval_shape(
                lambda: M.init_cache(None, cfg, B, S))
            c_sh = cache_shardings(cache_shape)
            step_fn = make_decode_step(cfg)
            tok_sh = batch_shardings(specs)["tokens"]
            jf = jax.jit(step_fn, in_shardings=(p_sh, tok_sh, c_sh),
                         out_shardings=None, donate_argnums=(2,))
            lowered = jf.lower(params_shape, specs["tokens"], cache_shape)

        lower_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        return analyze(compiled, lower_s, time.perf_counter() - t1)


def lower_cell(arch: str, shape_name: str, mesh, *, opt_overrides: dict | None = None,
               probes: bool = True) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if opt_overrides:
        cfg = _dc.replace(cfg, **opt_overrides.get("cfg", {}))
    meta = get_meta(arch)
    sh = SHAPES[shape_name]
    rules = _rules_for(cfg, shape_name)
    if opt_overrides:
        rules.update(opt_overrides.get("rules", {}))
    mb = (opt_overrides or {}).get("microbatches", meta.train_microbatch) \
        if sh["kind"] == "train" else 1

    rec = _lower_one(cfg, shape_name, mesh, rules, microbatches=mb)
    rec.update(arch=arch, shape=shape_name,
               mesh={k: v for k, v in mesh.shape.items()},
               chips=mesh_chip_count(mesh), kind=sh["kind"],
               microbatches=mb, n_periods=cfg.n_periods, period=cfg.period)

    if probes:
        # scan-count correction probes: 1 and 2 periods, UNROLLED (cost
        # analysis counts lax.scan bodies once), at microbatch scale
        try:
            probe_recs = []
            for k in (1, 2):
                cfg_k = _dc.replace(cfg, n_layers=cfg.dense_prefix + k * cfg.period,
                                    unroll_layers=True)
                gb = sh["global_batch"] // mb if sh["kind"] == "train" else None
                probe_recs.append(_lower_one(cfg_k, shape_name, mesh, rules,
                                             microbatches=1, global_batch=gb))
            _combine_probes(rec, probe_recs[0], probe_recs[1], cfg.n_periods, mb)
        except Exception as e:  # noqa: BLE001
            rec["probe_error"] = str(e)
    return rec


def partial_adamw_init(params_shape):
    from repro.optim.adamw import adamw_init
    return adamw_init(params_shape)


# ------------------------------------------------------------- sven cells ---

def lower_sven_cell(which: str, mesh, variant: str = "blocks") -> dict:
    """The paper's own distributed hot ops at genetics scale.

    variant (gram cell only): "blocks" (optimized block identity),
    "paper" (materialized Zhat, the MATLAB-faithful baseline),
    "blocks_bf16" (bf16 inputs, f32 accumulation)."""
    from repro.core.distributed import (distributed_gram, distributed_gram_paper,
                                        make_distributed_hessian_matvec,
                                        feature_sharding)
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(mesh.axis_names)
    if which == "sven_gram_nggp":           # n >> p dual: K build
        n, p = 1 << 20, 8192
        dtype = jnp.bfloat16 if variant == "blocks_bf16" else jnp.float32
        X = jax.ShapeDtypeStruct((n, p), dtype)
        y = jax.ShapeDtypeStruct((n,), dtype)
        x_sh = NamedSharding(mesh, P(axes, None))
        y_sh = NamedSharding(mesh, P(axes))
        if variant == "paper":
            fn = jax.jit(lambda X, y: distributed_gram_paper(mesh, X, y, 1.5),
                         in_shardings=(x_sh, y_sh))
        else:
            fn = jax.jit(lambda X, y: distributed_gram(mesh, X, y, 1.5),
                         in_shardings=(x_sh, y_sh))
        t0 = time.perf_counter()
        lowered = fn.lower(X, y)
        lower_s = time.perf_counter() - t0
    elif which == "sven_hess_pggn":         # p >> n primal: CG hot loop
        n, p = 4096, 1 << 20
        X = jax.ShapeDtypeStruct((n, p), jnp.float32)
        y = jax.ShapeDtypeStruct((n,), jnp.float32)
        act = jax.ShapeDtypeStruct((2 * p,), jnp.float32)
        v = jax.ShapeDtypeStruct((n,), jnp.float32)
        x_sh = NamedSharding(mesh, P(None, axes))
        rep = NamedSharding(mesh, P())

        def hv(Xa, ya, acta, va):
            f = make_distributed_hessian_matvec(mesh, Xa, ya, 1.5, 10.0)
            return f(va, acta)

        fn = jax.jit(hv, in_shardings=(x_sh, rep, rep, rep))
        t0 = time.perf_counter()
        lowered = fn.lower(X, y, act, v)
        lower_s = time.perf_counter() - t0
    else:
        raise ValueError(which)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec = analyze(compiled, lower_s, time.perf_counter() - t1)
    rec.update(arch=which, shape="paper", kind="sven",
               mesh={k: v for k, v in mesh.shape.items()},
               chips=mesh_chip_count(mesh))
    return rec


SVEN_CELLS = ["sven_gram_nggp", "sven_hess_pggn"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--include-sven", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_tag = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            if arch in SVEN_CELLS:
                cells = [(arch, "paper")]
            else:
                cells = [(arch, s) for s in shapes]
            for a, s in cells:
                if s == "long_500k" and a not in SVEN_CELLS and not get_meta(a).long_500k:
                    rec = {"arch": a, "shape": s, "mesh_tag": mesh_tag,
                           "status": "skipped",
                           "reason": get_meta(a).long_500k_note}
                    _write(args.out, a, s, mesh_tag, rec)
                    print(f"[dryrun] SKIP {a} x {s} ({mesh_tag})", flush=True)
                    continue
                path = _path(args.out, a, s, mesh_tag)
                if os.path.exists(path) and not args.force:
                    try:
                        with open(path) as fh:
                            cached = json.load(fh)
                    except Exception:  # noqa: BLE001
                        cached = {"status": "error"}
                    if cached.get("status") != "error":
                        print(f"[dryrun] cached {a} x {s} ({mesh_tag})", flush=True)
                        continue
                print(f"[dryrun] lowering {a} x {s} ({mesh_tag}) ...", flush=True)
                try:
                    if a in SVEN_CELLS:
                        rec = lower_sven_cell(a, mesh)
                    else:
                        rec = lower_cell(a, s, mesh)
                    rec["status"] = "ok"
                    rec["mesh_tag"] = mesh_tag
                    print(f"[dryrun] OK {a} x {s} ({mesh_tag}): "
                          f"flops={rec.get('flops', -1):.3e} "
                          f"peak={rec.get('peak_bytes_per_device', -1) / 2**30:.2f}GiB "
                          f"compile={rec.get('compile_s')}s", flush=True)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": a, "shape": s, "mesh_tag": mesh_tag,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[dryrun] FAIL {a} x {s} ({mesh_tag}): {e}", flush=True)
                _write(args.out, a, s, mesh_tag, rec)
                results.append(rec)
        if args.include_sven and args.arch == "all":
            for cell in SVEN_CELLS:
                path = _path(args.out, cell, "paper", mesh_tag)
                if os.path.exists(path) and not args.force:
                    continue
                print(f"[dryrun] lowering {cell} ({mesh_tag}) ...", flush=True)
                try:
                    rec = lower_sven_cell(cell, mesh)
                    rec["status"] = "ok"
                    rec["mesh_tag"] = mesh_tag
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": cell, "shape": "paper", "mesh_tag": mesh_tag,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[dryrun] FAIL {cell}: {e}", flush=True)
                _write(args.out, cell, "paper", mesh_tag, rec)
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"[dryrun] finished: {len(results)} lowered, {n_err} errors", flush=True)
    return 1 if n_err else 0


def _path(out, arch, shape, mesh_tag):
    return os.path.join(out, f"{arch}__{shape}__{mesh_tag}.json")


def _write(out, arch, shape, mesh_tag, rec):
    with open(_path(out, arch, shape, mesh_tag), "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    raise SystemExit(main())
