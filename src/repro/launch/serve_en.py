"""Elastic Net serving launcher: drive ElasticNetEngine with a synthetic
request stream of varied shapes and report batched-vs-sequential throughput,
bucket/executable reuse, and exactness vs direct per-request solves.
`--penalized N` mixes N glmnet-style (lambda1, lambda2) requests per wave
into the stream; those are verified against the coordinate-descent baseline.

    PYTHONPATH=src python -m repro.launch.serve_en --requests 24 --waves 3
"""
from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.baselines import elastic_net_cd
from repro.core import SvenConfig, enet, sven
from repro.core.elastic_net import lambda1_max
from repro.data.synthetic import make_regression
from repro.serve import ElasticNetEngine


def _random_requests(rng: np.random.Generator, count: int):
    """Varied-shape EN problems with t set from a ridge-ish scale heuristic."""
    reqs = []
    for _ in range(count):
        n = int(rng.integers(20, 90))
        p = int(rng.integers(10, 120))
        X, y, _ = make_regression(n, p, k_true=max(3, p // 8),
                                  rho=0.3, seed=int(rng.integers(1 << 30)))
        t = float(0.1 * jnp.sum(jnp.abs(X.T @ y)) / (X.shape[0]))
        lam2 = float(rng.choice([0.5, 1.0, 2.0]))
        reqs.append((X, y, max(t, 1e-3), lam2))
    return reqs


def _random_penalized(rng: np.random.Generator, count: int):
    """Penalized-form requests: lambda1 drawn as a fraction of lambda1_max."""
    reqs = []
    for _ in range(count):
        n = int(rng.integers(20, 90))
        p = int(rng.integers(10, 120))
        X, y, _ = make_regression(n, p, k_true=max(3, p // 8),
                                  rho=0.3, seed=int(rng.integers(1 << 30)))
        lam1 = float(rng.uniform(0.1, 0.6)) * float(lambda1_max(X, y))
        lam2 = float(rng.choice([0.5, 1.0, 2.0]))
        reqs.append((X, y, lam1, lam2))
    return reqs


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24, help="requests per wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=4,
                    help="requests per wave cross-checked against direct sven()")
    ap.add_argument("--penalized", type=int, default=2,
                    help="additional glmnet-form requests per wave "
                         "(verified against coordinate descent)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    cfg = SvenConfig()
    engine = ElasticNetEngine(cfg)

    new_execs_last_wave = 0
    for wave in range(args.waves):
        batches0 = engine.stats.batches
        execs0 = engine.stats.bucket_shapes
        padded0 = engine.stats.padded_slots
        reqs = _random_requests(rng, args.requests)
        ids = [engine.submit(*r) for r in reqs]
        pen_reqs = _random_penalized(rng, args.penalized)
        pen_ids = [engine.submit_penalized(*r) for r in pen_reqs]
        t0 = time.perf_counter()
        out = engine.drain()
        batched_s = time.perf_counter() - t0

        # sequential baseline: one engine-less solve per request (jit-cached
        # per raw shape — the dispatch pattern the engine replaces), covering
        # BOTH request forms so the speedup compares equal work
        t0 = time.perf_counter()
        seq = [jax.block_until_ready(sven(X, y, t, l2, cfg).beta)
               for X, y, t, l2 in reqs]
        seq_pen = [jax.block_until_ready(enet(X, y, l1, l2).beta)
                   for X, y, l1, l2 in pen_reqs]
        sequential_s = time.perf_counter() - t0

        max_dev = 0.0
        for i in range(min(args.verify, len(reqs))):
            max_dev = max(max_dev, float(jnp.abs(out[ids[i]].beta - seq[i]).max()))

        pen_dev = 0.0
        for (X, y, lam1, lam2), rid, sp in zip(pen_reqs, pen_ids, seq_pen):
            beta_cd = elastic_net_cd(X, y, lam1, lam2).beta
            pen_dev = max(pen_dev,
                          float(jnp.abs(out[rid].beta - beta_cd).max()),
                          float(jnp.abs(out[rid].beta - sp).max()))

        s = engine.stats
        new_execs_last_wave = s.bucket_shapes - execs0
        print(f"[serve_en] wave {wave}: {len(reqs)}+{len(pen_reqs)}pen reqs in "
              f"{s.batches - batches0} batches | "
              f"batched {batched_s*1e3:7.1f} ms  sequential {sequential_s*1e3:7.1f} ms "
              f"({sequential_s/max(batched_s,1e-9):4.1f}x) | "
              f"new_executables={new_execs_last_wave} "
              f"padded_slots={s.padded_slots - padded0} | "
              f"max|beta-beta_seq|={max_dev:.2e} pen_dev={pen_dev:.2e}")
        assert max_dev < 1e-6, "engine diverged from direct sven()"
        assert pen_dev < 1e-5, "penalized path diverged from coordinate descent"

    steady = ("last wave added none" if new_execs_last_wave == 0
              else f"last wave still added {new_execs_last_wave}")
    print(f"[serve_en] done: {engine.stats.requests} requests, "
          f"{engine.stats.bucket_shapes} compiled executables total ({steady}).")


if __name__ == "__main__":
    run()
