"""Elastic Net serving launcher, now on the continuous-batching runtime:
drive a `ContinuousScheduler` with a reproducible open-loop request stream
(mixed constrained + glmnet-form, adjacent-lambda pattern) and report
runtime-vs-reference throughput, warm-start cache behaviour, executable
reuse, and exactness against direct per-request solves. The synchronous
seed path survives as `ElasticNetEngine.drain_reference()` and is timed as
the baseline every wave.

    PYTHONPATH=src python -m repro.launch.serve_en --requests 24 --waves 3
"""
from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.baselines import elastic_net_cd
from repro.core import SvenConfig, enet, sven
from repro.runtime import (CONSTRAINED, PENALIZED, ContinuousScheduler,
                           LoadSpec, make_workload, run_open_loop)
from repro.serve import ElasticNetEngine


def _direct_solve(item, cfg: SvenConfig):
    if item.form == PENALIZED:
        return enet(item.X, item.y, item.lam, item.lambda2).beta
    return sven(item.X, item.y, item.lam, item.lambda2, cfg).beta


def _serve_metrics(registry, port: int):
    """Live Prometheus text exposition on a daemon thread (stdlib only).

    Scrape target for the duration of the run: ``GET /metrics`` renders
    `registry.to_prometheus()` at request time, so a scraper polling while
    waves are in flight sees counters move.
    """
    import http.server
    import threading

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path not in ("/metrics", "/"):
                self.send_error(404)
                return
            body = registry.to_prometheus().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):   # keep the wave report readable
            pass

    server = http.server.ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    return server


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24, help="requests per wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=4,
                    help="requests per wave cross-checked against direct "
                         "sven()/enet() solves")
    ap.add_argument("--penalized", type=int, default=2,
                    help="glmnet-form requests per wave (verified against "
                         "coordinate descent)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=2e-3,
                    help="coalescing window (s) before a deadline launch")
    ap.add_argument("--cache-dir", type=str, default=None,
                    help="persistent warm-start spill directory: solutions "
                         "survive restarts and are shareable across hosts "
                         "(DESIGN.md §11.2)")
    ap.add_argument("--speculate", action="store_true",
                    help="pre-solve predicted next lambda-crawl points in "
                         "idle batch slots (DESIGN.md §11.3)")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="enable structured tracing and export a Chrome-trace "
                         "JSON here on exit (chrome://tracing / Perfetto)")
    ap.add_argument("--metrics-json", type=str, default=None,
                    help="write the final metrics-registry snapshot (JSON)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live Prometheus text exposition on this port "
                         "for the duration of the run (GET /metrics)")
    ap.add_argument("--events-out", type=str, default=None,
                    help="dump the structured event ring as JSONL on exit")
    args = ap.parse_args(argv)

    if args.trace_out is not None:
        from repro.obs import enable_tracing

        enable_tracing()

    cfg = SvenConfig()
    total = args.requests + args.penalized
    cache = "default"
    if args.cache_dir is not None:
        from repro.runtime import TieredSolutionCache

        cache = TieredSolutionCache(spill_dir=args.cache_dir)
    sched = ContinuousScheduler(cfg, max_batch=args.max_batch,
                                max_wait=args.max_wait, cache=cache,
                                speculate=args.speculate)
    reference = ElasticNetEngine(cfg, max_batch=args.max_batch, cache=None)

    metrics_server = None
    if args.metrics_port is not None:
        metrics_server = _serve_metrics(sched.registry, args.metrics_port)
        print(f"[serve_en] Prometheus exposition on "
              f"http://127.0.0.1:{metrics_server.server_address[1]}/metrics")

    new_execs_last_wave = 0
    for wave in range(args.waves):
        execs0 = sched.stats.bucket_shapes
        batches0 = sched.stats.batches
        padded0 = sched.stats.padded_slots
        # data_seed pins the datasets: every wave revisits the same problems
        # at freshly drawn adjacent lambdas — steady-state serving traffic,
        # which is what exercises both executable reuse and the warm cache.
        spec = LoadSpec(n_requests=total,
                        penalized_fraction=args.penalized / max(total, 1),
                        seed=args.seed + wave, data_seed=args.seed)
        workload = make_workload(spec)

        out = run_open_loop(sched, workload)
        results, ids = out["results"], out["ids"]

        # synchronous baseline: the seed engine's cold blocking drain over
        # the SAME wave (its own executables; first wave pays compile)
        ref_ids = []
        for item in workload:
            if item.form == PENALIZED:
                ref_ids.append(reference.submit_penalized(
                    item.X, item.y, item.lam, item.lambda2))
            else:
                ref_ids.append(reference.submit(
                    item.X, item.y, item.lam, item.lambda2))
        t0 = time.perf_counter()
        ref_results = reference.drain_reference()
        reference_s = time.perf_counter() - t0

        max_dev = ref_dev = pen_dev = 0.0
        n_verified = 0
        for item, rid, ref_rid in zip(workload, ids, ref_ids):
            ref_dev = max(ref_dev, float(jnp.abs(
                results[rid].beta - ref_results[ref_rid].beta).max()))
            if n_verified < args.verify:
                direct = _direct_solve(item, cfg)
                max_dev = max(max_dev,
                              float(jnp.abs(results[rid].beta - direct).max()))
                n_verified += 1
            if item.form == PENALIZED:
                beta_cd = elastic_net_cd(item.X, item.y, item.lam,
                                         item.lambda2).beta
                pen_dev = max(pen_dev, float(jnp.abs(
                    results[rid].beta - beta_cd).max()))

        new_execs_last_wave = sched.stats.bucket_shapes - execs0
        print(f"[serve_en] wave {wave}: {total} reqs "
              f"({args.penalized} pen) in {sched.stats.batches - batches0} "
              f"batches | runtime {out['wall_seconds']*1e3:7.1f} ms  "
              f"reference {reference_s*1e3:7.1f} ms "
              f"({reference_s/max(out['wall_seconds'],1e-9):4.1f}x) | "
              f"p50 {out['p50_latency_s']*1e3:6.1f} ms "
              f"p99 {out['p99_latency_s']*1e3:6.1f} ms | "
              f"new_executables={new_execs_last_wave} "
              f"padded_slots={sched.stats.padded_slots - padded0} "
              f"cache_hit_rate={sched.cache.hit_rate:.2f} | "
              f"max|beta-beta_direct|={max_dev:.2e} "
              f"ref_dev={ref_dev:.2e} pen_dev={pen_dev:.2e}")
        assert max_dev < 1e-6, "runtime diverged from direct solves"
        assert ref_dev < 1e-6, "runtime diverged from drain_reference()"
        assert pen_dev < 1e-5, "penalized path diverged from coordinate descent"

    steady = ("last wave added none" if new_execs_last_wave == 0
              else f"last wave still added {new_execs_last_wave}")
    print(f"[serve_en] done: {sched.stats.requests} runtime requests, "
          f"{sched.stats.bucket_shapes} compiled executables ({steady}); "
          f"launches: {sched.stats.launched_full} full / "
          f"{sched.stats.launched_deadline} deadline / "
          f"{sched.stats.launched_flush} flush; "
          f"warm-start hits {sched.cache.hits}/"
          f"{sched.cache.hits + sched.cache.misses}.")

    if args.trace_out is not None:
        from repro.obs import get_tracer

        get_tracer().export(args.trace_out)
        print(f"[serve_en] trace -> {args.trace_out} "
              f"({len(get_tracer().spans())} events)")
    if args.metrics_json is not None:
        import json

        with open(args.metrics_json, "w") as fh:
            json.dump(sched.registry.snapshot(), fh, indent=2, sort_keys=True)
        print(f"[serve_en] metrics snapshot -> {args.metrics_json}")
    if args.events_out is not None:
        from repro.obs import default_events

        default_events().dump(args.events_out)
        print(f"[serve_en] events -> {args.events_out}")
    if metrics_server is not None:
        metrics_server.shutdown()


if __name__ == "__main__":
    run()
