"""Elastic Net serving launcher: drive ElasticNetEngine with a synthetic
request stream of varied shapes and report batched-vs-sequential throughput,
bucket/executable reuse, and exactness vs direct per-request solves.

    PYTHONPATH=src python -m repro.launch.serve_en --requests 24 --waves 3
"""
from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import SvenConfig, sven
from repro.data.synthetic import make_regression
from repro.serve import ElasticNetEngine


def _random_requests(rng: np.random.Generator, count: int):
    """Varied-shape EN problems with t set from a ridge-ish scale heuristic."""
    reqs = []
    for _ in range(count):
        n = int(rng.integers(20, 90))
        p = int(rng.integers(10, 120))
        X, y, _ = make_regression(n, p, k_true=max(3, p // 8),
                                  rho=0.3, seed=int(rng.integers(1 << 30)))
        t = float(0.1 * jnp.sum(jnp.abs(X.T @ y)) / (X.shape[0]))
        lam2 = float(rng.choice([0.5, 1.0, 2.0]))
        reqs.append((X, y, max(t, 1e-3), lam2))
    return reqs


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24, help="requests per wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=4,
                    help="requests per wave cross-checked against direct sven()")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    cfg = SvenConfig()
    engine = ElasticNetEngine(cfg)

    new_execs_last_wave = 0
    for wave in range(args.waves):
        batches0 = engine.stats.batches
        execs0 = engine.stats.bucket_shapes
        padded0 = engine.stats.padded_slots
        reqs = _random_requests(rng, args.requests)
        ids = [engine.submit(*r) for r in reqs]
        t0 = time.perf_counter()
        out = engine.drain()
        batched_s = time.perf_counter() - t0

        # sequential baseline: one engine-less sven() per request (jit-cached
        # per raw shape — the dispatch pattern the engine replaces)
        t0 = time.perf_counter()
        seq = [jax.block_until_ready(sven(X, y, t, l2, cfg).beta)
               for X, y, t, l2 in reqs]
        sequential_s = time.perf_counter() - t0

        max_dev = 0.0
        for i in range(min(args.verify, len(reqs))):
            max_dev = max(max_dev, float(jnp.abs(out[ids[i]].beta - seq[i]).max()))

        s = engine.stats
        new_execs_last_wave = s.bucket_shapes - execs0
        print(f"[serve_en] wave {wave}: {len(reqs)} reqs in "
              f"{s.batches - batches0} batches | "
              f"batched {batched_s*1e3:7.1f} ms  sequential {sequential_s*1e3:7.1f} ms "
              f"({sequential_s/max(batched_s,1e-9):4.1f}x) | "
              f"new_executables={new_execs_last_wave} "
              f"padded_slots={s.padded_slots - padded0} | "
              f"max|beta-beta_seq|={max_dev:.2e}")
        assert max_dev < 1e-6, "engine diverged from direct sven()"

    steady = ("last wave added none" if new_execs_last_wave == 0
              else f"last wave still added {new_execs_last_wave}")
    print(f"[serve_en] done: {engine.stats.requests} requests, "
          f"{engine.stats.bucket_shapes} compiled executables total ({steady}).")


if __name__ == "__main__":
    run()
