"""Elastic Net serving launcher, now on the continuous-batching runtime:
drive a `ContinuousScheduler` with a reproducible open-loop request stream
(mixed constrained + glmnet-form, adjacent-lambda pattern) and report
runtime-vs-reference throughput, warm-start cache behaviour, executable
reuse, and exactness against direct per-request solves. The synchronous
seed path survives as `ElasticNetEngine.drain_reference()` and is timed as
the baseline every wave.

    PYTHONPATH=src python -m repro.launch.serve_en --requests 24 --waves 3
"""
from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.baselines import elastic_net_cd
from repro.core import SvenConfig, enet, sven
from repro.runtime import (CONSTRAINED, PENALIZED, ContinuousScheduler,
                           LoadSpec, make_workload, run_open_loop)
from repro.serve import ElasticNetEngine


def _direct_solve(item, cfg: SvenConfig):
    if item.form == PENALIZED:
        return enet(item.X, item.y, item.lam, item.lambda2).beta
    return sven(item.X, item.y, item.lam, item.lambda2, cfg).beta


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24, help="requests per wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", type=int, default=4,
                    help="requests per wave cross-checked against direct "
                         "sven()/enet() solves")
    ap.add_argument("--penalized", type=int, default=2,
                    help="glmnet-form requests per wave (verified against "
                         "coordinate descent)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=2e-3,
                    help="coalescing window (s) before a deadline launch")
    ap.add_argument("--cache-dir", type=str, default=None,
                    help="persistent warm-start spill directory: solutions "
                         "survive restarts and are shareable across hosts "
                         "(DESIGN.md §11.2)")
    ap.add_argument("--speculate", action="store_true",
                    help="pre-solve predicted next lambda-crawl points in "
                         "idle batch slots (DESIGN.md §11.3)")
    args = ap.parse_args(argv)

    cfg = SvenConfig()
    total = args.requests + args.penalized
    cache = "default"
    if args.cache_dir is not None:
        from repro.runtime import TieredSolutionCache

        cache = TieredSolutionCache(spill_dir=args.cache_dir)
    sched = ContinuousScheduler(cfg, max_batch=args.max_batch,
                                max_wait=args.max_wait, cache=cache,
                                speculate=args.speculate)
    reference = ElasticNetEngine(cfg, max_batch=args.max_batch, cache=None)

    new_execs_last_wave = 0
    for wave in range(args.waves):
        execs0 = sched.stats.bucket_shapes
        batches0 = sched.stats.batches
        padded0 = sched.stats.padded_slots
        # data_seed pins the datasets: every wave revisits the same problems
        # at freshly drawn adjacent lambdas — steady-state serving traffic,
        # which is what exercises both executable reuse and the warm cache.
        spec = LoadSpec(n_requests=total,
                        penalized_fraction=args.penalized / max(total, 1),
                        seed=args.seed + wave, data_seed=args.seed)
        workload = make_workload(spec)

        out = run_open_loop(sched, workload)
        results, ids = out["results"], out["ids"]

        # synchronous baseline: the seed engine's cold blocking drain over
        # the SAME wave (its own executables; first wave pays compile)
        ref_ids = []
        for item in workload:
            if item.form == PENALIZED:
                ref_ids.append(reference.submit_penalized(
                    item.X, item.y, item.lam, item.lambda2))
            else:
                ref_ids.append(reference.submit(
                    item.X, item.y, item.lam, item.lambda2))
        t0 = time.perf_counter()
        ref_results = reference.drain_reference()
        reference_s = time.perf_counter() - t0

        max_dev = ref_dev = pen_dev = 0.0
        n_verified = 0
        for item, rid, ref_rid in zip(workload, ids, ref_ids):
            ref_dev = max(ref_dev, float(jnp.abs(
                results[rid].beta - ref_results[ref_rid].beta).max()))
            if n_verified < args.verify:
                direct = _direct_solve(item, cfg)
                max_dev = max(max_dev,
                              float(jnp.abs(results[rid].beta - direct).max()))
                n_verified += 1
            if item.form == PENALIZED:
                beta_cd = elastic_net_cd(item.X, item.y, item.lam,
                                         item.lambda2).beta
                pen_dev = max(pen_dev, float(jnp.abs(
                    results[rid].beta - beta_cd).max()))

        new_execs_last_wave = sched.stats.bucket_shapes - execs0
        print(f"[serve_en] wave {wave}: {total} reqs "
              f"({args.penalized} pen) in {sched.stats.batches - batches0} "
              f"batches | runtime {out['wall_seconds']*1e3:7.1f} ms  "
              f"reference {reference_s*1e3:7.1f} ms "
              f"({reference_s/max(out['wall_seconds'],1e-9):4.1f}x) | "
              f"p50 {out['p50_latency_s']*1e3:6.1f} ms "
              f"p99 {out['p99_latency_s']*1e3:6.1f} ms | "
              f"new_executables={new_execs_last_wave} "
              f"padded_slots={sched.stats.padded_slots - padded0} "
              f"cache_hit_rate={sched.cache.hit_rate:.2f} | "
              f"max|beta-beta_direct|={max_dev:.2e} "
              f"ref_dev={ref_dev:.2e} pen_dev={pen_dev:.2e}")
        assert max_dev < 1e-6, "runtime diverged from direct solves"
        assert ref_dev < 1e-6, "runtime diverged from drain_reference()"
        assert pen_dev < 1e-5, "penalized path diverged from coordinate descent"

    steady = ("last wave added none" if new_execs_last_wave == 0
              else f"last wave still added {new_execs_last_wave}")
    print(f"[serve_en] done: {sched.stats.requests} runtime requests, "
          f"{sched.stats.bucket_shapes} compiled executables ({steady}); "
          f"launches: {sched.stats.launched_full} full / "
          f"{sched.stats.launched_deadline} deadline / "
          f"{sched.stats.launched_flush} flush; "
          f"warm-start hits {sched.cache.hits}/"
          f"{sched.cache.hits + sched.cache.misses}.")


if __name__ == "__main__":
    run()
