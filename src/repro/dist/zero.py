"""ZeRO-1 optimizer-state sharding (DESIGN.md §Dist).

Optimizer moments don't enter the forward/backward math, so they can shard
wider than the params they mirror: `_widen_spec` adds the data axis to the
first unsharded dim it divides. launch/train|dryrun place AdamW m/v with
these specs — per-device optimizer memory drops by the data-axis size while
param shardings (and therefore the step HLO) stay untouched; XLA inserts the
gather on the update path.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _uses_axis(entry, axis: str) -> bool:
    if entry is None:
        return False
    if isinstance(entry, (tuple, list)):
        return axis in entry
    return entry == axis


def _widen_spec(spec: P, shape: tuple, axis: str, mesh) -> P:
    """Add `axis` to the FIRST unsharded dim of `spec` that it divides.

    Specs already using `axis`, and shapes with no unsharded dim divisible by
    the axis size, are returned unchanged. Only `mesh.shape` is consulted, so
    any object with a `.shape` axis->size mapping works.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    if any(_uses_axis(e, axis) for e in entries):
        return spec
    size = mesh.shape[axis]
    for i, (entry, dim) in enumerate(zip(entries, shape)):
        if entry is None and dim % size == 0:
            entries[i] = axis
            return P(*entries)
    return spec


def zero1_shardings(param_shardings, param_shapes, axis: str = "data"):
    """NamedSharding tree for optimizer moments: each param's sharding widened
    over `axis` (ZeRO-1). Trees must match; meshes without `axis` pass through."""

    def widen(sh, leaf):
        if axis not in sh.mesh.shape:
            return sh
        return NamedSharding(sh.mesh, _widen_spec(sh.spec, tuple(leaf.shape),
                                                  axis, sh.mesh))

    return jax.tree.map(widen, param_shardings, param_shapes)
