"""Logical-axis distribution layer (DESIGN.md §Dist).

Model code never names mesh axes. It annotates activations with LOGICAL axis
names (`constrain(x, "batch", None, "heads", None)`) and parameters with
logical spec tuples (the per-module `*_sharding()` helpers). A RULE TABLE —
`DEFAULT_RULES`, overridable per config (`cfg.rules_override`) and per shape
cell (launch/dryrun.py) — maps logical names onto physical mesh axes at
lowering time.

`constrain` resolves through the rule table of the innermost active
`mesh_context` and applies `jax.lax.with_sharding_constraint`; outside any
context it is the identity, so the same model code runs unmodified on a
single CPU device (tests) and on the 16x16 production mesh (dry-run).

Resolution is forgiving by construction: a logical axis whose mesh axis does
not evenly divide the array dimension, or whose mesh axis was already
consumed by an earlier dimension of the same spec, resolves to `None`
(unsharded) rather than erroring — small smoke configs and odd head counts
lower on any mesh.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axis (or None = replicated). One table for the whole
# model zoo; per-arch deviations go through cfg.rules_override and per-shape
# deviations through launch/dryrun.py::_rules_for.
DEFAULT_RULES: dict = {
    # activations
    "batch": "data",          # global batch dim (DP)
    "moe_batch": "data",      # MoE capacity buffer's batch dim (pre/post a2a)
    "seq_kv": None,           # KV sequence dim; "model"/"data" for long-ctx cells
    # shared activation/param feature axes
    "embed": None,            # d_model: replicated unless fsdp widens it
    "mlp": "model",           # dense FFN hidden (Megatron col->row TP)
    "vocab": "model",         # logits / embedding-table vocab dim
    "heads": "model",         # query heads (TP)
    "kv_heads": "model",      # KV heads (GQA TP; skipped when it won't divide)
    "ssm_inner": "model",     # mamba d_inner channels
    "ssm_heads": "model",     # SSD state heads
    "experts": "model",       # expert parallelism (mixtral overrides to TP)
    "expert_ffn": None,       # per-expert FFN hidden (TP-within-expert if set)
    "expert_fsdp": None,      # expert weight d_model dim (deepseek: "data")
    "latent": None,           # MLA low-rank latent dims
    # parameter-only pseudo-axis: when set, params_shardings/zero widen each
    # weight's first unsharded divisible dim over this mesh axis (FSDP/ZeRO).
    "fsdp": None,
}

def data_mesh(n_devices: Optional[int] = None, axis_name: str = "data"):
    """A 1-D ("data",) mesh over the process's visible devices.

    The solver layer's default placement: batch-axis sharding of stacked
    problems (`core/batch.py`, `runtime/scheduler.py`), fold placement for
    batched CV (`core/cv.py`) and row-sharded data-parallel solves
    (`core/distributed.py.sven_sharded`) all run on this mesh unless the
    caller supplies their own. The axis name matches DEFAULT_RULES'
    "batch" -> "data" mapping, so `constrain`/`resolve_spec` place batch
    dims across it with no extra rules.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"data_mesh: n_devices={n_devices} but "
                         f"{len(devs)} devices are visible")
    return Mesh(np.asarray(devs[:n]), (axis_name,))


_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextmanager
def mesh_context(mesh: Mesh, rules: Optional[dict] = None):
    """Activate `mesh` + a rule table for constrain()/params_shardings().

    `rules` entries take precedence over DEFAULT_RULES; passing a partial
    override dict and passing a fully merged table are both supported.
    Contexts nest; the innermost wins.
    """
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _stack().append((mesh, merged))
    try:
        yield mesh
    finally:
        _stack().pop()


def current_context() -> Optional[tuple]:
    """(mesh, rules) of the innermost active mesh_context, or None."""
    stack = _stack()
    return stack[-1] if stack else None


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve_spec(names: tuple, shape: tuple, mesh: Mesh, rules: dict) -> P:
    """Logical names (one per dim, None = unsharded) -> PartitionSpec.

    Skips a mesh axis when it would not divide the dimension or was already
    used by an earlier dimension of this spec.
    """
    used: set = set()
    out = []
    for dim, name in zip(shape, names):
        axis = rules.get(name) if name is not None else None
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        if (any(a not in mesh.shape for a in axes) or any(a in used for a in axes)
                or dim % _axis_size(mesh, axes) != 0):
            out.append(None)
            continue
        used.update(axes)
        out.append(axis)
    return P(*out)


def constrain(x: jax.Array, *names) -> jax.Array:
    """with_sharding_constraint(x, rules-resolved spec) — no-op outside a
    mesh_context. `names` gives one logical axis name (or None) per dim."""
    if len(names) != x.ndim:
        raise ValueError(f"constrain: {len(names)} names for rank-{x.ndim} array")
    ctx = current_context()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
