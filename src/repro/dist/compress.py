"""Gradient compression for cross-pod links (DESIGN.md §Dist).

Two ladder rungs below full-precision all-reduce:

* bf16 round-trip — halves gradient wire bytes; unbiased enough for AdamW
  (the f32 master accumulation lives in the optimizer state).
* top-k sparsification with ERROR FEEDBACK — each step emits only the
  `frac` largest-magnitude entries of (gradient + residual) and banks the
  rest in the residual. The residual guarantees every coordinate is
  eventually transmitted: with a constant gradient the running mean of
  emissions converges to the gradient (tested), and `frac=1.0` degenerates
  to exact transmission with a zero residual.

Both operate leaf-wise on gradient pytrees and are pure — state threads
explicitly, so they compose with jit/scan in the train step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def bf16_compress(grads):
    """Cast float leaves to bf16 for the wire; non-floats pass through."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16)
        if jnp.issubdtype(g.dtype, jnp.floating) else g, grads)


def bf16_decompress(wire, like):
    """Cast wire leaves back to the dtypes of `like` (the original grads)."""
    return jax.tree.map(lambda g, l: g.astype(l.dtype), wire, like)


def topk_init(grads):
    """Zero error-feedback residual, one leaf per gradient leaf."""
    return jax.tree.map(jnp.zeros_like, grads)


def _k_for(size: int, frac: float) -> int:
    return max(1, min(size, int(math.ceil(frac * size))))


def topk_compress(grads, state, *, frac: float = 0.01):
    """(grads, residual) -> (values, indices, new_residual).

    Per leaf: form the error-corrected signal c = g + residual, emit its
    top-k entries by magnitude (signed values + flat indices), and keep the
    un-emitted remainder as the new residual.
    """
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = treedef.flatten_up_to(state)
    vals_out, idx_out, res_out = [], [], []
    for g, r in zip(leaves, res_leaves):
        c = (g + r).reshape(-1)
        k = _k_for(c.size, frac)
        _, idx = jax.lax.top_k(jnp.abs(c), k)
        vals = c[idx]
        res_out.append(c.at[idx].set(0).reshape(g.shape))
        vals_out.append(vals)
        idx_out.append(idx)
    return (treedef.unflatten(vals_out), treedef.unflatten(idx_out),
            treedef.unflatten(res_out))


def topk_decompress(values, indices, like):
    """Scatter (values, flat indices) back to dense leaves shaped as `like`."""
    return jax.tree.map(
        lambda v, i, l: jnp.zeros((_size(l),), v.dtype).at[i].set(v).reshape(l.shape),
        values, indices, like)


def _size(leaf) -> int:
    n = 1
    for d in leaf.shape:
        n *= d
    return n
