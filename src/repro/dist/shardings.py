"""Pytree -> NamedSharding resolution through the logical rule table.

`params_shardings` walks a parameter pytree (arrays or ShapeDtypeStructs —
`jax.eval_shape(init_model)` is the usual input) and recognizes the module
sub-dicts by their key signatures (attention / MLA / MoE / dense MLP / SSM /
embedding / norm), applying each module's own `*_sharding()` logical spec.
Stacked scan-over-periods leaves (one extra leading dim vs the module spec)
get a `None` prepended. Leaves nothing unresolved: unknown leaves fall back
to replicated, then the `fsdp` rule (when set) widens every weight's first
unsharded divisible dim — FSDP without per-arch spec tables.

All resolvers require an active `dist.mesh_context`; the mesh and rule table
come from it, never from arguments.
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import current_context, resolve_spec
from repro.dist.zero import _widen_spec

# cache namedtuple field signatures -> per-field logical specs
_CACHE_SPECS = {
    ("k", "v", "pos"): {                      # attention KVCache
        "k": ("batch", "seq_kv", "kv_heads", None),
        "v": ("batch", "seq_kv", "kv_heads", None),
        "pos": ()},
    ("c_kv", "k_rope", "pos"): {              # MLACache (latent + rope keys)
        "c_kv": ("batch", "seq_kv", None),
        "k_rope": ("batch", "seq_kv", None),
        "pos": ()},
    ("conv", "h"): {                          # SSMCache
        "conv": ("batch", None, "ssm_inner"),
        "h": ("batch", "ssm_heads", None, None)},
}


def _require_ctx():
    ctx = current_context()
    if ctx is None:
        raise RuntimeError("dist.shardings resolvers require an active "
                           "dist.mesh_context(mesh, rules=...)")
    return ctx


def _module_specs(d: dict):
    """Match a params sub-dict to its module's logical sharding spec."""
    from repro.models.attention import attention_sharding
    from repro.models.layers import mlp_sharding
    from repro.models.mla import mla_sharding
    from repro.models.moe import moe_sharding
    from repro.models.ssm import ssm_sharding

    keys = set(d)
    if {"w_dq", "w_uq", "w_dkv", "w_kr", "w_uk", "w_uv", "wo"} <= keys:
        return mla_sharding(None)
    if {"wq", "wk", "wv", "wo"} <= keys:
        return attention_sharding(qkv_bias="bq" in keys)
    if {"router", "w_gate", "w_up", "w_down"} <= keys:
        return moe_sharding(SimpleNamespace(n_shared=int("shared" in keys)))
    if {"w_gate", "w_up", "w_down"} <= keys:
        return mlp_sharding()
    if {"w_in", "conv_w", "a_log"} <= keys:
        return ssm_sharding(None)
    if keys == {"table"}:
        return {"table": ("vocab", "embed")}
    if keys == {"scale"}:
        return {"scale": (None,)}
    return None


def _align(names, ndim: int) -> tuple:
    """Pad a logical spec to `ndim` dims (stacked leaves get leading Nones);
    a spec that cannot match the rank resolves fully replicated."""
    names = tuple(names) if names is not None else ()
    if len(names) > ndim:
        return (None,) * ndim
    return (None,) * (ndim - len(names)) + names


def _leaf_sharding(leaf, names, mesh, rules, fsdp=None):
    shape = tuple(leaf.shape)
    spec = resolve_spec(_align(names, len(shape)), shape, mesh, rules)
    if fsdp is not None and fsdp in mesh.shape:
        spec = _widen_spec(spec, shape, fsdp, mesh)
    return NamedSharding(mesh, spec)


def _walk(node, spec, leaf_fn):
    if isinstance(node, dict):
        sub = spec if isinstance(spec, dict) else (_module_specs(node) or {})
        return {k: _walk(v, sub.get(k), leaf_fn) for k, v in node.items()}
    if hasattr(node, "_fields"):              # NamedTuple (cache containers)
        sub = _CACHE_SPECS.get(node._fields, spec if isinstance(spec, dict) else {})
        return type(node)(*(_walk(getattr(node, f), sub.get(f), leaf_fn)
                            for f in node._fields))
    if isinstance(node, (list, tuple)):
        return type(node)(_walk(v, spec, leaf_fn) for v in node)
    return leaf_fn(node, spec if isinstance(spec, (tuple, list)) else None)


def params_shardings(params):
    """Parameter pytree (arrays / ShapeDtypeStructs) -> NamedSharding tree."""
    mesh, rules = _require_ctx()
    fsdp = rules.get("fsdp")
    return _walk(params, None,
                 lambda leaf, names: _leaf_sharding(leaf, names, mesh, rules,
                                                    fsdp=fsdp))


def batch_shardings(batch):
    """Model-input pytree -> shardings: dim 0 is the global batch ("batch"
    rule, normally the data axis), everything else replicated."""
    mesh, rules = _require_ctx()

    def leaf(x):
        names = ("batch",) + (None,) * (max(x.ndim, 1) - 1)
        return _leaf_sharding(x, names[:x.ndim], mesh, rules)

    return jax.tree.map(leaf, batch)


def cache_shardings(caches):
    """Decode-cache pytree -> shardings via the cache-container signatures
    (KVCache / MLACache / SSMCache); stacked body caches align like params."""
    mesh, rules = _require_ctx()
    return _walk(caches, None,
                 lambda leaf, names: _leaf_sharding(leaf, names, mesh, rules))


def replicated(x):
    """Fully replicated NamedSharding(s) on the active mesh, matching x."""
    mesh, _ = _require_ctx()
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), x)
