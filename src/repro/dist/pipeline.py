"""Microbatch pipeline parallelism over a `pipe` mesh axis (DESIGN.md §Dist).

GPipe-style schedule inside one shard_map: stage s holds its own slice of
the stacked stage params; at tick t it runs microbatch t-s (when valid) and
hands its activation to stage s+1 via a single ring `ppermute` — the only
collective in the loop. A run of M microbatches over S stages takes
M + S - 1 ticks with the familiar (S-1)/(M+S-1) bubble.

`sequential_reference` is the semantics oracle: composing the stages in
order over all microbatches must match `pipeline_apply` bit-for-bit modulo
collective reassociation (tested on a forced 4-device host mesh).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def sequential_reference(stage_fn, params, x):
    """Compose the S stages in order on the full (M, Bm, ...) batch."""
    n_stages = jax.tree.leaves(params)[0].shape[0]
    for s in range(n_stages):
        x = stage_fn(jax.tree.map(lambda t: t[s], params), x)
    return x


def pipeline_apply(mesh: Mesh, stage_fn, params, x, *, axis: str = "pipe"):
    """Run `stage_fn` as an S-stage pipeline over microbatches.

    params: pytree with a leading stage dim of size mesh.shape[axis] on every
    leaf; x: (M, Bm, ...) microbatched input. Stages must preserve the
    microbatch shape (residual-stream style), as each stage's output is the
    next stage's input. Returns (M, Bm, ...) outputs, replicated.
    """
    n_stages = mesh.shape[axis]
    n_mb = x.shape[0]
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(p_stage, x_full):
        p = jax.tree.map(lambda t: jnp.squeeze(t, 0), p_stage)
        s = jax.lax.axis_index(axis)
        last = n_stages - 1

        def tick(t, carry):
            state, out_buf = carry
            # stage 0 ingests microbatch t; later stages consume the rotated
            # activation (microbatch t - s, pipelined in from stage s-1)
            feed = x_full[jnp.minimum(t, n_mb - 1)]
            out = stage_fn(p, jnp.where(s == 0, feed, state))
            # stage S-1 retires microbatch t - (S-1) once it is valid
            m_out = t - last
            write = jnp.logical_and(s == last, m_out >= 0)
            slot = jnp.clip(m_out, 0, n_mb - 1)
            out_buf = out_buf.at[slot].add(jnp.where(write, out, 0))
            # reprolint: disable=COL001 -- one ring ppermute per tick IS the
            # GPipe schedule: stage s hands microbatch t to stage s+1 each
            # step; there is nothing to hoist (audited in PR 1, DESIGN.md §4)
            state = jax.lax.ppermute(out, axis, ring)
            return state, out_buf

        init = (jnp.zeros(x_full.shape[1:], x_full.dtype),
                jnp.zeros(x_full.shape, x_full.dtype))
        _, out_buf = jax.lax.fori_loop(0, n_mb + last, tick, init)
        # only the last stage wrote anything; psum replicates the result
        return jax.lax.psum(out_buf, axis)

    param_specs = jax.tree.map(
        lambda t: P(axis, *([None] * (t.ndim - 1))), params)
    return shard_map(local, mesh=mesh, in_specs=(param_specs, P()),
                     out_specs=P(), check_rep=False)(params, x)
