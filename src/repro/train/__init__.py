from repro.train.step import make_train_step, lm_loss

__all__ = ["make_train_step", "lm_loss"]
