"""Training step: causal-LM loss (z-loss regularized), microbatched gradient
accumulation (lax.scan), remat, clipping, AdamW. The returned step_fn is a
plain jittable function — launch/train.py wraps it in jit with in/out
shardings; launch/dryrun.py lowers it AOT."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim.adamw import AdamWState, adamw_update, clip_by_global_norm


def _xent(logits: jax.Array, targets: jax.Array, z_loss: float = 1e-4):
    """Stable CE + z-loss. logits (..., V) f32, targets (...) int32."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = lse - gold
    return ce + z_loss * jnp.square(lse)


def lm_loss(params, cfg: M.ModelConfig, batch: dict, aux_weight: float = 0.01,
            mtp_weight: float = 0.3):
    """Next-token loss across frontends; adds MoE aux and MTP losses."""
    need_hidden = cfg.mtp_depth > 0
    out = M.forward(params, cfg, batch, return_hidden=need_hidden)
    logits, aux = out[0], out[1]
    toks = batch["tokens"]

    if cfg.frontend == "codebooks":          # (B,S,K,V) vs (B,S,K)
        ce = _xent(logits[:, :-1], toks[:, 1:])
        loss = ce.mean()
    elif cfg.frontend == "patches":          # predict text tokens only
        P = cfg.vision_tokens
        txt_logits = logits[:, P:]
        ce = _xent(txt_logits[:, :-1], toks[:, 1:])
        loss = ce.mean()
    else:
        ce = _xent(logits[:, :-1], toks[:, 1:])
        loss = ce.mean()

    metrics = {"ce": loss}
    if cfg.mtp_depth > 0 and cfg.frontend == "tokens":
        h = out[2]
        mtp_logits = M.mtp_logits(params, cfg, h, batch)
        # depth-1 MTP predicts t+2: logits[:, t] vs tokens[:, t+2]
        mtp_ce = _xent(mtp_logits[:, :-2], toks[:, 2:]).mean()
        loss = loss + mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    loss = loss + aux_weight * aux
    metrics["aux"] = aux
    return loss, metrics


def make_train_step(cfg: M.ModelConfig, *, microbatches: int = 1,
                    learning_rate=1e-3, max_grad_norm: float = 1.0,
                    remat: bool = True, lr_schedule=None,
                    grad_shardings=None):
    """Build step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation: the global batch splits into `microbatches` groups
    scanned sequentially; grads are averaged in f32. This bounds per-layer
    activation memory for the huge cells (deepseek-v3 train_4k uses 8).

    grad_shardings: optional NamedSharding tree (same structure as params).
    Constraining each microbatch's grads to the FSDP-sharded param layout
    makes XLA reduce-SCATTER weight grads instead of full-shape all-reducing
    them (ZeRO-2-style; ~2x grad wire on the fsdp'd cells)."""

    # Remat lives at the layer-scan boundary inside the model (cfg.remat) —
    # wrapping the whole loss in jax.checkpoint would still stash every
    # per-layer scan residual during the rematerialized forward.
    loss_fn = lm_loss

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)
        if grad_shardings is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s) if s is not None else g,
                grads, grad_shardings)
        metrics = dict(metrics, loss=loss)
        return grads, metrics

    def step_fn(params, opt_state: AdamWState, batch: dict):
        if microbatches == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def acc_body(carry, mb_batch):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb_batch)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": 0.0, "ce": 0.0, "aux": 0.0}
            if cfg.mtp_depth > 0 and cfg.frontend == "tokens":
                m0["mtp_ce"] = 0.0
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(opt_state.count) if lr_schedule else learning_rate
        new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return step_fn
