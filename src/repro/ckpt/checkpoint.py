"""Fault-tolerant checkpointing (no orbax dependency).

Design for 1000+ nodes:
  * layout-independent: arrays are saved as logical (unsharded) .npy payloads
    chunked per leaf; on restore they are re-sharded to WHATEVER mesh is
    active (elastic scaling: a 512-chip checkpoint restores onto 256 chips or
    vice versa — tested).
  * atomic: writes go to step_XXXX.tmp-<nonce>/ then os.rename onto the final
    directory; a crashed writer never corrupts the latest pointer.
  * self-validating: every leaf records shape/dtype + a crc32 content hash,
    verified on load (bit-rot / torn-write detection).
  * retention: keep_last + keep_every for cheap rollback windows.

On a real multi-host pod each host would write only its addressable shards
(np.asarray on an addressable view); the single-process container exercises
the same code path with fully-addressable arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def _key_name(k):
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)

    paths = ["/".join(_key_name(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any, *, extra: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}-{int(time.time() * 1e6) % 1_000_000}"
    os.makedirs(tmp, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # numpy can't natively persist ml_dtypes (bf16 etc.): store the
            # raw bits as uint16 and record the logical dtype for restore
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": p, "file": fname, "shape": list(arr.shape),
            "dtype": logical_dtype, "crc32": zlib.crc32(arr.tobytes()),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, target_tree: Any, *, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of target_tree; re-shard to `shardings`
    (a matching pytree of NamedSharding / None) if given — this is the
    elastic-rescale path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    final = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        entry = by_path[p]
        arr = np.load(os.path.join(final, entry["file"]))
        if zlib.crc32(arr.tobytes()) != entry["crc32"]:
            raise IOError(f"checksum mismatch for {p} in {final}")
        if entry["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        else:
            arr = arr.astype(entry["dtype"])
        assert list(arr.shape) == list(leaf.shape), (p, arr.shape, leaf.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"], manifest["extra"]


class CheckpointManager:
    """Retention + resume policy around save/restore."""

    def __init__(self, directory: str, keep_last: int = 3, keep_every: int = 0):
        self.directory = directory
        self.keep_last = keep_last
        self.keep_every = keep_every

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return path

    def restore(self, target_tree: Any, step: Optional[int] = None, shardings=None):
        return restore_checkpoint(self.directory, target_tree, step=step,
                                  shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and ".tmp" not in d)
        keep = set(steps[-self.keep_last:])
        if self.keep_every:
            keep |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                              ignore_errors=True)
        # orphaned tmp dirs from crashed writers
        for d in os.listdir(self.directory):
            if ".tmp-" in d:
                shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
