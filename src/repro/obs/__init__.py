"""repro.obs — unified telemetry for the serving stack (DESIGN.md §12).

Three pillars, all host-side and hot-path safe:

    trace    structured spans/instants on the monotonic ns clock with
             Chrome-trace/Perfetto export and optional
             `jax.profiler.TraceAnnotation` bridging (`Tracer`);
    metrics  labeled counters / gauges / exponential-bucket histograms
             with JSON snapshots, Prometheus text exposition and the
             cross-process counter-delta merge protocol the multihost
             coordinator aggregates over (`MetricsRegistry`);
    solve    per-solve records (iterations, KKT, keep-fraction, route,
             modeled-vs-actual seconds) feeding the cost-model residual
             report that validates `core.routing` (`SolveLog`).

Plus `events` (bounded ring of structured JSONL events — host death,
requeue, deadline_exceeded, cache corruption, speculation hit/miss) and
`clock` (the canonical monotonic/walltime aliases the runtime lint pins
timing to).

Environment switches: ``REPRO_TRACE=1`` enables the default tracer at
import; ``REPRO_EVENTS_OUT=/path.jsonl`` dumps the default event log at
interpreter exit.
"""
from __future__ import annotations

import os

from repro.obs import clock
from repro.obs.events import EventLog, default_events, dump_on_exit, emit
from repro.obs.metrics import (Counter, ExponentialHistogram, Gauge,
                               Histogram, MetricsRegistry, default_registry)
from repro.obs.solve import SolveLog, SolveRecord
from repro.obs.trace import (Tracer, disable_tracing, enable_tracing,
                             get_tracer)

__all__ = [
    "clock",
    "Tracer", "get_tracer", "enable_tracing", "disable_tracing",
    "Counter", "Gauge", "Histogram", "ExponentialHistogram",
    "MetricsRegistry", "default_registry",
    "EventLog", "default_events", "emit", "dump_on_exit",
    "SolveLog", "SolveRecord",
]

if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    enable_tracing()
if os.environ.get("REPRO_EVENTS_OUT"):
    dump_on_exit(os.environ["REPRO_EVENTS_OUT"])
