"""Canonical clocks for the serving stack (DESIGN.md §12.1).

Every timestamp the runtime takes goes through these names — a CI lint
(reprolint rule TIM001; `tools/check_timing.py` is its deprecated shim)
rejects new bare ``time.time()`` / ``time.perf_counter()`` call sites
inside ``src/repro/runtime/`` so the choice of clock stays a single,
auditable decision:

    monotonic     durations and deadlines (never jumps backward);
    monotonic_ns  the tracer's span clock (integer ns, cheapest to take);
    walltime      epoch timestamps for things that must survive a process
                  (cache entry creation/TTL, event records, heartbeats).

These are aliases, not wrappers: ``monotonic is time.perf_counter`` holds,
so injected-clock tests and default-argument identity checks keep working
and there is zero call overhead.
"""
from __future__ import annotations

import time

monotonic = time.perf_counter
monotonic_ns = time.perf_counter_ns
walltime = time.time

__all__ = ["monotonic", "monotonic_ns", "walltime"]
