"""Labeled metrics registry: counters, gauges, exponential-bucket
histograms (DESIGN.md §12.2).

One `MetricsRegistry` per owner — each `ContinuousScheduler` (and its
cache) holds a private registry so per-scheduler counter semantics match
the pre-registry attribute counters they replaced; a process-wide
`default_registry()` collects cross-cutting series (solver trace counts,
router decisions). Snapshots serialize to plain JSON; `to_prometheus()`
renders the text exposition format `launch/serve_en.py --metrics-port`
serves.

Multihost aggregation (DESIGN.md §12.4) rides `counter_deltas()`: a worker
snapshots the counter increments since its previous snapshot and piggybacks
them on the result/error/stats messages it already sends; the coordinator
`merge_counter_deltas()` them into one fleet registry plus a per-host view.
Deltas are idempotent to host death — a dead host's final deltas either
arrived (salvaged with its buffered results) or are dropped with the
message, never double-merged, because each delta is consumed by exactly one
snapshot call on the worker side.

Instruments are deliberately lock-free: the serving runtime is
single-threaded per process, and the only concurrent reader (the metrics
HTTP endpoint) tolerates a torn multi-series view.
"""
from __future__ import annotations

import collections
import math
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "ExponentialHistogram",
           "MetricsRegistry", "default_registry"]


class ExponentialHistogram:
    """Fixed-size exponential-bucket histogram of positive samples.

    Bucket ``i`` covers ``(start*factor**(i-1), start*factor**i]``; samples
    at or below ``start`` land in bucket 0, samples beyond the last edge in
    the last bucket. The default geometry (1e-7 s, x1.08, 420 buckets)
    spans sub-microsecond to ~1e7 seconds with <= 4% relative quantile
    error — memory is O(buckets), never O(samples), which is the point:
    rolled-up latency state stays bounded under an unbounded request
    stream (the `LatencyRecorder` leak fix rides on this).
    """

    __slots__ = ("start", "factor", "_log_factor", "counts", "count",
                 "sum", "min", "max")

    def __init__(self, *, start: float = 1e-7, factor: float = 1.08,
                 n_buckets: int = 420) -> None:
        if not (start > 0 and factor > 1 and n_buckets >= 1):
            raise ValueError(f"ExponentialHistogram: need start > 0, "
                             f"factor > 1, n_buckets >= 1 "
                             f"(got {start}/{factor}/{n_buckets})")
        self.start = start
        self.factor = factor
        self._log_factor = math.log(factor)
        self.counts = [0] * n_buckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, v: float) -> int:
        if v <= self.start:
            return 0
        i = int(math.ceil(math.log(v / self.start) / self._log_factor))
        return min(i, len(self.counts) - 1)

    def observe(self, v: float) -> None:
        self.counts[self._index(v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def edges(self) -> List[float]:
        """Upper edge of every bucket (the Prometheus ``le`` values)."""
        return [self.start * self.factor ** i for i in range(len(self.counts))]

    def quantile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]); exact at the
        recorded min/max, within one bucket's width elsewhere."""
        if self.count == 0:
            raise ValueError("quantile: empty histogram")
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.start * self.factor ** (i - 1) if i else 0.0
                hi = self.start * self.factor ** i
                frac = (target - cum) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def merge(self, other: "ExponentialHistogram") -> None:
        if (other.start != self.start or other.factor != self.factor
                or len(other.counts) != len(self.counts)):
            raise ValueError("merge: histogram geometries differ")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def reset(self) -> None:
        self.counts = [0] * len(self.counts)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class _Instrument:
    kind = "abstract"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labels)}")
        return tuple(str(labels[k]) for k in self.labelnames)


class Counter(_Instrument):
    """Monotone counter (resettable — this is an introspection tool, not a
    long-lived Prometheus server; `set()` exists for the read-through shims
    that keep ``stats.requests += 1`` style call sites working)."""

    kind = "counter"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def set(self, value: float, **labels) -> None:
        self._values[self._key(labels)] = value

    def value(self, **labels) -> float:
        return self._values.get(self._key(labels), 0.0)

    def total(self) -> float:
        return sum(self._values.values())

    def series(self) -> Dict[tuple, float]:
        return dict(self._values)

    def reset(self) -> None:
        self._values.clear()


class Gauge(Counter):
    """Point-in-time value; same storage as Counter, different exposition
    type (and excluded from cross-host delta merging — a gauge has no
    meaningful sum across hosts)."""

    kind = "gauge"


class Histogram(_Instrument):
    """Labeled family of `ExponentialHistogram`s."""

    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), *, start=1e-7,
                 factor=1.08, n_buckets=420):
        super().__init__(name, help, labelnames)
        self._geometry = dict(start=start, factor=factor, n_buckets=n_buckets)
        self._series: Dict[tuple, ExponentialHistogram] = {}

    def _hist(self, labels: dict) -> ExponentialHistogram:
        key = self._key(labels)
        h = self._series.get(key)
        if h is None:
            h = self._series[key] = ExponentialHistogram(**self._geometry)
        return h

    def observe(self, v: float, **labels) -> None:
        self._hist(labels).observe(v)

    def quantile(self, q: float, **labels) -> float:
        return self._hist(labels).quantile(q)

    def stats(self, **labels) -> dict:
        h = self._hist(labels)
        return {"count": h.count, "sum": h.sum,
                "min": (None if h.count == 0 else h.min),
                "max": (None if h.count == 0 else h.max)}

    def series(self) -> Dict[tuple, ExponentialHistogram]:
        return self._series

    def reset(self) -> None:
        self._series.clear()


def _labelstr(labelnames, key) -> str:
    return ",".join(f'{n}="{v}"' for n, v in zip(labelnames, key))


class MetricsRegistry:
    """Get-or-create instrument registry with JSON / Prometheus export.

    Naming conventions (DESIGN.md §12.2): snake_case, unit-suffixed
    (``_total`` counters, ``_seconds`` histograms), label cardinality
    bounded by construction (reasons, statuses, route paths — never request
    ids or fingerprints).
    """

    def __init__(self) -> None:
        self._instruments: "collections.OrderedDict[str, _Instrument]" = (
            collections.OrderedDict())
        self._delta_marks: Dict[str, Dict[tuple, float]] = {}

    def _get(self, cls, name, help, labelnames, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, labelnames, **kw)
            self._instruments[name] = inst
            return inst
        if not isinstance(inst, cls) or type(inst) is not cls:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{inst.kind}, requested {cls.kind}")
        if tuple(labelnames) != inst.labelnames:
            raise ValueError(f"metric {name!r} labelnames mismatch: "
                             f"{inst.labelnames} vs {tuple(labelnames)}")
        return inst

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(), **geometry) -> Histogram:
        return self._get(Histogram, name, help, labelnames, **geometry)

    def instruments(self) -> Iterable[_Instrument]:
        return list(self._instruments.values())

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-JSON view of every series (histograms roll up to
        count/sum/min/max + headline quantiles, not raw buckets)."""
        out: dict = {}
        for inst in self._instruments.values():
            if isinstance(inst, Histogram):
                series = {}
                for key, h in inst.series().items():
                    s = {"count": h.count, "sum": h.sum}
                    if h.count:
                        s.update(min=h.min, max=h.max,
                                 p50=h.quantile(50.0), p99=h.quantile(99.0))
                    series[_labelstr(inst.labelnames, key) or "_"] = s
            else:
                series = {_labelstr(inst.labelnames, k) or "_": v
                          for k, v in inst.series().items()}
            out[inst.name] = {"type": inst.kind, "help": inst.help,
                              "values": series}
        return out

    def to_prometheus(self) -> str:
        """Prometheus/OpenMetrics text exposition."""
        lines: List[str] = []
        for inst in self._instruments.values():
            if inst.help:
                lines.append(f"# HELP {inst.name} {inst.help}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            if isinstance(inst, Histogram):
                for key, h in inst.series().items():
                    base = _labelstr(inst.labelnames, key)
                    sep = "," if base else ""
                    cum = 0
                    for edge, c in zip(h.edges(), h.counts):
                        cum += c
                        lines.append(f'{inst.name}_bucket{{{base}{sep}'
                                     f'le="{edge:.6g}"}} {cum}')
                    lines.append(f'{inst.name}_bucket{{{base}{sep}'
                                 f'le="+Inf"}} {h.count}')
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{inst.name}_sum{suffix} {h.sum:.9g}")
                    lines.append(f"{inst.name}_count{suffix} {h.count}")
            else:
                series = inst.series()
                if not series and not inst.labelnames:
                    series = {(): 0.0}   # expose unlabeled zeros explicitly
                for key, v in series.items():
                    base = _labelstr(inst.labelnames, key)
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{inst.name}{suffix} {v:.9g}")
        return "\n".join(lines) + "\n"

    # -- cross-process delta protocol (DESIGN.md §12.4) ---------------------

    def counter_deltas(self) -> dict:
        """Counter increments since the previous `counter_deltas()` call.

        Consumes the increments (advances the watermark), so each delta is
        merged at most once downstream — the idempotence the multihost
        salvage path relies on. Gauges and histograms are per-process by
        design and not shipped.
        """
        out: dict = {}
        for inst in self._instruments.values():
            if type(inst) is not Counter:
                continue
            marks = self._delta_marks.setdefault(inst.name, {})
            deltas = []
            for key, v in inst.series().items():
                d = v - marks.get(key, 0.0)
                if d:
                    deltas.append([list(key), d])
                    marks[key] = v
            if deltas:
                out[inst.name] = {"labelnames": list(inst.labelnames),
                                  "deltas": deltas}
        return out

    def merge_counter_deltas(self, deltas: Optional[dict]) -> None:
        for name, payload in (deltas or {}).items():
            c = self.counter(name, labelnames=tuple(payload["labelnames"]))
            for key, d in payload["deltas"]:
                c.inc(d, **dict(zip(payload["labelnames"], key)))

    def reset(self) -> None:
        for inst in self._instruments.values():
            inst.reset()
        self._delta_marks.clear()

    def reset_instrument(self, name: str) -> None:
        """Zero one instrument AND its delta watermark (so a post-reset
        `counter_deltas()` never ships a negative delta)."""
        inst = self._instruments.get(name)
        if inst is not None:
            inst.reset()
        self._delta_marks.pop(name, None)


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry for cross-cutting series (solver trace counts,
    router decisions) — per-scheduler counters live on their own registry."""
    return _DEFAULT
