"""Per-solve telemetry: what each dispatched batch actually cost vs what
the cost model priced it at (DESIGN.md §12.5).

Every harvested batch appends one `SolveRecord`: the bucket geometry,
route decision, solver effort (iterations, final KKT violation), the
screening keep-fraction (nonzero share of the solution — the quantity
gap-safe screening trades against), and modeled-vs-actual seconds. The
modeled price is `core.routing.estimate_batch_seconds` taken AT DISPATCH
(so it reflects the calibration the router actually used), the actual is
dispatch -> harvest wall time with the blocking wait broken out.

`SolveLog.residual_report()` folds the records into the cost-model
residual summary serialized into BENCH_path.json's ``obs`` section: per
route path, the distribution of log10(actual/modeled). A drifting residual
is the signal to re-run `core.routing.calibrate(force=True)` — this is the
data needed to validate and later recalibrate the router, closing the PR 6
loop.
"""
from __future__ import annotations

import collections
import math
from typing import List, NamedTuple

__all__ = ["SolveRecord", "SolveLog"]


class SolveRecord(NamedTuple):
    """One dispatched-and-harvested stacked solve."""

    bucket: tuple           # (bn, bp)
    form: str               # constrained | penalized
    batch: int              # padded batch B the executable ran at
    b_real: int             # real (non-padding) requests in the batch
    route_path: str         # router decision: single | sharded | batch
    modeled_s: float        # cost-model price at dispatch (0.0 = unmodeled)
    actual_s: float         # dispatch -> harvest wall seconds
    blocked_s: float        # host seconds inside block_until_ready
    iters_max: int          # max solver iterations across the batch
    iters_mean: float
    kkt_max: float          # worst EN KKT violation across real slots
    keep_fraction: float    # nonzero share of the solution (screening keep)


class SolveLog:
    """Bounded log of `SolveRecord`s with a cost-model residual report."""

    def __init__(self, *, capacity: int = 4096) -> None:
        self._records: collections.deque = collections.deque(maxlen=capacity)
        self.recorded = 0

    def add(self, record: SolveRecord) -> None:
        self._records.append(record)
        self.recorded += 1

    def __len__(self) -> int:
        return len(self._records)

    def records(self) -> List[SolveRecord]:
        return list(self._records)

    def residual_report(self) -> dict:
        """Modeled-vs-actual summary per route path.

        ``log10_ratio`` statistics are over log10(actual/modeled): 0 means
        the calibration prices this path perfectly, +1 means solves run 10x
        slower than modeled (recalibrate), negative means the model is
        pessimistic (routing may be leaving fan-out wins on the table).
        Records without a model price (pinned meshes, unpriced forms) are
        counted but excluded from the ratio stats.
        """
        by_path: dict = {}
        unmodeled = 0
        for r in self._records:
            if r.modeled_s <= 0.0 or r.actual_s <= 0.0:
                unmodeled += 1
                continue
            by_path.setdefault(r.route_path, []).append(r)
        paths = {}
        for path, recs in sorted(by_path.items()):
            ratios = sorted(math.log10(r.actual_s / r.modeled_s)
                            for r in recs)
            n = len(ratios)
            paths[path] = {
                "n": n,
                "modeled_s_mean": sum(r.modeled_s for r in recs) / n,
                "actual_s_mean": sum(r.actual_s for r in recs) / n,
                "log10_ratio_mean": sum(ratios) / n,
                "log10_ratio_p50": ratios[n // 2],
                "log10_ratio_max_abs": max(abs(ratios[0]), abs(ratios[-1])),
            }
        return {"n_records": len(self._records), "n_unmodeled": unmodeled,
                "by_path": paths}

    def clear(self) -> None:
        self._records.clear()
        self.recorded = 0
