"""Structured runtime events: a bounded ring buffer of JSONL-able records
(DESIGN.md §12.3).

Events are the rare, high-signal state transitions metrics can only count
and traces only timestamp — host death, batch requeue, deadline_exceeded
terminals, cache corruption-degrade, speculation hit/miss. Each record is
a plain dict ``{"ts": <epoch s>, "kind": <str>, ...fields}`` kept in a
fixed-capacity ring (old events roll off; `emitted` keeps the true total),
dumpable as JSON-lines at any time or automatically on interpreter exit
(``REPRO_EVENTS_OUT=/path/file.jsonl`` or `dump_on_exit()`).

Events always record (they are rare by construction); the tracer mirrors
each one as an instant when tracing is enabled, so the Perfetto view shows
WHERE in the request flow a death/requeue landed.
"""
from __future__ import annotations

import atexit
import collections
import json
from typing import List, Optional

from repro.obs import clock as _clock
from repro.obs import trace as _trace

__all__ = ["EventLog", "default_events", "emit", "dump_on_exit"]


class EventLog:
    """Bounded ring buffer of structured events."""

    def __init__(self, *, capacity: int = 4096) -> None:
        if capacity < 1:
            raise ValueError(f"EventLog: capacity >= 1 required "
                             f"(got {capacity})")
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._counts: collections.Counter = collections.Counter()
        self.emitted = 0

    def emit(self, kind: str, **fields) -> dict:
        record = {"ts": _clock.walltime(), "kind": kind, **fields}
        self._events.append(record)
        self._counts[kind] += 1
        self.emitted += 1
        _trace.get_tracer().instant(f"event:{kind}", **fields)
        return record

    def __len__(self) -> int:
        return len(self._events)

    def records(self, kind: Optional[str] = None) -> List[dict]:
        if kind is None:
            return list(self._events)
        return [e for e in self._events if e["kind"] == kind]

    def counts(self) -> dict:
        """kind -> total emitted (rolled-off events included)."""
        return dict(self._counts)

    def to_jsonl(self) -> str:
        return "".join(json.dumps(e, default=str) + "\n"
                       for e in self._events)

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path

    def clear(self) -> None:
        self._events.clear()
        self._counts.clear()
        self.emitted = 0


_DEFAULT = EventLog()


def default_events() -> EventLog:
    return _DEFAULT


def emit(kind: str, **fields) -> dict:
    """Emit onto the process-default event log."""
    return _DEFAULT.emit(kind, **fields)


_exit_hooks: set = set()


def dump_on_exit(path: str) -> None:
    """Dump the default event log to `path` at interpreter exit (idempotent
    per path; a crashed run still leaves its last `capacity` events)."""
    if path in _exit_hooks:
        return
    _exit_hooks.add(path)
    atexit.register(lambda: _DEFAULT.dump(path))
