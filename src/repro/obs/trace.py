"""Structured host-side tracing: spans, instants, Chrome-trace export
(DESIGN.md §12.1).

A `Tracer` records nested spans on the host's monotonic ns clock into a
bounded deque — no device syncs, no allocation beyond one tuple per span,
and a disabled tracer costs one attribute check per span site, so the
instrumentation can stay in the serving hot path permanently (the
telemetry-overhead gate in ``benchmarks/bench_obs.py`` holds enabled
tracing to <= 1.10x disabled p99).

Span taxonomy (DESIGN.md §12.1 — the names CI schema-checks for):

    admit          one request admitted (scheduler.submit)
    launch         one bucket dispatched: pad/stack/warm-start + the async
                   solve call (reason=full|deadline|flush)
    warm_start     cache lookups for one launch (hits recorded in args)
    harvest.block  the only blocking wait in the runtime
    complete       unpad + cache refill + delivery (parent of none)
    mh.place       coordinator placed a batch on a host
    route          router decision instant (path + full price table)
    trace:<entry>  solver (re)trace instant — nonzero steady-state count
                   is the regression the zero-retrace CI gate catches

Export is Chrome-trace JSON (``chrome://tracing`` / Perfetto: "X" complete
events, µs timestamps). With ``annotate=True`` each span also enters a
`jax.profiler.TraceAnnotation`, so when a jax profile is being captured the
host spans line up with device timelines in the same Perfetto view.
"""
from __future__ import annotations

import collections
import json
import os
import threading
from typing import Optional

from repro.obs import clock as _clock

__all__ = ["Tracer", "get_tracer", "enable_tracing", "disable_tracing"]


def _jax_annotation(name: str):
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:  # noqa: BLE001 — profiler API absent: spans still work
        return None


class _Span:
    """Reusable context manager for one span — cheaper than a generator
    contextmanager on the per-request path."""

    __slots__ = ("tracer", "name", "args", "t0", "parent", "annot")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = None

    def __enter__(self):
        tr = self.tracer
        if not tr.enabled:
            return self
        stack = tr._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self.annot = None
        if tr.annotate:
            annot = _jax_annotation(self.name)
            if annot is not None:
                annot.__enter__()
                self.annot = annot
        self.t0 = _clock.monotonic_ns()
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        if not tr.enabled or self.t0 is None:
            return False   # disabled, or toggled mid-span: record nothing
        dur = _clock.monotonic_ns() - self.t0
        if self.annot is not None:
            self.annot.__exit__(*exc)
        stack = tr._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        tr._record("X", self.name, self.parent, self.t0, dur, self.args)
        return False


class _NoopSpan:
    """Shared do-nothing span handed out while tracing is disabled — keeps
    the disabled hot path allocation-free (no `_Span` per call site)."""

    __slots__ = ()
    args = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class Tracer:
    """Bounded in-memory span recorder with Chrome-trace export."""

    def __init__(self, *, capacity: int = 200_000) -> None:
        self.enabled = False
        self.annotate = False
        self._spans: collections.deque = collections.deque(maxlen=capacity)
        self._counts: collections.Counter = collections.Counter()
        self._tls = threading.local()

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _record(self, phase, name, parent, t0_ns, dur_ns, args) -> None:
        self._counts[name] += 1
        self._spans.append((phase, name, parent,
                            threading.get_ident(), t0_ns, dur_ns, args))

    # -- control -----------------------------------------------------------

    def enable(self, *, annotate: bool = False) -> "Tracer":
        self.enabled = True
        self.annotate = annotate
        return self

    def disable(self) -> None:
        self.enabled = False
        self.annotate = False

    def reset(self) -> None:
        self._spans.clear()
        self._counts.clear()

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **args):
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, args or None)

    def traced(self, name: Optional[str] = None):
        """Decorator form: ``@tracer.traced("phase")``."""
        def deco(fn):
            span_name = name or fn.__qualname__

            def wrapper(*a, **kw):
                with self.span(span_name):
                    return fn(*a, **kw)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        stack = self._stack()
        self._record("i", name, stack[-1] if stack else None,
                     _clock.monotonic_ns(), 0, args or None)

    # -- introspection / export --------------------------------------------

    def __len__(self) -> int:
        return len(self._spans)

    def counts(self) -> dict:
        """Span-name -> recorded occurrences (includes rolled-off spans)."""
        return dict(self._counts)

    def spans(self) -> list:
        return list(self._spans)

    def chrome_trace(self) -> dict:
        """The retained spans as a Chrome-trace/Perfetto JSON object."""
        pid = os.getpid()
        events = []
        for phase, name, parent, tid, t0_ns, dur_ns, args in self._spans:
            ev = {"ph": phase, "name": name, "cat": "repro",
                  "pid": pid, "tid": tid, "ts": t0_ns / 1e3}
            if phase == "X":
                ev["dur"] = dur_ns / 1e3
            else:
                ev["s"] = "t"
            ev["args"] = dict(args or {})
            if parent is not None:
                ev["args"]["parent"] = parent
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON; returns the path written."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, default=str)
        return path


_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-default tracer every runtime component records into
    unless handed a private one."""
    return _TRACER


def enable_tracing(*, annotate: bool = False) -> Tracer:
    return _TRACER.enable(annotate=annotate)


def disable_tracing() -> None:
    _TRACER.disable()
