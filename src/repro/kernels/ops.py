"""Jitted public wrappers for the kernel bodies: backend resolution through
the per-backend registry (kernels/registry.py), tile selection through the
autotuner (kernels/autotune.py), padding, dtype/precision handling, and the
deprecation shims that keep the old `use_pallas`/`interpret` flags working.

One `backend` enum drives everything (DESIGN.md §10): a resolved value from
`registry.RESOLVED_BACKENDS` names both the kernel body ("tpu" Pallas,
"gpu" Pallas/Triton, "ref" jnp oracle) and how it executes (compiled vs
interpret). `None`/"auto" resolves from the OPERANDS' committed devices,
never from the process default backend at trace time (the §9.3 bugfix);
traced callers (`core/sven.py`, the bucket executables) thread an explicit
resolved value from `SvenConfig.backend`, pinned pre-trace by
`core.sven.resolve_backend`.

Precision (`"f32" | "bf16" | "tf32"`) selects the MAC path of the Gram
kernel and the storage dtype fed to the fused stats kernels; accumulation
is f32 in every cell of the matrix (README "Backends & precision"). The
"ref" body ignores it — the oracle always computes at full input precision.

Tiles: explicit `bm=`/`bn=`/`bk=`/`bp=` kwargs always win; unset tiles come
from `autotune.tiles_for`, which measures candidates once per (body,
shape-bucket) on compiled backends and uses static defaults elsewhere.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune, registry
from repro.kernels import gram as _gram
from repro.kernels import gram_gpu as _gram_gpu          # registers gpu body
from repro.kernels import hinge as _hinge
from repro.kernels import hinge_stats as _hs
from repro.kernels import hinge_stats_gpu as _hs_gpu     # registers gpu body
from repro.kernels import ref as _ref

PRECISIONS = ("f32", "bf16", "tf32")

# bodies not defined with a @register decorator wire up here, once, at
# import time (re-import just overwrites the same keys)
registry.register("shifted_gram", "tpu")(_gram.gram_pallas_raw)
registry.register("shifted_gram", "ref")(_ref.gram_blocks_ref)
registry.register("hinge_stats", "tpu")(_hs.hinge_stats_raw)
registry.register("hinge_stats", "ref")(_ref.hinge_stats_ref)
registry.register("hinge_xtv", "tpu")(_hinge.hinge_xtv_raw)
registry.register("hinge_xtv", "ref")(_ref.hinge_xtv_ref)
registry.register("hinge_xd", "tpu")(_hinge.hinge_xd_raw)
registry.register("hinge_xd", "ref")(_ref.hinge_xd_ref)


def resolve_interpret(interpret, *arrays) -> bool:
    """Deprecated two-flag-era helper: the interpret bit of the resolved
    backend. Kept callable because DESIGN.md §9.3 and older call sites name
    it; new code should use `registry.resolve_kernel_backend`."""
    if interpret is not None:
        return bool(interpret)
    return registry.split_backend(
        registry.resolve_kernel_backend(None, *arrays))[1]


def _resolve(backend: Optional[str], use_pallas, interpret, what: str,
             *arrays) -> str:
    """Fold the deprecated flags into one RESOLVED backend string."""
    if use_pallas is not None or interpret is not None:
        warnings.warn(
            f"{what}: use_pallas=/interpret= are deprecated — pass "
            f"backend= (one of {registry.RESOLVED_BACKENDS}, 'auto', or "
            f"'ref' for the old use_pallas=False)", DeprecationWarning,
            stacklevel=3)
    if use_pallas is False:
        return "ref"
    resolved = registry.resolve_kernel_backend(backend, *arrays)
    if interpret is not None and resolved != "ref":
        body, _ = registry.split_backend(resolved)
        resolved = body + ("_interpret" if interpret else "")
    return resolved


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _next_mult(sz: int, base: int = 128) -> int:
    """Largest power-of-two-ish tile not exceeding the padded size."""
    m = base
    while m > sz:
        m //= 2
    return max(m, 8)


def _storage(Xp: jax.Array, precision: str) -> jax.Array:
    """bf16 keeps reduced-precision STORAGE (the Rgtsvm recipe — kernels
    accumulate f32 regardless); f32/tf32 leave the operand alone."""
    return Xp.astype(jnp.bfloat16) if precision == "bf16" else Xp


def _gram_tiles(backend: str, n: int, p: int, bm, bn, bk,
                precision: str) -> dict:
    if bm is not None and bn is not None and bk is not None:
        return {"bm": bm, "bn": bn, "bk": bk}
    dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
    tiles = autotune.tiles_for("shifted_gram", backend, n, p, dtype)
    for k, v in (("bm", bm), ("bn", bn), ("bk", bk)):
        if v is not None:
            tiles[k] = v
    return tiles


# -- shifted Gram -----------------------------------------------------------

def shifted_gram(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    *,
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
    flatten: bool = True,
    backend: Optional[str] = None,
    precision: str = "f32",
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """K = Zhat^T Zhat of the SVEN dual, as (2p, 2p) (flatten) or (2,2,p,p).

    `backend=None`/"auto" resolves against X's committed devices (see
    `registry.resolve_kernel_backend`); traced call sites must pass an
    explicit resolved value. `use_pallas=`/`interpret=` are the deprecated
    two-flag spelling.
    """
    resolved = _resolve(backend, use_pallas, interpret, "shifted_gram", X, y)
    tiles = _gram_tiles(resolved, *X.shape, bm, bn, bk, precision)
    return _shifted_gram_jit(X, y, t, flatten=flatten, backend=resolved,
                             precision=precision, **tiles)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "flatten", "backend",
                                   "precision"))
def _shifted_gram_jit(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    *,
    bm: int,
    bn: int,
    bk: int,
    flatten: bool,
    backend: str,
    precision: str,
) -> jax.Array:
    n, p = X.shape
    impl, body, interp = registry.lookup("shifted_gram", backend)
    if body == "ref":
        Kb = _ref.gram_blocks_ref(X, y, t)
        return _ref.flatten_gram(Kb) if flatten else Kb
    Xp = _storage(_pad_to(_pad_to(X, 0, bk), 1, max(bm, bn)), precision)
    y2d = _storage(_pad_to(y[:, None], 0, bk).astype(X.dtype), precision)
    invt = (1.0 / jnp.asarray(t, jnp.float32)).reshape(1, 1)
    Kb = impl(Xp, y2d, invt, bm=bm, bn=bn, bk=bk, precision=precision,
              interpret=interp)
    Kb = Kb[:, :, :p, :p]
    return _ref.flatten_gram(Kb) if flatten else Kb


# -- hinge Hessian mat-vec --------------------------------------------------

def hinge_hessian_matvec(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    C: jax.Array | float,
    act_top: jax.Array,
    act_bot: jax.Array,
    v: jax.Array,
    *,
    bp: int = 512,
    bn: int = 512,
    bk: int = 512,
    backend: Optional[str] = None,
    precision: str = "f32",
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """H v = v + 2C Xhat^T(act . (Xhat v)) via two fused GEMV passes.

    Only the TPU body exists — the op is GEMV-shaped and memory-bound, so
    on GPU the registry serves the "ref" oracle (XLA/cuBLAS is the honest
    choice there; see README "Backends & precision").
    """
    resolved = _resolve(backend, use_pallas, interpret,
                        "hinge_hessian_matvec", X, v)
    return _hinge_hessian_matvec_jit(
        X, y, t, C, act_top, act_bot, v, bp=bp, bn=bn, bk=bk,
        backend=resolved, precision=precision)


@partial(jax.jit, static_argnames=("bp", "bn", "bk", "backend", "precision"))
def _hinge_hessian_matvec_jit(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    C: jax.Array | float,
    act_top: jax.Array,
    act_bot: jax.Array,
    v: jax.Array,
    *,
    bp: int,
    bn: int,
    bk: int,
    backend: str,
    precision: str,
) -> jax.Array:
    impl_xtv, body, interp = registry.lookup("hinge_xtv", backend)
    if body == "ref":
        return _ref.hessian_matvec_ref(X, y, t, C, act_top, act_bot, v)
    impl_xd, _, _ = registry.lookup("hinge_xd", backend)
    n, p = X.shape
    bp_ = min(bp, _next_mult(p))
    bk1 = min(bk, _next_mult(n))
    Xp1 = _storage(_pad_to(_pad_to(X, 0, bk1), 1, bp_), precision)
    v2d = _pad_to(v[:, None], 0, bk1).astype(jnp.float32)
    y2d = _pad_to(y[:, None], 0, bk1).astype(jnp.float32)
    at2d = _pad_to(act_top[:, None].astype(jnp.float32), 0, bp_)
    ab2d = _pad_to(act_bot[:, None].astype(jnp.float32), 0, bp_)
    invt = (1.0 / jnp.asarray(t, jnp.float32)).reshape(1, 1)
    d2d, e_part = impl_xtv(Xp1, v2d, y2d, at2d, ab2d, invt,
                           bp=bp_, bk=bk1, interpret=interp)
    e = jnp.sum(e_part)

    bn_ = min(bn, _next_mult(n))
    bk2 = min(bk, _next_mult(p))
    Xp2 = _storage(_pad_to(_pad_to(X, 0, bn_), 1, bk2), precision)
    d2d = _pad_to(d2d[: p], 0, bk2)
    y2d2 = _pad_to(y[:, None], 0, bn_).astype(jnp.float32)
    v2d2 = _pad_to(v[:, None], 0, bn_).astype(jnp.float32)
    scal = jnp.stack([1.0 / jnp.asarray(t, jnp.float32),
                      e.astype(jnp.float32),
                      2.0 * jnp.asarray(C, jnp.float32)]).reshape(3, 1)
    hv = impl_xd(Xp2, d2d, y2d2, v2d2, scal, bn=bn_, bk=bk2,
                 interpret=interp)
    return hv[:n, 0].astype(v.dtype)


# -- fused Newton outer-step stats ------------------------------------------

def hinge_stats(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    w: jax.Array,
    C: jax.Array | float,
    *,
    bp: Optional[int] = None,
    bk: Optional[int] = None,
    backend: Optional[str] = None,
    precision: str = "f32",
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
):
    """Fused Newton outer-step stats: (margin (2p,), act (2p,), loss, galpha).

    Served by the TPU body, the GPU (Triton) body, or the ref oracle per
    the resolved backend; the deprecated flags shim as in `shifted_gram`.
    """
    resolved = _resolve(backend, use_pallas, interpret, "hinge_stats", X, w)
    if bp is None or bk is None:
        tiles = autotune.tiles_for("hinge_stats", resolved, *X.shape)
        bp = bp if bp is not None else tiles["bp"]
        bk = bk if bk is not None else tiles["bk"]
    return _hinge_stats_jit(X, y, t, w, C, bp=bp, bk=bk, backend=resolved,
                            precision=precision)


@partial(jax.jit, static_argnames=("bp", "bk", "backend", "precision"))
def _hinge_stats_jit(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    w: jax.Array,
    C: jax.Array | float,
    *,
    bp: int,
    bk: int,
    backend: str,
    precision: str,
):
    impl, body, interp = registry.lookup("hinge_stats", backend)
    if body == "ref":
        return _ref.hinge_stats_ref(X, y, t, w, C)
    n, p = X.shape
    bp_ = min(bp, _next_mult(p))
    bk_ = min(bk, _next_mult(n))
    Xp = _storage(_pad_to(_pad_to(X, 0, bk_), 1, bp_), precision)
    w2d = _pad_to(w[:, None], 0, bk_).astype(jnp.float32)
    y2d = _pad_to(y[:, None], 0, bk_).astype(jnp.float32)
    scal = jnp.stack([1.0 / jnp.asarray(t, jnp.float32),
                      jnp.asarray(C, jnp.float32)]).reshape(2, 1)
    mt, mb, gt, gb, lp = impl(Xp, w2d, y2d, scal, bp=bp_, bk=bk_,
                              interpret=interp)
    # padded feature columns produce margin 1-eps... no: padded cols give a=0,
    # o=-+byw; slice them off before assembling
    margin = jnp.concatenate([mt[:p, 0], mb[:p, 0]]).astype(w.dtype)
    act = (margin < 1.0).astype(w.dtype)
    galpha = jnp.concatenate([gt[:p, 0], gb[:p, 0]]).astype(w.dtype)
    # loss partials include padded columns of the LAST block: recompute their
    # contribution exactly by masking is cheap: padded cols have a=0 =>
    # xi_top = act*(1-(-byw))... subtract analytically:
    pad = (-p) % bp_
    byw = (y @ w) / jnp.asarray(t, w.dtype)
    xi_pad = jnp.maximum(1.0 + byw, 0.0)   # padded cols: a=0 => both halves
    pad_loss = pad * jnp.asarray(C, jnp.float32) * 2.0 * xi_pad ** 2
    loss = 0.5 * (w @ w) + jnp.sum(lp) - pad_loss
    return margin, act, loss.astype(w.dtype), galpha


# -- sharded Gram -----------------------------------------------------------

def sharded_shifted_gram(
    mesh,
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    *,
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
    backend: Optional[str] = None,
    precision: str = "f32",
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """K = Zhat^T Zhat with the ROWS of X sharded over `mesh` (DESIGN.md §9).

    Each device runs the block-gram kernel for the RESOLVED backend on its
    local row shard and ONE psum over the flattened mesh assembles the full
    (2p, 2p) kernel: the quadrant identity is linear in the per-shard
    statistics (G, u, s), so partial block-grams sum exactly. The backend is
    resolved OUTSIDE the shard_map region — inside it the process default
    backend is unrelated to the kernel's actual placement, which is
    precisely why trace-time sniffing was a bug.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    resolved = _resolve(backend, use_pallas, interpret,
                        "sharded_shifted_gram", X, y)
    n_loc = X.shape[0] // mesh.size
    tiles = _gram_tiles(resolved, n_loc, X.shape[1], bm, bn, bk, precision)

    def local(X_loc, y_loc, t_op):
        Kb = _shifted_gram_jit(X_loc, y_loc, t_op, flatten=True,
                               backend=resolved, precision=precision,
                               **tiles)
        return jax.lax.psum(Kb, axes)

    fn = shard_map(local, mesh=mesh, in_specs=(P(axes, None), P(axes), P()),
                   out_specs=P(), check_rep=False)
    return fn(X, y, jnp.asarray(t, X.dtype))
