"""Jitted public wrappers for the Pallas kernels: padding, dtype handling,
interpret-mode fallback on CPU, and a `use_pallas=False` escape hatch that
routes to the pure-jnp oracle (ref.py) — used for A/B testing and as the
path taken for shapes where kernel tiling would be wasteful.

Interpret-mode selection is resolved from the OPERANDS, never from the
process default backend at trace time: an array committed to a non-default
device (or living on a `repro.dist` mesh) must run the kernel for ITS
platform. `resolve_interpret` pins the choice before the jitted core is
entered; traced callers (`core/sven.py`, the bucket executables) thread an
explicit choice from `SvenConfig.interpret` instead, which `sven()`/
`sven_batch()`/the penalized front-end resolve against the concrete inputs
before tracing (DESIGN.md §9.3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import gram as _gram
from repro.kernels import hinge as _hinge
from repro.kernels import hinge_stats as _hs
from repro.kernels import ref as _ref


def resolve_interpret(interpret, *arrays) -> bool:
    """Pin the Pallas interpret-mode choice for a kernel launch.

    An explicit `interpret` always wins. With None, the decision comes from
    the platform(s) the first CONCRETE array operand is committed to — the
    devices the kernel will actually run on — not from the process default
    backend (which is wrong for arrays placed on a non-default device, and
    meaningless inside a trace). Tracers and numpy inputs carry no device,
    so the process default backend remains the last-resort fallback only.
    """
    if interpret is not None:
        return bool(interpret)
    for a in arrays:
        if isinstance(a, jax.Array) and not isinstance(a, jax.core.Tracer):
            try:
                platforms = {d.platform for d in a.devices()}
            except Exception:  # noqa: BLE001 — abstract/deleted arrays
                continue
            if platforms:
                return platforms == {"cpu"}
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def shifted_gram(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    flatten: bool = True,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """K = Zhat^T Zhat of the SVEN dual, as (2p, 2p) (flatten) or (2,2,p,p).

    `interpret=None` resolves against X's committed devices (see
    `resolve_interpret`); traced call sites must pass an explicit choice.
    """
    return _shifted_gram_jit(X, y, t, bm=bm, bn=bn, bk=bk, flatten=flatten,
                             use_pallas=use_pallas,
                             interpret=resolve_interpret(interpret, X, y))


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "flatten", "use_pallas", "interpret"))
def _shifted_gram_jit(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    *,
    bm: int,
    bn: int,
    bk: int,
    flatten: bool,
    use_pallas: bool,
    interpret: bool,
) -> jax.Array:
    n, p = X.shape
    if not use_pallas:
        Kb = _ref.gram_blocks_ref(X, y, t)
        return _ref.flatten_gram(Kb) if flatten else Kb
    interp = interpret
    Xp = _pad_to(_pad_to(X, 0, bk), 1, max(bm, bn))
    y2d = _pad_to(y[:, None], 0, bk).astype(X.dtype)
    invt = (1.0 / jnp.asarray(t, jnp.float32)).reshape(1, 1)
    Kb = _gram.gram_pallas_raw(Xp, y2d, invt, bm=bm, bn=bn, bk=bk, interpret=interp)
    Kb = Kb[:, :, :p, :p]
    return _ref.flatten_gram(Kb) if flatten else Kb


def hinge_hessian_matvec(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    C: jax.Array | float,
    act_top: jax.Array,
    act_bot: jax.Array,
    v: jax.Array,
    *,
    bp: int = 512,
    bn: int = 512,
    bk: int = 512,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """H v = v + 2C Xhat^T(act . (Xhat v)) via two fused GEMV passes.

    `interpret=None` resolves against X's committed devices (see
    `resolve_interpret`); traced call sites must pass an explicit choice.
    """
    return _hinge_hessian_matvec_jit(
        X, y, t, C, act_top, act_bot, v, bp=bp, bn=bn, bk=bk,
        use_pallas=use_pallas, interpret=resolve_interpret(interpret, X, v))


@partial(jax.jit, static_argnames=("bp", "bn", "bk", "use_pallas", "interpret"))
def _hinge_hessian_matvec_jit(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    C: jax.Array | float,
    act_top: jax.Array,
    act_bot: jax.Array,
    v: jax.Array,
    *,
    bp: int,
    bn: int,
    bk: int,
    use_pallas: bool,
    interpret: bool,
) -> jax.Array:
    if not use_pallas:
        return _ref.hessian_matvec_ref(X, y, t, C, act_top, act_bot, v)
    interp = interpret
    n, p = X.shape
    bp_ = min(bp, _next_mult(p))
    bk1 = min(bk, _next_mult(n))
    Xp1 = _pad_to(_pad_to(X, 0, bk1), 1, bp_)
    v2d = _pad_to(v[:, None], 0, bk1).astype(jnp.float32)
    y2d = _pad_to(y[:, None], 0, bk1).astype(jnp.float32)
    at2d = _pad_to(act_top[:, None].astype(jnp.float32), 0, bp_)
    ab2d = _pad_to(act_bot[:, None].astype(jnp.float32), 0, bp_)
    invt = (1.0 / jnp.asarray(t, jnp.float32)).reshape(1, 1)
    d2d, e_part = _hinge.hinge_xtv_raw(Xp1, v2d, y2d, at2d, ab2d, invt,
                                       bp=bp_, bk=bk1, interpret=interp)
    e = jnp.sum(e_part)

    bn_ = min(bn, _next_mult(n))
    bk2 = min(bk, _next_mult(p))
    Xp2 = _pad_to(_pad_to(X, 0, bn_), 1, bk2)
    d2d = _pad_to(d2d[: p], 0, bk2)
    y2d2 = _pad_to(y[:, None], 0, bn_).astype(jnp.float32)
    v2d2 = _pad_to(v[:, None], 0, bn_).astype(jnp.float32)
    scal = jnp.stack([1.0 / jnp.asarray(t, jnp.float32),
                      e.astype(jnp.float32),
                      2.0 * jnp.asarray(C, jnp.float32)]).reshape(3, 1)
    hv = _hinge.hinge_xd_raw(Xp2, d2d, y2d2, v2d2, scal, bn=bn_, bk=bk2,
                             interpret=interp)
    return hv[:n, 0].astype(v.dtype)


def hinge_stats(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    w: jax.Array,
    C: jax.Array | float,
    *,
    bp: int = 512,
    bk: int = 512,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """Fused Newton outer-step stats: (margin (2p,), act (2p,), loss, galpha).

    `interpret=None` resolves against X's committed devices (see
    `resolve_interpret`); traced call sites must pass an explicit choice.
    """
    return _hinge_stats_jit(X, y, t, w, C, bp=bp, bk=bk,
                            use_pallas=use_pallas,
                            interpret=resolve_interpret(interpret, X, w))


@partial(jax.jit, static_argnames=("bp", "bk", "use_pallas", "interpret"))
def _hinge_stats_jit(
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    w: jax.Array,
    C: jax.Array | float,
    *,
    bp: int,
    bk: int,
    use_pallas: bool,
    interpret: bool,
):
    if not use_pallas:
        return _ref.hinge_stats_ref(X, y, t, w, C)
    interp = interpret
    n, p = X.shape
    bp_ = min(bp, _next_mult(p))
    bk_ = min(bk, _next_mult(n))
    Xp = _pad_to(_pad_to(X, 0, bk_), 1, bp_)
    w2d = _pad_to(w[:, None], 0, bk_).astype(jnp.float32)
    y2d = _pad_to(y[:, None], 0, bk_).astype(jnp.float32)
    scal = jnp.stack([1.0 / jnp.asarray(t, jnp.float32),
                      jnp.asarray(C, jnp.float32)]).reshape(2, 1)
    mt, mb, gt, gb, lp = _hs.hinge_stats_raw(Xp, w2d, y2d, scal,
                                             bp=bp_, bk=bk_, interpret=interp)
    # padded feature columns produce margin 1-eps... no: padded cols give a=0,
    # o=-+byw; slice them off before assembling
    margin = jnp.concatenate([mt[:p, 0], mb[:p, 0]]).astype(w.dtype)
    act = (margin < 1.0).astype(w.dtype)
    galpha = jnp.concatenate([gt[:p, 0], gb[:p, 0]]).astype(w.dtype)
    # loss partials include padded columns of the LAST block: recompute their
    # contribution exactly by masking is cheap: padded cols have a=0 =>
    # xi_top = act*(1-(-byw))... subtract analytically:
    pad = (-p) % bp_
    byw = (y @ w) / jnp.asarray(t, w.dtype)
    xi_pad = jnp.maximum(1.0 + byw, 0.0)   # padded cols: a=0 => both halves
    pad_loss = pad * jnp.asarray(C, jnp.float32) * 2.0 * xi_pad ** 2
    loss = 0.5 * (w @ w) + jnp.sum(lp) - pad_loss
    return margin, act, loss.astype(w.dtype), galpha


def _next_mult(sz: int, base: int = 128) -> int:
    """Largest power-of-two-ish tile not exceeding the padded size."""
    m = base
    while m > sz:
        m //= 2
    return max(m, 8)


def sharded_shifted_gram(
    mesh,
    X: jax.Array,
    y: jax.Array,
    t: jax.Array | float,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """K = Zhat^T Zhat with the ROWS of X sharded over `mesh` (DESIGN.md §9).

    Each device runs the block-gram kernel (Pallas, or the jnp oracle with
    `use_pallas=False`) on its local row shard and ONE psum over the
    flattened mesh assembles the full (2p, 2p) kernel: the quadrant identity
    is linear in the per-shard statistics (G, u, s), so partial block-grams
    sum exactly. Interpret mode is pinned OUTSIDE the shard_map region —
    inside it the process default backend is unrelated to the kernel's
    actual placement, which is precisely why trace-time sniffing was a bug.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    interp = resolve_interpret(interpret, X, y)

    def local(X_loc, y_loc, t_op):
        Kb = _shifted_gram_jit(X_loc, y_loc, t_op, bm=bm, bn=bn, bk=bk,
                               flatten=True, use_pallas=use_pallas,
                               interpret=interp)
        return jax.lax.psum(Kb, axes)

    fn = shard_map(local, mesh=mesh, in_specs=(P(axes, None), P(axes), P()),
                   out_specs=P(), check_rep=False)
    return fn(X, y, jnp.asarray(t, X.dtype))
