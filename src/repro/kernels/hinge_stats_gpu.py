"""Pallas GPU (Triton) kernel: fused margins + squared-hinge loss +
dual-gradient — the GPU twin of kernels/hinge_stats.py.

Identical contract to the TPU body (`hinge_stats_raw`): one pass over X
yields a = X^T w and byw = y.w/t, then the fused epilogue produces margins,
active set, per-block loss partials and galpha for BOTH halves of the
implicit SVEN dataset, so none of those round-trip HBM as separate
elementwise sweeps.

Triton structure (see gram_gpu.py for the rationale): the grid covers only
the feature tiles (p/bp,); the n-reduction runs inside the program as a
`fori_loop` over `pl.load` slices with register accumulators — there is no
sequential grid axis and no TPU VMEM scratch. The reductions here are
GEMV-shaped, so everything accumulates as f32 elementwise-multiply+sum
(Triton's `tl.dot` cannot emit N=1 products); the kernel is memory-bound
and its win is the fusion, not the MACs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _stats_gpu_kernel(x_ref, w_ref, y_ref, scal_ref,
                      mt_ref, mb_ref, gt_ref, gb_ref, loss_ref, *, bk: int):
    n, bp = x_ref.shape

    def body(k, carry):
        acc_a, acc_byw = carry
        rows = (pl.ds(k * bk, bk), slice(None))
        xk = pl.load(x_ref, rows).astype(jnp.float32)   # (bk, bp)
        wk = pl.load(w_ref, rows).astype(jnp.float32)   # (bk, 1)
        yk = pl.load(y_ref, rows).astype(jnp.float32)   # (bk, 1)
        acc_a = acc_a + jnp.sum(xk * wk, axis=0)        # (bp,)
        acc_byw = acc_byw + jnp.sum(yk * wk)
        return acc_a, acc_byw

    init = (jnp.zeros((bp,), jnp.float32), jnp.zeros((), jnp.float32))
    acc_a, acc_byw = jax.lax.fori_loop(0, n // bk, body, init)

    invt = scal_ref[0, 0].astype(jnp.float32)
    C = scal_ref[1, 0].astype(jnp.float32)
    a = acc_a[:, None]                                  # (bp, 1)
    byw = acc_byw * invt
    o_top = a - byw
    o_bot = a + byw
    m_top = o_top                                       # yhat=+1
    m_bot = -o_bot                                      # yhat=-1
    act_t = (m_top < 1.0).astype(jnp.float32)
    act_b = (m_bot < 1.0).astype(jnp.float32)
    xi_t = act_t * (1.0 - m_top)
    xi_b = act_b * (1.0 - m_bot)
    mt_ref[...] = m_top.astype(mt_ref.dtype)
    mb_ref[...] = m_bot.astype(mb_ref.dtype)
    gt_ref[...] = (act_t * (o_top - 1.0)).astype(gt_ref.dtype)
    gb_ref[...] = (act_b * (o_bot + 1.0)).astype(gb_ref.dtype)
    loss_ref[0, 0] = (C * (jnp.sum(xi_t * xi_t) + jnp.sum(xi_b * xi_b))
                      ).astype(loss_ref.dtype)


@registry.register("hinge_stats", "gpu")
def hinge_stats_gpu_raw(X, w2d, y2d, scal, *, bp: int, bk: int,
                        interpret: bool = False):
    """Same call/return convention as the TPU `hinge_stats_raw`:
    (mt, mb, gt, gb, loss_partials) with padded shapes (p, 1)×4 and
    (p // bp, 1)."""
    from jax.experimental.pallas import triton as plgpu

    n, p = X.shape
    assert n % bk == 0 and p % bp == 0, (n, p, bp, bk)
    grid = (p // bp,)
    out = [jax.ShapeDtypeStruct((p, 1), jnp.float32) for _ in range(4)]
    out.append(jax.ShapeDtypeStruct((p // bp, 1), jnp.float32))
    vec = pl.BlockSpec((bp, 1), lambda i: (i, 0))
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = plgpu.TritonCompilerParams(
            num_warps=4, num_stages=2)
    return pl.pallas_call(
        functools.partial(_stats_gpu_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((2, 1), lambda i: (0, 0)),
        ],
        out_specs=[vec, vec, vec, vec,
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=out,
        interpret=interpret,
        **kwargs,
    )(X, w2d, y2d, scal)
