"""Pallas TPU kernels: fused squared-hinge Hessian mat-vec (primal Newton-CG).

The primal hot loop is H v = v + 2C Xhat^T (act . (Xhat v)) on the implicit
SVEN dataset. With c = X^T v, byv = y.v/t:

    u_t = act_top . (c - byv),  u_b = act_bot . (c + byv)
    H v = v + 2C ( X (u_t + u_b) + (y/t) (sum u_b - sum u_t) )

Two GEMV-shaped passes, each with its mask/shift epilogue fused into the
mat-vec tile (no (2p,)-sized intermediates in HBM beyond d itself):

  pass 1 (hinge_xtv): grid (p/bp, n/bk) — c-accumulate + hinge mask epilogue
  pass 2 (hinge_xd):  grid (n/bn, p/bk) — X d accumulate + rank-1/v epilogue
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------- pass 1 ---

def _xtv_kernel(x_ref, v_ref, y_ref, at_ref, ab_ref, invt_ref,
                d_ref, e_ref, acc_c, acc_byv):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc_c[...] = jnp.zeros_like(acc_c)
        acc_byv[...] = jnp.zeros_like(acc_byv)

    xk = x_ref[...].astype(jnp.float32)          # (bk, bp)
    vk = v_ref[...].astype(jnp.float32)          # (bk, 1)
    yk = y_ref[...].astype(jnp.float32)          # (bk, 1)

    acc_c[...] += jax.lax.dot_general(
        xk, vk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_byv[...] += jax.lax.dot_general(
        yk, vk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        invt = invt_ref[0, 0].astype(jnp.float32)
        byv = acc_byv[0, 0] * invt
        c = acc_c[...]                            # (bp, 1)
        at = at_ref[...].astype(jnp.float32)      # (bp, 1)
        ab = ab_ref[...].astype(jnp.float32)
        u_t = at * (c - byv)
        u_b = ab * (c + byv)
        d_ref[...] = (u_t + u_b).astype(d_ref.dtype)
        e_ref[0, 0] = jnp.sum(u_b - u_t).astype(e_ref.dtype)


def hinge_xtv_raw(X, v2d, y2d, at2d, ab2d, invt, *, bp: int, bk: int,
                  interpret: bool = False):
    n, p = X.shape
    assert n % bk == 0 and p % bp == 0
    grid = (p // bp, n // bk)
    return pl.pallas_call(
        _xtv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bp), lambda i, k: (k, i)),
            pl.BlockSpec((bk, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((bk, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((bp, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bp, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
            jax.ShapeDtypeStruct((p // bp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bp, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(X, v2d, y2d, at2d, ab2d, invt)


# ---------------------------------------------------------------- pass 2 ---

def _xd_kernel(x_ref, d_ref, y_ref, v_ref, scal_ref, hv_ref, acc):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    xk = x_ref[...].astype(jnp.float32)          # (bn, bk)
    dk = d_ref[...].astype(jnp.float32)          # (bk, 1)
    acc[...] += jax.lax.dot_general(
        xk, dk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        invt = scal_ref[0, 0].astype(jnp.float32)
        e = scal_ref[1, 0].astype(jnp.float32)
        twoC = scal_ref[2, 0].astype(jnp.float32)
        yv = y_ref[...].astype(jnp.float32)       # (bn, 1)
        vv = v_ref[...].astype(jnp.float32)       # (bn, 1)
        hv = vv + twoC * (acc[...] + yv * invt * e)
        hv_ref[...] = hv.astype(hv_ref.dtype)


def hinge_xd_raw(X, d2d, y2d, v2d, scal, *, bn: int, bk: int,
                 interpret: bool = False):
    n, p = X.shape
    assert n % bn == 0 and p % bk == 0
    grid = (n // bn, p // bk)
    return pl.pallas_call(
        _xd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((bn, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((3, 1), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32)],
        interpret=interpret,
    )(X, d2d, y2d, v2d, scal)
