"""Pallas kernels for the SVEN hot spots (TPU + GPU/Triton bodies), with
pure-jnp oracles and a per-backend registry.

Public surface:

  - `ops` — the jitted entry points (`shifted_gram`, `hinge_hessian_matvec`,
    `hinge_stats`): backend resolution, tiling/padding/precision handling,
    and the deprecated `use_pallas=`/`interpret=` shims;
  - `registry` — the op -> body table and the `backend` enum
    (`resolve_kernel_backend`, `lookup`, `kernel_backends`);
  - `autotune` — per-(body, shape-bucket) tile selection with an on-disk
    winner cache (`tiles_for`, `resolve_tiles`);
  - `ref` — the pure-jnp oracles, the correctness ground truth every kernel
    is parity-tested against (`tests/test_kernels.py`,
    `tests/test_kernels_surface.py`, `tests/test_kernels_gpu.py`).

The ops are re-exported at package level; `core/sven.py` selects them via
`SvenConfig(backend=...)`. Raw kernel bodies (`gram`, `gram_gpu`, `hinge`,
`hinge_stats`, `hinge_stats_gpu` modules) are implementation detail — call
through `ops`, which owns backend lookup, tiling and padding.
"""
from repro.kernels import autotune, ops, ref, registry
from repro.kernels.ops import (hinge_hessian_matvec, hinge_stats,
                               resolve_interpret, sharded_shifted_gram,
                               shifted_gram)
from repro.kernels.registry import resolve_kernel_backend

__all__ = [
    "ops",
    "ref",
    "registry",
    "autotune",
    "shifted_gram",
    "sharded_shifted_gram",
    "hinge_hessian_matvec",
    "hinge_stats",
    "resolve_interpret",
    "resolve_kernel_backend",
]
