"""Pallas TPU kernels for the SVEN hot spots, with pure-jnp oracles.

Public surface:

  - `ops` — the jitted entry points (`shifted_gram`, `hinge_hessian_matvec`,
    `hinge_stats`): padding/dtype handling, interpret-mode fallback on CPU,
    and a `use_pallas=False` escape hatch routing to the oracle;
  - `ref` — the pure-jnp oracles, the correctness ground truth every kernel
    is parity-tested against (`tests/test_kernels.py`,
    `tests/test_kernels_surface.py`).

The three ops are re-exported at package level; `core/sven.py` selects them
via `SvenConfig(backend="pallas")`. Raw kernel bodies (`gram`, `hinge`,
`hinge_stats` modules) are implementation detail — call through `ops`,
which owns tiling and padding.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (hinge_hessian_matvec, hinge_stats,
                               resolve_interpret, sharded_shifted_gram,
                               shifted_gram)

__all__ = [
    "ops",
    "ref",
    "shifted_gram",
    "sharded_shifted_gram",
    "hinge_hessian_matvec",
    "hinge_stats",
    "resolve_interpret",
]
