"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_blocks_ref(X: jax.Array, y: jax.Array, t: float) -> jax.Array:
    """Oracle for the fused shifted-Gram kernel.

    Returns K in block layout (2, 2, p, p) with
        K[a, b, i, j] = s_a s_b G_ij - s_a u_i - s_b u_j + s
    where s_0=+1, s_1=-1, G = X^T X, u = X^T y / t, s = y^T y / t^2.
    Flattened via K.transpose(0,2,1,3).reshape(2p, 2p) it equals
    Zhat^T Zhat of the paper's dual (eq. 3).
    """
    G = X.T @ X
    u = (X.T @ y) / t
    s = (y @ y) / (t * t)
    signs = jnp.array([1.0, -1.0], X.dtype)
    sa = signs[:, None, None, None]          # (2,1,1,1)
    sb = signs[None, :, None, None]          # (1,2,1,1)
    ui = u[None, None, :, None]
    uj = u[None, None, None, :]
    return sa * sb * G[None, None] - sa * ui - sb * uj + s


def flatten_gram(Kb: jax.Array) -> jax.Array:
    """(2,2,p,p) block layout -> (2p,2p) kernel matrix."""
    p = Kb.shape[-1]
    return Kb.transpose(0, 2, 1, 3).reshape(2 * p, 2 * p)


def hinge_xtv_ref(X: jax.Array, y: jax.Array, v: jax.Array, t: float,
                  act_top: jax.Array, act_bot: jax.Array):
    """Oracle for hinge pass 1: masked dual-side reduction of Xhat @ v.

    c   = X^T v                       (p,)
    byv = (y . v) / t                 scalar
    u_t = act_top * (c - byv);  u_b = act_bot * (c + byv)
    returns d = u_t + u_b (p,), e = sum(u_b) - sum(u_t) (scalar)
    """
    c = X.T @ v
    byv = (y @ v) / t
    u_t = act_top * (c - byv)
    u_b = act_bot * (c + byv)
    return u_t + u_b, jnp.sum(u_b) - jnp.sum(u_t)


def hinge_xd_ref(X: jax.Array, y: jax.Array, d: jax.Array, e: jax.Array,
                 v: jax.Array, t: float, C: float) -> jax.Array:
    """Oracle for hinge pass 2: H v = v + 2C (X d + (y/t) e)."""
    return v + 2.0 * C * (X @ d + (y / t) * e)


def hessian_matvec_ref(X, y, t, C, act_top, act_bot, v):
    """Full squared-hinge Hessian mat-vec (primal Newton-CG inner op)."""
    d, e = hinge_xtv_ref(X, y, v, t, act_top, act_bot)
    return hinge_xd_ref(X, y, d, e, v, t, C)


def hinge_stats_from_moments(a: jax.Array, byw, ww, C):
    """The margin/act/loss/galpha tail of the hinge-stats fusion, from the
    sufficient moments a = X^T w (p,), byw = (y . w) / t and ww = w . w.

    Shared by the full oracle below and by the data-parallel twin
    (`core.distributed.sharded_hinge_stats`, which psums the moments over
    row shards first) so the formula has exactly one home.
    """
    p = a.shape[0]
    dtype = a.dtype
    o = jnp.concatenate([a - byw, a + byw])
    margin = jnp.concatenate([o[:p], -o[p:]])
    act = (margin < 1.0).astype(dtype)
    xi = act * (1.0 - margin)
    loss = 0.5 * ww + C * (xi @ xi)
    yhat = jnp.concatenate([jnp.ones((p,), dtype), -jnp.ones((p,), dtype)])
    galpha = act * (o - yhat)
    return margin, act, loss, galpha


def hinge_stats_ref(X: jax.Array, y: jax.Array, t: float, w: jax.Array, C: float):
    """Oracle for the fused margins/loss/gradient kernel (Newton outer step).

    On the implicit SVEN dataset (m=2p rows [x_j -+ y/t], labels [+1;-1]):
        a      = X^T w                      (p,)
        byw    = (y . w) / t                scalar
        o      = [a - byw ; a + byw]        Xhat @ w
        margin = [o_top ; -o_bot]           yhat * o
        act    = margin < 1
        xi     = act * (1 - margin)
        loss   = 0.5 w.w + C xi.xi
        galpha = act * (o - yhat)  (2p,)    (grad = w + 2C Xhat^T galpha)
    Returns (margin, act, loss, galpha).
    """
    a = (X.T @ w).astype(w.dtype)
    return hinge_stats_from_moments(a, (y @ w) / t, w @ w, C)
