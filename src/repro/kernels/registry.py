"""Per-backend kernel registry: one `backend` enum replaces the old
`use_pallas: bool` + `interpret: bool` two-flag maze (DESIGN.md §10).

Every logical op (`shifted_gram`, `hinge_stats`, `hinge_xtv`/`hinge_xd`,
`sharded_shifted_gram`) resolves to exactly one of three BODIES:

    "tpu"   the Pallas TPU kernel (kernels/gram.py, hinge.py, hinge_stats.py)
    "gpu"   the Pallas GPU (Triton) kernel (kernels/gram_gpu.py,
            hinge_stats_gpu.py) — k-loop inside the program, no TPU scratch
    "ref"   the pure-jnp oracle (kernels/ref.py) — also the XLA escape hatch

and a RESOLVED backend names a body plus how it executes:

    "tpu" | "gpu"                      compiled Pallas for that platform
    "tpu_interpret" | "gpu_interpret"  the same body under Pallas interpret
                                       mode (how CPU CI exercises both code
                                       paths without an accelerator)
    "ref"                              the jnp oracle under plain XLA

Resolution is OPERAND-DRIVEN, never trace-time backend sniffing (the §9.3
bugfix): `resolve_kernel_backend(None, *arrays)` reads the platform of the
first concrete operand's committed devices — tpu -> "tpu", gpu -> "gpu",
cpu -> "tpu_interpret" (the historical CPU default) — with the process
default backend only as the numpy/tracer fallback. An explicit resolved
backend always wins. Traced call sites thread `SvenConfig.backend`, pinned
pre-trace by `core.sven.resolve_backend`, so the choice is part of the
static jit key.

Ops without a body for the resolved platform fall back to "ref" via
`lookup` — e.g. the hinge Hessian mat-vec has no Triton body (GEMV-shaped,
memory-bound; cuBLAS under XLA is the honest choice), so "gpu" serves it
from the oracle. `kernel_backends(op)` reports what is actually registered.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

#: the three kernel bodies a logical op may register
BODIES = ("tpu", "gpu", "ref")

#: every resolved backend value accepted by the ops layer / SvenConfig
RESOLVED_BACKENDS = ("tpu", "gpu", "tpu_interpret", "gpu_interpret", "ref")

#: platform -> resolved backend (the "auto" rule)
_PLATFORM_DEFAULT = {
    "tpu": "tpu",
    "gpu": "gpu",
    "cuda": "gpu",
    "rocm": "gpu",
    "cpu": "tpu_interpret",
}

_REGISTRY: dict[tuple[str, str], Callable] = {}


def register(op: str, body: str):
    """Class the decorated callable as `op`'s kernel body for `body`."""
    if body not in BODIES:
        raise ValueError(f"register: body must be one of {BODIES}, got {body!r}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, body)] = fn
        return fn

    return deco


def lookup(op: str, backend: str) -> tuple[Callable, str, bool]:
    """Resolve (impl, body, interpret) for a RESOLVED backend.

    Falls back to the "ref" body when the platform has no kernel for this
    op — the fallback is part of the contract (README "Backends &
    precision" matrix), not an error.
    """
    if backend not in RESOLVED_BACKENDS:
        raise ValueError(
            f"lookup({op!r}): backend must be resolved "
            f"({RESOLVED_BACKENDS}), got {backend!r} — call "
            f"resolve_kernel_backend first")
    body, interpret = split_backend(backend)
    if (op, body) in _REGISTRY:
        return _REGISTRY[(op, body)], body, interpret
    if (op, "ref") in _REGISTRY:
        return _REGISTRY[(op, "ref")], "ref", False
    raise KeyError(f"no kernel body registered for op {op!r} "
                   f"(backend {backend!r}); registered: {kernel_backends(op)}")


def split_backend(backend: str) -> tuple[str, bool]:
    """Resolved backend -> (body, interpret) pair."""
    if backend.endswith("_interpret"):
        return backend[: -len("_interpret")], True
    return backend, False


def kernel_backends(op: str) -> tuple[str, ...]:
    """The bodies registered for `op` (subset of BODIES)."""
    return tuple(b for b in BODIES if (op, b) in _REGISTRY)


def registered_ops() -> tuple[str, ...]:
    return tuple(sorted({op for op, _ in _REGISTRY}))


def resolve_kernel_backend(backend: Optional[str], *arrays) -> str:
    """Pin the kernel backend for a launch (the one-enum successor of
    `resolve_interpret`).

    An explicit RESOLVED backend always wins. `None` / `"auto"` / the
    deprecated `"pallas"` resolve from the platform(s) the first CONCRETE
    array operand is committed to — the devices the kernel will actually
    run on — not from the process default backend (wrong for arrays placed
    on a non-default device, meaningless inside a trace). Tracers and
    numpy inputs carry no device, so the process default platform remains
    the last-resort fallback only.
    """
    if backend is not None and backend not in ("auto", "pallas"):
        if backend not in RESOLVED_BACKENDS:
            raise ValueError(
                f"resolve_kernel_backend: unknown backend {backend!r} "
                f"(expected one of {RESOLVED_BACKENDS} or 'auto')")
        return backend
    for a in arrays:
        if isinstance(a, jax.Array) and not isinstance(a, jax.core.Tracer):
            try:
                platforms = {d.platform for d in a.devices()}
            except Exception:  # noqa: BLE001 — abstract/deleted arrays
                continue
            if len(platforms) == 1:
                return _PLATFORM_DEFAULT.get(platforms.pop(), "ref")
            if platforms:
                return "ref"           # mixed placements: oracle is safe
    return _PLATFORM_DEFAULT.get(jax.default_backend(), "ref")
