"""Pallas TPU kernel: fused margins + squared-hinge loss + dual-gradient
(the primal Newton OUTER step, complementing hinge.py's CG inner mat-vec).

One pass over X computes, for the implicit SVEN dataset, everything the
Newton iteration needs between CG solves:
    a = X^T w, byw = y.w/t  ->  margins, active set, loss, galpha
where grad_w = w + 2C Xhat^T galpha (second pass via hinge_xd). The fused
epilogue means margins/act/xi/galpha never round-trip HBM as separate
elementwise passes — on the MATLAB path these are 4 extra BLAS-1 sweeps
over 2p-vectors.

Grid (p/bp, n/bk); fp32 accumulation; both +/- halves produced per tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stats_kernel(x_ref, w_ref, y_ref, scal_ref,
                  mt_ref, mb_ref, gt_ref, gb_ref, loss_ref,
                  acc_a, acc_byw):
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc_a[...] = jnp.zeros_like(acc_a)
        acc_byw[...] = jnp.zeros_like(acc_byw)

    xk = x_ref[...].astype(jnp.float32)           # (bk, bp)
    wk = w_ref[...].astype(jnp.float32)           # (bk, 1)
    yk = y_ref[...].astype(jnp.float32)           # (bk, 1)
    acc_a[...] += jax.lax.dot_general(
        xk, wk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_byw[...] += jax.lax.dot_general(
        yk, wk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        invt = scal_ref[0, 0].astype(jnp.float32)
        C = scal_ref[1, 0].astype(jnp.float32)
        a = acc_a[...]                             # (bp, 1)
        byw = acc_byw[0, 0] * invt
        o_top = a - byw
        o_bot = a + byw
        m_top = o_top                              # yhat=+1
        m_bot = -o_bot                             # yhat=-1
        act_t = (m_top < 1.0).astype(jnp.float32)
        act_b = (m_bot < 1.0).astype(jnp.float32)
        xi_t = act_t * (1.0 - m_top)
        xi_b = act_b * (1.0 - m_bot)
        mt_ref[...] = m_top.astype(mt_ref.dtype)
        mb_ref[...] = m_bot.astype(mb_ref.dtype)
        gt_ref[...] = (act_t * (o_top - 1.0)).astype(gt_ref.dtype)
        gb_ref[...] = (act_b * (o_bot + 1.0)).astype(gb_ref.dtype)
        loss_ref[0, 0] = (C * (jnp.sum(xi_t * xi_t) + jnp.sum(xi_b * xi_b))
                          ).astype(loss_ref.dtype)


def hinge_stats_raw(X, w2d, y2d, scal, *, bp: int, bk: int,
                    interpret: bool = False):
    n, p = X.shape
    assert n % bk == 0 and p % bp == 0
    grid = (p // bp, n // bk)
    out = [jax.ShapeDtypeStruct((p, 1), jnp.float32) for _ in range(4)]
    out.append(jax.ShapeDtypeStruct((p // bp, 1), jnp.float32))
    vec = pl.BlockSpec((bp, 1), lambda i, k: (i, 0))
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bp), lambda i, k: (k, i)),
            pl.BlockSpec((bk, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((bk, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((2, 1), lambda i, k: (0, 0)),
        ],
        out_specs=[vec, vec, vec, vec,
                   pl.BlockSpec((1, 1), lambda i, k: (i, 0))],
        out_shape=out,
        scratch_shapes=[pltpu.VMEM((bp, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(X, w2d, y2d, scal)
