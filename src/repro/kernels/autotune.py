"""Tile autotuner for the Pallas kernel bodies.

Tile shapes (bm, bn, bk) that saturate one accelerator generation are
mediocre on the next; hardcoded defaults are how a "GPU-speed" claim decays.
This module picks tiles the same way GPU-SVM practice does (Rgtsvm tunes
its kernel-evaluation tile to the card): measure a few candidates ONCE per
(body, shape-bucket) on the hardware at hand and reuse the winner.

Mechanics:

  * shapes are BUCKETED to the next power of two (capped, so a 1e6-row
    problem is tuned on a bounded probe) — tiles depend on how a problem
    fills the machine, not its exact dims, and buckets keep the candidate
    sweep from re-running per shape;
  * measurement happens only on COMPILED backends ("tpu", "gpu"). Interpret
    mode is a pure-Python emulator whose timings are pathological and
    meaningless, and the ref body has no tiles — both get the static
    defaults instantly;
  * winners cache in-process and persist via `utils.disk_cache_*` under the
    `autotune` kind, keyed (op, body, shape-bucket, dtype, jax version), so
    repeat processes skip the sweep the same way routing calibration skips
    its microbenchmark.

The ops layer (kernels/ops.py) consults `tiles_for(op, backend, n, p)` only
when the caller did not pin tiles explicitly — explicit tile kwargs always
win, which is also the escape hatch if a measured winner misbehaves.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.kernels import registry

# candidates per body — orderings chosen so the FIRST entry is the static
# default used whenever measurement is unavailable. GPU tiles respect
# Triton's >= 16 tl.dot dimension floor; TPU tiles are MXU/VREG multiples.
GRAM_CANDIDATES = {
    "tpu": ((128, 128, 128), (256, 128, 128), (128, 128, 256),
            (128, 256, 128)),
    "gpu": ((64, 64, 32), (32, 32, 32), (64, 64, 64), (128, 64, 32),
            (128, 128, 32)),
    "ref": ((128, 128, 128),),
}
HINGE_STATS_CANDIDATES = {
    "tpu": ((512, 512), (256, 512), (512, 256), (256, 256)),
    "gpu": ((64, 128), (32, 128), (64, 256), (128, 128)),
    "ref": ((512, 512),),
}
_TILE_NAMES = {"shifted_gram": ("bm", "bn", "bk"),
               "hinge_stats": ("bp", "bk")}
_CANDIDATES = {"shifted_gram": GRAM_CANDIDATES,
               "hinge_stats": HINGE_STATS_CANDIDATES}

#: probe caps: tuning happens on min(bucket, cap)-sized synthetic operands
_N_CAP = 8192
_P_CAP = 1024

_MEMORY: dict = {}


def shape_bucket(n: int, p: int) -> tuple[int, int]:
    """Next power of two per dim (floor 8, probe-capped)."""
    return min(_pow2(n), _N_CAP), min(_pow2(p), _P_CAP)


def _pow2(sz: int) -> int:
    b = 8
    while b < sz:
        b *= 2
    return b


def clear_autotune_cache() -> None:
    """Drop the in-process winners (the disk cache is left alone — delete
    `<cache_dir>/autotune.json` to force re-measurement across processes)."""
    _MEMORY.clear()


def _key(op: str, body: str, nb: int, pb: int, dtype) -> str:
    return f"{op}|{body}|{nb}x{pb}|{jnp.dtype(dtype).name}|jax{jax.__version__}"


def _clamp(tiles: tuple, op: str, nb: int, pb: int, body: str) -> tuple:
    """Shrink candidate tiles that exceed the bucket (tiny problems); the
    GPU gram body keeps >= 16 so tl.dot stays legal."""
    floor = 16 if (body == "gpu" and op == "shifted_gram") else 8
    names = _TILE_NAMES[op]
    dims = {"bm": pb, "bn": pb, "bp": pb, "bk": nb}
    return tuple(max(min(t, _pow2(dims[nm])), floor)
                 for t, nm in zip(tiles, names))


def _measure_candidate(op: str, body: str, tiles: tuple,
                       nb: int, pb: int, dtype) -> float:
    """Best-of-3 wall clock of one raw kernel body on bucket-sized ones()."""
    impl, got_body, interpret = registry.lookup(op, body)
    assert got_body == body and not interpret
    X = jnp.ones((nb, pb), dtype)
    v2d = jnp.ones((nb, 1), dtype if op == "shifted_gram" else jnp.float32)
    if op == "shifted_gram":
        bm, bn, bk = tiles
        scal = jnp.ones((1, 1), jnp.float32)
        fn = lambda: impl(X, v2d, scal, bm=bm, bn=bn, bk=bk)
    else:
        bp, bk = tiles
        scal = jnp.ones((2, 1), jnp.float32)
        fn = lambda: impl(X, v2d, v2d, scal, bp=bp, bk=bk)
    best, _ = utils.timeit(jax.jit(fn), warmup=1, iters=3)
    return best


def resolve_tiles(op: str, backend: str, n: int, p: int,
                  dtype=jnp.float32,
                  measure: Optional[Callable] = None) -> tuple[dict, str]:
    """(tiles, source) for one kernel launch.

    `backend` is a RESOLVED backend (registry.RESOLVED_BACKENDS). Source is
    one of "default" (static, no measurement possible), "memory", "disk",
    or "measured" (sweep ran here). `measure` overrides the timing probe —
    the test seam.
    """
    body, interpret = registry.split_backend(backend)
    if op not in _CANDIDATES:
        raise ValueError(f"resolve_tiles: unknown op {op!r} "
                         f"(expected one of {sorted(_CANDIDATES)})")
    cands = _CANDIDATES[op].get(body, _CANDIDATES[op]["ref"])
    nb, pb = shape_bucket(n, p)
    names = _TILE_NAMES[op]
    default = dict(zip(names, _clamp(cands[0], op, nb, pb, body)))
    if interpret or body == "ref" or len(cands) == 1:
        return default, "default"

    key = _key(op, body, nb, pb, dtype)
    if key in _MEMORY:
        return dict(zip(names, _MEMORY[key])), "memory"
    disk = utils.disk_cache_load("autotune")
    if key in disk and isinstance(disk[key], list):
        tiles = tuple(int(t) for t in disk[key])
        if len(tiles) == len(names):
            _MEMORY[key] = tiles
            return dict(zip(names, tiles)), "disk"

    probe = measure or _measure_candidate
    seen: dict[tuple, float] = {}
    for cand in cands:
        tiles = _clamp(cand, op, nb, pb, body)
        if tiles in seen:
            continue
        try:
            seen[tiles] = probe(op, body, tiles, nb, pb, dtype)
        except Exception:  # noqa: BLE001 — a candidate the compiler rejects
            continue       # (register pressure, shmem) just drops out
    if not seen:
        return default, "default"
    winner = min(seen, key=seen.get)
    _MEMORY[key] = winner
    utils.disk_cache_update("autotune", {key: list(winner)})
    return dict(zip(names, winner)), "measured"


def tiles_for(op: str, backend: str, n: int, p: int,
              dtype=jnp.float32) -> dict:
    """The tile kwargs for `op` on `backend` at shape (n, p) — what
    kernels/ops.py splices in when the caller didn't pin tiles."""
    return resolve_tiles(op, backend, n, p, dtype)[0]
