"""Pallas TPU kernel: fused shifted-Gram for the SVEN dual.

Computes the paper's dual kernel matrix K = Zhat^T Zhat (eq. 3) directly from
the ORIGINAL (n, p) design matrix — the (2p, n) constructed SVM dataset never
exists in HBM. Beyond the fusion, the kernel exploits the block identity

    K[a,b][i,j] = s_a s_b (X^T X)_ij - s_a u_i - s_b u_j + s,
    u = X^T y / t,  s = y^T y / t^2,  s_0 = +1, s_1 = -1,

so one p x p Gram pass yields all four (2p)^2 blocks: 4x fewer MACs and 2x
less HBM read traffic than the paper-faithful materialize-then-matmul.

Tiling: grid (p/bm, p/bn, n/bk), MXU-aligned 128-multiples, fp32 accumulation
in VMEM scratch; the rank-1 shift terms (u_i, u_j) and the scalar s are
accumulated in the same pass and applied in the final-k epilogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(xi_ref, xj_ref, y_ref, invt_ref, out_ref,
                 acc_p, acc_a, acc_b, acc_c, *, precision="f32"):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_p[...] = jnp.zeros_like(acc_p)
        acc_a[...] = jnp.zeros_like(acc_a)
        acc_b[...] = jnp.zeros_like(acc_b)
        acc_c[...] = jnp.zeros_like(acc_c)

    xi = xi_ref[...].astype(jnp.float32)          # (bk, bm)
    xj = xj_ref[...].astype(jnp.float32)          # (bk, bn)
    yk = y_ref[...].astype(jnp.float32)           # (bk, 1)

    # "f32" forces full-precision MACs; "bf16"/"tf32" allow the MXU's fast
    # low-precision passes — accumulation stays f32 either way, and one f32
    # refinement re-solve on top restores <= 1e-10 parity (DESIGN.md §10.3).
    prec = (jax.lax.Precision.HIGHEST if precision == "f32"
            else jax.lax.Precision.DEFAULT)
    acc_p[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32, precision=prec)
    acc_a[...] += jax.lax.dot_general(
        xi, yk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_b[...] += jax.lax.dot_general(
        xj, yk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_c[...] += jax.lax.dot_general(
        yk, yk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        invt = invt_ref[0, 0].astype(jnp.float32)
        P = acc_p[...]
        a = acc_a[...] * invt                      # (bm, 1) broadcasts over cols
        b = (acc_b[...] * invt).T                  # (1, bn) broadcasts over rows
        s = acc_c[0, 0] * invt * invt
        dt = out_ref.dtype
        out_ref[0, 0] = (P - a - b + s).astype(dt)
        out_ref[0, 1] = (-P - a + b + s).astype(dt)
        out_ref[1, 0] = (-P + a - b + s).astype(dt)
        out_ref[1, 1] = (P + a + b + s).astype(dt)


def gram_pallas_raw(
    X: jax.Array,        # (n, p) with n % bk == 0, p % bm == p % bn == 0
    y2d: jax.Array,      # (n, 1)
    invt: jax.Array,     # (1, 1)
    *,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=jnp.float32,
    precision: str = "f32",
    interpret: bool = False,
) -> jax.Array:
    """Unpadded core call. Returns K in block layout (2, 2, p, p)."""
    import functools

    n, p = X.shape
    assert n % bk == 0 and p % bm == 0 and p % bn == 0, (n, p, bm, bn, bk)
    grid = (p // bm, p // bn, n // bk)
    return pl.pallas_call(
        functools.partial(_gram_kernel, precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, 2, bm, bn), lambda i, j, k: (0, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((2, 2, p, p), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bn, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(X, X, y2d, invt)  # X passed twice: row-tile view (xi) and col-tile view (xj)
