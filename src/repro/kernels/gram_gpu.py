"""Pallas GPU (Triton) kernel: fused shifted-Gram for the SVEN dual.

Same math as the TPU body (kernels/gram.py): one pass over the ORIGINAL
(n, p) design matrix yields all four (2p)^2 quadrants of K = Zhat^T Zhat
through the block identity

    K[a,b][i,j] = s_a s_b (X^T X)_ij - s_a u_i - s_b u_j + s,
    u = X^T y / t,  s = y^T y / t^2,  s_0 = +1, s_1 = -1,

with the rank-1 shift terms accumulated in the same pass and applied in a
final epilogue. The STRUCTURE is Triton-shaped, not TPU-shaped:

  * grid (p/bm, p/bn) only — each program owns one output tile and runs the
    k-reduction itself via `fori_loop` + `pl.load` slices (Rgtsvm-style
    tiled kernel evaluation); there is no sequential grid axis to carry
    VMEM scratch across, so accumulators live in registers;
  * the matmul accumulator uses `tl.dot`-shaped `dot_general` with f32
    `preferred_element_type` (tensor-core path for f16/bf16/tf32 inputs);
  * the rank-1 statistics accumulate as f32 elementwise-multiply+sum
    reductions — Triton's `tl.dot` cannot emit N=1 GEMVs, and the VPU-sized
    work is negligible next to the (bm, bn, bk) MAC tile.

Mixed precision: `precision="bf16"` expects bf16 inputs (storage halved,
accumulation still f32 — the Rgtsvm reduced-precision-storage recipe);
`"tf32"` keeps f32 storage but allows tf32 tensor-core MACs
(`Precision.DEFAULT`); `"f32"` forces full-precision MACs
(`Precision.HIGHEST`). The <= 1e-10 solver parity gates on top of the
low-precision paths are restored by one step of f32 iterative refinement
in `core/sven.py` (DESIGN.md §10.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def dot_precision(precision: str) -> jax.lax.Precision:
    """"f32" -> HIGHEST (full-precision MACs); "tf32"/"bf16" -> DEFAULT
    (tensor-core MACs; accumulation stays f32 via preferred_element_type)."""
    return (jax.lax.Precision.HIGHEST if precision == "f32"
            else jax.lax.Precision.DEFAULT)


def _num_warps(bm: int, bn: int) -> int:
    return max(1, min(8, (bm * bn) // 1024))


def _gram_gpu_kernel(xi_ref, xj_ref, y_ref, invt_ref, out_ref, *,
                     bk: int, precision: str):
    n = xi_ref.shape[0]
    bm, bn = xi_ref.shape[1], xj_ref.shape[1]
    prec = dot_precision(precision)

    # low-precision storage feeds tensor cores directly; anything wider than
    # f32 (x64-mode callers) is cut to f32 first — accumulation is f32 in
    # every case, and preferred_element_type may not downcast its operands
    cdt = (xi_ref.dtype if xi_ref.dtype in (jnp.bfloat16, jnp.float16)
           else jnp.float32)

    def body(k, carry):
        acc_p, acc_a, acc_b, acc_c = carry
        rows = (pl.ds(k * bk, bk), slice(None))
        xi = pl.load(xi_ref, rows).astype(cdt)         # (bk, bm)
        xj = pl.load(xj_ref, rows).astype(cdt)         # (bk, bn)
        yk = pl.load(y_ref, rows).astype(cdt)          # (bk, 1)
        acc_p = acc_p + jax.lax.dot_general(
            xi, xj, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32, precision=prec)
        xif = xi.astype(jnp.float32)
        xjf = xj.astype(jnp.float32)
        ykf = yk.astype(jnp.float32)
        acc_a = acc_a + jnp.sum(xif * ykf, axis=0)     # (bm,)
        acc_b = acc_b + jnp.sum(xjf * ykf, axis=0)     # (bn,)
        acc_c = acc_c + jnp.sum(ykf * ykf)
        return acc_p, acc_a, acc_b, acc_c

    init = (jnp.zeros((bm, bn), jnp.float32), jnp.zeros((bm,), jnp.float32),
            jnp.zeros((bn,), jnp.float32), jnp.zeros((), jnp.float32))
    acc_p, acc_a, acc_b, acc_c = jax.lax.fori_loop(0, n // bk, body, init)

    invt = invt_ref[0, 0].astype(jnp.float32)
    P = acc_p
    a = (acc_a * invt)[:, None]                        # (bm, 1) over cols
    b = (acc_b * invt)[None, :]                        # (1, bn) over rows
    s = acc_c * invt * invt
    dt = out_ref.dtype
    out_ref[0, 0] = (P - a - b + s).astype(dt)
    out_ref[1, 1] = (P + a + b + s).astype(dt)
    out_ref[0, 1] = (-P - a + b + s).astype(dt)
    out_ref[1, 0] = (-P + a - b + s).astype(dt)


@registry.register("shifted_gram", "gpu")
def gram_gpu_raw(
    X: jax.Array,        # (n, p) with n % bk == 0, p % bm == p % bn == 0
    y2d: jax.Array,      # (n, 1), same dtype family as X
    invt: jax.Array,     # (1, 1)
    *,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=jnp.float32,
    precision: str = "f32",
    interpret: bool = False,
) -> jax.Array:
    """Unpadded core call. Returns K in block layout (2, 2, p, p)."""
    from jax.experimental.pallas import triton as plgpu

    n, p = X.shape
    assert n % bk == 0 and p % bm == 0 and p % bn == 0, (n, p, bm, bn, bk)
    grid = (p // bm, p // bn)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = plgpu.TritonCompilerParams(
            num_warps=_num_warps(bm, bn), num_stages=2)
    return pl.pallas_call(
        functools.partial(_gram_gpu_kernel, bk=bk, precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bm), lambda i, j: (0, i)),
            pl.BlockSpec((n, bn), lambda i, j: (0, j)),
            pl.BlockSpec((n, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((2, 2, bm, bn), lambda i, j: (0, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((2, 2, p, p), out_dtype),
        interpret=interpret,
        **kwargs,
    )(X, X, y2d, invt)  # X twice: row-tile view (xi) and col-tile view (xj)
