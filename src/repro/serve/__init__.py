from repro.serve.engine import make_decode_step, make_prefill_step, greedy_generate

__all__ = ["make_decode_step", "make_prefill_step", "greedy_generate"]
