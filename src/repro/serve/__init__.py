from repro.serve.engine import (ElasticNetEngine, EngineStats, EnResult,
                                greedy_generate, make_decode_step,
                                make_prefill_step)

__all__ = [
    "ElasticNetEngine",
    "EngineStats",
    "EnResult",
    "make_decode_step",
    "make_prefill_step",
    "greedy_generate",
]
