"""Serving entry points.

LM side: prefill_step / decode_step builders (the functions the dry-run
lowers for prefill_32k / decode_32k / long_500k cells) and a simple batched
greedy generation driver for the examples.

Elastic Net side: `ElasticNetEngine` — the shape-bucketed batch server of
DESIGN.md §6.4, now a facade over the continuous-batching runtime
(`repro.runtime.scheduler`, DESIGN.md §8). Incoming (n, p) problems are
padded up to a small ladder of power-of-two buckets, so arbitrary request
shapes hit a bounded set of compiled executables. Padding is exact, not
approximate: zero rows (with zero responses) add nothing to the Elastic Net
objective, and zero columns provably carry beta_j = 0 through the SVM
reduction, so the unpadded slice of the padded solution IS the original
solution (tested against unpadded `sven`).

The engine speaks both of the paper's problem forms: `submit` takes the
constrained (t, lambda2) and `submit_penalized` the glmnet-style
(lambda1, lambda2); penalized requests drain in their own buckets through
`core.api.enet_batch` (the vmapped multiplier root-find, DESIGN.md §7) and
the same padding argument applies — zero columns are screened/zeroed and
the dummy batch-fill problems (X = 0) short-circuit to beta = 0.

`drain()` routes through the runtime scheduler: buckets dispatch
asynchronously (overlapping with each other) with warm starts from the
scheduler's solution cache, and results are awaited only at harvest.
`drain_reference()` keeps the seed engine's synchronous path — one
blocking, cold `sven_batch`/`enet_batch` call per bucket chunk — as the
parity oracle the runtime is tested and benchmarked against
(`benchmarks/bench_serve.py`).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.api import PathConfig, enet_batch
from repro.core.batch import sven_batch
from repro.core.sven import SvenConfig
from repro.models import model as M
from repro.runtime.cache import PENALIZED, SolutionCache
from repro.runtime.scheduler import (ContinuousScheduler, EnResult,
                                     RuntimeStats, ceil_pow2, stack_padded)

#: Back-compat alias: the engine's stats ARE the runtime scheduler's.
EngineStats = RuntimeStats


def make_prefill_step(cfg: M.ModelConfig, max_len: int):
    """prefill_step(params, batch) -> (last_logits, caches)."""

    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch, max_len=max_len)
        return logits[:, -1], caches

    return prefill_step


def make_decode_step(cfg: M.ModelConfig):
    """decode_step(params, tokens, caches) -> (logits, caches). One new token
    with a KV cache of seq_len — exactly the assigned decode_* lowering."""

    def decode_step(params, tokens, caches):
        return M.decode_step(params, cfg, tokens, caches)

    return decode_step


def greedy_generate(params, cfg: M.ModelConfig, batch: dict, *, steps: int,
                    max_len: int):
    """Prefill then greedy-decode `steps` tokens (example/test driver)."""
    prefill_step = jax.jit(make_prefill_step(cfg, max_len))
    decode_step = jax.jit(make_decode_step(cfg))
    logits, caches = prefill_step(params, batch)
    outs = []
    if cfg.frontend == "codebooks":
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,K)
    else:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,)
    for _ in range(steps):
        outs.append(tok)
        logits, caches = decode_step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs.append(tok)
    return jnp.stack(outs, axis=1)


# ---------------------------------------------------------------------------
# Elastic Net serving: facade over the continuous-batching runtime
# ---------------------------------------------------------------------------

class ElasticNetEngine:
    """Queue + bucket + drain server for Elastic Net solves.

    `submit()` / `submit_penalized()` enqueue a problem and return a request
    id; `drain()` solves everything queued through the runtime scheduler —
    one asynchronously dispatched, warm-started `sven_batch`/`enet_batch`
    per bucket chunk, awaited only at harvest. Because t/lambda2 are traced
    operands and shapes are bucketed, steady-state traffic runs entirely on
    cached executables — `stats.bucket_shapes` counts the distinct shapes
    ever compiled, which stays small and constant under load (tested).

    The engine is drain-on-demand (no deadlines): for latency-driven
    continuous batching use `repro.runtime.ContinuousScheduler` directly
    with a `max_wait` coalescing window, as `launch/serve_en.py` does.
    """

    def __init__(self, config: SvenConfig = SvenConfig(), *,
                 path_config: PathConfig = PathConfig(),
                 max_batch: int = 64, min_n: int = 16, min_p: int = 8,
                 cache: Optional[SolutionCache] = "default",
                 cache_dir: Optional[str] = None, speculate: bool = False,
                 mesh="auto", dtype=jnp.float64):
        if max_batch < 1 or min_n < 1 or min_p < 1:
            raise ValueError(f"ElasticNetEngine: max_batch/min_n/min_p must be "
                             f">= 1 (got {max_batch}/{min_n}/{min_p})")
        # `cache_dir` upgrades the default warm-start cache to the two-tier
        # one (DESIGN.md §11.2): solutions spill to a persistent directory
        # that survives engine restarts and is shareable across processes. A
        # restarted engine pointed at the same directory serves warm starts
        # from its first request. Ignored when an explicit cache instance
        # (or None) is passed — the caller owns tiering then.
        if cache_dir is not None and cache == "default":
            from repro.runtime.cache import TieredSolutionCache

            cache = TieredSolutionCache(spill_dir=cache_dir)
        self.config = config
        self.path_config = path_config
        self.max_batch = max_batch
        self.min_n = min_n
        self.min_p = min_p
        self.dtype = dtype
        # drain-on-demand: no deadlines AND no bucket-full auto-launch, so
        # nothing runs before an explicit drain/solve — which also keeps
        # drain_reference() a genuinely synchronous, untouched-queue oracle.
        # `mesh` passes straight through to the scheduler ("auto" = place
        # bucket batches across the devices when more than one is visible).
        self._scheduler = ContinuousScheduler(
            config, path_config=path_config, max_batch=max_batch,
            min_n=min_n, min_p=min_p, max_wait=None, cache=cache,
            auto_launch_full=False, mesh=mesh, speculate=speculate,
            dtype=dtype)

    @property
    def scheduler(self) -> ContinuousScheduler:
        """The underlying runtime scheduler (deadlines disabled)."""
        return self._scheduler

    @property
    def stats(self) -> RuntimeStats:
        return self._scheduler.stats

    @property
    def registry(self):
        """The scheduler's MetricsRegistry — the engine's whole telemetry
        (stats, cache counters, latency histograms) in one snapshot."""
        return self._scheduler.registry

    @property
    def cache(self) -> Optional[SolutionCache]:
        return self._scheduler.cache

    @property
    def _queue(self):
        return self._scheduler.pending_requests

    # -- request side ------------------------------------------------------

    def submit(self, X, y, t: float, lambda2: float) -> int:
        return self._scheduler.submit(X, y, t=t, lambda2=lambda2)

    def submit_penalized(self, X, y, lambda1: float, lambda2: float) -> int:
        """Enqueue a glmnet-style penalized request (DESIGN.md §7 front-end).

        Penalized requests bucket and pad exactly like constrained ones but
        drain through `core.api.enet_batch` — the vmapped multiplier
        root-find that maps (lambda1, lambda2) onto the constrained engine.
        """
        return self._scheduler.submit(X, y, lambda1=lambda1, lambda2=lambda2)

    def solve(self, X, y, t: float, lambda2: float) -> EnResult:
        """Submit + solve a single request (convenience / interactive path).

        Only this request's bucket is launched; same-bucket ride-alongs that
        complete with it are held and returned by the next `drain()`.
        """
        req_id = self.submit(X, y, t, lambda2)
        return self._scheduler.result(req_id)

    # -- bucket side -------------------------------------------------------

    def bucket_of(self, n: int, p: int) -> tuple:
        return self._scheduler.bucket_of(n, p)

    # -- drain side --------------------------------------------------------

    def drain(self) -> dict:
        """Solve everything queued; returns {request_id: EnResult}, including
        any results solved earlier but not yet delivered."""
        return self._scheduler.drain()

    def drain_reference(self) -> dict:
        """The seed engine's synchronous drain: one blocking, COLD (no
        warm-start cache) batched solve per bucket chunk, in bucket order.

        Kept as the parity oracle for the runtime path: `drain()` and
        `drain_reference()` return identical solutions to solver tolerance
        (tested), and `benchmarks/bench_serve.py` measures the continuous
        runtime's throughput against this baseline.
        """
        queue = self._scheduler.take_pending()
        groups: dict = {}
        for req in queue:
            key = self._scheduler.bucket_of(*req.X.shape) + (req.form,)
            groups.setdefault(key, []).append(req)

        results = self._scheduler.harvest(block=True)
        done_ids: set = set()
        try:
            for (bn, bp, form), reqs in sorted(groups.items()):
                for lo in range(0, len(reqs), self.max_batch):
                    chunk = reqs[lo:lo + self.max_batch]
                    self._drain_chunk(bn, bp, chunk, results,
                                      form == PENALIZED)
                    done_ids.update(r.req_id for r in chunk)
        except Exception:
            # A failed chunk must not lose the rest of the queue: re-queue
            # unsolved requests (results already held stay claimable).
            self._scheduler.requeue(
                [r for g in groups.values() for r in g
                 if r.req_id not in done_ids])
            self._scheduler._results.update(results)
            raise
        return results

    def _drain_chunk(self, bn: int, bp: int, reqs: list, results: dict,
                     pen: bool = False) -> None:
        sched = self._scheduler
        b_real = len(reqs)
        b_pad = min(ceil_pow2(b_real, 1), self.max_batch)
        Xb, yb = stack_padded(reqs, bn, bp, b_pad, self.dtype)
        fill = [1.0] * (b_pad - b_real)
        lamb = jnp.asarray([r.lam for r in reqs] + fill, self.dtype)
        l2b = jnp.asarray([r.lambda2 for r in reqs] + fill, self.dtype)

        t0 = sched.clock()
        if pen:
            pts = jax.block_until_ready(
                enet_batch(Xb, yb, lamb, l2b, self.path_config))
            betas, iters, kkts = pts.beta, pts.sven_iters, pts.kkt
        else:
            sol = jax.block_until_ready(
                sven_batch(Xb, yb, lamb, l2b, self.config))
            betas, iters, kkts = sol.beta, sol.iters, sol.kkt
        now = sched.clock()
        sched.stats.solve_seconds += now - t0
        sched.stats.batches += 1
        sched.stats.padded_slots += b_pad - b_real
        sched._seen_shapes.add((bn, bp, b_pad, "ref-pen" if pen else "ref"))
        sched.stats.bucket_shapes = len(sched._seen_shapes)
        sched.metrics.launched([r.req_id for r in reqs], t0)
        sched.metrics.completed([r.req_id for r in reqs], now)

        for i, req in enumerate(reqs):
            p = req.X.shape[1]
            results[req.req_id] = EnResult(beta=betas[i, :p], iters=iters[i],
                                           kkt=kkts[i], bucket=(bn, bp))
