"""Serving entry points: prefill_step / decode_step builders (the functions
the dry-run lowers for prefill_32k / decode_32k / long_500k cells) and a
simple batched greedy generation driver for the examples."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M


def make_prefill_step(cfg: M.ModelConfig, max_len: int):
    """prefill_step(params, batch) -> (last_logits, caches)."""

    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch, max_len=max_len)
        return logits[:, -1], caches

    return prefill_step


def make_decode_step(cfg: M.ModelConfig):
    """decode_step(params, tokens, caches) -> (logits, caches). One new token
    with a KV cache of seq_len — exactly the assigned decode_* lowering."""

    def decode_step(params, tokens, caches):
        return M.decode_step(params, cfg, tokens, caches)

    return decode_step


def greedy_generate(params, cfg: M.ModelConfig, batch: dict, *, steps: int,
                    max_len: int):
    """Prefill then greedy-decode `steps` tokens (example/test driver)."""
    prefill_step = jax.jit(make_prefill_step(cfg, max_len))
    decode_step = jax.jit(make_decode_step(cfg))
    logits, caches = prefill_step(params, batch)
    outs = []
    if cfg.frontend == "codebooks":
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,K)
    else:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,)
    for _ in range(steps):
        outs.append(tok)
        logits, caches = decode_step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs.append(tok)
    return jnp.stack(outs, axis=1)
