"""Serving entry points.

LM side: prefill_step / decode_step builders (the functions the dry-run
lowers for prefill_32k / decode_32k / long_500k cells) and a simple batched
greedy generation driver for the examples.

Elastic Net side: `ElasticNetEngine` — a shape-bucketed batch server that
makes the paper's workload itself servable (DESIGN.md §6). Incoming
(n, p) problems are padded up to a small ladder of power-of-two buckets, so
arbitrary request shapes hit a bounded set of compiled executables; queued
requests drain through `core.batch.sven_batch`, one vmapped solve per
bucket. Padding is exact, not approximate: zero rows (with zero responses)
add nothing to the Elastic Net objective, and zero columns provably carry
beta_j = 0 through the SVM reduction, so the unpadded slice of the padded
solution IS the original solution (tested against unpadded `sven`).

The engine speaks both of the paper's problem forms: `submit` takes the
constrained (t, lambda2) and `submit_penalized` the glmnet-style
(lambda1, lambda2); penalized requests drain in their own buckets through
`core.api.enet_batch` (the vmapped multiplier root-find, DESIGN.md §7) and
the same padding argument applies — zero columns are screened/zeroed and
the dummy batch-fill problems (X = 0) short-circuit to beta = 0.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.api import PathConfig, enet_batch
from repro.core.batch import sven_batch
from repro.core.sven import SvenConfig
from repro.models import model as M


def make_prefill_step(cfg: M.ModelConfig, max_len: int):
    """prefill_step(params, batch) -> (last_logits, caches)."""

    def prefill_step(params, batch):
        logits, caches = M.prefill(params, cfg, batch, max_len=max_len)
        return logits[:, -1], caches

    return prefill_step


def make_decode_step(cfg: M.ModelConfig):
    """decode_step(params, tokens, caches) -> (logits, caches). One new token
    with a KV cache of seq_len — exactly the assigned decode_* lowering."""

    def decode_step(params, tokens, caches):
        return M.decode_step(params, cfg, tokens, caches)

    return decode_step


def greedy_generate(params, cfg: M.ModelConfig, batch: dict, *, steps: int,
                    max_len: int):
    """Prefill then greedy-decode `steps` tokens (example/test driver)."""
    prefill_step = jax.jit(make_prefill_step(cfg, max_len))
    decode_step = jax.jit(make_decode_step(cfg))
    logits, caches = prefill_step(params, batch)
    outs = []
    if cfg.frontend == "codebooks":
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,K)
    else:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,)
    for _ in range(steps):
        outs.append(tok)
        logits, caches = decode_step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    outs.append(tok)
    return jnp.stack(outs, axis=1)


# ---------------------------------------------------------------------------
# Elastic Net serving: shape-bucketed batch engine over sven_batch
# ---------------------------------------------------------------------------

class EnResult(NamedTuple):
    """Per-request solve result, unpadded back to the request's own p."""

    beta: jax.Array           # (p,)
    iters: jax.Array          # solver outer iterations (padded problem)
    kkt: jax.Array            # EN KKT violation of the padded problem
    bucket: tuple             # (n_bucket, p_bucket) executable this ran on


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    batches: int = 0          # sven_batch launches issued by drain()
    bucket_shapes: int = 0    # distinct (n, p, B, form) executables compiled
    padded_slots: int = 0     # batch slots occupied by padding problems
    solve_seconds: float = 0.0


class _Pending(NamedTuple):
    req_id: int
    X: jax.Array
    y: jax.Array
    t: float              # constrained form: L1 budget; penalized: unused
    lambda2: float
    lambda1: Optional[float] = None   # set => penalized-form request


def _ceil_pow2(v: int, floor: int) -> int:
    b = floor
    while b < v:
        b *= 2
    return b


class ElasticNetEngine:
    """Queue + bucket + drain server for Elastic Net solves.

    `submit()` enqueues a problem and returns a request id; `drain()` groups
    the queue by padded (n, p) bucket, stacks each group (batch dim padded to
    a power of two, bounded by `max_batch`) and solves it with one
    `sven_batch` call per chunk. Because t/lambda2 are traced operands and
    shapes are bucketed, steady-state traffic runs entirely on cached
    executables — `stats.bucket_shapes` counts the distinct shapes ever
    compiled, which stays small and constant under load (tested).
    """

    def __init__(self, config: SvenConfig = SvenConfig(), *,
                 path_config: PathConfig = PathConfig(),
                 max_batch: int = 64, min_n: int = 16, min_p: int = 8,
                 dtype=jnp.float64):
        if max_batch < 1 or min_n < 1 or min_p < 1:
            raise ValueError(f"ElasticNetEngine: max_batch/min_n/min_p must be "
                             f">= 1 (got {max_batch}/{min_n}/{min_p})")
        self.config = config
        self.path_config = path_config
        self.max_batch = max_batch
        self.min_n = min_n
        self.min_p = min_p
        self.dtype = dtype
        self.stats = EngineStats()
        self._queue: list[_Pending] = []
        self._undelivered: dict = {}   # solved by solve() but not yet drained
        self._next_id = 0
        self._seen_shapes: set = set()

    # -- request side ------------------------------------------------------

    def submit(self, X, y, t: float, lambda2: float) -> int:
        X = jnp.asarray(X, self.dtype)
        y = jnp.asarray(y, self.dtype)
        if X.ndim != 2 or y.shape != (X.shape[0],):
            raise ValueError(f"submit: bad shapes X{X.shape} y{y.shape}")
        if not (t > 0 and lambda2 >= 0):
            raise ValueError(f"submit: need t > 0, lambda2 >= 0 (t={t}, lambda2={lambda2})")
        req_id = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(req_id, X, y, float(t), float(lambda2)))
        self.stats.requests += 1
        return req_id

    def submit_penalized(self, X, y, lambda1: float, lambda2: float) -> int:
        """Enqueue a glmnet-style penalized request (DESIGN.md §7 front-end).

        Penalized requests bucket and pad exactly like constrained ones but
        drain through `core.api.enet_batch` — the vmapped multiplier
        root-find that maps (lambda1, lambda2) onto the constrained engine.
        """
        X = jnp.asarray(X, self.dtype)
        y = jnp.asarray(y, self.dtype)
        if X.ndim != 2 or y.shape != (X.shape[0],):
            raise ValueError(f"submit_penalized: bad shapes X{X.shape} y{y.shape}")
        if not (lambda1 > 0 and lambda2 >= 0):
            raise ValueError(f"submit_penalized: need lambda1 > 0, lambda2 >= 0 "
                             f"(lambda1={lambda1}, lambda2={lambda2})")
        req_id = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(req_id, X, y, 0.0, float(lambda2),
                                    lambda1=float(lambda1)))
        self.stats.requests += 1
        return req_id

    def solve(self, X, y, t: float, lambda2: float) -> EnResult:
        """Submit + drain a single request (convenience / interactive path).

        Other pending requests ride along in the same drain; their results
        are held and returned by the next `drain()` call, not lost.
        """
        req_id = self.submit(X, y, t, lambda2)
        results = self.drain()
        mine = results.pop(req_id)
        self._undelivered.update(results)
        return mine

    # -- bucket side -------------------------------------------------------

    def bucket_of(self, n: int, p: int) -> tuple:
        return (_ceil_pow2(n, self.min_n), _ceil_pow2(p, self.min_p))

    def _pad_problem(self, req: _Pending, bn: int, bp: int):
        n, p = req.X.shape
        X = jnp.pad(req.X, ((0, bn - n), (0, bp - p)))
        y = jnp.pad(req.y, (0, bn - n))
        return X, y

    def _dummy_problem(self, bn: int, bp: int):
        # Solved alongside real requests to fill the batch to a power of two;
        # X = 0, y = 0 converges in O(1) solver iterations.
        return jnp.zeros((bn, bp), self.dtype), jnp.zeros((bn,), self.dtype)

    # -- drain side --------------------------------------------------------

    def drain(self) -> dict:
        """Solve everything queued; returns {request_id: EnResult}, including
        any results a previous `solve()` drained but did not deliver."""
        queue, self._queue = self._queue, []
        groups: dict = {}
        for req in queue:
            key = (self.bucket_of(*req.X.shape), req.lambda1 is not None)
            groups.setdefault(key, []).append(req)

        results, self._undelivered = self._undelivered, {}
        done_ids: set = set()
        try:
            for ((bn, bp), pen), reqs in sorted(groups.items()):
                for lo in range(0, len(reqs), self.max_batch):
                    chunk = reqs[lo:lo + self.max_batch]
                    self._drain_chunk(bn, bp, chunk, results, pen)
                    done_ids.update(r.req_id for r in chunk)
        except Exception:
            # A failed chunk must not lose the rest of the queue or results
            # already held: re-queue unsolved requests, re-stash solved ones.
            self._queue = [r for g in groups.values() for r in g
                           if r.req_id not in done_ids] + self._queue
            self._undelivered.update(results)
            raise
        return results

    def _drain_chunk(self, bn: int, bp: int, reqs: list, results: dict,
                     pen: bool = False) -> None:
        b_real = len(reqs)
        b_pad = min(_ceil_pow2(b_real, 1), self.max_batch)
        padded = [self._pad_problem(r, bn, bp) for r in reqs]
        padded += [self._dummy_problem(bn, bp)] * (b_pad - b_real)
        Xb = jnp.stack([x for x, _ in padded])
        yb = jnp.stack([y for _, y in padded])
        fill = [1.0] * (b_pad - b_real)
        l2b = jnp.asarray([r.lambda2 for r in reqs] + fill, self.dtype)

        t0 = time.perf_counter()
        if pen:
            l1b = jnp.asarray([r.lambda1 for r in reqs] + fill, self.dtype)
            pts = jax.block_until_ready(
                enet_batch(Xb, yb, l1b, l2b, self.path_config))
            betas, iters, kkts = pts.beta, pts.sven_iters, pts.kkt
        else:
            tb = jnp.asarray([r.t for r in reqs] + fill, self.dtype)
            sol = jax.block_until_ready(sven_batch(Xb, yb, tb, l2b, self.config))
            betas, iters, kkts = sol.beta, sol.iters, sol.kkt
        self.stats.solve_seconds += time.perf_counter() - t0
        self.stats.batches += 1
        self.stats.padded_slots += b_pad - b_real
        self._seen_shapes.add((bn, bp, b_pad, pen))
        self.stats.bucket_shapes = len(self._seen_shapes)

        for i, req in enumerate(reqs):
            p = req.X.shape[1]
            results[req.req_id] = EnResult(beta=betas[i, :p], iters=iters[i],
                                           kkt=kkts[i], bucket=(bn, bp))
