"""Adafactor (Shazeer & Stern, 2018): factored second moments — rank-1
(row, col) statistics instead of a full v tensor for matrices, cutting
optimizer memory from 2x to ~1.01x params. The memory-scarce cells
(deepseek-v3 train) can switch via --optimizer adafactor."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    v_row: Any     # per-leaf: (rows,) for matrices, full shape for vectors
    v_col: Any     # per-leaf: (cols,) for matrices, 0-size stub otherwise
    count: jax.Array


def _is_factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Any) -> AdafactorState:
    def vr(p):
        if _is_factored(p.shape):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        if _is_factored(p.shape):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((0,), jnp.float32)

    return AdafactorState(v_row=jax.tree.map(vr, params),
                          v_col=jax.tree.map(vc, params),
                          count=jnp.zeros((), jnp.int32))


def adafactor_update(grads: Any, state: AdafactorState, params: Any, *,
                     lr=1e-2, decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0,
                     weight_decay: float = 0.0):
    count = state.count + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)

    def upd(g, vr, vc, p):
        g32 = g.astype(jnp.float32)
        gsq = g32 * g32 + eps
        if _is_factored(p.shape):
            vr_new = beta2 * vr + (1 - beta2) * jnp.mean(gsq, axis=-1)
            vc_new = beta2 * vc + (1 - beta2) * jnp.mean(gsq, axis=-2)
            denom = jnp.maximum(jnp.mean(vr_new, axis=-1, keepdims=True), eps)
            vhat = (vr_new[..., None] / denom[..., None]) * vc_new[..., None, :]
            step = g32 / jnp.sqrt(vhat + eps)
        else:
            vr_new = beta2 * vr + (1 - beta2) * gsq
            vc_new = vc
            step = g32 / jnp.sqrt(vr_new + eps)
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(step * step) + eps)
        step = step / jnp.maximum(1.0, rms / clip_threshold)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + weight_decay * p32)
        return p_new.astype(p.dtype), vr_new, vc_new

    flat_g, treedef = jax.tree.flatten(grads)
    out = [upd(g, vr, vc, p) for g, vr, vc, p in zip(
        flat_g, jax.tree.leaves(state.v_row), jax.tree.leaves(state.v_col),
        jax.tree.leaves(params))]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_vr = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_vc = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdafactorState(v_row=new_vr, v_col=new_vc, count=count)
