from repro.optim.adamw import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.schedules import constant_lr, warmup_cosine

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "clip_by_global_norm", "global_norm", "constant_lr", "warmup_cosine"]
