"""AdamW on raw pytrees (no optax dependency). First/second moments are f32
regardless of param dtype (bf16-safe); ZeRO-1 sharding of (m, v) follows from
the same logical specs as the params plus an extra "fsdp" data-axis shard —
see dist/zero.py."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def _f32_like(t):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)


def adamw_init(params: Any) -> AdamWState:
    return AdamWState(m=_f32_like(params), v=_f32_like(params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[Any, AdamWState]:
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, count=count)
