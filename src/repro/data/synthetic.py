"""Synthetic regression problem generators matched to the paper's regimes.

The paper evaluates on 12 real datasets (8 with p >> n, 4 with n >> p);
offline we generate problems with controlled (n, p, sparsity, correlation,
noise) that reproduce those regimes. Features are standardized and the
response centered, as the paper assumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_regression(
    n: int,
    p: int,
    *,
    k_true: int = 10,
    rho: float = 0.3,
    noise: float = 0.1,
    seed: int = 0,
    dtype=jnp.float64,
):
    """Correlated Gaussian design + k-sparse ground truth.

    rho: AR(1)-style column correlation (captures the 'correlated genes'
    setting where the Elastic Net's L2 term matters).
    Returns (X, y, beta_true) with columns standardized, y centered.
    """
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((n, p))
    if rho > 0:
        # AR(1) mixing along features via cumulative blend (cheap, full-rank)
        x = np.empty_like(z)
        x[:, 0] = z[:, 0]
        a = np.sqrt(1 - rho * rho)
        for j in range(1, p):
            x[:, j] = rho * x[:, j - 1] + a * z[:, j]
    else:
        x = z
    beta = np.zeros(p)
    idx = rng.choice(p, size=min(k_true, p), replace=False)
    beta[idx] = rng.standard_normal(len(idx)) * 2.0
    y = x @ beta + noise * rng.standard_normal(n)
    # standardize columns, center response (paper's preprocessing)
    x = (x - x.mean(0)) / (x.std(0) + 1e-12)
    y = y - y.mean()
    return jnp.asarray(x, dtype), jnp.asarray(y, dtype), jnp.asarray(beta, dtype)


def prostate_like(seed: int = 7, dtype=jnp.float64):
    """8-feature, ~100-sample problem shaped like the paper's Fig.1 dataset."""
    return make_regression(97, 8, k_true=5, rho=0.4, noise=0.5, seed=seed, dtype=dtype)
