"""Token data pipeline: deterministic, shardable, resumable.

Sources: synthetic LM streams (seeded, infinite) and memory-mapped token
files. Determinism contract: batch content is a pure function of
(seed, step, host_shard) — so (a) restarts resume exactly (the step index is
in the checkpoint), (b) stragglers/failed hosts can be re-issued their shard
("skip-ahead": no data server handshake needed at 1000-node scale), and
(c) elastic rescale re-partitions by recomputing shard indices."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    n_codebooks: int = 0        # musicgen-style multi-stream tokens
    vision_tokens: int = 0      # internvl2-style prepended patch embeds
    d_model: int = 0            # for patch embeds


def _host_batch(cfg: DataConfig) -> int:
    assert cfg.global_batch % cfg.n_hosts == 0
    return cfg.global_batch // cfg.n_hosts


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Pure function of (seed, step, host): a Zipf-ish token stream with
    local n-gram structure (so loss curves are non-trivial)."""
    rng = np.random.default_rng((cfg.seed * 1_000_003 + step) * 65_537 + cfg.host_id)
    B = _host_batch(cfg)
    shape = (B, cfg.seq_len, cfg.n_codebooks) if cfg.n_codebooks else (B, cfg.seq_len)
    # Zipf marginal via inverse-CDF on a power law
    u = rng.random(shape)
    toks = np.floor((cfg.vocab_size ** u - 1.0) / (cfg.vocab_size - 1) * cfg.vocab_size)
    toks = np.clip(toks.astype(np.int32), 0, cfg.vocab_size - 1)
    # local structure: every 4th token repeats its predecessor
    if cfg.n_codebooks == 0:
        toks[:, 3::4] = toks[:, 2::4]
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.vision_tokens:
        pe = rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)).astype(np.float32)
        batch["patch_embeds"] = jnp.asarray(pe)
    return batch


class SyntheticStream:
    """Iterator facade with explicit step state (resume = set .step)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = synthetic_batch(self.cfg, self.step)
        self.step += 1
        return b


class MemmapTokens:
    """Pre-tokenized corpus on disk: (N,) int32 memmap, sampled in windows.
    Window starts are a pure function of (seed, step, host) => deterministic
    and resumable, same contract as SyntheticStream."""

    def __init__(self, path: str, cfg: DataConfig, start_step: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        self.step = start_step
        assert len(self.tokens) > cfg.seq_len + 1

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed * 7_368_787 + self.step) * 65_537 + cfg.host_id)
        B = _host_batch(cfg)
        starts = rng.integers(0, len(self.tokens) - cfg.seq_len - 1, size=B)
        toks = np.stack([self.tokens[s: s + cfg.seq_len] for s in starts])
        self.step += 1
        return {"tokens": jnp.asarray(toks.astype(np.int32))}


def write_token_file(path: str, tokens: np.ndarray):
    np.asarray(tokens, np.int32).tofile(path)
