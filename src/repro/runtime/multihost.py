"""Multi-host serving coordinator: the scheduler spanned over worker
processes (DESIGN.md §11.1).

One `ContinuousScheduler` serves one process — its executables, cache and
event loop die with it. `MultiHostCoordinator` spans that runtime over N
worker PROCESSES ("hosts": separate interpreters, separate JAX runtimes,
the single-machine stand-in for separate machines), adding the three
things a single process cannot have:

    placement   admitted requests coalesce onto the same pow2 bucket
                ladder as the scheduler's, but whole BATCHES are placed
                onto hosts: least modeled outstanding seconds first
                (`core.routing.estimate_batch_seconds` — the PR 6 cost
                model reused as a load signal), bucket affinity as the
                tiebreak so each host re-serves executables it has
                already compiled;
    admission   per-host in-flight caps (`max_inflight_per_host`) —
                batches beyond a host's cap wait in the coordinator's
                dispatch queue instead of piling onto a busy host;
    failure     each worker heartbeats on its duplex pipe while idle; a
                host whose process has exited (SIGKILL included — the
                `kill_host` fault injection), whose pipe has hit EOF, or
                whose last sign of life is older than `heartbeat_timeout`
                is declared dead, and every batch in flight on it is
                REQUEUED. Requeues re-check deadlines exactly like
                `ContinuousScheduler.requeue`: an expired request
                completes terminally as "deadline_exceeded" instead of
                chasing the fault forever. When NO host remains, every
                unfinished request completes terminally as "aborted" —
                the no-silent-drops contract: every admitted request ends
                in exactly one of "ok" / "deadline_exceeded" / "aborted".

Transport is one duplex `multiprocessing.Pipe` per worker, `spawn` start
method (fork is unsafe once JAX has threads). A pipe has a single writer
on each end, so a SIGKILLed worker can corrupt at most its OWN channel —
the coordinator sees EOF/closed and fails over — whereas a shared queue
killed mid-`put` can wedge every producer behind a half-written record.

Workers share one persistent spill directory when `cache_dir` is given
(`TieredSolutionCache`, §11.2): work a dead host completed before dying
is warm-servable by the survivors, and a restarted coordinator starts
warm. `speculate=True` turns on §11.3 pre-solves inside each worker.

The coordinator duck-types the scheduler's serving surface —
`submit`/`flush`/`drain`/`metrics` — so `loadgen.run_open_loop` drives a
multi-host mesh unchanged (``python -m repro.runtime.loadgen --hosts 2``
is the CI smoke).
"""
from __future__ import annotations

import math
import multiprocessing as mp
import os
import traceback
from typing import Dict, List, Optional

import numpy as np

from repro.obs import clock as obs_clock
from repro.obs import events as obs_events
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer
from repro.runtime.cache import CONSTRAINED, PENALIZED
from repro.runtime.metrics import LatencyRecorder

_HB_INTERVAL_DEFAULT = 0.05


# -- worker process ---------------------------------------------------------

def _worker_main(host_id: int, conn, cfg: dict) -> None:
    """One host: a private ContinuousScheduler behind a request pipe.

    Protocol (parent -> child): ("solve", batch_id, items) | ("stop",).
    (child -> parent): ("ready", host_id) once serving; ("hb", host_id, ts)
    whenever `heartbeat_interval` passes with no work; ("result", host_id,
    batch_id, {req_id: result dict}, deltas); ("error", host_id, batch_id,
    tb, deltas) for a failed batch (the coordinator requeues it); ("stats",
    host_id, dict, deltas) once, just before a clean exit.

    `deltas` are the worker registry's `counter_deltas()` — metric
    increments since the previous message, piggybacked on the pipes the
    results already ride (DESIGN.md §12.4). Each delta is consumed by
    exactly one snapshot, so the coordinator's merge is idempotent under
    host death: a dead host's final deltas either arrived with a buffered
    message (salvaged) or died with the pipe — never merged twice.
    """
    if cfg.get("scrub_xla", True):
        # the parent may run under XLA_FLAGS host-device simulation; each
        # worker is its own "host" and must not inherit an 8-device world
        os.environ.pop("XLA_FLAGS", None)
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.runtime.cache import TieredSolutionCache
    from repro.runtime.scheduler import ContinuousScheduler

    cache = ("default" if not cfg.get("cache_dir") else
             TieredSolutionCache(spill_dir=cfg["cache_dir"]))
    sched = ContinuousScheduler(
        max_batch=cfg.get("max_batch", 8), min_n=cfg.get("min_n", 16),
        min_p=cfg.get("min_p", 8), max_wait=None, cache=cache,
        fixed_batch=cfg.get("fixed_batch", False),
        speculate=cfg.get("speculate", False))
    hb = cfg.get("heartbeat_interval", _HB_INTERVAL_DEFAULT)
    conn.send(("ready", host_id))
    try:
        while True:
            if not conn.poll(hb):
                conn.send(("hb", host_id, obs_clock.walltime()))
                continue
            msg = conn.recv()
            if msg[0] == "stop":
                break
            _, batch_id, items = msg
            try:
                local = {}
                for it in items:
                    kw = ({"lambda1": it["lam"]} if it["form"] == PENALIZED
                          else {"t": it["lam"]})
                    rid = sched.submit(it["X"], it["y"],
                                       lambda2=it["lambda2"],
                                       priority=it["priority"], **kw)
                    local[rid] = it["req_id"]
                results = sched.drain()
                payload = {}
                for rid, res in results.items():
                    payload[local[rid]] = {
                        "beta": (None if res.beta is None
                                 else np.asarray(res.beta)),
                        "iters": int(res.iters), "kkt": float(res.kkt),
                        "bucket": tuple(res.bucket), "status": res.status}
                conn.send(("result", host_id, batch_id, payload,
                           sched.registry.counter_deltas()))
            except Exception:  # noqa: BLE001 — report, let the parent requeue
                conn.send(("error", host_id, batch_id,
                           traceback.format_exc(),
                           sched.registry.counter_deltas()))
        c = sched.cache
        conn.send(("stats", host_id, {
            "requests": sched.stats.requests,
            "batches": sched.stats.batches,
            "bucket_shapes": sched.stats.bucket_shapes,
            "speculative_slots": sched.stats.speculative_slots,
            "cache_hits": getattr(c, "hits", 0),
            "cache_misses": getattr(c, "misses", 0),
            "spill_hits": getattr(c, "spill_hits", 0)},
            sched.registry.counter_deltas()))
    except (EOFError, BrokenPipeError, OSError):
        pass                    # parent gone: nothing left to report to
    finally:
        conn.close()


# -- coordinator-side host bookkeeping --------------------------------------

class _Host:
    def __init__(self, host_id, proc, conn, clock):
        self.host_id = host_id
        self.proc = proc
        self.conn = conn
        self.ready = False
        self.dead = False
        self.last_seen = clock()
        self.outstanding: Dict[int, "_Batch"] = {}   # batch_id -> batch
        self.load_s = 0.0          # modeled seconds of outstanding work
        self.buckets_seen: set = set()
        self.stats: Optional[dict] = None


class _Batch:
    __slots__ = ("batch_id", "key", "reqs", "cost")

    def __init__(self, batch_id, key, reqs, cost):
        self.batch_id = batch_id
        self.key = key
        self.reqs = reqs
        self.cost = cost


class MultiHostCoordinator:
    """Span the serving runtime over `n_hosts` worker processes.

    `max_wait=None` (default) is drain-on-demand: requests wait for an
    explicit `flush`/`drain`. A float arms per-request deadlines — they
    gate REQUEUE on failure (expired requeued requests terminate as
    "deadline_exceeded"); batch formation itself happens at flush.

    `cache_dir` points every worker's TieredSolutionCache at one shared
    persistent spill tier; None serves memory-only. `heartbeat_timeout`
    (None disables) additionally declares a host dead when its pipe has
    been silent too long — process exit and pipe EOF are always fatal.
    NOTE a worker mid-solve does not heartbeat (it is draining, not
    idling), so a timeout must comfortably exceed the slowest batch.
    """

    def __init__(self, n_hosts: int = 2, *, max_batch: int = 8,
                 min_n: int = 16, min_p: int = 8,
                 max_wait: Optional[float] = None,
                 cache_dir: Optional[str] = None, speculate: bool = False,
                 fixed_batch: bool = False,
                 max_inflight_per_host: int = 2,
                 heartbeat_interval: float = _HB_INTERVAL_DEFAULT,
                 heartbeat_timeout: Optional[float] = None,
                 scrub_xla: bool = True, clock=obs_clock.monotonic,
                 spawn_timeout: float = 120.0, start: bool = True,
                 registry: Optional[MetricsRegistry] = None):
        if n_hosts < 1:
            raise ValueError(f"MultiHostCoordinator: n_hosts >= 1 required "
                             f"(got {n_hosts})")
        self.n_hosts = n_hosts
        self.max_batch = max_batch
        self.min_n = min_n
        self.min_p = min_p
        self.max_wait = max_wait
        self.max_inflight_per_host = max_inflight_per_host
        self.heartbeat_timeout = heartbeat_timeout
        self.spawn_timeout = spawn_timeout
        self.clock = clock
        self.tracer = get_tracer()
        # three metric scopes (DESIGN.md §12.4): `registry` is the
        # coordinator's OWN accounting (admission, terminals, failover),
        # `fleet` is every worker's counter deltas merged, `host_registries`
        # keeps the same deltas split per host — a dead host's view freezes
        # at its last delivered message.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.fleet = MetricsRegistry()
        self.host_registries: Dict[int, MetricsRegistry] = {}
        self.metrics = LatencyRecorder(registry=self.registry)
        self._admitted = self.registry.counter(
            "requests_admitted_total", "requests accepted by the coordinator")
        self._terminal = self.registry.counter(
            "requests_terminal_total",
            "admitted requests by terminal status", ("status",))
        self._requeues = self.registry.counter(
            "batches_requeued_total",
            "batches re-placed after a host failure or worker error")
        self._lost = self.registry.counter(
            "hosts_lost_total", "worker hosts declared dead")
        self.worker_stats: List[dict] = []
        self._cfg = {"max_batch": max_batch, "min_n": min_n, "min_p": min_p,
                     "cache_dir": cache_dir, "speculate": speculate,
                     "fixed_batch": fixed_batch, "scrub_xla": scrub_xla,
                     "heartbeat_interval": heartbeat_interval}
        self._hosts: List[_Host] = []
        self._buckets: Dict[tuple, list] = {}
        self._queue: List[_Batch] = []
        self._results: Dict[int, "object"] = {}
        self._owner: Dict[int, int] = {}     # req_id -> batch_id (in flight)
        self._next_req = 0
        self._next_batch = 0
        self._started = False
        if start:
            self.start()

    # -- telemetry ---------------------------------------------------------

    @property
    def hosts_lost(self) -> int:
        return int(self._lost.value())

    @property
    def requeued_batches(self) -> int:
        return int(self._requeues.value())

    def _merge_deltas(self, host_id: int, deltas: Optional[dict]) -> None:
        """Fold one worker message's piggybacked counter deltas into the
        fleet view and that host's view."""
        if not deltas:
            return
        self.fleet.merge_counter_deltas(deltas)
        reg = self.host_registries.setdefault(host_id, MetricsRegistry())
        reg.merge_counter_deltas(deltas)

    def metrics_snapshot(self) -> dict:
        """Coordinator + fleet + per-host metric state as plain JSON."""
        return {"coordinator": self.registry.snapshot(),
                "fleet": self.fleet.snapshot(),
                "hosts": {hid: reg.snapshot()
                          for hid, reg in sorted(self.host_registries.items())}}

    def accounting(self) -> dict:
        """The no-silent-drops invariant as numbers (bench_obs gates it):
        every admitted request must sit in exactly one terminal-status
        counter once traffic has drained."""
        terminals = {status: int(v) for (status,), v
                     in self._terminal.series().items()}
        admitted = int(self._admitted.value())
        return {"admitted": admitted, "terminals": terminals,
                "outstanding": len(self._owner) + len(self._queue_reqs()),
                "balanced": admitted == sum(terminals.values())}

    def _queue_reqs(self) -> list:
        return ([r for b in self._queue for r in b.reqs]
                + [r for b in self._buckets.values() for r in b])

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        """Spawn the workers and wait for every "ready" (compilation-free:
        workers compile lazily, per bucket, on first traffic)."""
        if self._started:
            return
        ctx = mp.get_context("spawn")
        for i in range(self.n_hosts):
            parent, child = ctx.Pipe(duplex=True)
            proc = ctx.Process(target=_worker_main, args=(i, child, self._cfg),
                               daemon=True, name=f"en-host-{i}")
            proc.start()
            child.close()            # the parent keeps only its own end
            self._hosts.append(_Host(i, proc, parent, self.clock))
        self._started = True
        t0 = self.clock()
        while not all(h.ready or h.dead for h in self._hosts):
            self._service(0.05)
            if self.clock() - t0 > self.spawn_timeout:
                self.shutdown()
                raise TimeoutError(
                    f"multihost: workers not ready after {self.spawn_timeout}s")
        if not self._alive():
            raise RuntimeError("multihost: every worker died during startup")

    def shutdown(self) -> List[dict]:
        """Stop every worker, collect final stats, reap processes."""
        for h in self._hosts:
            if not h.dead:
                try:
                    h.conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
        t0 = self.clock()
        while (any(not h.dead and h.stats is None for h in self._hosts)
               and self.clock() - t0 < 10.0):
            self._service(0.05)
        for h in self._hosts:
            h.proc.join(timeout=2.0)
            if h.proc.is_alive():
                h.proc.kill()
                h.proc.join(timeout=2.0)
            try:
                h.conn.close()
            except OSError:
                pass
        self.worker_stats = [h.stats for h in self._hosts
                             if h.stats is not None]
        return self.worker_stats

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()

    # -- fault injection ---------------------------------------------------

    def kill_host(self, host_id: int) -> None:
        """SIGKILL one worker — the fault the test harness injects. The
        coordinator is NOT told: death must be DETECTED (exitcode / pipe
        EOF / stale heartbeat), exercising the real failover path."""
        self._hosts[host_id].proc.kill()

    # -- admission (mirrors ContinuousScheduler.submit) ----------------------

    def submit(self, X, y, *, t: Optional[float] = None,
               lambda1: Optional[float] = None, lambda2: float = 1.0,
               priority: int = 0, deadline: Optional[float] = None) -> int:
        from repro.runtime.scheduler import EnRequest, ceil_pow2

        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        if X.ndim != 2 or y.shape != (X.shape[0],):
            raise ValueError(f"submit: bad shapes X{X.shape} y{y.shape}")
        if (t is None) == (lambda1 is None):
            raise ValueError("submit: give exactly one of t= and lambda1=")
        if t is not None and not (t > 0 and lambda2 >= 0):
            raise ValueError(f"submit: need t > 0, lambda2 >= 0 "
                             f"(t={t}, lambda2={lambda2})")
        if lambda1 is not None and not (lambda1 >= 0 and lambda2 >= 0):
            raise ValueError(f"submit: need lambda1 >= 0, lambda2 >= 0 "
                             f"(lambda1={lambda1}, lambda2={lambda2})")
        now = self.clock()
        if deadline is None:
            deadline = (math.inf if self.max_wait is None
                        else now + self.max_wait)
        form = CONSTRAINED if t is not None else PENALIZED
        req = EnRequest(
            req_id=self._next_req, X=X, y=y, form=form,
            lam=float(t if t is not None else lambda1),
            lambda2=float(lambda2), priority=priority, deadline=deadline,
            submitted=now, fingerprint=None)
        self._next_req += 1
        key = (ceil_pow2(X.shape[0], self.min_n),
               ceil_pow2(X.shape[1], self.min_p), form)
        self._buckets.setdefault(key, []).append(req)
        self.metrics.submitted(req.req_id, now)
        self._admitted.inc()
        if len(self._buckets[key]) >= self.max_batch:
            self._form_batches(only_full=True)
        self._pump()
        self._service(0.0)
        return req.req_id

    # -- placement ---------------------------------------------------------

    def _alive(self) -> List[_Host]:
        return [h for h in self._hosts if not h.dead]

    def _form_batches(self, only_full: bool = False) -> None:
        """Cut pending buckets into max_batch chunks on the dispatch queue."""
        from repro.core import routing

        for key in list(self._buckets):
            while (len(self._buckets.get(key, ())) >=
                   (self.max_batch if only_full else 1)):
                bucket = self._buckets[key]
                bucket.sort(key=lambda r: (-r.priority, r.deadline, r.req_id))
                chunk, rest = bucket[:self.max_batch], bucket[self.max_batch:]
                if rest:
                    self._buckets[key] = rest
                else:
                    del self._buckets[key]
                bn, bp, form = key
                cost = routing.estimate_batch_seconds(
                    bn, bp, len(chunk),
                    form="penalized" if form == PENALIZED else "constrained")
                self._queue.append(_Batch(self._next_batch, key,
                                          list(chunk), cost))
                self._next_batch += 1
                if not self._buckets.get(key):
                    break

    def _pump(self) -> None:
        """Place queued batches: among hosts under their in-flight cap,
        least modeled load wins, bucket affinity breaks ties (a host that
        has compiled this (bn, bp, form) executable keeps getting it)."""
        while self._queue:
            eligible = [h for h in self._alive() if h.ready and
                        len(h.outstanding) < self.max_inflight_per_host]
            if not eligible:
                if self._started and not self._alive():
                    self._abort_everything()
                return
            batch = self._queue.pop(0)
            host = min(eligible, key=lambda h: (
                h.load_s, 0 if batch.key in h.buckets_seen else 1, h.host_id))
            items = [{"req_id": r.req_id, "X": r.X, "y": r.y, "form": r.form,
                      "lam": r.lam, "lambda2": r.lambda2,
                      "priority": r.priority} for r in batch.reqs]
            try:
                with self.tracer.span("mh.place", host=host.host_id,
                                      bucket=batch.key[:2],
                                      b=len(batch.reqs), cost_s=batch.cost):
                    host.conn.send(("solve", batch.batch_id, items))
            except (BrokenPipeError, OSError):
                self._mark_dead(host)
                self._queue.insert(0, batch)
                continue
            host.outstanding[batch.batch_id] = batch
            host.load_s += batch.cost
            host.buckets_seen.add(batch.key)
            now = self.clock()
            self.metrics.launched([r.req_id for r in batch.reqs], now)
            for r in batch.reqs:
                self._owner[r.req_id] = batch.batch_id

    # -- failure handling --------------------------------------------------

    def _mark_dead(self, host: _Host) -> None:
        if host.dead:
            return
        # salvage messages that beat the death into the pipe: a batch whose
        # result is already buffered completed — requeueing it would be
        # duplicate (if harmless) work
        try:
            while host.conn.poll(0):
                msg = host.conn.recv()
                if msg[0] == "result":
                    self._merge_deltas(host.host_id, msg[4])
                    self._finish_batch(host, msg[2], msg[3])
                elif msg[0] == "stats":
                    host.stats = msg[2]
                    self._merge_deltas(host.host_id, msg[3])
        except (EOFError, OSError):
            pass
        host.dead = True
        host.ready = False
        lost = list(host.outstanding.values())
        host.outstanding.clear()
        host.load_s = 0.0
        # a host whose FINAL stats arrived and whose slate is clean merely
        # stopped (shutdown handshake) — only count genuine failures
        if host.stats is None or lost:
            self._lost.inc()
            obs_events.emit("host_death", host=host.host_id,
                            lost_batches=len(lost),
                            exitcode=host.proc.exitcode)
        for batch in lost:
            self._requeues.inc()
            self._requeue(batch.reqs)

    def _requeue(self, reqs) -> None:
        """Re-admit a failed batch's requests; expired deadlines terminate
        (the ContinuousScheduler.requeue contract, across processes)."""
        from repro.runtime.scheduler import EnResult, ceil_pow2

        now = self.clock()
        for r in reqs:
            self._owner.pop(r.req_id, None)
            if r.deadline <= now:
                self._results[r.req_id] = EnResult(
                    beta=None, iters=np.int64(0), kkt=math.inf,
                    bucket=(ceil_pow2(r.X.shape[0], self.min_n),
                            ceil_pow2(r.X.shape[1], self.min_p)),
                    status="deadline_exceeded")
                self.metrics.completed([r.req_id], now)
                self._terminal.inc(status="deadline_exceeded")
                obs_events.emit("deadline_exceeded", req_id=r.req_id,
                                deadline=r.deadline, now=now)
                continue
            key = (ceil_pow2(r.X.shape[0], self.min_n),
                   ceil_pow2(r.X.shape[1], self.min_p), r.form)
            self._buckets.setdefault(key, []).append(r)
        self._form_batches()

    def _abort_everything(self) -> None:
        """No host left: terminate every unfinished request explicitly."""
        from repro.runtime.scheduler import EnResult, ceil_pow2

        now = self.clock()
        doomed = self._queue_reqs()
        self._queue.clear()
        self._buckets.clear()
        if doomed:
            obs_events.emit("abort_all", n=len(doomed))
        for r in doomed:
            self._owner.pop(r.req_id, None)
            self._results[r.req_id] = EnResult(
                beta=None, iters=np.int64(0), kkt=math.inf,
                bucket=(ceil_pow2(r.X.shape[0], self.min_n),
                        ceil_pow2(r.X.shape[1], self.min_p)),
                status="aborted")
            self.metrics.completed([r.req_id], now)
            self._terminal.inc(status="aborted")

    # -- event loop --------------------------------------------------------

    def _service(self, timeout: float) -> None:
        """Drain worker pipes, detect deaths, refresh liveness clocks."""
        from multiprocessing.connection import wait as mp_wait

        conns = {h.conn: h for h in self._hosts if not h.dead}
        if conns:
            for conn in mp_wait(list(conns), timeout=timeout or 0):
                host = conns[conn]
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    self._mark_dead(host)
                    continue
                host.last_seen = self.clock()
                kind = msg[0]
                if kind == "ready":
                    host.ready = True
                elif kind == "hb":
                    pass
                elif kind == "result":
                    self._merge_deltas(host.host_id, msg[4])
                    self._finish_batch(host, msg[2], msg[3])
                elif kind == "error":
                    self._merge_deltas(host.host_id, msg[4])
                    batch = host.outstanding.pop(msg[2], None)
                    if batch is not None:
                        host.load_s = max(0.0, host.load_s - batch.cost)
                        self._requeues.inc()
                        obs_events.emit("requeue", host=host.host_id,
                                        batch=msg[2])
                        self._requeue(batch.reqs)
                elif kind == "stats":
                    host.stats = msg[2]
                    self._merge_deltas(host.host_id, msg[3])
        now = self.clock()
        for h in self._hosts:
            if h.dead:
                continue
            if h.proc.exitcode is not None and h.stats is None:
                self._mark_dead(h)
            elif (self.heartbeat_timeout is not None
                  and now - h.last_seen > self.heartbeat_timeout):
                self._mark_dead(h)
        if self._started and not self._alive() and (self._queue
                                                    or self._buckets):
            self._abort_everything()

    def _finish_batch(self, host: _Host, batch_id: int, payload: dict) -> None:
        from repro.runtime.scheduler import EnResult

        batch = host.outstanding.pop(batch_id, None)
        if batch is None:
            return                   # duplicate delivery after a requeue
        host.load_s = max(0.0, host.load_s - batch.cost)
        now = self.clock()
        done = []
        for r in batch.reqs:
            out = payload.get(r.req_id)
            if out is None:          # worker lost it: requeue, never drop
                self._requeue([r])
                continue
            self._owner.pop(r.req_id, None)
            self._results[r.req_id] = EnResult(
                beta=out["beta"], iters=np.int64(out["iters"]),
                kkt=out["kkt"], bucket=tuple(out["bucket"]),
                status=out["status"])
            self._terminal.inc(status=out["status"])
            done.append(r.req_id)
        if done:
            self.metrics.completed(done, now)

    # -- serving surface (duck-types ContinuousScheduler) --------------------

    def flush(self) -> int:
        self._form_batches()
        n = len(self._queue)
        self._pump()
        return n

    def poll(self, now=None) -> int:
        self._service(0.0)
        self._pump()
        return 0

    def harvest(self, *, block: bool = False) -> Dict[int, "object"]:
        self._service(0.0)
        self._pump()
        out, self._results = self._results, {}
        return out

    def drain(self, timeout: float = 300.0) -> Dict[int, "object"]:
        """Flush + wait until every admitted request has a result."""
        self.flush()
        t0 = self.clock()
        while self._owner or self._queue or self._buckets:
            self._service(0.05)
            self._pump()
            if self.clock() - t0 > timeout:
                raise TimeoutError(
                    f"multihost drain: {len(self._owner)} in flight, "
                    f"{len(self._queue)} queued after {timeout}s")
        out, self._results = self._results, {}
        return out
