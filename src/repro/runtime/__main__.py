"""`python -m repro.runtime` — the serving-load smoke (loadgen CLI)."""
from repro.runtime.loadgen import main

main()
