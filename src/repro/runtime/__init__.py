"""repro.runtime — asynchronous continuous-batching serving runtime
(DESIGN.md §8).

The layer between the solver engine (core/) and the serving facade
(serve/): an event-loop scheduler that coalesces live requests onto the
power-of-two bucket ladder and launches vmapped solves asynchronously
(`scheduler`), a warm-start solution cache exploiting the paper's
adjacent-lambda observation (`cache`), rank-1 streaming-row updates
(`online`), latency/throughput percentile accounting (`metrics`) and a
reproducible open-loop load generator (`loadgen` — also the CI serving
smoke: ``python -m repro.runtime.loadgen``).

Telemetry (DESIGN.md §12) lives in `repro.obs` — the registry / tracer /
event-log surface is re-exported here because the runtime components are
its primary producers.
"""
from repro.obs import (EventLog, MetricsRegistry, SolveLog, SolveRecord,
                       Tracer, default_events, default_registry,
                       disable_tracing, enable_tracing, get_tracer)
from repro.runtime.cache import (CONSTRAINED, PENALIZED, PersistentCacheTier,
                                 SolutionCache, TieredSolutionCache,
                                 WarmEntry, fingerprint_problem)
from repro.runtime.loadgen import LoadItem, LoadSpec, make_workload, run_open_loop
from repro.runtime.metrics import LatencyRecorder, percentile
from repro.runtime.multihost import MultiHostCoordinator
from repro.runtime.online import OnlineElasticNet, OnlineSolution, OnlineStats
from repro.runtime.scheduler import (ContinuousScheduler, EnRequest, EnResult,
                                     RuntimeStats, ceil_pow2)

__all__ = [
    "ContinuousScheduler",
    "EnRequest",
    "EnResult",
    "RuntimeStats",
    "ceil_pow2",
    "SolutionCache",
    "TieredSolutionCache",
    "PersistentCacheTier",
    "MultiHostCoordinator",
    "WarmEntry",
    "fingerprint_problem",
    "CONSTRAINED",
    "PENALIZED",
    "OnlineElasticNet",
    "OnlineSolution",
    "OnlineStats",
    "LatencyRecorder",
    "percentile",
    "LoadSpec",
    "LoadItem",
    "make_workload",
    "run_open_loop",
    "MetricsRegistry",
    "Tracer",
    "EventLog",
    "SolveLog",
    "SolveRecord",
    "default_registry",
    "default_events",
    "get_tracer",
    "enable_tracing",
    "disable_tracing",
]
