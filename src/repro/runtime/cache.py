"""Warm-start solution cache for the serving runtime (DESIGN.md §8).

The paper's own warm-start observation — solutions at adjacent points of the
regularization surface are near-identical, which is why `sven_path` carries
(alpha, w) down the t-grid — is exactly the structure serving traffic has:
the same dataset re-solved at a new lambda (hyperparameter sweeps, CV-like
exploration, online refresh). The cache keys solved problems by

    (data fingerprint, problem form)  ->  [ (lambda-point, solution), ... ]

and answers a lookup with the stored solution whose regularization point is
NEAREST in log-space, provided it falls inside the `neighborhood` radius.
The hit is fed back into `sven_batch` / `enet_batch` as a warm start — never
returned directly — so a hit changes iteration count, not the answer:
repeat and adjacent-lambda traffic re-solves in a few Newton steps instead
of from cold (measured in BENCH_path.json's ``serve.cache_hit_rate``).

Stored warm arrays live in the PADDED bucket geometry the scheduler solves
in (a fingerprint maps to one bucket, since buckets are shape-derived), so
a hit is handed straight to the stacked solve with no re-layout.

Two tiers (DESIGN.md §11.2): `SolutionCache` is the in-process memory tier
and dies with its process. `TieredSolutionCache` backs it with a
`PersistentCacheTier` — one ``.npz`` file per stored point under a shared
spill directory, written with the same atomic tmp+rename discipline as
`utils.disk_cache_update`, TTL- and size-bounded. Because keys are blake2b
CONTENT fingerprints, spilled entries survive restarts and are shared by
every host pointed at the same directory: a restarted (or sibling) server
warm-starts from work another process already paid for. Every disk failure
mode — corrupt/truncated file, wrong fingerprint, races with eviction —
degrades to a MISS, never to an exception on the serving path.
"""
from __future__ import annotations

import collections
import contextlib
import hashlib
import math
import os
import tempfile
import zipfile
from pathlib import Path
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.obs import clock as obs_clock
from repro.obs import events as obs_events
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer

#: Problem forms the runtime serves; the cache keeps them in disjoint keys
#: because their lambda-points live on different axes (t vs lambda1).
CONSTRAINED = "constrained"
PENALIZED = "penalized"


def fingerprint_problem(X, y) -> str:
    """Content hash of one (X, y) problem: shape + exact bytes.

    blake2b over the raw buffers — a repeat submission of the same data hits
    the same key; any changed entry (even 1 ulp) is a different problem.
    Costs one host pass over X, negligible next to a solve.
    """
    h = hashlib.blake2b(digest_size=16)
    Xh = np.asarray(X)
    yh = np.asarray(y)
    h.update(str((Xh.shape, str(Xh.dtype))).encode())
    h.update(Xh.tobytes())
    h.update(yh.tobytes())
    return h.hexdigest()


class WarmEntry(NamedTuple):
    """One cached solution at one point of the regularization surface.

    Arrays are HOST (numpy) copies in the padded bucket geometry: a hit is
    a memcpy into the next launch's warm buffers, no device round trip."""

    lam: float            # the lambda-point: t (constrained) or lambda1
    lambda2: float
    alpha: np.ndarray     # (2*bp,) dual iterate, padded bucket geometry
    w: np.ndarray         # (bn,) primal iterate, padded bucket geometry
    beta: np.ndarray      # (bp,) padded solution (penalized warm screening)
    t: float              # L1 budget of the stored solution
    nu: float             # multiplier at the stored solution (penalized)


def _log_distance(a: float, b: float) -> float:
    """|log(a/b)| on the positive lambda axis, with the zero edge exact.

    lambda = 0 is a FORM boundary, not a small lambda: lambda1 = 0 is pure
    ridge and lambda2 = 0 is the Lasso. It gets its own point on the key
    axis — distance 0 to another exact zero (lasso-only / ridge-only repeat
    traffic warm-starts itself) and +inf to any positive lambda (a
    regularized entry never masquerades as the edge form, and log(0) is
    never evaluated). The previous eps-floored `log((|a|+eps)/(|b|+eps))`
    broke both ways at the edges: genuinely tiny lambdas collapsed onto the
    floor (1e-13 vs 1e-14 scored as "adjacent"), and an entry within eps of
    zero scored finite distance to the exact edge.
    """
    a, b = abs(a), abs(b)
    if a == 0.0 and b == 0.0:
        return 0.0
    if a == 0.0 or b == 0.0:
        return math.inf
    return abs(math.log(a / b))


class SolutionCache:
    """LRU cache of solved problems, bounded per problem and overall.

    `neighborhood` is the hit radius in log-lambda space: an entry at
    (lam_e, lambda2_e) warm-starts a request at (lam_r, lambda2_r) when
    |log(lam_r/lam_e)| + |log(lambda2_r/lambda2_e)| <= neighborhood. The
    default (1.0 ~ one e-fold) is deliberately wide — a warm start is an
    initial iterate, so a far hit costs extra iterations, never correctness.

    Hit/miss accounting lives on a `MetricsRegistry`
    (``cache_lookups_total{result=hit|miss}``, DESIGN.md §12.2) — the
    scheduler passes its own so cache counters export with the rest of its
    telemetry; the historical ``hits`` / ``misses`` ints remain as
    read-through properties.
    """

    def __init__(self, *, max_problems: int = 128, per_problem: int = 8,
                 neighborhood: float = 1.0,
                 registry: Optional[MetricsRegistry] = None) -> None:
        if max_problems < 1 or per_problem < 1 or neighborhood <= 0:
            raise ValueError(
                f"SolutionCache: max_problems/per_problem must be >= 1 and "
                f"neighborhood > 0 (got {max_problems}/{per_problem}/"
                f"{neighborhood})")
        self.max_problems = max_problems
        self.per_problem = per_problem
        self.neighborhood = neighborhood
        self.registry = registry if registry is not None else MetricsRegistry()
        self._lookups = self.registry.counter(
            "cache_lookups_total",
            "warm-start cache lookups by result", ("result",))
        self._store: "collections.OrderedDict[Tuple[str, str], list]" = (
            collections.OrderedDict())

    def __len__(self) -> int:
        return sum(len(v) for v in self._store.values())

    @property
    def hits(self) -> int:
        return int(self._lookups.value(result="hit"))

    @property
    def misses(self) -> int:
        return int(self._lookups.value(result="miss"))

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_counters(self) -> None:
        self.registry.reset_instrument("cache_lookups_total")

    def _search(self, fp: str, form: str, lam: float,
                lambda2: float) -> Tuple[Optional[WarmEntry], float]:
        """(nearest stored entry, its log-distance) — no counters, no
        neighborhood cut; callers decide what a hit means."""
        entries = self._store.get((fp, form))
        if not entries:
            return None, math.inf
        self._store.move_to_end((fp, form))
        best = min(entries, key=lambda e: (_log_distance(lam, e.lam)
                                           + _log_distance(lambda2,
                                                           e.lambda2)))
        return best, (_log_distance(lam, best.lam)
                      + _log_distance(lambda2, best.lambda2))

    def lookup(self, fp: str, form: str, lam: float, lambda2: float, *,
               count: bool = True) -> Optional[WarmEntry]:
        """Nearest stored solution within the neighborhood, else None.

        `count=False` leaves the hit/miss counters untouched — the
        scheduler's SPECULATIVE warm-start lookups use it so the reported
        hit rate keeps measuring client traffic only."""
        best, dist = self._search(fp, form, lam, lambda2)
        if best is not None and dist <= self.neighborhood:
            if count:
                self._lookups.inc(result="hit")
            return best
        if count:
            self._lookups.inc(result="miss")
        return None

    def probe(self, fp: str, form: str, lam: float, lambda2: float, *,
              radius: float = 1e-9) -> bool:
        """True when a stored point sits within `radius` of the query —
        i.e. this exact point is already solved. Counter-free; the
        scheduler's speculation uses it to skip predicting known points."""
        _, dist = self._search(fp, form, lam, lambda2)
        return dist <= radius

    def insert(self, fp: str, form: str, entry: WarmEntry) -> None:
        """Store a solved point; evicts the nearest-lambda duplicate first,
        then the oldest, keeping at most `per_problem` spread-out points."""
        key = (fp, form)
        entries = self._store.get(key)
        if entries is None:
            if len(self._store) >= self.max_problems:
                self._store.popitem(last=False)   # LRU problem eviction
            entries = []
            self._store[key] = entries
        else:
            self._store.move_to_end(key)
            same = [e for e in entries
                    if _log_distance(entry.lam, e.lam)
                    + _log_distance(entry.lambda2, e.lambda2) < 1e-9]
            for e in same:
                entries.remove(e)
        entries.append(entry)
        if len(entries) > self.per_problem:
            entries.pop(0)


# ---------------------------------------------------------------------------
# Persistent spill tier (DESIGN.md §11.2)
# ---------------------------------------------------------------------------

#: Errors a spilled entry can fail to load with. Anything here means "this
#: file is not a usable cache entry" — the tier deletes it and reports a
#: miss; it NEVER propagates into the solve path.
_LOAD_ERRORS = (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile)


def _point_digest(lam: float, lambda2: float) -> str:
    """Filename-safe digest of one exact regularization point."""
    h = hashlib.blake2b(digest_size=8)
    h.update(float(lam).hex().encode())
    h.update(float(lambda2).hex().encode())
    return h.hexdigest()


class PersistentCacheTier:
    """Disk spill tier: one atomic ``.npz`` per (fingerprint, form, point).

    Layout: ``<root>/<fp>.<form>.<point-digest>.npz`` — the fingerprint is
    the blake2b content hash of (X, y), so the same problem submitted to a
    different process (or after a restart) resolves to the same files. Two
    inserts at the same exact point overwrite each other (tmp + rename:
    concurrent writers race benignly, readers see old or new, never torn).

    Bounds: `ttl_s` ages entries out (checked at lookup and by `expire()`);
    `max_bytes` LRU-evicts by mtime, which `lookup` refreshes on a hit, so
    hot entries survive. `root=None` resolves under `utils.cache_dir()`
    (the ``REPRO_CACHE_DIR`` override applies); an unwritable root disables
    the tier — every operation degrades to miss/no-op, never raises.
    """

    def __init__(self, root=None, *, max_bytes: int = 64 << 20,
                 ttl_s: Optional[float] = None,
                 clock=obs_clock.walltime) -> None:
        if max_bytes < 1 or (ttl_s is not None and ttl_s <= 0):
            raise ValueError(f"PersistentCacheTier: need max_bytes >= 1 and "
                             f"ttl_s > 0 or None (got {max_bytes}/{ttl_s})")
        if root is None:
            from repro.utils import cache_dir
            base = cache_dir()
            root = None if base is None else base / "warm"
        self.root: Optional[Path] = None
        if root is not None:
            try:
                p = Path(root)
                p.mkdir(parents=True, exist_ok=True)
                self.root = p
            except OSError:
                self.root = None
        self.max_bytes = max_bytes
        self.ttl_s = ttl_s
        self.clock = clock
        self.corrupt_dropped = 0
        self.expired_dropped = 0
        self.evicted = 0

    # -- file plumbing -----------------------------------------------------

    def _path(self, fp: str, form: str, lam: float, lambda2: float) -> Path:
        return self.root / f"{fp}.{form}.{_point_digest(lam, lambda2)}.npz"

    def _drop(self, path: Path) -> None:
        with contextlib.suppress(OSError):
            path.unlink()

    def _load(self, path: Path, fp: str):
        """(WarmEntry, created-timestamp) or (None, None); a file that
        cannot be loaded, fails its fingerprint check, or has inconsistent
        geometry is deleted on the spot — corruption degrades to miss."""
        try:
            with np.load(path, allow_pickle=False) as z:
                if str(z["fingerprint"]) != fp:
                    raise ValueError("fingerprint mismatch")
                entry = WarmEntry(
                    lam=float(z["lam"]), lambda2=float(z["lambda2"]),
                    alpha=np.asarray(z["alpha"], np.float64),
                    w=np.asarray(z["w"], np.float64),
                    beta=np.asarray(z["beta"], np.float64),
                    t=float(z["t"]), nu=float(z["nu"]))
                created = float(z["created"])
            if (entry.alpha.ndim != 1 or entry.w.ndim != 1
                    or entry.beta.ndim != 1
                    or entry.alpha.shape[0] != 2 * entry.beta.shape[0]):
                raise ValueError("inconsistent warm-array geometry")
            return entry, created
        except _LOAD_ERRORS as e:
            self.corrupt_dropped += 1
            obs_events.emit("cache_corrupt", path=path.name,
                            error=type(e).__name__)
            self._drop(path)
            return None, None

    # -- tier interface ----------------------------------------------------

    def __len__(self) -> int:
        if self.root is None:
            return 0
        return sum(1 for _ in self.root.glob("*.npz"))

    def total_bytes(self) -> int:
        if self.root is None:
            return 0
        total = 0
        for path in self.root.glob("*.npz"):
            with contextlib.suppress(OSError):
                total += path.stat().st_size
        return total

    def lookup(self, fp: str, form: str, lam: float, lambda2: float, *,
               neighborhood: float = 1.0) -> Optional[WarmEntry]:
        """Nearest spilled point within `neighborhood`, else None. A hit
        refreshes the file's mtime (the LRU clock)."""
        if self.root is None or fp is None:
            return None
        best, best_d, best_path = None, math.inf, None
        for path in self.root.glob(f"{fp}.{form}.*.npz"):
            entry, created = self._load(path, fp)
            if entry is None:
                continue
            if self.ttl_s is not None and self.clock() - created > self.ttl_s:
                self.expired_dropped += 1
                self._drop(path)
                continue
            d = (_log_distance(lam, entry.lam)
                 + _log_distance(lambda2, entry.lambda2))
            if d < best_d:
                best, best_d, best_path = entry, d, path
        if best is not None and best_d <= neighborhood:
            with contextlib.suppress(OSError):
                os.utime(best_path)
            return best
        return None

    def insert(self, fp: str, form: str, entry: WarmEntry) -> bool:
        """Spill one solved point atomically; False when the tier is
        disabled or the write fails (both are silent no-ops upstream)."""
        if self.root is None or fp is None:
            return False
        try:
            fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".spill-")
            try:
                with os.fdopen(fd, "wb") as f:
                    np.savez(f, fingerprint=fp, form=form,
                             created=float(self.clock()),
                             lam=float(entry.lam),
                             lambda2=float(entry.lambda2),
                             alpha=np.asarray(entry.alpha, np.float64),
                             w=np.asarray(entry.w, np.float64),
                             beta=np.asarray(entry.beta, np.float64),
                             t=float(entry.t), nu=float(entry.nu))
                os.replace(tmp, self._path(fp, form, entry.lam, entry.lambda2))
            finally:
                with contextlib.suppress(OSError):
                    os.unlink(tmp)
        except OSError:
            return False
        self._enforce_bound()
        return True

    def expire(self) -> int:
        """Drop every TTL-expired entry now; returns the number removed."""
        if self.root is None or self.ttl_s is None:
            return 0
        dropped = 0
        for path in list(self.root.glob("*.npz")):
            try:
                with np.load(path, allow_pickle=False) as z:
                    created = float(z["created"])
            except _LOAD_ERRORS as e:
                self.corrupt_dropped += 1
                obs_events.emit("cache_corrupt", path=path.name,
                                error=type(e).__name__)
                self._drop(path)
                continue
            if self.clock() - created > self.ttl_s:
                self.expired_dropped += 1
                self._drop(path)
                dropped += 1
        return dropped

    def _enforce_bound(self) -> None:
        """LRU-evict (oldest mtime first) until under `max_bytes`."""
        files = []
        for path in self.root.glob("*.npz"):
            with contextlib.suppress(OSError):
                st = path.stat()
                files.append((st.st_mtime, st.st_size, path))
        total = sum(size for _, size, _ in files)
        files.sort()
        while total > self.max_bytes and files:
            _, size, path = files.pop(0)
            self._drop(path)
            self.evicted += 1
            total -= size


class TieredSolutionCache(SolutionCache):
    """Memory tier backed by a persistent spill tier (write-through).

    Lookups search memory first; on a memory miss the spill tier is
    consulted and a spill hit is PROMOTED into memory (so the disk pays
    once per process per point). Inserts write through to both tiers.
    The hit/miss counters on THIS object are the authoritative serving
    metrics (spill hits count as hits, broken out in `spill_hits`); the
    inherited memory-tier machinery never double-counts because this class
    owns every counted path.
    """

    def __init__(self, *, max_problems: int = 128, per_problem: int = 8,
                 neighborhood: float = 1.0,
                 spill: Optional[PersistentCacheTier] = None,
                 spill_dir=None, max_bytes: int = 64 << 20,
                 ttl_s: Optional[float] = None, clock=obs_clock.walltime,
                 registry: Optional[MetricsRegistry] = None) -> None:
        super().__init__(max_problems=max_problems, per_problem=per_problem,
                         neighborhood=neighborhood, registry=registry)
        if spill is None:
            spill = PersistentCacheTier(spill_dir, max_bytes=max_bytes,
                                        ttl_s=ttl_s, clock=clock)
        self.spill = spill
        self._spill_hits = self.registry.counter(
            "cache_spill_hits_total",
            "memory-tier misses served by the persistent spill tier")

    @property
    def spill_hits(self) -> int:
        return int(self._spill_hits.value())

    def lookup(self, fp: str, form: str, lam: float, lambda2: float, *,
               count: bool = True) -> Optional[WarmEntry]:
        best, dist = self._search(fp, form, lam, lambda2)
        if best is not None and dist <= self.neighborhood:
            if count:
                self._lookups.inc(result="hit")
            return best
        spilled = self.spill.lookup(fp, form, lam, lambda2,
                                    neighborhood=self.neighborhood)
        if spilled is not None:
            super().insert(fp, form, spilled)      # promote, memory only
            get_tracer().instant("cache.spill_promote", form=form)
            if count:
                self._lookups.inc(result="hit")
                self._spill_hits.inc()
            return spilled
        if count:
            self._lookups.inc(result="miss")
        return None

    def insert(self, fp: str, form: str, entry: WarmEntry) -> None:
        super().insert(fp, form, entry)
        self.spill.insert(fp, form, entry)

    def reset_counters(self) -> None:
        super().reset_counters()
        self.registry.reset_instrument("cache_spill_hits_total")
