"""Warm-start solution cache for the serving runtime (DESIGN.md §8).

The paper's own warm-start observation — solutions at adjacent points of the
regularization surface are near-identical, which is why `sven_path` carries
(alpha, w) down the t-grid — is exactly the structure serving traffic has:
the same dataset re-solved at a new lambda (hyperparameter sweeps, CV-like
exploration, online refresh). The cache keys solved problems by

    (data fingerprint, problem form)  ->  [ (lambda-point, solution), ... ]

and answers a lookup with the stored solution whose regularization point is
NEAREST in log-space, provided it falls inside the `neighborhood` radius.
The hit is fed back into `sven_batch` / `enet_batch` as a warm start — never
returned directly — so a hit changes iteration count, not the answer:
repeat and adjacent-lambda traffic re-solves in a few Newton steps instead
of from cold (measured in BENCH_path.json's ``serve.cache_hit_rate``).

Stored warm arrays live in the PADDED bucket geometry the scheduler solves
in (a fingerprint maps to one bucket, since buckets are shape-derived), so
a hit is handed straight to the stacked solve with no re-layout.
"""
from __future__ import annotations

import collections
import hashlib
import math
from typing import NamedTuple, Optional, Tuple

import numpy as np

#: Problem forms the runtime serves; the cache keeps them in disjoint keys
#: because their lambda-points live on different axes (t vs lambda1).
CONSTRAINED = "constrained"
PENALIZED = "penalized"


def fingerprint_problem(X, y) -> str:
    """Content hash of one (X, y) problem: shape + exact bytes.

    blake2b over the raw buffers — a repeat submission of the same data hits
    the same key; any changed entry (even 1 ulp) is a different problem.
    Costs one host pass over X, negligible next to a solve.
    """
    h = hashlib.blake2b(digest_size=16)
    Xh = np.asarray(X)
    yh = np.asarray(y)
    h.update(str((Xh.shape, str(Xh.dtype))).encode())
    h.update(Xh.tobytes())
    h.update(yh.tobytes())
    return h.hexdigest()


class WarmEntry(NamedTuple):
    """One cached solution at one point of the regularization surface.

    Arrays are HOST (numpy) copies in the padded bucket geometry: a hit is
    a memcpy into the next launch's warm buffers, no device round trip."""

    lam: float            # the lambda-point: t (constrained) or lambda1
    lambda2: float
    alpha: np.ndarray     # (2*bp,) dual iterate, padded bucket geometry
    w: np.ndarray         # (bn,) primal iterate, padded bucket geometry
    beta: np.ndarray      # (bp,) padded solution (penalized warm screening)
    t: float              # L1 budget of the stored solution
    nu: float             # multiplier at the stored solution (penalized)


def _log_distance(a: float, b: float) -> float:
    """|log(a/b)| on the positive lambda axis, with the zero edge exact.

    lambda = 0 is a FORM boundary, not a small lambda: lambda1 = 0 is pure
    ridge and lambda2 = 0 is the Lasso. It gets its own point on the key
    axis — distance 0 to another exact zero (lasso-only / ridge-only repeat
    traffic warm-starts itself) and +inf to any positive lambda (a
    regularized entry never masquerades as the edge form, and log(0) is
    never evaluated). The previous eps-floored `log((|a|+eps)/(|b|+eps))`
    broke both ways at the edges: genuinely tiny lambdas collapsed onto the
    floor (1e-13 vs 1e-14 scored as "adjacent"), and an entry within eps of
    zero scored finite distance to the exact edge.
    """
    a, b = abs(a), abs(b)
    if a == 0.0 and b == 0.0:
        return 0.0
    if a == 0.0 or b == 0.0:
        return math.inf
    return abs(math.log(a / b))


class SolutionCache:
    """LRU cache of solved problems, bounded per problem and overall.

    `neighborhood` is the hit radius in log-lambda space: an entry at
    (lam_e, lambda2_e) warm-starts a request at (lam_r, lambda2_r) when
    |log(lam_r/lam_e)| + |log(lambda2_r/lambda2_e)| <= neighborhood. The
    default (1.0 ~ one e-fold) is deliberately wide — a warm start is an
    initial iterate, so a far hit costs extra iterations, never correctness.
    """

    def __init__(self, *, max_problems: int = 128, per_problem: int = 8,
                 neighborhood: float = 1.0) -> None:
        if max_problems < 1 or per_problem < 1 or neighborhood <= 0:
            raise ValueError(
                f"SolutionCache: max_problems/per_problem must be >= 1 and "
                f"neighborhood > 0 (got {max_problems}/{per_problem}/"
                f"{neighborhood})")
        self.max_problems = max_problems
        self.per_problem = per_problem
        self.neighborhood = neighborhood
        self.hits = 0
        self.misses = 0
        self._store: "collections.OrderedDict[Tuple[str, str], list]" = (
            collections.OrderedDict())

    def __len__(self) -> int:
        return sum(len(v) for v in self._store.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_counters(self) -> None:
        self.hits = 0
        self.misses = 0

    def lookup(self, fp: str, form: str, lam: float,
               lambda2: float) -> Optional[WarmEntry]:
        """Nearest stored solution within the neighborhood, else None."""
        entries = self._store.get((fp, form))
        if entries:
            self._store.move_to_end((fp, form))
            best = min(entries, key=lambda e: (_log_distance(lam, e.lam)
                                               + _log_distance(lambda2,
                                                               e.lambda2)))
            dist = (_log_distance(lam, best.lam)
                    + _log_distance(lambda2, best.lambda2))
            if dist <= self.neighborhood:
                self.hits += 1
                return best
        self.misses += 1
        return None

    def insert(self, fp: str, form: str, entry: WarmEntry) -> None:
        """Store a solved point; evicts the nearest-lambda duplicate first,
        then the oldest, keeping at most `per_problem` spread-out points."""
        key = (fp, form)
        entries = self._store.get(key)
        if entries is None:
            if len(self._store) >= self.max_problems:
                self._store.popitem(last=False)   # LRU problem eviction
            entries = []
            self._store[key] = entries
        else:
            self._store.move_to_end(key)
            same = [e for e in entries
                    if _log_distance(entry.lam, e.lam)
                    + _log_distance(entry.lambda2, e.lambda2) < 1e-9]
            for e in same:
                entries.remove(e)
        entries.append(entry)
        if len(entries) > self.per_problem:
            entries.pop(0)
