"""Streaming-row Elastic Net: rank-1 statistic updates + warm re-solves
(DESIGN.md §8).

The SVEN dual is built entirely from three sufficient statistics of the
data — G = X^T X, c = X^T y, r = y^T y (`core.reduction.gram_from_stats`)
— and the dual's size is 2p regardless of n. That makes row arrival the
cheap direction: absorbing a new sample (x, y_new) is the rank-1 update

    G += x x^T,    c += y_new x,    r += y_new^2,    n += 1

(O(p^2), no pass over history), and re-solving after an update is a dual
Newton solve on the refreshed (2p, 2p) kernel, warm-started from the
previous dual alpha — a few iterations, cost INDEPENDENT of how many rows
have streamed by. The alternative the runtime replaces is a from-scratch
`sven()` on the concatenated data: O(np) per matvec and recompiled per
(n, p) shape as n grows; here the executable is fixed at (p,) for the
stream's lifetime, so online traffic never retraces.

Diagnostics never touch the raw rows either: the Elastic Net smooth
gradient is 2 (G beta - c) + 2 lambda2 beta, so the same KKT residual
`sven()` reports is available from the statistics
(`core.elastic_net.kkt_violation_from_grad`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import elastic_net as en
from repro.core import reduction as red
from repro.core.svm import solve_dual_fista, solve_dual_newton


class OnlineStats(NamedTuple):
    """Sufficient statistics of everything streamed so far."""

    G: jax.Array      # (p, p)  X^T X
    c: jax.Array      # (p,)    X^T y
    r: jax.Array      # ()      y^T y
    n: jax.Array      # ()      rows absorbed


class OnlineSolution(NamedTuple):
    beta: jax.Array           # (p,)
    alpha: jax.Array          # (2p,) dual iterate — next solve's warm start
    iters: jax.Array          # dual Newton iterations this re-solve cost
    kkt: jax.Array            # EN KKT violation from the statistics
    n: int                    # rows absorbed at solve time


def init_stats(p: int, dtype=jnp.float64) -> OnlineStats:
    return OnlineStats(G=jnp.zeros((p, p), dtype), c=jnp.zeros((p,), dtype),
                       r=jnp.zeros((), dtype), n=jnp.zeros((), jnp.int32))


@jax.jit
def _absorb(stats: OnlineStats, Xr: jax.Array, yr: jax.Array) -> OnlineStats:
    """Rank-k statistic update for a block of k arriving rows (k=1: rank-1).

    Shapes are (k, p)/(k,) with k static per call site, so a stream of
    single rows is one compiled executable run n times.
    """
    return OnlineStats(G=stats.G + Xr.T @ Xr, c=stats.c + Xr.T @ yr,
                       r=stats.r + yr @ yr,
                       n=stats.n + jnp.asarray(Xr.shape[0], stats.n.dtype))


@partial(jax.jit, static_argnames=("solver", "tol", "lambda2_floor"))
def _resolve(stats: OnlineStats, t, lambda2, warm_alpha, solver: str,
             tol: float, lambda2_floor: float):
    """Dual solve on the statistics-built kernel; t/lambda2 are operands."""
    dtype = stats.G.dtype
    t = jnp.asarray(t, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)
    K = red.gram_from_stats(stats.G, stats.c / t, stats.r / (t * t))
    C = red.svm_C(lambda2, floor=lambda2_floor).astype(dtype)
    solve = solve_dual_newton if solver == "newton" else solve_dual_fista
    res = solve(lambda v: K @ v, K.shape[0], C, dtype=dtype, tol=tol,
                alpha0=warm_alpha)
    beta = red.recover_beta(res.alpha, t)
    g = 2.0 * (stats.G @ beta - stats.c) + 2.0 * lambda2 * beta
    return beta, res.alpha, res.iters, en.kkt_violation_from_grad(g, beta)


@dataclasses.dataclass
class OnlineElasticNet:
    """A p-fixed Elastic Net session over streaming rows.

    `update(X_rows, y_rows)` absorbs arriving samples into the sufficient
    statistics; `solve(t, lambda2)` re-solves the constrained problem on
    whatever has arrived, warm-started from the previous call's dual alpha.
    Equal to a from-scratch `sven()` on the concatenated rows to solver
    tolerance (tested), at O(p^2) per arrival instead of O(n p) + retrace.
    """

    p: int
    dtype: jnp.dtype = jnp.float64
    solver: str = "newton"
    tol: float = 1e-8
    lambda2_floor: float = red.LAMBDA2_FLOOR

    def __post_init__(self):
        self.stats = init_stats(self.p, self.dtype)
        self._warm_alpha = jnp.zeros((2 * self.p,), self.dtype)
        self.updates = 0
        self.solves = 0

    @property
    def n(self) -> int:
        return int(self.stats.n)

    def update(self, X_rows, y_rows) -> "OnlineElasticNet":
        """Absorb one row ((p,)/scalar) or a block ((k, p)/(k,))."""
        Xr = jnp.asarray(X_rows, self.dtype)
        yr = jnp.asarray(y_rows, self.dtype)
        if Xr.ndim == 1:
            Xr, yr = Xr[None, :], yr[None]
        if Xr.ndim != 2 or Xr.shape[1] != self.p or yr.shape != (Xr.shape[0],):
            raise ValueError(f"update: bad shapes X{Xr.shape} y{yr.shape} "
                             f"for p={self.p}")
        self.stats = _absorb(self.stats, Xr, yr)
        self.updates += 1
        return self

    def solve(self, t: float, lambda2: float = 1.0) -> OnlineSolution:
        if not (t > 0 and lambda2 >= 0):
            raise ValueError(f"solve: need t > 0, lambda2 >= 0 "
                             f"(t={t}, lambda2={lambda2})")
        if self.n == 0:
            raise ValueError("solve: no rows absorbed yet")
        beta, alpha, iters, kkt = _resolve(
            self.stats, t, lambda2, self._warm_alpha, self.solver, self.tol,
            self.lambda2_floor)
        self._warm_alpha = alpha
        self.solves += 1
        return OnlineSolution(beta=beta, alpha=alpha, iters=iters, kkt=kkt,
                              n=self.n)
