"""Continuous micro-batching scheduler for Elastic Net serving (DESIGN.md §8).

The seed engine's `drain()` was a synchronous wall: requests queued on the
host, then one blocking `block_until_ready` per bucket chunk. This scheduler
replaces it with an event loop over three request states:

    PENDING   admitted into a priority/deadline queue, grouped by the
              power-of-two (n, p, form) bucket ladder of DESIGN.md §6.4;
    IN-FLIGHT a bucket's stacked, padded, warm-started solve has been
              dispatched to the device (JAX async dispatch: the Python
              thread returns immediately and keeps admitting/coalescing
              while the device computes);
    COMPLETED `harvest()` touched the result arrays — the ONLY place
              `jax.block_until_ready` appears — unpadded them, fed the
              solutions back into the warm-start cache and recorded
              completion latency.

A bucket launches the moment it is FULL (`max_batch` requests coalesced) or
its earliest member DEADLINE expires (`max_wait` after submission, per-
request overridable) — so light traffic still meets latency targets while
heavy traffic rides full vmapped executables. Solves go through
`core.batch.sven_batch` / `core.api.enet_batch`, which means (a) steady-
state traffic re-uses one compiled executable per (bucket, batch, form)
shape — `trace_counts()` stays constant under load, asserted in CI — and
(b) multi-device placement is ROUTED per launch: the `core.routing` cost
model prices each (bucket, batch) shape on the calibrated mesh and only
fans the batch axis out when that wins over a single device (an explicit
Mesh or `route="batch"` pins the fan-out).

Warm starts come from `runtime.cache.SolutionCache`: hits are handed to the
stacked solve as initial iterates (zero rows = cold start, so mixed
hit/miss batches keep a single executable) and every harvested solution is
inserted back, closing the loop the paper's adjacent-lambda observation
suggests.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import heapq
import math
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.core.api import EnetCarry, PathConfig, enet_batch
from repro.core.batch import sven_batch
from repro.core.sven import SvenConfig
from repro.obs import clock as obs_clock
from repro.obs import events as obs_events
from repro.obs.metrics import MetricsRegistry
from repro.obs.solve import SolveLog, SolveRecord
from repro.obs.trace import get_tracer
from repro.runtime.cache import (CONSTRAINED, PENALIZED, SolutionCache,
                                 WarmEntry, fingerprint_problem)
from repro.runtime.metrics import LatencyRecorder


def ceil_pow2(v: int, floor: int) -> int:
    """Smallest power-of-two multiple of `floor` that is >= v."""
    b = floor
    while b < v:
        b *= 2
    return b


def stack_padded(reqs, bn: int, bp: int, b_pad: int, dtype):
    """Zero-pad and stack a bucket's requests into (B, bn, bp)/(B, bn) HOST
    buffers — one allocation, one fill pass, one device transfer at the jit
    boundary. Trailing batch slots stay all-zero: the X = 0, y = 0 dummy
    problems that converge in O(1) solver iterations. (Per-request
    `jnp.pad`+`jnp.stack` here costs more eager-dispatch time than the
    solves being scheduled — host staging stays in numpy by design.)"""
    Xb = np.zeros((b_pad, bn, bp), dtype)
    yb = np.zeros((b_pad, bn), dtype)
    for i, r in enumerate(reqs):
        n, p = r.X.shape
        Xb[i, :n, :p] = r.X
        yb[i, :n] = r.y
    return Xb, yb


class EnResult(NamedTuple):
    """Per-request solve result, unpadded back to the request's own p.

    `status` is "ok" for a solved request; "deadline_exceeded" marks a
    request whose deadline had already passed when a failure-recovery
    requeue re-examined it — those complete WITHOUT a solve (beta is None)
    instead of looping through the bucket ladder forever. The multi-host
    coordinator adds one more terminal status: "aborted", for requests
    still unserved when every worker host has died (runtime/multihost.py).
    Every admitted request ends in exactly one of these — never silence.
    """

    beta: jax.Array           # (p,) — None when status != "ok"
    iters: jax.Array          # solver iterations spent (padded problem)
    kkt: jax.Array            # EN KKT violation of the padded problem
    bucket: tuple             # (n_bucket, p_bucket) executable this ran on
    status: str = "ok"        # "ok" | "deadline_exceeded" | "aborted"


#: RuntimeStats attribute -> (instrument kind, metric name, fixed labels).
#: The attribute surface is a read-through shim (PR 9): the values live on
#: the owning scheduler's MetricsRegistry, these names keep every existing
#: ``stats.requests += 1`` call site and test assertion working unchanged.
_STAT_SPECS = {
    "requests": ("counter", "runtime_requests_total", {}),
    "batches": ("counter", "runtime_batches_total", {}),
    "bucket_shapes": ("gauge", "runtime_bucket_executables", {}),
    "padded_slots": ("counter", "runtime_padded_slots_total", {}),
    "solve_seconds": ("counter", "runtime_solve_seconds_total", {}),
    "launched_full": ("counter", "runtime_launches_total",
                      {"reason": "full"}),
    "launched_deadline": ("counter", "runtime_launches_total",
                          {"reason": "deadline"}),
    "launched_flush": ("counter", "runtime_launches_total",
                       {"reason": "flush"}),
    "speculative_slots": ("counter", "runtime_speculative_slots_total", {}),
}


class RuntimeStats:
    """Counters shared by the runtime scheduler and the engine facade.

    Since PR 9 this is a thin attribute view over a `MetricsRegistry`
    (DESIGN.md §12.2): reads and writes of the historical fields
    (``requests``, ``batches``, ``launched_full``, ...) resolve to labeled
    registry series, so one store feeds both the legacy attribute
    consumers and the JSON/Prometheus exposition. Counts read back as
    ints; ``solve_seconds`` stays a float. Cache hit/miss counters live on
    `SolutionCache` itself — one owner.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        object.__setattr__(self, "registry",
                           registry if registry is not None
                           else MetricsRegistry())

    def _series(self, name: str):
        kind, metric, labels = _STAT_SPECS[name]
        make = (self.registry.gauge if kind == "gauge"
                else self.registry.counter)
        return make(metric, labelnames=tuple(labels)), labels

    def __getattr__(self, name: str):
        if name not in _STAT_SPECS:
            raise AttributeError(name)
        inst, labels = self._series(name)
        v = inst.value(**labels)
        return v if name == "solve_seconds" else int(v)

    def __setattr__(self, name: str, value) -> None:
        if name not in _STAT_SPECS:
            raise AttributeError(f"RuntimeStats has no field {name!r}")
        inst, labels = self._series(name)
        inst.set(float(value), **labels)

    def __repr__(self) -> str:
        fields = ", ".join(f"{k}={getattr(self, k)}" for k in _STAT_SPECS)
        return f"RuntimeStats({fields})"


@dataclasses.dataclass
class EnRequest:
    """One admitted problem; `lam` is t (constrained) or lambda1 (penalized).

    X/y are held as HOST (numpy) arrays until their bucket launches — the
    device sees one stacked transfer per batch, not one per request."""

    req_id: int
    X: np.ndarray
    y: np.ndarray
    form: str                 # CONSTRAINED | PENALIZED
    lam: float
    lambda2: float
    priority: int
    deadline: float
    submitted: float
    fingerprint: Optional[str]


class _InFlight(NamedTuple):
    """A dispatched (not yet harvested) stacked solve."""

    key: tuple                # (bn, bp, form)
    reqs: tuple               # the b_real EnRequests, slot order
    beta: jax.Array           # (B, bp)
    iters: jax.Array          # (B,)
    kkt: jax.Array            # (B,)
    alpha: jax.Array          # (B, 2*bp)
    w: jax.Array              # (B, bn)
    t_out: jax.Array          # (B,) |beta|_1 (penalized) or request t
    nu_out: jax.Array         # (B,) measured multiplier (penalized only)
    spec: tuple = ()          # ((slot, fingerprint, lam, lambda2), ...)
    #                           speculative pre-solves riding padding slots
    t_dispatch: float = 0.0   # scheduler clock at dispatch (solve telemetry)
    modeled_s: float = 0.0    # cost-model price of this launch (0 = unpriced)
    route_path: str = "single"  # router decision this launch ran under


def _urgency(req: EnRequest) -> tuple:
    return (-req.priority, req.deadline, req.req_id)


class ContinuousScheduler:
    """Priority/deadline admission queue + bucket coalescing + async launch.

    `max_wait` is the default coalescing window: a submitted request's
    deadline is `now + max_wait`, and `poll()` launches its whole bucket
    once any member's deadline passes (or earlier, the moment the bucket
    holds `max_batch` requests). `max_wait=None` disables deadlines —
    drain-on-demand, the seed engine's semantics. Per-request `deadline` /
    `priority` override the default; higher priority solves first when a
    bucket overflows.

    `cache="default"` builds a private `SolutionCache`; pass None to serve
    every request cold. `fixed_batch=True` pads every launch to the full
    `max_batch` (instead of the power-of-two ladder), pinning the runtime
    to exactly ONE executable per (bucket, form) — what the CI steady-state
    zero-retrace assertion and the serve bench run with, since launch sizes
    under deadline scheduling depend on wall-clock timing.
    `auto_launch_full=False` disables the bucket-full trigger so NOTHING
    launches before an explicit flush/drain/result — the engine facade's
    drain-on-demand mode, which keeps `drain_reference()` a genuinely
    synchronous baseline.
    """

    def __init__(self, config: SvenConfig = SvenConfig(), *,
                 path_config: PathConfig = PathConfig(),
                 max_batch: int = 64, min_n: int = 16, min_p: int = 8,
                 max_wait: Optional[float] = 0.01,
                 cache="default", fixed_batch: bool = False,
                 auto_launch_full: bool = True, mesh="auto",
                 route: str = "auto", speculate: bool = False,
                 clock=obs_clock.monotonic, dtype=jnp.float64,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        if max_batch < 1 or min_n < 1 or min_p < 1:
            raise ValueError(f"ContinuousScheduler: max_batch/min_n/min_p "
                             f"must be >= 1 (got {max_batch}/{min_n}/{min_p})")
        if max_wait is not None and max_wait < 0:
            raise ValueError(f"ContinuousScheduler: max_wait must be >= 0 or "
                             f"None (got {max_wait})")
        self.config = config
        self.path_config = path_config
        self.max_batch = max_batch
        self.min_n = min_n
        self.min_p = min_p
        self.max_wait = max_wait
        # one registry per scheduler: stats, latency histograms and cache
        # counters share it, so a scheduler's whole telemetry exports as a
        # single snapshot / Prometheus page (DESIGN.md §12.2)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.solve_log = SolveLog()
        self.cache = (SolutionCache(registry=self.registry)
                      if cache == "default" else cache)
        # mesh="auto": OFFER the process's devices when there is more than
        # one — whether a bucket launch actually fans out is decided per
        # (shape, batch) by the core.routing cost model at dispatch. None =
        # single device, exactly the seed behavior. An explicit Mesh PINS
        # placement (routing is skipped); `route` pins the layout for auto
        # meshes ("batch" = always fan out, "single" = never).
        if route not in ("auto", "batch", "single"):
            raise ValueError(f"ContinuousScheduler: route must be "
                             f"auto|batch|single (got {route!r})")
        self._mesh_pinned = mesh != "auto" and mesh is not None
        if mesh == "auto":
            mesh = dist.data_mesh() if jax.device_count() > 1 else None
        self.mesh = mesh
        self.route = route
        self.fixed_batch = fixed_batch
        self.auto_launch_full = auto_launch_full
        # speculate=True repurposes a launch's PADDING slots as pre-solves:
        # when a client is crawling a lambda path (two distinct recent
        # points on one fingerprint), the geometric continuation of the
        # crawl is solved in a slot that would otherwise hold an all-zero
        # dummy, and the solution lands in the warm-start cache BEFORE the
        # client asks for it (DESIGN.md §11.3). Executable shapes are
        # untouched — speculation changes slot contents, never geometry —
        # so the zero-retrace steady-state contract holds with it on.
        self.speculate = speculate and cache is not None
        self.clock = clock
        self.dtype = dtype
        self.stats = RuntimeStats(self.registry)
        self.metrics = LatencyRecorder(registry=self.registry)
        # every admitted request must end in exactly ONE terminal status —
        # the accounting invariant bench_obs gates fleet-wide
        self._terminal = self.registry.counter(
            "requests_terminal_total",
            "admitted requests by terminal status", ("status",))
        self._buckets: Dict[tuple, List[EnRequest]] = {}
        self._deadlines: list = []       # heap of (deadline, req_id, key)
        self._in_flight: List[_InFlight] = []
        self._results: Dict[int, EnResult] = {}
        self._next_id = 0
        self._seen_shapes: set = set()
        # (fingerprint, form, lambda2) -> (prev_lam, last_lam): the crawl
        # trail speculation extrapolates; bounded, oldest trail dropped.
        self._lam_trail: "collections.OrderedDict" = collections.OrderedDict()
        # speculative points inserted but not yet consumed by a client
        # lookup — consumption emits a speculation_hit event, eviction from
        # this bounded set an (unconsumed) speculation_miss
        self._spec_points: "collections.OrderedDict" = collections.OrderedDict()

    # -- admission ---------------------------------------------------------

    def bucket_of(self, n: int, p: int) -> tuple:
        return (ceil_pow2(n, self.min_n), ceil_pow2(p, self.min_p))

    def submit(self, X, y, *, t: Optional[float] = None,
               lambda1: Optional[float] = None, lambda2: float = 1.0,
               priority: int = 0, deadline: Optional[float] = None) -> int:
        """Admit one problem; exactly one of `t` (constrained form) and
        `lambda1` (penalized form) must be given. Returns the request id.

        Admission already polls, so a bucket that fills launches before
        this call returns — queueing overlaps the device compute of
        previously launched buckets (results are only touched in harvest).
        """
        X = np.asarray(X, self.dtype)
        y = np.asarray(y, self.dtype)
        if X.ndim != 2 or y.shape != (X.shape[0],):
            raise ValueError(f"submit: bad shapes X{X.shape} y{y.shape}")
        if (t is None) == (lambda1 is None):
            raise ValueError("submit: give exactly one of t= and lambda1=")
        if t is not None and not (t > 0 and lambda2 >= 0):
            raise ValueError(f"submit: need t > 0, lambda2 >= 0 "
                             f"(t={t}, lambda2={lambda2})")
        # lambda1 = 0 (pure ridge) and lambda2 = 0 (Lasso) are both served:
        # the cache keys these edges exactly (runtime/cache.py).
        if lambda1 is not None and not (lambda1 >= 0 and lambda2 >= 0):
            raise ValueError(f"submit: need lambda1 >= 0, lambda2 >= 0 "
                             f"(lambda1={lambda1}, lambda2={lambda2})")
        now = self.clock()
        if deadline is None:
            deadline = math.inf if self.max_wait is None else now + self.max_wait
        form = CONSTRAINED if t is not None else PENALIZED
        req = EnRequest(
            req_id=self._next_id, X=X, y=y, form=form,
            lam=float(t if t is not None else lambda1), lambda2=float(lambda2),
            priority=priority, deadline=deadline, submitted=now,
            fingerprint=(fingerprint_problem(X, y) if self.cache is not None
                         else None))
        self._next_id += 1
        key = self.bucket_of(*X.shape) + (form,)
        with self.tracer.span("admit", bucket=key[:2], form=form):
            self._buckets.setdefault(key, []).append(req)
            heapq.heappush(self._deadlines, (deadline, req.req_id, key))
            self.stats.requests += 1
            self.metrics.submitted(req.req_id, now)
            if self.speculate and req.fingerprint is not None:
                self._note_crawl(req)
        self.poll(now)
        return req.req_id

    def _note_crawl(self, req: EnRequest) -> None:
        """Record this request's lambda point on its fingerprint's trail."""
        tkey = (req.fingerprint, req.form, req.lambda2)
        prev = self._lam_trail.pop(tkey, (None, None))
        if prev[1] != req.lam:
            prev = (prev[1], req.lam)
        self._lam_trail[tkey] = prev
        while len(self._lam_trail) > 512:
            self._lam_trail.popitem(last=False)

    @property
    def pending_requests(self) -> List[EnRequest]:
        """Admitted, not-yet-launched requests in submission order."""
        reqs = [r for b in self._buckets.values() for r in b]
        return sorted(reqs, key=lambda r: r.req_id)

    @property
    def in_flight_count(self) -> int:
        return sum(len(inf.reqs) for inf in self._in_flight)

    def take_pending(self) -> List[EnRequest]:
        """Remove and return every pending request (the engine's reference
        drain path pulls the queue through here)."""
        reqs = self.pending_requests
        self._buckets.clear()
        self._deadlines.clear()
        return reqs

    def requeue(self, reqs: List[EnRequest]) -> None:
        """Put requests back into the admission queue (failure recovery).

        Re-admission re-checks each deadline against the NOW LATER clock: a
        request whose deadline has already passed completes immediately
        with status="deadline_exceeded" (a terminal result, beta=None)
        instead of re-entering the bucket ladder — where its expired
        deadline would fire it straight back into the launch that just
        failed, an infinite requeue loop under any persistent fault.
        `deadline=inf` (max_wait=None, the drain-on-demand engines) never
        expires, so those requeues keep the seed's retry-forever semantics.
        """
        now = self.clock()
        for r in reqs:
            if r.deadline <= now:
                self._results[r.req_id] = EnResult(
                    beta=None, iters=np.int64(0), kkt=math.inf,
                    bucket=self.bucket_of(*r.X.shape),
                    status="deadline_exceeded")
                self.metrics.completed([r.req_id], now)
                self._terminal.inc(status="deadline_exceeded")
                obs_events.emit("deadline_exceeded", req_id=r.req_id,
                                deadline=r.deadline, now=now)
                continue
            key = self.bucket_of(*r.X.shape) + (r.form,)
            self._buckets.setdefault(key, []).append(r)
            heapq.heappush(self._deadlines, (r.deadline, r.req_id, key))
            obs_events.emit("requeue", req_id=r.req_id, bucket=key[:2])

    # -- event loop --------------------------------------------------------

    def poll(self, now: Optional[float] = None) -> int:
        """Launch every full bucket and every bucket past its deadline;
        opportunistically harvest in-flight batches whose arrays are ready
        (without blocking). Returns the number of batches launched."""
        if now is None:
            now = self.clock()
        launched = 0
        if self.auto_launch_full:
            for key in list(self._buckets):
                while len(self._buckets.get(key, ())) >= self.max_batch:
                    launched += self._launch_bucket(key, self.max_batch, "full")
        while self._deadlines and self._deadlines[0][0] <= now:
            deadline, rid, key = heapq.heappop(self._deadlines)
            # lazy invalidation: an entry whose request already launched
            # (bucket-full path, flush, result) must not fire the bucket
            # early for LATER arrivals still inside their max_wait window
            bucket = self._buckets.get(key)
            if bucket and any(r.req_id == rid for r in bucket):
                launched += self._launch_bucket(key, None, "deadline")
                rest = self._buckets.get(key)
                if rest and any(r.req_id == rid for r in rest):
                    # priority sorting bumped this expired request out of
                    # the launched chunk: re-arm its (already due) entry so
                    # the loop immediately launches the remainder too
                    heapq.heappush(self._deadlines, (deadline, rid, key))
        ready = [inf for inf in self._in_flight if _batch_ready(inf)]
        for inf in ready:
            self._in_flight.remove(inf)
            try:
                self._complete(inf)
            except Exception:
                self._in_flight.append(inf)   # keep retryable, never drop
                raise
        return launched

    def flush(self) -> int:
        """Launch everything pending regardless of fill level or deadline."""
        launched = 0
        for key in list(self._buckets):
            while self._buckets.get(key):
                launched += self._launch_bucket(key, self.max_batch, "flush")
        return launched

    def harvest(self, *, block: bool = True) -> Dict[int, EnResult]:
        """Complete in-flight batches (the one place results are awaited)
        and return every unclaimed result, including earlier leftovers."""
        pending = list(self._in_flight)
        self._in_flight = []
        try:
            while pending:
                inf = pending[0]
                if not block and not _batch_ready(inf):
                    self._in_flight.append(pending.pop(0))
                    continue
                self._complete(inf)     # idempotent: safe to retry on error
                pending.pop(0)
        except Exception:
            # the failed batch AND the untouched ones stay live — a later
            # harvest retries them; no request is ever dropped
            self._in_flight.extend(pending)
            raise
        out, self._results = self._results, {}
        return out

    def drain(self) -> Dict[int, EnResult]:
        """Flush + harvest: solve everything admitted, return all results."""
        self.flush()
        return self.harvest(block=True)

    def result(self, req_id: int) -> EnResult:
        """Block until one request's result is available and return it;
        other completed results stay claimable by later harvests."""
        if req_id in self._results:
            return self._results.pop(req_id)
        for key, bucket in list(self._buckets.items()):
            if any(r.req_id == req_id for r in bucket):
                while self._buckets.get(key):
                    self._launch_bucket(key, self.max_batch, "flush")
                break
        for inf in list(self._in_flight):
            if any(r.req_id == req_id for r in inf.reqs):
                self._in_flight.remove(inf)
                try:
                    self._complete(inf)
                except Exception:
                    self._in_flight.append(inf)
                    raise
                break
        if req_id not in self._results:
            raise KeyError(f"result: unknown request id {req_id}")
        return self._results.pop(req_id)

    # -- launch ------------------------------------------------------------

    def _launch_bucket(self, key: tuple, take: Optional[int],
                       reason: str) -> int:
        bucket = self._buckets[key]
        bucket.sort(key=_urgency)
        chunk = bucket[:take] if take is not None else bucket[:self.max_batch]
        rest = bucket[len(chunk):]
        if rest:
            self._buckets[key] = rest
        else:
            del self._buckets[key]
        try:
            with self.tracer.span("launch", reason=reason, bucket=key[:2],
                                  form=key[2], b_real=len(chunk)):
                inf = self._dispatch(key, chunk)
        except Exception:
            # a failed dispatch must not lose the queue: requeue the chunk
            # (which completes already-expired requests as
            # deadline_exceeded rather than spinning them through the
            # ladder again — see requeue())
            self.requeue(chunk)
            raise
        self._in_flight.append(inf)
        now = self.clock()
        self.metrics.launched([r.req_id for r in chunk], now)
        self.stats.batches += 1
        setattr(self.stats, f"launched_{reason}",
                getattr(self.stats, f"launched_{reason}") + 1)
        return 1

    def _warm_arrays(self, reqs: List[EnRequest], bn: int, bp: int,
                     b_pad: int, form: str):
        """Stack cache hits into warm-start operands (zeros where cold).

        Host (numpy) buffers filled in place; cached entries are stored as
        numpy at harvest, so a hit is a memcpy, not a device round trip."""
        alpha = np.zeros((b_pad, 2 * bp), self.dtype)
        w = np.zeros((b_pad, bn), self.dtype)
        beta = np.zeros((b_pad, bp), self.dtype)
        t_prev = np.zeros((b_pad,), self.dtype)
        nu_prev = np.zeros((b_pad,), self.dtype)
        hot = np.zeros((b_pad,), bool)
        if self.cache is not None:
            with self.tracer.span("warm_start", b=len(reqs)) as sp:
                for i, r in enumerate(reqs):
                    entry = self.cache.lookup(r.fingerprint, form, r.lam,
                                              r.lambda2)
                    if entry is not None:
                        alpha[i], w[i], beta[i] = (entry.alpha, entry.w,
                                                   entry.beta)
                        t_prev[i], nu_prev[i] = entry.t, entry.nu
                        hot[i] = True
                        skey = (r.fingerprint, form, entry.lam, entry.lambda2)
                        if self._spec_points.pop(skey, None) is not None:
                            # a pre-solved padding-slot point served a real
                            # client request — speculation paid off
                            obs_events.emit("speculation_hit",
                                            lam=entry.lam,
                                            lambda2=entry.lambda2)
                if sp.args is not None:
                    sp.args["hits"] = int(hot[:len(reqs)].sum())
        return alpha, w, beta, t_prev, nu_prev, hot

    def _predict_candidates(self, reqs, form: str) -> list:
        """Predicted next crawl points for this chunk's fingerprints.

        A fingerprint whose trail shows two distinct positive lambda points
        is a crawl; its GEOMETRIC continuation `last * (last / prev)` — the
        step structure of every glmnet-style grid — is the prediction.
        Points already in the cache and duplicates within the launch are
        skipped (counter-free probe: speculation must not skew the client
        hit rate). Returns [(request, predicted_lam), ...]."""
        cands: list = []
        seen: set = set()
        for r in reqs:
            trail = (self._lam_trail.get((r.fingerprint, form, r.lambda2))
                     if r.fingerprint is not None else None)
            if trail is None or trail[0] is None:
                continue
            prev, last = trail
            if not (prev > 0.0 and last > 0.0) or prev == last:
                continue
            pred = last * (last / prev)
            if not (math.isfinite(pred) and pred > 0.0):
                continue
            skey = (r.fingerprint, r.lambda2, pred)
            if skey in seen or self.cache.probe(r.fingerprint, form, pred,
                                                r.lambda2):
                continue
            seen.add(skey)
            cands.append((r, pred))
        return cands

    def _fill_spec_slots(self, cands, key, b_real, Xb, yb, lamb, l2b,
                         wa, ww, wb, wt, wnu, hot) -> tuple:
        """Write the predicted problems into the padding slots (warm-started
        from the crawl tip when the cache has it). Returns the spec tuple
        `_complete` inserts the pre-solved solutions from."""
        bn, bp, form = key
        spec: list = []
        for slot, (r, pred) in enumerate(cands, start=b_real):
            n, p = r.X.shape
            Xb[slot, :n, :p] = r.X
            yb[slot, :n] = r.y
            lamb[slot] = pred
            l2b[slot] = r.lambda2
            entry = self.cache.lookup(r.fingerprint, form, pred, r.lambda2,
                                      count=False)
            if entry is not None:
                wa[slot], ww[slot], wb[slot] = entry.alpha, entry.w, entry.beta
                wt[slot], wnu[slot] = entry.t, entry.nu
                hot[slot] = True
            spec.append((slot, r.fingerprint, float(pred), r.lambda2))
            # remember the prediction: a later warm-start hit on exactly
            # this point is a speculation_hit; falling off the bounded set
            # unconsumed is a speculation_miss (the crawl went elsewhere)
            self._spec_points[(r.fingerprint, form, float(pred),
                               r.lambda2)] = True
            while len(self._spec_points) > 1024:
                old, _ = self._spec_points.popitem(last=False)
                obs_events.emit("speculation_miss", lam=old[2], lambda2=old[3])
        self.stats.speculative_slots += len(spec)
        return tuple(spec)

    def _dispatch(self, key: tuple, reqs: List[EnRequest]) -> _InFlight:
        """Pad, stack, warm-start and launch one bucket — NO blocking: the
        returned arrays are futures under JAX async dispatch.

        Mesh placement is ROUTED, not assumed: with an auto mesh the
        `core.routing` cost model prices this (bn, bp, b_pad) launch and
        the fan-out only happens when it wins — small buckets stay on one
        device (the PR 6 regression fix). A pinned mesh (explicit Mesh at
        construction) or `route="batch"` always enters the mesh context;
        inside it, `sven_batch`/`enet_batch` get the decision pinned so
        they do not re-route (their structural vetoes — e.g. a batch the
        mesh does not divide — still apply and fall back to one device)."""
        bn, bp, form = key
        b_real = len(reqs)
        t_disp = self.clock()
        cands = (self._predict_candidates(reqs, form)
                 if self.speculate else [])
        if self.fixed_batch:
            b_pad = self.max_batch
        else:
            # speculation may GROW the pad one rung up the pow2 ladder to
            # make room for predicted points — a lone crawling client would
            # otherwise never have an idle slot to pre-solve in. The ladder
            # and max_batch still bound the executable set.
            want = b_real + min(len(cands), self.max_batch - b_real)
            b_pad = min(ceil_pow2(max(want, b_real), 1), self.max_batch)
        cands = cands[:b_pad - b_real]
        Xb, yb = stack_padded(reqs, bn, bp, b_pad, self.dtype)
        fill = [1.0] * (b_pad - b_real)
        lamb = np.asarray([r.lam for r in reqs] + fill, self.dtype)
        l2b = np.asarray([r.lambda2 for r in reqs] + fill, self.dtype)
        wa, ww, wb, wt, wnu, hot = self._warm_arrays(reqs, bn, bp, b_pad, form)
        spec = ()
        if cands:
            spec = self._fill_spec_slots(cands, key, b_real, Xb, yb, lamb,
                                         l2b, wa, ww, wb, wt, wnu, hot)

        route_form = "penalized" if form == PENALIZED else "constrained"
        mesh = self.mesh
        modeled_s = 0.0
        route_path = "single"
        if (mesh is not None and not self._mesh_pinned
                and self.route != "batch"):
            from repro.core import routing
            decision = routing.route_batch(bn, bp, b_pad, mesh,
                                           form=route_form, route=self.route)
            self.tracer.instant("route", path=decision.path,
                                costs=dict(decision.costs),
                                reason=decision.reason)
            route_path = decision.path
            modeled_s = float(decision.costs.get(decision.path, 0.0))
            if decision.path != "batch":
                mesh = None
        elif mesh is None:
            # single device by construction: nothing to route, but the
            # solve telemetry still wants the model's price for this launch
            from repro.core import routing
            modeled_s = float(routing.estimate_batch_seconds(
                bn, bp, b_pad, form=route_form))
        else:
            route_path = "batch"    # pinned mesh / route="batch": unpriced
        ctx = (dist.mesh_context(mesh) if mesh is not None
               else contextlib.nullcontext())
        route = "batch" if mesh is not None else "auto"
        with ctx:
            if form == PENALIZED:
                warm = EnetCarry(beta=wb, alpha=wa, w=ww, t=wt, nu=wnu)
                pts, carry = enet_batch(Xb, yb, lamb, l2b, self.path_config,
                                        warm=warm, has_warm=hot,
                                        return_carry=True, route=route)
                inf = _InFlight(key=key, reqs=tuple(reqs), beta=pts.beta,
                                iters=pts.sven_iters, kkt=pts.kkt,
                                alpha=carry.alpha, w=carry.w, t_out=pts.t,
                                nu_out=pts.nu, spec=spec, t_dispatch=t_disp,
                                modeled_s=modeled_s, route_path=route_path)
            else:
                sol = sven_batch(Xb, yb, lamb, l2b, self.config,
                                 warm_alpha=wa, warm_w=ww, route=route)
                inf = _InFlight(key=key, reqs=tuple(reqs), beta=sol.beta,
                                iters=sol.iters, kkt=sol.kkt, alpha=sol.alpha,
                                w=sol.w, t_out=lamb, nu_out=jnp.zeros_like(lamb),
                                spec=spec, t_dispatch=t_disp,
                                modeled_s=modeled_s, route_path=route_path)
        self.stats.padded_slots += b_pad - b_real
        self._seen_shapes.add((bn, bp, b_pad, form))
        self.stats.bucket_shapes = len(self._seen_shapes)
        return inf

    # -- completion --------------------------------------------------------

    def _complete(self, inf: _InFlight) -> None:
        """Await one batch, unpad per-request results, refill the cache.

        The stacked device arrays are pulled to host ONCE and sliced in
        numpy — per-request eager `Array.__getitem__` costs more dispatch
        time than the solves themselves at serving batch sizes."""
        bn, bp, form = inf.key
        with self.tracer.span("complete", bucket=(bn, bp),
                              b_real=len(inf.reqs)):
            t0 = self.clock()
            with self.tracer.span("harvest.block"):
                # reprolint: disable=SYN002 -- THE sanctioned harvest site
                # (DESIGN.md §8): the runtime's single block point, one per
                # bucket chunk, after which the numpy pulls below are free
                jax.block_until_ready(inf.beta)
            blocked = self.clock() - t0
            self.stats.solve_seconds += blocked
            beta, iters, kkt, alpha, w, t_out, nu_out = (
                np.asarray(a) for a in (inf.beta, inf.iters, inf.kkt,
                                        inf.alpha, inf.w, inf.t_out,
                                        inf.nu_out))
            for i, req in enumerate(inf.reqs):
                p = req.X.shape[1]
                self._results[req.req_id] = EnResult(
                    beta=beta[i, :p], iters=iters[i], kkt=kkt[i],
                    bucket=(bn, bp))
                if self.cache is not None:
                    self.cache.insert(req.fingerprint, form, WarmEntry(
                        lam=req.lam, lambda2=req.lambda2, alpha=alpha[i],
                        w=w[i], beta=beta[i], t=t_out[i], nu=nu_out[i]))
            if self.cache is not None:
                # speculative slots: nobody asked for these yet — the whole
                # point is that the NEXT step of the crawl finds them warm
                for slot, fp, lam, lam2 in inf.spec:
                    self.cache.insert(fp, form, WarmEntry(
                        lam=lam, lambda2=lam2, alpha=alpha[slot], w=w[slot],
                        beta=beta[slot], t=t_out[slot], nu=nu_out[slot]))
            now = self.clock()
            self.metrics.completed([r.req_id for r in inf.reqs], now)
            # nothing past this point can raise: a harvest retry after a
            # cache/unpad failure must not double-count terminals or solves
            self._terminal.inc(len(inf.reqs), status="ok")
            nnz = 0
            dim = 0
            for i, req in enumerate(inf.reqs):
                p = req.X.shape[1]
                nnz += int(np.count_nonzero(np.abs(beta[i, :p]) > 1e-12))
                dim += p
            b_real = len(inf.reqs)
            real_iters = iters[:b_real]
            self.solve_log.add(SolveRecord(
                bucket=(bn, bp), form=form, batch=int(beta.shape[0]),
                b_real=b_real, route_path=inf.route_path,
                modeled_s=inf.modeled_s,
                actual_s=(now - inf.t_dispatch if inf.t_dispatch > 0.0
                          else blocked),
                blocked_s=blocked, iters_max=int(real_iters.max(initial=0)),
                iters_mean=float(real_iters.mean()) if b_real else 0.0,
                kkt_max=float(kkt[:b_real].max(initial=0.0)),
                keep_fraction=nnz / dim if dim else 0.0))


def _batch_ready(inf: _InFlight) -> bool:
    """True when a dispatched batch's arrays have landed (non-blocking)."""
    try:
        return bool(inf.beta.is_ready())
    except AttributeError:     # older jax: no readiness probe, stay async
        return False
