"""Reproducible open-loop load generator for the serving runtime.

Builds a seeded synthetic request stream — a handful of distinct datasets,
each hit repeatedly at nearby points of the regularization surface (the
"adjacent-lambda" pattern real hyperparameter-sweep traffic has, and the
pattern the warm-start cache exists for) — and plays it into a
`ContinuousScheduler` WITHOUT waiting for completions between submissions
(open loop: arrival times are independent of service times, so the
scheduler's coalescing and async dispatch are what's being measured, not
the client's pacing).

    PYTHONPATH=src python -m repro.runtime.loadgen --requests 24 --waves 3

The CLI is the CI serving smoke: wave 1 compiles the bucket executables,
later waves must add ZERO new traces and ZERO new executables (asserted) —
the continuous-batching runtime serves steady-state traffic on a constant
compiled set, with the cache absorbing repeat/adjacent work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.obs import clock as obs_clock
from repro.runtime.cache import CONSTRAINED, PENALIZED


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """A seeded description of one request stream (fully reproducible)."""

    n_requests: int = 64
    n_datasets: int = 3                       # distinct (X, y) problems
    shapes: Sequence[Tuple[int, int]] = ((48, 24), (64, 40), (30, 56))
    pattern: str = "adjacent"                 # "adjacent" | "uniform"
    adjacent_width: float = 0.1               # +-10% around each lam center
    penalized_fraction: float = 0.0           # mix of glmnet-form requests
    lambda2_choices: Sequence[float] = (0.5, 1.0, 2.0)
    arrival_rate: Optional[float] = None      # req/s; None = back-to-back
    seed: int = 0
    data_seed: Optional[int] = None           # pin datasets across specs:
    # two specs sharing data_seed draw DIFFERENT lambda/arrival streams over
    # the SAME datasets — the repeat-traffic shape warm-start caching serves.


class LoadItem(NamedTuple):
    arrival: float        # seconds after stream start (0.0 when unpaced)
    dataset: int
    X: np.ndarray
    y: np.ndarray
    form: str
    lam: float
    lambda2: float
    priority: int


def make_workload(spec: LoadSpec) -> List[LoadItem]:
    """Materialize the stream: every array and lambda is a pure function of
    the spec (same spec => byte-identical workload => same fingerprints)."""
    from repro.core.elastic_net import lambda1_max
    from repro.data.synthetic import make_regression

    rng = np.random.default_rng(spec.seed)
    data_seed = spec.seed if spec.data_seed is None else spec.data_seed
    rng_data = np.random.default_rng(data_seed * 7919 + 13)
    datasets = []
    for d in range(spec.n_datasets):
        n, p = spec.shapes[d % len(spec.shapes)]
        X, y, _ = make_regression(n, p, k_true=max(3, p // 6), rho=0.3,
                                  seed=data_seed * 1000 + d)
        X, y = np.asarray(X), np.asarray(y)
        t_center = float(0.15 * np.abs(X.T @ y).sum() / n)
        l1_center = float(0.3 * lambda1_max(X, y))
        # lambda2 is a per-DATASET trait (drawn from the data rng): waves
        # sharing data_seed revisit the same (dataset, lambda2) pairs, so
        # adjacent-lambda1/t traffic lands inside the cache neighborhood.
        lam2 = float(rng_data.choice(spec.lambda2_choices))
        datasets.append((X, y, max(t_center, 1e-3), l1_center, lam2))

    items: List[LoadItem] = []
    arrival = 0.0
    for _ in range(spec.n_requests):
        d = int(rng.integers(spec.n_datasets))
        X, y, t_c, l1_c, lam2 = datasets[d]
        pen = rng.random() < spec.penalized_fraction
        center = l1_c if pen else t_c
        if spec.pattern == "adjacent":
            lam = center * (1.0 + spec.adjacent_width
                            * float(rng.uniform(-1.0, 1.0)))
        elif spec.pattern == "uniform":
            lam = center * float(rng.uniform(0.4, 1.6))
        else:
            raise ValueError(f"make_workload: unknown pattern {spec.pattern!r}")
        if spec.arrival_rate:
            arrival += float(rng.exponential(1.0 / spec.arrival_rate))
        items.append(LoadItem(
            arrival=arrival, dataset=d, X=X, y=y,
            form=PENALIZED if pen else CONSTRAINED, lam=lam, lambda2=lam2,
            priority=int(rng.integers(0, 3))))
    return items


def run_open_loop(scheduler, workload: Sequence[LoadItem], *,
                  pace: bool = False) -> dict:
    """Play a workload into a scheduler; returns wall time + metrics summary.

    Submissions never wait on results (`submit` polls, launching full /
    expired buckets asynchronously); everything still pending is flushed
    and harvested at the end, so the returned summary covers every request.
    The scheduler's latency recorder is reset first — each run's summary
    stands alone even when waves share one scheduler (warm cache, compiled
    executables).
    """
    scheduler.metrics.reset()
    ids = []
    t0 = obs_clock.monotonic()
    for item in workload:
        if pace and item.arrival > 0.0:
            lag = t0 + item.arrival - obs_clock.monotonic()
            if lag > 0:
                time.sleep(lag)
        kw = ({"lambda1": item.lam} if item.form == PENALIZED
              else {"t": item.lam})
        ids.append(scheduler.submit(item.X, item.y, lambda2=item.lambda2,
                                    priority=item.priority, **kw))
    results = scheduler.drain()
    wall = obs_clock.monotonic() - t0
    out = {"n_requests": len(workload), "wall_seconds": wall,
           "results": results, "ids": ids}
    out.update(scheduler.metrics.summary())
    return out


def export_telemetry(args, *, registry_snapshot: dict,
                     required_metrics: Sequence[str],
                     required_spans: Sequence[str] = ()) -> None:
    """Write `--trace-out` / `--metrics-json` / `--events-out` artifacts and
    SCHEMA-CHECK them on the spot (the CI telemetry smoke): the trace must
    be loadable Chrome-trace JSON containing the expected span names, the
    metrics snapshot must carry the expected series. Assertion failures here
    are loadgen failures — a telemetry regression fails the smoke, not just
    some later dashboard."""
    import json

    from repro.obs.events import default_events
    from repro.obs.trace import get_tracer

    if args.trace_out:
        path = get_tracer().export(args.trace_out)
        with open(path) as f:
            trace = json.load(f)
        names = {ev["name"] for ev in trace["traceEvents"]}
        missing = set(required_spans) - names
        assert not missing, f"trace missing expected spans: {sorted(missing)}"
        assert all(ev["ph"] in ("X", "i") and "ts" in ev
                   for ev in trace["traceEvents"]), "malformed trace event"
        print(f"[loadgen] trace: {len(trace['traceEvents'])} events "
              f"-> {path}")
    if args.metrics_json:
        # reprolint: disable=ATM001 -- operator-requested CLI export path,
        # not a cache/spill tier: nothing re-reads it on a warm start, and a
        # torn file on crash is visible to the operator who asked for it
        with open(args.metrics_json, "w") as f:
            json.dump(registry_snapshot, f, indent=1, default=str)
        flat = json.dumps(registry_snapshot)
        missing = [m for m in required_metrics if m not in flat]
        assert not missing, f"metrics snapshot missing series: {missing}"
        print(f"[loadgen] metrics snapshot -> {args.metrics_json}")
    if args.events_out:
        path = default_events().dump(args.events_out)
        with open(path) as f:
            for line in f:
                rec = json.loads(line)
                assert "ts" in rec and "kind" in rec, f"malformed event {rec}"
        print(f"[loadgen] events: {len(default_events())} -> {path}")


def run_multihost(args) -> None:
    """Multi-process serving smoke (`--hosts N`): the same seeded waves
    played into a `MultiHostCoordinator` spanning N worker processes, with
    an optional mid-stream host kill (`--kill-host`). Asserts the
    cross-process no-silent-drops contract — every submitted request gets a
    terminal result, and with no injected fault every status is "ok" — plus
    cross-host warm-start hits through the shared spill tier. (The
    zero-retrace assertion is per-process; each worker holds its own
    executables, so the single-process smoke keeps owning that gate.)"""
    import tempfile

    from repro.runtime.multihost import MultiHostCoordinator

    spec = LoadSpec(n_requests=args.requests,
                    penalized_fraction=args.penalized, seed=args.seed)
    workload = make_workload(spec)
    with tempfile.TemporaryDirectory() as tmp:
        coord = MultiHostCoordinator(n_hosts=args.hosts,
                                     max_batch=args.max_batch,
                                     cache_dir=tmp, speculate=True)
        try:
            for wave in range(args.waves):
                if args.kill_host >= 0 and wave == 1:
                    coord.kill_host(args.kill_host)
                    print(f"[loadgen] wave {wave}: injected SIGKILL on "
                          f"host {args.kill_host}")
                summary = run_open_loop(coord, workload)
                statuses: dict = {}
                for res in summary["results"].values():
                    statuses[res.status] = statuses.get(res.status, 0) + 1
                print(f"[loadgen] wave {wave}: {summary['n_completed']}/"
                      f"{args.requests} done in "
                      f"{summary['wall_seconds']*1e3:7.1f} ms"
                      f" | p99 {summary['p99_latency_s']*1e3:6.1f} ms"
                      f" | statuses={statuses}"
                      f" hosts_lost={coord.hosts_lost}")
                assert set(summary["results"]) == set(summary["ids"]), \
                    "lost requests across hosts"
                if args.kill_host < 0:
                    assert statuses == {"ok": args.requests}, statuses
        finally:
            stats = coord.shutdown()
        hits = sum(s["cache_hits"] for s in stats)
        spill = sum(s["spill_hits"] for s in stats)
        acct = coord.accounting()
        print(f"[loadgen] multihost OK: {args.hosts} hosts, "
              f"{coord.hosts_lost} lost, {coord.requeued_batches} batches "
              f"requeued, {hits} warm hits ({spill} via shared spill).")
        print(f"[loadgen] accounting: {acct['admitted']} admitted, "
              f"terminals={acct['terminals']}")
        assert acct["balanced"], f"terminal accounting broken: {acct}"
        assert hits > 0, "multihost waves produced no warm-start hits"
        export_telemetry(
            args, registry_snapshot=coord.metrics_snapshot(),
            required_metrics=("requests_admitted_total",
                              "requests_terminal_total",
                              "runtime_requests_total"),
            required_spans=("mh.place",) if args.trace_out else ())


def main(argv=None) -> None:
    """CI serving smoke: steady-state waves must not retrace or recompile."""
    import argparse

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core import reset_trace_counts, trace_counts
    from repro.runtime.scheduler import ContinuousScheduler

    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24, help="per wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--penalized", type=float, default=0.25,
                    help="fraction of glmnet-form requests")
    ap.add_argument("--hosts", type=int, default=0,
                    help="> 0: drive a MultiHostCoordinator over this many "
                         "worker processes instead of an in-process scheduler")
    ap.add_argument("--kill-host", type=int, default=-1,
                    help="with --hosts: SIGKILL this host before wave 1")
    ap.add_argument("--trace-out", default="",
                    help="enable tracing; write Chrome-trace JSON here and "
                         "schema-check it")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics snapshot (JSON) here and "
                         "schema-check it")
    ap.add_argument("--events-out", default="",
                    help="write the structured event log (JSONL) here")
    args = ap.parse_args(argv)

    if args.trace_out:
        from repro.obs.trace import enable_tracing
        enable_tracing()

    if args.hosts > 0:
        run_multihost(args)
        return

    # fixed_batch pins one executable per (bucket, form); repeating the SAME
    # seeded wave makes the steady-state zero-retrace assertion exact (launch
    # sizes under deadline scheduling would otherwise vary with wall clock).
    sched = ContinuousScheduler(max_batch=args.max_batch, max_wait=0.005,
                                fixed_batch=True)
    spec = LoadSpec(n_requests=args.requests,
                    penalized_fraction=args.penalized, seed=args.seed)
    workload = make_workload(spec)
    reset_trace_counts()
    steady_traces = None
    steady_execs = None
    for wave in range(args.waves):
        summary = run_open_loop(sched, workload)
        new_traces = dict(trace_counts())
        execs = sched.stats.bucket_shapes
        print(f"[loadgen] wave {wave}: {summary['n_completed']}/"
              f"{args.requests} done in {summary['wall_seconds']*1e3:7.1f} ms"
              f" | p50 {summary['p50_latency_s']*1e3:6.1f} ms"
              f" p99 {summary['p99_latency_s']*1e3:6.1f} ms"
              f" | executables={execs}"
              f" cache_hit_rate={sched.cache.hit_rate:.2f}"
              f" traces={sum(new_traces.values())}")
        assert summary["n_completed"] == args.requests, "lost requests"
        if wave > 0:
            assert new_traces == steady_traces, (
                f"steady-state wave retraced: {steady_traces} -> {new_traces}")
            assert execs == steady_execs, (
                f"steady-state wave compiled new executables: "
                f"{steady_execs} -> {execs}")
        steady_traces, steady_execs = new_traces, execs
    assert sched.cache.hits > 0, "adjacent-lambda stream produced no cache hits"
    print(f"[loadgen] steady state OK: {sched.stats.requests} requests, "
          f"{steady_execs} executables, zero retrace after wave 0, "
          f"{sched.cache.hits} warm-start cache hits.")
    export_telemetry(
        args, registry_snapshot=sched.registry.snapshot(),
        required_metrics=("runtime_requests_total", "runtime_launches_total",
                          "cache_lookups_total", "request_latency_seconds",
                          "requests_terminal_total"),
        required_spans=("admit", "launch", "warm_start", "harvest.block",
                        "complete") if args.trace_out else ())


if __name__ == "__main__":
    main()
