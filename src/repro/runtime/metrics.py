"""Latency and throughput accounting for the serving runtime (DESIGN.md §8).

Every request passes through three instants — submitted (admission),
launched (its micro-batch dispatched to the device) and completed (results
unpadded and delivered) — so the recorder can split end-to-end latency into
queue wait (submitted -> launched: the price of coalescing) and service
time (launched -> completed: device compute + harvest). `summary()` folds
the per-request records into the percentile/throughput numbers
`benchmarks/bench_serve.py` serializes into BENCH_path.json's ``serve``
section.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]) of a non-empty list."""
    if not values:
        raise ValueError("percentile: empty sequence")
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = math.floor(pos)
    hi = math.ceil(pos)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclasses.dataclass
class RequestTimes:
    """The three instants of one request's life in the runtime."""

    submitted: float
    launched: Optional[float] = None
    completed: Optional[float] = None

    @property
    def queue_wait(self) -> Optional[float]:
        if self.launched is None:
            return None
        return self.launched - self.submitted

    @property
    def latency(self) -> Optional[float]:
        if self.completed is None:
            return None
        return self.completed - self.submitted


class LatencyRecorder:
    """Per-request event log; pure host-side bookkeeping, no device syncs."""

    def __init__(self) -> None:
        self._times: Dict[int, RequestTimes] = {}

    def submitted(self, req_id: int, now: float) -> None:
        self._times[req_id] = RequestTimes(submitted=now)

    def launched(self, req_ids: Iterable[int], now: float) -> None:
        # ids missing from _times were submitted before a reset() — they
        # are simply no longer tracked, never an error on the serving path
        for rid in req_ids:
            t = self._times.get(rid)
            if t is not None:
                t.launched = now

    def completed(self, req_ids: Iterable[int], now: float) -> None:
        for rid in req_ids:
            t = self._times.get(rid)
            if t is not None:
                t.completed = now

    def reset(self) -> None:
        self._times.clear()

    @property
    def completed_count(self) -> int:
        return sum(1 for t in self._times.values() if t.completed is not None)

    def summary(self, quantiles: Sequence[float] = (50.0, 90.0, 99.0)) -> dict:
        """Latency percentiles (seconds) + open-loop throughput (req/s).

        Throughput is completed requests over the span from the first
        submission to the last completion — the sustained rate an open-loop
        client observed, not the reciprocal of mean latency.
        """
        done = [t for t in self._times.values() if t.completed is not None]
        if not done:
            return {"n_completed": 0, "req_per_s": 0.0}
        lat = [t.latency for t in done]
        waits = [t.queue_wait for t in done if t.queue_wait is not None]
        span = (max(t.completed for t in done)
                - min(t.submitted for t in done))
        out = {
            "n_completed": len(done),
            "req_per_s": len(done) / max(span, 1e-12),
            "mean_latency_s": sum(lat) / len(lat),
        }
        for q in quantiles:
            out[f"p{int(q)}_latency_s"] = percentile(lat, q)
        if waits:
            out["mean_queue_wait_s"] = sum(waits) / len(waits)
        return out
