"""Latency and throughput accounting for the serving runtime (DESIGN.md §8,
§12.2).

Every request passes through three instants — submitted (admission),
launched (its micro-batch dispatched to the device) and completed (results
unpadded and delivered) — so the recorder can split end-to-end latency into
queue wait (submitted -> launched: the price of coalescing) and service
time (launched -> completed: device compute + harvest). `summary()` folds
the rolled-up state into the percentile/throughput numbers
`benchmarks/bench_serve.py` serializes into BENCH_path.json's ``serve``
section.

Memory is BOUNDED: only OPEN (not-yet-completed) requests keep a
per-request record; completion folds the record into exponential-bucket
histograms on the recorder's `MetricsRegistry` (`request_latency_seconds`,
`request_queue_wait_seconds`) plus scalar rollups. The previous
implementation retained every completed `RequestTimes` forever — a slow
leak under the long-running loadgen. Percentiles are now histogram
quantiles (<= ~4% relative error, exact at min/max), which every consumer
of `summary()` uses as ratios or ordering, never as exact values.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence

from repro.obs.metrics import MetricsRegistry


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]) of a non-empty list."""
    if not values:
        raise ValueError("percentile: empty sequence")
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = math.floor(pos)
    hi = math.ceil(pos)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


@dataclasses.dataclass
class RequestTimes:
    """The three instants of one request's life in the runtime."""

    submitted: float
    launched: Optional[float] = None
    completed: Optional[float] = None

    @property
    def queue_wait(self) -> Optional[float]:
        if self.launched is None:
            return None
        return self.launched - self.submitted

    @property
    def latency(self) -> Optional[float]:
        if self.completed is None:
            return None
        return self.completed - self.submitted


class LatencyRecorder:
    """Per-request event log; pure host-side bookkeeping, no device syncs.

    `registry` hooks the latency/queue-wait histograms into an owner's
    `MetricsRegistry` (the scheduler passes its own, so the series show up
    in its Prometheus exposition); by default the recorder keeps a private
    one. Open requests are the only per-request state — completed requests
    live on solely as histogram mass.
    """

    def __init__(self, *, registry: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self._open: Dict[int, RequestTimes] = {}
        self._lat = self.registry.histogram(
            "request_latency_seconds",
            "end-to-end latency of completed requests")
        self._wait = self.registry.histogram(
            "request_queue_wait_seconds",
            "admission -> launch coalescing wait of completed requests")
        self._n_completed = 0
        self._first_submitted: Optional[float] = None
        self._last_completed: Optional[float] = None

    def submitted(self, req_id: int, now: float) -> None:
        self._open[req_id] = RequestTimes(submitted=now)

    def launched(self, req_ids: Iterable[int], now: float) -> None:
        # ids missing from the open table were submitted before a reset()
        # (or already completed) — they are simply no longer tracked, never
        # an error on the serving path
        for rid in req_ids:
            t = self._open.get(rid)
            if t is not None:
                t.launched = now

    def completed(self, req_ids: Iterable[int], now: float) -> None:
        for rid in req_ids:
            t = self._open.pop(rid, None)
            if t is None:
                continue
            t.completed = now
            self._lat.observe(max(t.latency, 0.0))
            if t.queue_wait is not None:
                self._wait.observe(max(t.queue_wait, 0.0))
            self._n_completed += 1
            if (self._first_submitted is None
                    or t.submitted < self._first_submitted):
                self._first_submitted = t.submitted
            if self._last_completed is None or now > self._last_completed:
                self._last_completed = now

    def reset(self) -> None:
        self._open.clear()
        self._lat.reset()
        self._wait.reset()
        self._n_completed = 0
        self._first_submitted = None
        self._last_completed = None

    @property
    def open_count(self) -> int:
        return len(self._open)

    @property
    def completed_count(self) -> int:
        return self._n_completed

    def summary(self, quantiles: Sequence[float] = (50.0, 90.0, 99.0)) -> dict:
        """Latency percentiles (seconds) + open-loop throughput (req/s).

        Throughput is completed requests over the span from the first
        submission to the last completion — the sustained rate an open-loop
        client observed, not the reciprocal of mean latency.
        """
        lat = self._lat.series().get(())
        if self._n_completed == 0 or lat is None or lat.count == 0:
            return {"n_completed": 0, "req_per_s": 0.0}
        span = self._last_completed - self._first_submitted
        out = {
            "n_completed": self._n_completed,
            "req_per_s": self._n_completed / max(span, 1e-12),
            "mean_latency_s": lat.sum / lat.count,
        }
        for q in quantiles:
            out[f"p{int(q)}_latency_s"] = lat.quantile(q)
        wait = self._wait.series().get(())
        if wait is not None and wait.count:
            out["mean_queue_wait_s"] = wait.sum / wait.count
        return out
