"""FISTA (accelerated proximal gradient) for the penalized Elastic Net.

Stand-in for the paper's L1_LS comparison point (an interior-point Lasso
solver): a first-order method dominated by X/X^T matvecs. Smooth part
g(b) = ||Xb - y||^2 + lambda2 ||b||^2, prox of lambda1|.|_1 is soft-threshold.
Step 1/L with L = 2 lambda_max(X^T X) + 2 lambda2 via power iteration.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class FistaResult(NamedTuple):
    beta: jax.Array
    iters: jax.Array
    delta: jax.Array


@partial(jax.jit, static_argnames=("max_iters",))
def elastic_net_fista(
    X: jax.Array,
    y: jax.Array,
    lambda1: float,
    lambda2: float,
    *,
    tol: float = 1e-12,
    max_iters: int = 20000,
    beta0: jax.Array | None = None,
) -> FistaResult:
    n, p = X.shape
    dtype = X.dtype
    lambda1 = jnp.asarray(lambda1, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)

    # power iteration for L
    v = jnp.ones((p,), dtype) / jnp.sqrt(p)

    def pw(_, v):
        w = X.T @ (X @ v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, 50, pw, v)
    L = 2.0 * (v @ (X.T @ (X @ v))) + 2.0 * lambda2
    step = 1.0 / (L * 1.01)

    def grad(b):
        return 2.0 * (X.T @ (X @ b - y)) + 2.0 * lambda2 * b

    def prox(b):
        return jnp.sign(b) * jnp.maximum(jnp.abs(b) - step * lambda1, 0.0)

    b_init = jnp.zeros((p,), dtype) if beta0 is None else beta0.astype(dtype)

    def body(state):
        b, z, tk, it, _ = state
        b_new = prox(z - step * grad(z))
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk))
        z_new = b_new + ((tk - 1.0) / t_new) * (b_new - b)
        return b_new, z_new, t_new, it + 1, jnp.max(jnp.abs(b_new - b))

    def cond(state):
        _, _, _, it, delta = state
        return (delta > tol) & (it < max_iters)

    one = jnp.asarray(1.0, dtype)
    state = (b_init, b_init, one, jnp.zeros((), jnp.int32), jnp.asarray(jnp.inf, dtype))
    b, _, _, iters, delta = jax.lax.while_loop(cond, body, state)
    return FistaResult(beta=b, iters=iters, delta=delta)
