"""glmnet-style coordinate descent for the penalized Elastic Net.

    min_beta ||X beta - y||^2 + lambda2 ||beta||^2 + lambda1 |beta|_1

(no 1/2 or 1/n factors — the paper's scaling). Coordinate update:

    beta_j <- S(2 x_j^T r_j, lambda1) / (2 ||x_j||^2 + 2 lambda2),
    r_j = y - X beta + x_j beta_j,  S = soft threshold.

This is the framework's ground-truth reference (stands in for glmnet, which
is unavailable offline); it is independently validated by KKT property tests
so SVEN-vs-CD agreement is a two-sided check. Full residual updates via
lax.fori_loop keep it jittable; cyclic sweeps until max |delta beta| < tol.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CDResult(NamedTuple):
    beta: jax.Array
    sweeps: jax.Array
    delta: jax.Array


@partial(jax.jit, static_argnames=("max_sweeps",))
def elastic_net_cd(
    X: jax.Array,
    y: jax.Array,
    lambda1: float,
    lambda2: float,
    *,
    tol: float = 1e-12,
    max_sweeps: int = 2000,
    beta0: jax.Array | None = None,
) -> CDResult:
    n, p = X.shape
    dtype = X.dtype
    lambda1 = jnp.asarray(lambda1, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)
    col_sq = jnp.sum(X * X, axis=0)                      # ||x_j||^2
    denom = 2.0 * col_sq + 2.0 * lambda2

    beta_init = jnp.zeros((p,), dtype) if beta0 is None else beta0.astype(dtype)
    r_init = y - X @ beta_init

    def coord_update(j, carry):
        beta, r = carry
        bj = beta[j]
        xj = X[:, j]
        rho = 2.0 * (xj @ r) + 2.0 * col_sq[j] * bj       # 2 x_j^T r_j
        bj_new = jnp.sign(rho) * jnp.maximum(jnp.abs(rho) - lambda1, 0.0) / denom[j]
        r = r - xj * (bj_new - bj)
        beta = beta.at[j].set(bj_new)
        return beta, r

    def sweep(state):
        beta, r, it, _ = state
        beta_new, r_new = jax.lax.fori_loop(0, p, coord_update, (beta, r))
        delta = jnp.max(jnp.abs(beta_new - beta))
        return beta_new, r_new, it + 1, delta

    def cond(state):
        _, _, it, delta = state
        return (delta > tol) & (it < max_sweeps)

    state = (beta_init, r_init, jnp.zeros((), jnp.int32), jnp.asarray(jnp.inf, dtype))
    beta, _, sweeps, delta = jax.lax.while_loop(cond, sweep, state)
    return CDResult(beta=beta, sweeps=sweeps, delta=delta)


def cd_path(X: jax.Array, y: jax.Array, lambda1s, lambda2: float, **kw):
    """Warm-started CD along a decreasing lambda1 grid (glmnet's pathwise trick)."""
    betas, beta = [], None
    for l1 in list(lambda1s):
        res = elastic_net_cd(X, y, float(l1), lambda2, beta0=beta, **kw)
        beta = res.beta
        betas.append(beta)
    return jnp.stack(betas)
