"""Shotgun-style parallel coordinate descent (Bradley et al., ICML 2011).

The paper's parallel-CD comparison point. Shotgun updates P randomly chosen
coordinates *simultaneously* from the same residual snapshot; convergence
holds for P up to ~p/(2*spectral_radius). We implement the synchronous
variant as a vectorized JAX step: draw P coordinates, compute their
soft-threshold targets from the shared residual, apply all deltas at once
(a scatter-add) with a step damping factor. This maps onto SIMD/TPU
hardware exactly the way Shotgun maps onto multicore.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ShotgunResult(NamedTuple):
    beta: jax.Array
    rounds: jax.Array
    delta: jax.Array


@partial(jax.jit, static_argnames=("parallel", "max_rounds"))
def elastic_net_shotgun(
    X: jax.Array,
    y: jax.Array,
    lambda1: float,
    lambda2: float,
    *,
    parallel: int = 64,
    max_rounds: int = 20000,
    tol: float = 1e-10,
    damping: float = 0.5,
    seed: int = 0,
) -> ShotgunResult:
    n, p = X.shape
    dtype = X.dtype
    lambda1 = jnp.asarray(lambda1, dtype)
    lambda2 = jnp.asarray(lambda2, dtype)
    col_sq = jnp.sum(X * X, axis=0)
    denom = 2.0 * col_sq + 2.0 * lambda2
    P = min(parallel, p)

    def round_step(state):
        beta, r, key, it, _ = state
        key, sub = jax.random.split(key)
        js = jax.random.choice(sub, p, shape=(P,), replace=False)
        Xj = X[:, js]                                     # (n, P)
        bj = beta[js]
        rho = 2.0 * (Xj.T @ r) + 2.0 * col_sq[js] * bj
        bj_new = jnp.sign(rho) * jnp.maximum(jnp.abs(rho) - lambda1, 0.0) / denom[js]
        delta_b = damping * (bj_new - bj)
        beta = beta.at[js].add(delta_b)
        r = r - Xj @ delta_b
        return beta, r, key, it + 1, jnp.max(jnp.abs(delta_b))

    def cond(state):
        _, _, _, it, delta = state
        return (delta > tol) & (it < max_rounds)

    beta0 = jnp.zeros((p,), dtype)
    key = jax.random.PRNGKey(seed)
    state = (beta0, y - X @ beta0, key, jnp.zeros((), jnp.int32), jnp.asarray(jnp.inf, dtype))
    beta, _, _, rounds, delta = jax.lax.while_loop(cond, round_step, state)
    return ShotgunResult(beta=beta, rounds=rounds, delta=delta)
