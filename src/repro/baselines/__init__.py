from repro.baselines.coordinate_descent import elastic_net_cd
from repro.baselines.fista import elastic_net_fista
from repro.baselines.shotgun import elastic_net_shotgun

__all__ = ["elastic_net_cd", "elastic_net_fista", "elastic_net_shotgun"]
