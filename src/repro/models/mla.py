"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill use the expanded form (per-head K/V decompressed from the
latent). Decode uses the ABSORBED form: W_uk is folded into the query and
W_uv into the output so attention runs directly against the compressed
(c_kv, k_rope) cache — the cache is (kv_lora_rank + rope_dim) per token
instead of 2*H*dh, the property that makes 32k/500k decode memory-light.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist import constrain
from repro.models.layers import apply_rope, rope_freqs, rms_norm, init_rms_norm


class MLAConfig(NamedTuple):
    n_heads: int = 128
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, kv_lora_rank)
    k_rope: jax.Array  # (B, S, rope_dim) — shared across heads, roped
    pos: jax.Array


def init_mla(key, d_model: int, cfg: MLAConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    H, r_q, r_kv = cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    s = d_model ** -0.5
    return {
        "w_dq": jax.random.normal(ks[0], (d_model, r_q), dtype) * s,
        "q_norm": init_rms_norm(r_q, dtype),
        "w_uq": jax.random.normal(ks[1], (r_q, H, dn + dr), dtype) * r_q ** -0.5,
        "w_dkv": jax.random.normal(ks[2], (d_model, r_kv), dtype) * s,
        "kv_norm": init_rms_norm(r_kv, dtype),
        "w_kr": jax.random.normal(ks[3], (d_model, dr), dtype) * s,
        "w_uk": jax.random.normal(ks[4], (r_kv, H, dn), dtype) * r_kv ** -0.5,
        "w_uv": jax.random.normal(ks[5], (r_kv, H, dv), dtype) * r_kv ** -0.5,
        "wo": jax.random.normal(ks[6], (H, dv, d_model), dtype) * (H * dv) ** -0.5,
    }


def mla_sharding(cfg: MLAConfig) -> dict:
    return {
        "w_dq": ("embed", None),
        "q_norm": {"scale": (None,)},
        "w_uq": ("latent", "heads", None),
        "w_dkv": ("embed", None),
        "kv_norm": {"scale": (None,)},
        "w_kr": ("embed", None),
        "w_uk": ("latent", "heads", None),
        "w_uv": ("latent", "heads", None),
        "wo": ("heads", None, "embed"),
    }


def _queries(params, x, cfg: MLAConfig, cos, sin):
    cq = rms_norm(x @ params["w_dq"], params["q_norm"]["scale"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q = constrain(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _latents(params, x, cos, sin):
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_norm"]["scale"])
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], cos, sin)[:, :, 0]
    return c_kv, k_rope


def mla_full(params: dict, x: jax.Array, cfg: MLAConfig, *, rope_theta: float,
             dense_max: int = 2048) -> jax.Array:
    """Expanded-form causal attention (train / prefill). The rope part is
    folded into an effective head dim so the shared chunked-SDPA core applies:
    q_eff = [q_nope ; q_rope], k_eff = [k_nope ; k_rope broadcast]."""
    from repro.models.attention import CHUNKED_THRESHOLD, _sdpa, sdpa_chunked

    B, S, _ = x.shape
    H = cfg.n_heads
    pos = jnp.arange(S)
    cos, sin = rope_freqs(cfg.qk_rope_dim, rope_theta, pos)
    q_nope, q_rope = _queries(params, x, cfg, cos, sin)
    c_kv, k_rope = _latents(params, x, cos, sin)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    k_nope = constrain(k_nope, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)

    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_eff = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                      (B, S, H, cfg.qk_rope_dim))], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    if S > dense_max:
        out = sdpa_chunked(q_eff, k_eff, v, scale=scale)
    else:
        mask = (pos[None, :] <= pos[:, None])[None, None]
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_eff, k_eff).astype(jnp.float32) * scale
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        out = constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bqhd,hdm->bqm", out, params["wo"])


def mla_prefill(params: dict, x: jax.Array, cfg: MLAConfig, *, rope_theta: float,
                cache_len: int, dense_max: int = 2048) -> tuple[jax.Array, MLACache]:
    B, S, _ = x.shape
    out = mla_full(params, x, cfg, rope_theta=rope_theta, dense_max=dense_max)
    pos = jnp.arange(S)
    cos, sin = rope_freqs(cfg.qk_rope_dim, rope_theta, pos)
    c_kv, k_rope = _latents(params, x, cos, sin)
    pad = cache_len - S
    c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
    k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    c_kv = constrain(c_kv, "batch", "seq_kv", None)
    k_rope = constrain(k_rope, "batch", "seq_kv", None)
    return out, MLACache(c_kv=c_kv, k_rope=k_rope, pos=jnp.asarray(S, jnp.int32))


def mla_decode_step(params: dict, x: jax.Array, cache: MLACache, cfg: MLAConfig,
                    *, rope_theta: float) -> tuple[jax.Array, MLACache]:
    """Absorbed-form one-token decode against the compressed cache."""
    B = x.shape[0]
    pos = cache.pos
    cos, sin = rope_freqs(cfg.qk_rope_dim, rope_theta, pos[None])
    q_nope, q_rope = _queries(params, x, cfg, cos, sin)      # (B,1,H,*)
    c_new, kr_new = _latents(params, x, cos, sin)            # (B,1,r), (B,1,dr)
    z = jnp.zeros((), pos.dtype)
    c_kv = jax.lax.dynamic_update_slice(cache.c_kv, c_new.astype(cache.c_kv.dtype), (z, pos, z))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, kr_new.astype(cache.k_rope.dtype), (z, pos, z))
    c_kv = constrain(c_kv, "batch", "seq_kv", None)
    k_rope = constrain(k_rope, "batch", "seq_kv", None)

    # absorb W_uk into q: q_abs (B,1,H,r) = q_nope @ W_uk^T per head
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, params["w_uk"])
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_abs, c_kv)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)).astype(jnp.float32) * scale
    valid = jnp.arange(c_kv.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    # attend in latent space, then absorb W_uv on the way out
    lat = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv)
    out = jnp.einsum("bqhr,rhd->bqhd", lat, params["w_uv"])
    out = constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bqhd,hdm->bqm", out, params["wo"]), MLACache(c_kv=c_kv, k_rope=k_rope, pos=pos + 1)
