"""Mixture-of-Experts: top-k router + capacity-bounded scatter dispatch.

Dispatch is scatter/gather-based (no (B,S,E,C) one-hot einsums — those cost
O(S^2 k cf d) MACs and would poison the roofline's useful-FLOP ratio).
Tokens are scattered into a (B, E, C, d) capacity buffer, expert FFNs run as
a batched einsum over E, and results gather back with routing weights.

Expert parallelism is expressed in pure GSPMD: a sharding constraint moves
the buffer from batch-sharded to expert-sharded ("experts" -> model axis)
and back — XLA lowers the reshard to the EP all-to-all. For E < mesh-model
archs (mixtral: 8 experts on 16-way TP) configs remap "experts" -> None and
"expert_ffn" -> model: weights shard on d_ff instead (TP-within-expert) and
the buffer never reshards (set via per-arch rules override).

Router: softmax top-k, probs renormalized over the chosen experts; returns
the standard load-balance aux loss. (DeepSeek-V3's sigmoid+bias-free router
is approximated by this softmax router; noted in DESIGN.md.)
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import constrain
from repro.models.layers import init_mlp


class MoEConfig(NamedTuple):
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 14336
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25


def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    k_r, k_e, k_s = jax.random.split(key, 3)
    E, f = cfg.n_experts, cfg.d_ff_expert
    s_in, s_out = d_model ** -0.5, f ** -0.5
    ks = jax.random.split(k_e, 3)
    p = {
        "router": jax.random.normal(k_r, (d_model, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[0], (E, d_model, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[1], (E, d_model, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[2], (E, f, d_model), dtype) * s_out,
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(k_s, d_model, cfg.d_ff_shared * cfg.n_shared, dtype)
    return p


def moe_sharding(cfg: MoEConfig) -> dict:
    s = {
        "router": ("embed", None),
        "w_gate": ("experts", "expert_fsdp", "expert_ffn"),
        "w_up": ("experts", "expert_fsdp", "expert_ffn"),
        "w_down": ("experts", "expert_ffn", "expert_fsdp"),
    }
    if cfg.n_shared:
        s["shared"] = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                       "w_down": ("mlp", "embed")}
    return s


def _capacity(S: int, cfg: MoEConfig) -> int:
    c = math.ceil(cfg.top_k * S * cfg.capacity_factor / cfg.n_experts)
    return max(8, min(c, cfg.top_k * S))  # floor for tiny decode steps


def apply_moe(params: dict, x: jax.Array, cfg: MoEConfig):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)

    logits = (x.astype(jnp.float32) @ params["router"])            # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                          # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- rank within expert via SORT, O(B*S*K) memory ---
    # (a (B,SK,E) one-hot cumsum would cost S*K*E ints — 8.6 TB at
    # deepseek-v3 prefill scale; sort+run-position gives the same ranks)
    e_flat = top_e.reshape(B, S * K)
    order = jnp.argsort(e_flat, axis=1, stable=True)                # (B,SK)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    idx = jnp.arange(S * K, dtype=jnp.int32)[None, :]
    run_start = jnp.where(jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1),
        idx, 0)
    run_start = jax.lax.cummax(run_start, axis=1)
    rank_sorted = idx - run_start                                   # pos within expert run
    rank = jnp.zeros((B, S * K), jnp.int32)
    rank = rank.at[jnp.arange(B)[:, None], order].set(rank_sorted)
    keep = (rank < C)
    r_clip = jnp.minimum(rank, C - 1)

    # --- dispatch: scatter tokens into the capacity buffer ---
    # vmap over batch keeps the scatter's batch dim partitionable (a single
    # advanced-indexing scatter over (B, SK) made GSPMD replicate the updates
    # — 224 GiB/device at deepseek-v3 prefill scale)
    x_flat = (x.reshape(B, S, 1, d) * jnp.ones((1, 1, K, 1), x.dtype)).reshape(B, S * K, d)
    x_flat = constrain(x_flat, "batch", None, None)
    w_keep = keep[..., None].astype(x.dtype)

    def dispatch_row(x_r, e_r, r_r, wk_r):
        return jnp.zeros((E, C, d), x.dtype).at[e_r, r_r].add(x_r * wk_r)

    buf = jax.vmap(dispatch_row)(x_flat, e_flat, r_clip, w_keep)
    # EP: reshard token buffer from batch-sharded to expert-sharded (all-to-all)
    buf = constrain(buf, "moe_batch", "experts", None, None)

    # --- expert SwiGLU (batched over E) ---
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, params["w_up"])
    h = constrain(h, "moe_batch", "experts", None, "expert_ffn")
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out_buf = constrain(out_buf, "moe_batch", "experts", None, None)

    # --- combine: gather back with routing weights ---
    gathered = jax.vmap(lambda ob_r, e_r, r_r: ob_r[e_r, r_r])(
        out_buf, e_flat, r_clip)                                    # (B,SK,d)
    w_flat = (top_p.reshape(B, S * K) * keep).astype(x.dtype)
    y = (gathered * w_flat[..., None]).reshape(B, S, K, d).sum(axis=2)
    y = constrain(y, "batch", None, "embed")

    if cfg.n_shared:
        sh = params["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        y = y + hs @ sh["w_down"]

    # load-balance aux (Switch/GShard style)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
