"""Base layers: RMSNorm, RoPE, SwiGLU MLP, embeddings. Pure-function style:
params are nested dicts, `init_*` builds them, `apply_*` consumes them."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import constrain


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dtype) * scale.astype(dtype)


def init_rms_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


# ------------------------------------------------------------------ RoPE ---

def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., head_dim/2), f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (S, hd/2) broadcast over batch/heads."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------- SwiGLU ---

def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def apply_mlp(params: dict, x: jax.Array) -> jax.Array:
    """SwiGLU: silu(x W_g) * (x W_u) W_d, Megatron col->row TP on d_ff."""
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constrain(h, "batch", None, "mlp")
    return h @ params["w_down"]


def mlp_sharding() -> dict:
    return {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}


# ------------------------------------------------------------ embeddings ---

def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * (d_model ** -0.5)}


def embed_tokens(params: dict, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return constrain(out, "batch", None, "embed")


def logits_from_embedding(params: dict, x: jax.Array) -> jax.Array:
    """Tied output head: x (..., d) @ table^T -> (..., vocab), f32 logits."""
    logits = x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T
    return constrain(logits, "batch", None, "vocab")
