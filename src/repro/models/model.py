"""TransformerLM assembly: heterogeneous layer plans (attn/mla/ssm mixers x
dense/moe MLPs), scan-over-periods parameter stacking (compile hygiene: the
HLO contains one period body regardless of depth), tied-embedding head,
modality frontends, and train/prefill/decode entry points.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist import constrain
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    rope_theta: float = 1e4
    qkv_bias: bool = False
    swa_window: Optional[int] = None
    mixer_pattern: tuple = ("attn",)          # tiled over layers
    mlp_pattern: tuple = ("dense",)
    dense_prefix: int = 0                      # first k layers: dense MLP (d_ff_dense)
    d_ff_dense: Optional[int] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    frontend: str = "tokens"                   # tokens | codebooks | patches
    n_codebooks: int = 1
    vision_tokens: int = 0                     # prepended patch embeddings (patches)
    mtp_depth: int = 0                         # DeepSeek-V3 multi-token prediction
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: bool = True                         # per-layer activation checkpointing
    remat_policy: str = "full"                 # "full" | "dots" (save matmul outs)
    attn_dense_max: int = 2048                 # S above this -> chunked (flash) SDPA
    unroll_layers: bool = False                # python-loop instead of lax.scan
    # (used by dry-run cost probes: XLA cost_analysis counts scan bodies once,
    # unrolled probes recover true per-period flops/bytes/collectives)
    rules_override: dict = dataclasses.field(default_factory=dict)

    @property
    def period(self) -> int:
        return int(math.lcm(len(self.mixer_pattern), len(self.mlp_pattern)))

    def layer_spec(self, i: int) -> tuple[str, str]:
        mixer = self.mixer_pattern[i % len(self.mixer_pattern)]
        mlp = self.mlp_pattern[i % len(self.mlp_pattern)]
        if i < self.dense_prefix:
            mlp = "dense"
        return mixer, mlp

    @property
    def n_body(self) -> int:
        return self.n_layers - self.dense_prefix

    @property
    def n_periods(self) -> int:
        assert self.n_body % self.period == 0, (self.n_body, self.period)
        return self.n_body // self.period


# ------------------------------------------------------------------ init ---

def _init_layer(key, cfg: ModelConfig, mixer: str, mlp: str) -> dict:
    k1, k2 = jax.random.split(key)
    p: dict = {"mixer_norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
               "mlp_norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype)}
    if mixer == "attn":
        p["mixer"] = attn.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                         cfg.head_dim, cfg.param_dtype, cfg.qkv_bias)
    elif mixer == "mla":
        p["mixer"] = mla_mod.init_mla(k1, cfg.d_model, cfg.mla, cfg.param_dtype)
    elif mixer == "ssm":
        p["mixer"] = ssm_mod.init_ssm(k1, cfg.d_model, cfg.ssm, cfg.param_dtype)
    else:
        raise ValueError(mixer)
    if mlp == "dense":
        d_ff = cfg.d_ff_dense or cfg.d_ff
        p["mlp"] = L.init_mlp(k2, cfg.d_model, d_ff, cfg.param_dtype)
    elif mlp == "moe":
        p["mlp"] = moe_mod.init_moe(k2, cfg.d_model, cfg.moe, cfg.param_dtype)
    elif mlp == "none":   # pure-SSM blocks (mamba2): mixer only, no MLP
        p.pop("mlp_norm")
    else:
        raise ValueError(mlp)
    return p


def init_model(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "final_norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
    }
    if cfg.frontend == "codebooks" and cfg.n_codebooks > 1:
        params["codebook_embeds"] = [
            L.init_embedding(jax.random.fold_in(keys[1], c), cfg.vocab_size, cfg.d_model,
                             cfg.param_dtype) for c in range(1, cfg.n_codebooks)]
    params["prefix"] = [
        _init_layer(keys[2 + i], cfg, *cfg.layer_spec(i)) for i in range(cfg.dense_prefix)]
    # body: stack params across periods for each position-in-period
    body = []
    for j in range(cfg.period):
        per_period = [
            _init_layer(keys[2 + cfg.dense_prefix + r * cfg.period + j], cfg,
                        *cfg.layer_spec(cfg.dense_prefix + j))
            for r in range(cfg.n_periods)]
        body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
    params["body"] = body
    if cfg.mtp_depth:
        k_mtp = jax.random.fold_in(keys[-1], 99)
        params["mtp"] = {
            "proj": jax.random.normal(k_mtp, (2 * cfg.d_model, cfg.d_model),
                                      cfg.param_dtype) * (2 * cfg.d_model) ** -0.5,
            "layer": _init_layer(jax.random.fold_in(k_mtp, 1), cfg, "attn", "dense"),
            "norm": L.init_rms_norm(cfg.d_model, cfg.param_dtype),
        }
    return params


# --------------------------------------------------------------- forward ---

def _apply_mixer(p, x, cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        return attn.attend_full(p, x, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                                rope_theta=cfg.rope_theta, window=cfg.swa_window,
                                dense_max=cfg.attn_dense_max)
    if mixer == "mla":
        return mla_mod.mla_full(p, x, cfg.mla, rope_theta=cfg.rope_theta,
                                dense_max=cfg.attn_dense_max)
    if mixer == "ssm":
        return ssm_mod.ssm_forward(p, x, cfg.d_model, cfg.ssm)
    raise ValueError(mixer)


def _apply_layer(p, x, cfg: ModelConfig, mixer: str, mlp: str):
    h = _apply_mixer(p["mixer"], L.rms_norm(x, p["mixer_norm"]["scale"]), cfg, mixer)
    x = x + h
    if mlp == "none":
        return x, 0.0
    hn = L.rms_norm(x, p["mlp_norm"]["scale"])
    if mlp == "dense":
        h2, aux = L.apply_mlp(p["mlp"], hn), 0.0
    else:
        h2, aux = moe_mod.apply_moe(p["mlp"], hn, cfg.moe)
    return x + h2, aux


def _embed_inputs(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    if cfg.frontend == "tokens":
        return L.embed_tokens(params["embed"], batch["tokens"])
    if cfg.frontend == "codebooks":
        toks = batch["tokens"]                    # (B, S, K)
        x = L.embed_tokens(params["embed"], toks[..., 0])
        for c in range(1, cfg.n_codebooks):
            x = x + L.embed_tokens(params["codebook_embeds"][c - 1], toks[..., c])
        return x
    if cfg.frontend == "patches":
        x_txt = L.embed_tokens(params["embed"], batch["tokens"])   # (B, S_txt, d)
        x_img = batch["patch_embeds"].astype(x_txt.dtype)          # (B, P, d)
        return jnp.concatenate([x_img, x_txt], axis=1)
    raise ValueError(cfg.frontend)


def forward(params: dict, cfg: ModelConfig, batch: dict, return_hidden: bool = False):
    """Full-sequence forward -> (logits, aux_loss[, hidden]). Scan over periods."""
    x = _embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", None, "embed")
    aux_total = jnp.zeros((), jnp.float32)

    def apply_prefix_layer(p, x, i):
        return _apply_layer(p, x, cfg, *cfg.layer_spec(i))

    policy = (jax.checkpoint_policies.checkpoint_dots
              if cfg.remat_policy == "dots" else None)
    if cfg.remat:
        apply_prefix_layer = jax.checkpoint(apply_prefix_layer, static_argnums=(2,),
                                            policy=policy)
    for i, p in enumerate(params["prefix"]):
        x, aux = apply_prefix_layer(p, x, i)
        aux_total = aux_total + aux

    body = params["body"]
    if cfg.n_periods > 0:
        # Remat at the period boundary: backward saves only the (B,S,d) carry
        # per scanned period, recomputing layer internals (attention tiles,
        # MoE buffers) — THE memory policy that makes the big cells fit.
        def period_body(carry, stacked):
            x, aux_acc = carry
            for j in range(cfg.period):
                mixer, mlp = cfg.layer_spec(cfg.dense_prefix + j)
                x, aux = _apply_layer(stacked[j], x, cfg, mixer, mlp)
                aux_acc = aux_acc + aux
            return (x, aux_acc), None

        if cfg.remat:
            period_body = jax.checkpoint(period_body, policy=policy)
        if cfg.unroll_layers:
            carry = (x, aux_total)
            for r in range(cfg.n_periods):
                stacked_r = jax.tree.map(lambda t: t[r], tuple(body))
                carry, _ = period_body(carry, stacked_r)
            x, aux_total = carry
        else:
            (x, aux_total), _ = jax.lax.scan(
                period_body, (x, aux_total), tuple(body), length=cfg.n_periods)

    x = L.rms_norm(x, params["final_norm"]["scale"])
    logits = _head(params, cfg, x)
    if return_hidden:
        return logits, aux_total, x
    return logits, aux_total


def _head(params, cfg: ModelConfig, x):
    if cfg.frontend == "codebooks":
        tables = [params["embed"]["table"]] + [e["table"] for e in params.get("codebook_embeds", [])]
        logits = jnp.stack([x.astype(jnp.float32) @ t.astype(jnp.float32).T for t in tables], axis=2)
        return constrain(logits, "batch", None, None, "vocab")    # (B,S,K,V)
    return L.logits_from_embedding(params["embed"], x)


def mtp_logits(params: dict, cfg: ModelConfig, h: jax.Array, batch: dict):
    """DeepSeek-V3 MTP depth-1: predict token t+2 from (h_t, emb(tok_{t+1}))."""
    mtp = params["mtp"]
    toks = batch["tokens"]
    emb_next = L.embed_tokens(params["embed"], jnp.roll(toks, -1, axis=1))
    z = jnp.concatenate([L.rms_norm(h, mtp["norm"]["scale"]), emb_next], axis=-1)
    z = z @ mtp["proj"]
    z, _ = _apply_layer(mtp["layer"], z, cfg, "attn", "dense")
    return L.logits_from_embedding(params["embed"], z)


# ------------------------------------------------------------- serve path ---

def init_cache(params: dict, cfg: ModelConfig, batch_size: int, max_len: int):
    """Allocate per-layer caches (layout mirrors prefix/body stacking)."""
    def layer_cache(i, stacked: Optional[int]):
        mixer, _ = cfg.layer_spec(i)
        shape_pfx = (stacked,) if stacked else ()

        def z(shape, dtype):
            return jnp.zeros(shape_pfx + shape, dtype)

        if mixer == "attn":
            buf = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
            return attn.KVCache(
                k=z((batch_size, buf, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                v=z((batch_size, buf, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                pos=jnp.zeros(shape_pfx, jnp.int32))
        if mixer == "mla":
            return mla_mod.MLACache(
                c_kv=z((batch_size, max_len, cfg.mla.kv_lora_rank), cfg.dtype),
                k_rope=z((batch_size, max_len, cfg.mla.qk_rope_dim), cfg.dtype),
                pos=jnp.zeros(shape_pfx, jnp.int32))
        if mixer == "ssm":
            d_inner, H, conv_ch = ssm_mod._dims(cfg.d_model, cfg.ssm)
            return ssm_mod.SSMCache(
                conv=z((batch_size, cfg.ssm.d_conv - 1, conv_ch), cfg.dtype),
                h=z((batch_size, H, cfg.ssm.d_state, cfg.ssm.head_dim), jnp.float32))
        raise ValueError(mixer)

    caches = {"prefix": [layer_cache(i, None) for i in range(cfg.dense_prefix)],
              "body": [layer_cache(cfg.dense_prefix + j, cfg.n_periods)
                       for j in range(cfg.period)]}
    return caches


def _mixer_step(p, x, cache, cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        return attn.decode_step(p, x, cache, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                                rope_theta=cfg.rope_theta, window=cfg.swa_window)
    if mixer == "mla":
        return mla_mod.mla_decode_step(p, x, cache, cfg.mla, rope_theta=cfg.rope_theta)
    if mixer == "ssm":
        return ssm_mod.ssm_decode_step(p, x, cache, cfg.d_model, cfg.ssm)
    raise ValueError(mixer)


def _layer_step(p, x, cache, cfg: ModelConfig, mixer: str, mlp: str):
    h, cache = _mixer_step(p["mixer"], L.rms_norm(x, p["mixer_norm"]["scale"]), cache, cfg, mixer)
    x = x + h
    if mlp == "none":
        return x, cache
    hn = L.rms_norm(x, p["mlp_norm"]["scale"])
    if mlp == "dense":
        h2 = L.apply_mlp(p["mlp"], hn)
    else:
        h2, _ = moe_mod.apply_moe(p["mlp"], hn, cfg.moe)
    return x + h2, cache


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, caches: dict):
    """One-token decode. tokens (B,) or (B,K) for codebooks -> logits, caches."""
    if cfg.frontend == "codebooks":
        x = _embed_inputs(params, cfg, {"tokens": tokens[:, None, :]})
    else:  # "patches" decodes text tokens only (image is prefill-time)
        x = L.embed_tokens(params["embed"], tokens[:, None])
    new_prefix = []
    for i, p in enumerate(params["prefix"]):
        x, c = _layer_step(p, x, caches["prefix"][i], cfg, *cfg.layer_spec(i))
        new_prefix.append(c)

    new_body = list(caches["body"])
    if cfg.n_periods > 0:
        def period_body(x, stacked):
            ps, cs = stacked
            new_cs = []
            for j in range(cfg.period):
                mixer, mlp = cfg.layer_spec(cfg.dense_prefix + j)
                x, c = _layer_step(ps[j], x, cs[j], cfg, mixer, mlp)
                new_cs.append(c)
            return x, tuple(new_cs)

        if cfg.unroll_layers:
            ys = []
            for r in range(cfg.n_periods):
                sl = jax.tree.map(lambda t: t[r],
                                  (tuple(params["body"]), tuple(caches["body"])))
                x, y_r = period_body(x, sl)
                ys.append(y_r)
            new_body = list(jax.tree.map(lambda *l: jnp.stack(l), *ys))
        else:
            x, new_body = jax.lax.scan(
                period_body, x, (tuple(params["body"]), tuple(caches["body"])),
                length=cfg.n_periods)
            new_body = list(new_body)

    x = L.rms_norm(x, params["final_norm"]["scale"])
    logits = _head(params, cfg, x)
    return logits[:, 0], {"prefix": new_prefix, "body": new_body}


def prefill(params: dict, cfg: ModelConfig, batch: dict, max_len: int):
    """Prefill: full forward + cache build. Layer-by-layer with cache outputs."""
    x = _embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", None, "embed")
    B = x.shape[0]

    def layer_prefill(p, x, i):
        mixer, mlp = cfg.layer_spec(i)
        hn = L.rms_norm(x, p["mixer_norm"]["scale"])
        if mixer == "attn":
            buf = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
            h, c = attn.prefill(p["mixer"], hn, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                                rope_theta=cfg.rope_theta, window=cfg.swa_window,
                                cache_len=buf, dense_max=cfg.attn_dense_max)
        elif mixer == "mla":
            h, c = mla_mod.mla_prefill(p["mixer"], hn, cfg.mla,
                                       rope_theta=cfg.rope_theta, cache_len=max_len,
                                       dense_max=cfg.attn_dense_max)
        else:
            h, c = ssm_mod.ssm_forward(p["mixer"], hn, cfg.d_model, cfg.ssm, return_cache=True)
        x = x + h
        if mlp == "none":
            return x, c
        hn2 = L.rms_norm(x, p["mlp_norm"]["scale"])
        if mlp == "dense":
            h2 = L.apply_mlp(p["mlp"], hn2)
        else:
            h2, _ = moe_mod.apply_moe(p["mlp"], hn2, cfg.moe)
        return x + h2, c

    new_prefix = []
    for i, p in enumerate(params["prefix"]):
        x, c = layer_prefill(p, x, i)
        new_prefix.append(c)

    new_body = []
    if cfg.n_periods > 0:
        def period_body(x, ps):
            cs = []
            for j in range(cfg.period):
                x, c = layer_prefill(ps[j], x, cfg.dense_prefix + j)
                cs.append(c)
            return x, tuple(cs)

        if cfg.unroll_layers:
            ys = []
            for r in range(cfg.n_periods):
                sl = jax.tree.map(lambda t: t[r], tuple(params["body"]))
                x, y_r = period_body(x, sl)
                ys.append(y_r)
            new_body = list(jax.tree.map(lambda *l: jnp.stack(l), *ys))
        else:
            x, body_caches = jax.lax.scan(period_body, x, tuple(params["body"]),
                                          length=cfg.n_periods)
            new_body = list(body_caches)

    x = L.rms_norm(x, params["final_norm"]["scale"])
    logits = _head(params, cfg, x)
    return logits, {"prefix": new_prefix, "body": new_body}
