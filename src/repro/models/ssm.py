"""Mamba-2 SSD (state-space duality) block: chunked quadratic-within-chunk /
recurrent-across-chunks training form + O(1)-state decode form.

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;   y_t = C_t h_t + D x_t

Used by mamba2-130m and (as the SSM half) jamba. NOTE (DESIGN.md): Jamba's
paper uses Mamba-1 (S6) layers; we implement its SSM layers with the SSD
form — same state size/interleave structure, TPU-friendlier compute.
State math is f32 throughout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist import constrain
from repro.models.layers import rms_norm, init_rms_norm


class SSMConfig(NamedTuple):
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_channels) trailing inputs
    h: jax.Array      # (B, H, d_state, head_dim) f32 SSM state


def _dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    conv_ch = d_inner + 2 * cfg.n_groups * cfg.d_state
    return d_inner, n_heads, conv_ch


def init_ssm(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_inner, H, conv_ch = _dims(d_model, cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * cfg.n_groups * cfg.d_state + H
    return {
        "w_in": jax.random.normal(ks[0], (d_model, d_in_proj), dtype) * d_model ** -0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, conv_ch), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "d_skip": jnp.ones((H,), dtype),
        "norm": init_rms_norm(d_inner, dtype),
        "w_out": jax.random.normal(ks[2], (d_inner, d_model), dtype) * d_inner ** -0.5,
    }


def ssm_sharding(cfg: SSMConfig) -> dict:
    return {
        "w_in": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "dt_bias": (None,),
        "a_log": (None,),
        "d_skip": (None,),
        "norm": {"scale": ("ssm_inner",)},
        "w_out": ("ssm_inner", "embed"),
    }


def _split_in_proj(params, x, d_model, cfg: SSMConfig):
    d_inner, H, conv_ch = _dims(d_model, cfg)
    gds = cfg.n_groups * cfg.d_state
    zxbcdt = x @ params["w_in"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xbc, dt


def _causal_conv(params, xbc, cfg: SSMConfig):
    """Depthwise causal conv over (B,S,C) with kernel (d_conv, C)."""
    dc = cfg.d_conv
    pads = jnp.pad(xbc, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(pads[:, i: i + xbc.shape[1], :] * params["conv_w"][i] for i in range(dc))
    return jax.nn.silu(out + params["conv_b"])


def _ssd_scan(xh, a, dtv, Bm, Cm, cfg: SSMConfig):
    """Chunked SSD as one lax.scan over chunks: the (Q,Q) quadratic intra-chunk
    form, the chunk-state contraction and the inter-chunk carry all live
    inside the scan body, so peak memory is one chunk's tile regardless of S.

    xh (B,S,H,P); a = dt*A (B,S,H) log-decay <= 0; dtv (B,S,H);
    Bm/Cm (B,S,H,ds). Returns y (B,S,H,P) f32, final h (B,H,ds,P) f32."""
    Bsz, S, H, P = xh.shape
    ds = Bm.shape[-1]
    Q = min(cfg.chunk, S)
    nc = S // Q
    assert S % Q == 0, (S, Q)

    def r(t):  # (B,S,...) -> (nc,B,Q,...) scan-major
        return t.reshape(Bsz, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(r, (xh.astype(jnp.float32), a.astype(jnp.float32),
                       dtv.astype(jnp.float32),
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32))))
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_body(h, inp):
        x_c, a_c, dt_c, B_c, C_c = inp             # (B,Q,H,*) for this chunk
        L = jnp.cumsum(a_c, axis=1)                # (B,Q,H)
        # intra-chunk: M_ij = (C_i.B_j) exp(L_i - L_j) dt_j  (i >= j)
        scores = jnp.einsum("bqhd,bkhd->bhqk", C_c, B_c)
        decay = jnp.exp(jnp.clip(L[:, :, None, :] - L[:, None, :, :], -60, 0))
        M = scores * decay.transpose(0, 3, 1, 2) * dt_c.transpose(0, 2, 1)[:, :, None, :]
        M = jnp.where(mask[None, None], M, 0.0)
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", M, x_c)
        # inter-chunk: y_i += C_i exp(L_i) . h_prev
        y_inter = jnp.einsum("bqhd,bhdp->bqhp", C_c * jnp.exp(jnp.clip(L, -60, 0))[..., None], h)
        # state update: h = exp(sum a) h + sum_j exp(Lend - L_j) dt_j B_j (x) x_j
        Lend = L[:, -1:, :]
        w = jnp.exp(jnp.clip(Lend - L, -60, 0)) * dt_c
        S_c = jnp.einsum("bqh,bqhd,bqhp->bhdp", w, B_c, x_c)
        h_new = jnp.exp(jnp.clip(jnp.sum(a_c, axis=1), -60, 0))[:, :, None, None] * h + S_c
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, ds, P), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y, h_final


def ssm_forward(params: dict, x: jax.Array, d_model: int, cfg: SSMConfig,
                return_cache: bool = False):
    """Full-sequence Mamba-2 block (train / prefill)."""
    Bsz, S, _ = x.shape
    d_inner, H, conv_ch = _dims(d_model, cfg)
    gds = cfg.n_groups * cfg.d_state
    z, xbc, dt = _split_in_proj(params, x, d_model, cfg)
    xbc_c = _causal_conv(params, xbc, cfg)
    xc = xbc_c[..., :d_inner]
    Bm = xbc_c[..., d_inner: d_inner + gds].reshape(Bsz, S, cfg.n_groups, cfg.d_state)
    Cm = xbc_c[..., d_inner + gds:].reshape(Bsz, S, cfg.n_groups, cfg.d_state)
    rep = H // cfg.n_groups
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    a = dtv * A                                    # (B,S,H)
    xh = xc.reshape(Bsz, S, H, cfg.head_dim)
    xh = constrain(xh, "batch", None, "ssm_heads", None)
    y, h_final = _ssd_scan(xh, a, dtv, Bm, Cm, cfg)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = y @ params["w_out"]
    if not return_cache:
        return out
    conv_tail = xbc[:, S - (cfg.d_conv - 1):, :] if S >= cfg.d_conv - 1 else \
        jnp.pad(xbc, ((0, 0), (cfg.d_conv - 1 - S, 0), (0, 0)))
    return out, SSMCache(conv=conv_tail, h=h_final)


def ssm_decode_step(params: dict, x: jax.Array, cache: SSMCache, d_model: int,
                    cfg: SSMConfig):
    """One-token recurrent step. x (B,1,d)."""
    Bsz = x.shape[0]
    d_inner, H, conv_ch = _dims(d_model, cfg)
    gds = cfg.n_groups * cfg.d_state
    z, xbc, dt = _split_in_proj(params, x, d_model, cfg)      # (B,1,*)
    window = jnp.concatenate([cache.conv, xbc], axis=1)       # (B,d_conv,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    xbc_c = jax.nn.silu(conv_out)[:, None, :]
    xc = xbc_c[..., :d_inner]
    Bm = xbc_c[..., d_inner: d_inner + gds].reshape(Bsz, cfg.n_groups, cfg.d_state)
    Cm = xbc_c[..., d_inner + gds:].reshape(Bsz, cfg.n_groups, cfg.d_state)
    rep = H // cfg.n_groups
    Bm = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)      # (B,H,ds)
    Cm = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    dec = jnp.exp(dtv * A)                                    # (B,H)
    xh = xc[:, 0].reshape(Bsz, H, cfg.head_dim).astype(jnp.float32)
    h = dec[:, :, None, None] * cache.h + jnp.einsum("bh,bhd,bhp->bhdp", dtv, Bm, xh)
    y = jnp.einsum("bhd,bhdp->bhp", Cm, h)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = y @ params["w_out"]
    new_conv = jnp.concatenate([cache.conv[:, 1:], xbc], axis=1)
    return out, SSMCache(conv=new_conv, h=h)
