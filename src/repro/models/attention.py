"""GQA attention (optional QKV bias, sliding window) with train / prefill /
decode paths and a KV cache (rolling buffer under SWA).

Sharding: heads on the TP ("model") axis; KV cache layout is config-driven:
"heads" (default) or "seq" (split-KV decode for long contexts, SP-style)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import constrain
from repro.models.layers import apply_rope, rope_freqs


class KVCache(NamedTuple):
    k: jax.Array      # (B, S_buf, kv_heads, head_dim) — roped keys
    v: jax.Array      # (B, S_buf, kv_heads, head_dim)
    pos: jax.Array    # () int32: number of tokens already written


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "wq": jax.random.normal(kq, (d_model, n_heads, head_dim), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, n_kv_heads, head_dim), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, n_kv_heads, head_dim), dtype) * s,
        "wo": jax.random.normal(ko, (n_heads, head_dim, d_model), dtype) * (n_heads * head_dim) ** -0.5,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv_heads, head_dim), dtype)
    return p


def attention_sharding(qkv_bias: bool = False) -> dict:
    s = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if qkv_bias:
        s.update({"bq": ("heads", None), "bk": ("kv_heads", None), "bv": ("kv_heads", None)})
    return s


def _project_qkv(params: dict, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,kv,dh) -> (B,S,H,dh) by repeating each kv head H/kv times."""
    b, s, kv, dh = k.shape
    rep = n_heads // kv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _sdpa(q, k, v, mask, head_dim):
    """q (B,Sq,H,dh), k/v (B,Sk,H,dh), mask (1|B, 1, Sq, Sk) bool."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (head_dim ** -0.5)
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return constrain(out, "batch", None, "heads", None)


# Materialized-score SDPA is used below this many query positions; above it
# we run the online-softmax (flash-style) chunked path.
CHUNKED_THRESHOLD = 2048
CHUNK_Q = 1024
CHUNK_KV = 1024


def sdpa_chunked(q, k, v, *, scale: float, window: Optional[int] = None,
                 causal: bool = True,
                 chunk_q: int = CHUNK_Q, chunk_kv: int = CHUNK_KV):
    """Online-softmax attention: never materializes (Sq, Sk) scores.

    q (B,Sq,H,dh_qk), k (B,Sk,H,dh_qk), v (B,Sk,H,dh_v). Double lax.scan over
    query and KV chunks with running (m, l, o) accumulators — the standard
    flash-attention recurrence in pure JAX (the TPU kernel itself is XLA's
    job here; this bounds live memory to one (cq, ckv) score tile).
    Assumes q positions == arange(Sq), k positions == arange(Sk) (self-attn).
    """
    B, Sq, H, Dk = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Sk)
    assert Sq % cq == 0 and Sk % ckv == 0, (Sq, Sk, cq, ckv)
    nq, nk = Sq // cq, Sk // ckv

    qr = q.reshape(B, nq, cq, H, Dk)
    kr = k.reshape(B, nk, ckv, H, Dk)
    vr = v.reshape(B, nk, ckv, H, Dv)

    def q_block(carry, qi):
        q_c, iq = qi                                   # (B,cq,H,Dk), ()
        q_pos = iq * cq + jnp.arange(cq)

        def kv_block(acc, kvj):
            m, l, o = acc
            k_c, v_c, jk = kvj
            k_pos = jk * ckv + jnp.arange(ckv)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_c, k_c).astype(jnp.float32) * scale
            mask = jnp.ones((cq, ckv), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None], s, jnp.float32(-1e30))
            m_new = jnp.maximum(m, s.max(-1))          # (B,H,cq)
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_c.dtype), v_c).astype(jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        o0 = jnp.zeros((B, H, cq, Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_block, (m0, l0, o0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4), jnp.arange(nk)))
        out_c = (o / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3)  # (B,cq,H,Dv)
        return carry, out_c.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None,
                           (qr.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dv)
    return constrain(out, "batch", None, "heads", None)


def attend_full(params: dict, x: jax.Array, *, n_heads: int, head_dim: int,
                rope_theta: float, window: Optional[int] = None,
                positions: Optional[jax.Array] = None,
                dense_max: int = CHUNKED_THRESHOLD) -> jax.Array:
    """Training / prefill self-attention over the whole sequence (causal)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x)
    if positions is None:
        positions = jnp.arange(S)
    cos, sin = rope_freqs(head_dim, rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k = _repeat_kv(k, n_heads)
    v = _repeat_kv(v, n_heads)
    if S > dense_max:
        out = sdpa_chunked(q, k, v, scale=head_dim ** -0.5, window=window)
    else:
        i = positions[:, None]
        j = positions[None, :]
        mask = j <= i
        if window is not None:
            mask &= (i - j) < window
        out = _sdpa(q, k, v, mask[None, None], head_dim)
    return jnp.einsum("bqhd,hdm->bqm", out, params["wo"])


def prefill(params: dict, x: jax.Array, *, n_heads: int, head_dim: int,
            rope_theta: float, window: Optional[int] = None,
            cache_len: Optional[int] = None,
            dense_max: int = CHUNKED_THRESHOLD) -> tuple[jax.Array, KVCache]:
    """Full-sequence attention that also returns the KV cache (possibly a
    rolling buffer of size `window` when SWA is active)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x)
    positions = jnp.arange(S)
    cos, sin = rope_freqs(head_dim, rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kf = _repeat_kv(k, n_heads)
    vf = _repeat_kv(v, n_heads)
    if S > dense_max:
        out = sdpa_chunked(q, kf, vf, scale=head_dim ** -0.5, window=window)
    else:
        i = positions[:, None]
        j = positions[None, :]
        mask = j <= i
        if window is not None:
            mask &= (i - j) < window
        out = _sdpa(q, kf, vf, mask[None, None], head_dim)
    out = jnp.einsum("bqhd,hdm->bqm", out, params["wo"])

    buf = cache_len if cache_len is not None else S
    if window is not None:
        buf = min(buf, window)
    if buf >= S:
        pad = buf - S
        k_buf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_buf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:  # rolling buffer keeps the trailing `buf` positions at slot pos%buf
        tail_k = k[:, S - buf:]
        tail_v = v[:, S - buf:]
        shift = S % buf
        k_buf = jnp.roll(tail_k, shift, axis=1)
        v_buf = jnp.roll(tail_v, shift, axis=1)
    k_buf = constrain(k_buf, "batch", "seq_kv", "kv_heads", None)
    v_buf = constrain(v_buf, "batch", "seq_kv", "kv_heads", None)
    return out, KVCache(k=k_buf, v=v_buf, pos=jnp.asarray(S, jnp.int32))


def decode_step(params: dict, x: jax.Array, cache: KVCache, *, n_heads: int,
                head_dim: int, rope_theta: float,
                window: Optional[int] = None) -> tuple[jax.Array, KVCache]:
    """One-token decode: x (B, 1, d) against the cache."""
    B, one, _ = x.shape
    S_buf = cache.k.shape[1]
    pos = cache.pos
    q, k, v = _project_qkv(params, x)
    cos, sin = rope_freqs(head_dim, rope_theta, pos[None])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = jnp.minimum(pos, S_buf - 1) if window is None else pos % S_buf
    z = jnp.zeros((), slot.dtype)
    k_new = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (z, slot, z, z))
    v_new = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (z, slot, z, z))
    k_new = constrain(k_new, "batch", "seq_kv", "kv_heads", None)
    v_new = constrain(v_new, "batch", "seq_kv", "kv_heads", None)

    idx = jnp.arange(S_buf)
    if window is None:
        valid = idx <= pos
    else:
        valid = jnp.where(pos >= S_buf, jnp.ones((S_buf,), bool), idx <= pos)
    kf = _repeat_kv(k_new, n_heads)
    vf = _repeat_kv(v_new, n_heads)
    out = _sdpa(q, kf, vf, valid[None, None, None, :], head_dim)
    out = jnp.einsum("bqhd,hdm->bqm", out, params["wo"])
    return out, KVCache(k=k_new, v=v_new, pos=pos + 1)
