# Makes `python -m tools.reprolint` resolvable from the repo root.
