"""Rule registry: every lint rule registers itself here at import time.

A rule is a class with a `meta` (`RuleMeta`) describing its id, the
invariant it encodes, and its *default* path scope, plus a
``check(ctx) -> Iterable[RawFinding]`` generator over one parsed file
(`engine.FileContext`). Default scopes are repo conventions baked into
code; `pyproject.toml` ``[tool.reprolint.rules.<ID>]`` tables override
them per directory (see `config.py`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, Optional, Tuple


@dataclass(frozen=True)
class RawFinding:
    """A rule hit before scope/suppression filtering (file-relative)."""
    line: int
    col: int
    message: str


@dataclass(frozen=True)
class RuleMeta:
    id: str                      # e.g. "TRC001" — [A-Z]{3}\d{3}
    name: str                    # short kebab-case handle, e.g. "import-time-jnp"
    summary: str                 # one-line invariant statement
    #: path prefixes (posix, repo-relative) the rule lints by default;
    #: None = every linted file. Overridable from pyproject.toml.
    default_include: Optional[Tuple[str, ...]] = None
    default_exclude: Tuple[str, ...] = ()


class Rule:
    """Base class; subclasses set `meta` and implement `check`."""

    meta: RuleMeta

    def check(self, ctx) -> Iterable[RawFinding]:  # pragma: no cover - abstract
        raise NotImplementedError


_REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate and index the rule by id."""
    inst = cls()
    rid = inst.meta.id
    if rid in _REGISTRY:
        raise ValueError(f"duplicate rule id {rid}")
    _REGISTRY[rid] = inst
    return cls


def all_rules() -> Dict[str, Rule]:
    """id -> rule instance, importing the built-in rule battery on first use."""
    from . import rules  # noqa: F401  (registers on import)
    return dict(sorted(_REGISTRY.items()))
