"""Configuration: ``[tool.reprolint]`` in the project's pyproject.toml.

Two knobs, both path-based (posix, repo-relative prefixes or fnmatch
patterns):

    [tool.reprolint]
    exclude = ["generated"]              # never lint these paths at all

    [tool.reprolint.rules.COL001]
    exclude = ["src/repro/core/distributed.py"]   # audited collective sites

    [tool.reprolint.rules.TRC002]
    include = ["src/repro/core"]         # rule runs ONLY under these paths

A per-rule table *replaces* the key it sets and inherits the rule's
built-in default for the key it doesn't: setting only ``exclude`` keeps
the default ``include`` scope.

TOML loading prefers stdlib ``tomllib`` (3.11+), falls back to ``tomli``
(a pytest transitive dependency on 3.10, so present in every dev env),
and finally to a tiny subset parser that understands exactly the shapes
above — reprolint must stay runnable with zero installs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from fnmatch import fnmatch
from pathlib import Path
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class RuleOverride:
    """Per-rule scope override; None means "keep the rule's default"."""
    include: Optional[Tuple[str, ...]] = None
    exclude: Optional[Tuple[str, ...]] = None


@dataclass(frozen=True)
class LintConfig:
    exclude: Tuple[str, ...] = ()
    rules: Dict[str, RuleOverride] = field(default_factory=dict)


def _path_matches(relpath: str, pattern: str) -> bool:
    """Prefix match on path components, or fnmatch for glob patterns."""
    pattern = pattern.rstrip("/")
    if relpath == pattern or relpath.startswith(pattern + "/"):
        return True
    return fnmatch(relpath, pattern)


def path_excluded(cfg: LintConfig, relpath: str) -> bool:
    return any(_path_matches(relpath, p) for p in cfg.exclude)


def rule_applies(cfg: LintConfig, rule_meta, relpath: str) -> bool:
    """Does `rule_meta`'s scope (after config overrides) cover `relpath`?"""
    ov = cfg.rules.get(rule_meta.id, RuleOverride())
    include = ov.include if ov.include is not None else rule_meta.default_include
    exclude = ov.exclude if ov.exclude is not None else rule_meta.default_exclude
    if include is not None and not any(_path_matches(relpath, p) for p in include):
        return False
    return not any(_path_matches(relpath, p) for p in exclude)


def _load_toml(text: str) -> dict:
    try:
        import tomllib
        return tomllib.loads(text)
    except ModuleNotFoundError:
        pass
    try:
        import tomli
        return tomli.loads(text)
    except ModuleNotFoundError:
        pass
    return _parse_toml_subset(text)


def _parse_toml_subset(text: str) -> dict:
    """Last-resort parser for the flat table/str/list-of-str/bool subset
    reprolint's own config uses. NOT a general TOML parser."""
    doc: dict = {}
    table = doc
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = re.fullmatch(r"\[([^\]]+)\]", line)
        if m:
            table = doc
            for part in _split_table_key(m.group(1)):
                table = table.setdefault(part, {})
            continue
        if "=" not in line:
            continue
        key, _, val = line.partition("=")
        key = key.strip().strip('"')
        table[key] = _parse_value(val.strip())
    return doc


def _split_table_key(key: str):
    # handles bare keys and quoted dotted segments: a.b."c.d"
    return [p.strip().strip('"') for p in re.findall(r'"[^"]*"|[^.]+', key)]


def _parse_value(val: str):
    if val.startswith("["):
        return [v.strip().strip('"').strip("'")
                for v in val.strip("[]").split(",") if v.strip()]
    if val in ("true", "false"):
        return val == "true"
    return val.strip('"').strip("'")


def load_config(root: Path) -> LintConfig:
    """Read ``[tool.reprolint]`` from `root`/pyproject.toml (missing file
    or section -> all-defaults config)."""
    pyproject = root / "pyproject.toml"
    if not pyproject.is_file():
        return LintConfig()
    doc = _load_toml(pyproject.read_text(encoding="utf-8"))
    section = doc.get("tool", {}).get("reprolint", {})
    if not section:
        return LintConfig()
    rules = {}
    for rid, table in section.get("rules", {}).items():
        rules[rid] = RuleOverride(
            include=tuple(table["include"]) if "include" in table else None,
            exclude=tuple(table["exclude"]) if "exclude" in table else None)
    return LintConfig(exclude=tuple(section.get("exclude", ())), rules=rules)
