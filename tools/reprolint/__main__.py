"""CLI: ``python -m tools.reprolint [paths...] [options]``.

Exit codes: 0 clean, 1 findings, 2 usage/internal error (unparseable
files included — everything under lint must parse)."""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .config import LintConfig, load_config
from .engine import render_json, render_text, run_paths
from .registry import all_rules

DEFAULT_PATHS = ["src", "benchmarks", "tools"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="AST lint for this repo's trace/collective/sync/"
                    "atomicity invariants (DESIGN.md §13)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs relative to --root "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=None,
                    help="repo root (default: parent of tools/)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="also write a JSON report to this file "
                         "(CI artifact), regardless of --format")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--no-config", action="store_true",
                    help="ignore [tool.reprolint] in pyproject.toml")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in all_rules().items():
            scope = rule.meta.default_include or ("<all>",)
            print(f"{rid}  {rule.meta.name:28s} {rule.meta.summary} "
                  f"[{', '.join(scope)}]")
        print("SUP001  suppression-justification      suppression comments "
              "must carry '-- <reason>' [<all>]")
        return 0

    root = Path(args.root).resolve() if args.root else \
        Path(__file__).resolve().parent.parent.parent
    paths = args.paths or DEFAULT_PATHS
    select = tuple(s.strip() for s in args.select.split(",")) \
        if args.select else None
    cfg = LintConfig() if args.no_config else load_config(root)
    try:
        res = run_paths(root, paths, cfg, select)
    except SyntaxError as e:
        print(f"reprolint: cannot parse {e.filename}:{e.lineno}: {e.msg}",
              file=sys.stderr)
        return 2
    except OSError as e:
        print(f"reprolint: {e}", file=sys.stderr)
        return 2

    if args.output:
        Path(args.output).write_text(
            render_json(res, root=str(root), paths=list(paths)) + "\n",
            encoding="utf-8")
    print(render_json(res, root=str(root), paths=list(paths))
          if args.format == "json" else render_text(res))
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
