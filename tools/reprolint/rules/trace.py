"""Trace-safety rules (DESIGN.md §6/§13): the solver hot path compiles
ONCE per (shape, config); everything that silently retraces or runs device
work at import is a measured regression class (PR 2)."""
from __future__ import annotations

import ast

from ..registry import RawFinding, Rule, RuleMeta, register
from ._common import (is_device_work_call, jit_decorated, loop_bodies,
                      param_names)


@register
class ImportTimeDeviceWork(Rule):
    """TRC001: `jnp.*` (and device_put) calls evaluated at module import.

    Import-time device work allocates buffers / compiles before anyone
    chose a device or config, breaks JAX_PLATFORMS-late selection, and
    slows every CLI/test import. Flags module-level statements, class
    bodies, and function default arguments; `if __name__ == "__main__"`
    and `if TYPE_CHECKING` blocks stay exempt.
    """

    meta = RuleMeta(
        id="TRC001", name="import-time-jnp",
        summary="no jax.numpy/device work at module import time",
        default_include=("src", "benchmarks"))

    def check(self, ctx):
        for node in self._import_time_nodes(ctx.tree):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    name = ctx.resolve(sub.func)
                    if name and is_device_work_call(name):
                        yield RawFinding(
                            sub.lineno, sub.col_offset,
                            f"`{name}` runs device work at import time — "
                            "build arrays lazily inside the function that "
                            "uses them")

    def _import_time_nodes(self, tree):
        """Statements executed at import: module body (minus guarded ifs
        and def/class *bodies*), class bodies, and default-arg expressions."""
        for stmt in self._module_stmts(tree.body):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from stmt.args.defaults
                yield from (d for d in stmt.args.kw_defaults if d is not None)
            elif isinstance(stmt, ast.ClassDef):
                for sub in stmt.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        yield from sub.args.defaults
                        yield from (d for d in sub.args.kw_defaults
                                    if d is not None)
                    else:
                        yield sub
            else:
                yield stmt

    def _module_stmts(self, body):
        for stmt in body:
            if isinstance(stmt, ast.If):
                if not self._guarded(stmt):
                    yield from self._module_stmts(stmt.body + stmt.orelse)
            elif isinstance(stmt, (ast.Try, ast.With)):
                inner = list(getattr(stmt, "body", []))
                for h in getattr(stmt, "handlers", []):
                    inner.extend(h.body)
                inner.extend(getattr(stmt, "orelse", []))
                inner.extend(getattr(stmt, "finalbody", []))
                yield from self._module_stmts(inner)
            else:
                yield stmt

    def _guarded(self, stmt: ast.If) -> bool:
        src = ast.dump(stmt.test)
        return "__main__" in src or "TYPE_CHECKING" in src


@register
class PythonBranchOnTraced(Rule):
    """TRC002: Python control flow / scalar coercion on traced values.

    Inside traced code — jit-decorated functions and `while_loop` /
    `fori_loop` / `scan` bodies — `bool()`, `float()`, `int()`, `.item()`
    and `if`/`while` on operands force a device sync at trace time (or a
    TracerBoolConversionError). Branching on *static* jit args is legal
    and recognized via `static_argnames`/`static_argnums`.
    """

    meta = RuleMeta(
        id="TRC002", name="traced-python-branch",
        summary="no Python bool/if or scalar coercion on traced values in "
                "solver bodies",
        default_include=("src/repro/core",))

    _COERCERS = ("bool", "float", "int")

    def check(self, ctx):
        for fn, statics, _dec in jit_decorated(ctx):
            yield from self._scan(ctx, fn, set(param_names(fn)) - statics)
        for body, _call, loop in loop_bodies(ctx):
            yield from self._scan(ctx, body, set(param_names(body)),
                                  where=f"{loop.rsplit('.', 1)[-1]} body")

    def _scan(self, ctx, fn, traced_params, where="jit-compiled function"):
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for node in (n for stmt in body for n in ast.walk(stmt)):
            if isinstance(node, (ast.If, ast.While)):
                if not self._structure_check(node.test) and \
                        self._touches_traced(ctx, node.test, traced_params):
                    yield RawFinding(
                        node.lineno, node.col_offset,
                        f"Python `{'if' if isinstance(node, ast.If) else 'while'}`"
                        f" on a traced value inside a {where} — use lax.cond/"
                        "jnp.where, or mark the argument static")
            elif isinstance(node, ast.Call):
                fname = ctx.resolve(node.func)
                if fname in self._COERCERS and node.args and \
                        self._touches_traced(ctx, node.args[0], traced_params):
                    yield RawFinding(
                        node.lineno, node.col_offset,
                        f"`{fname}()` concretizes a traced value inside a "
                        f"{where} — keep it on-device (trace-once discipline, "
                        "DESIGN.md §6)")
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args and \
                        self._touches_traced(ctx, node.func.value, traced_params):
                    yield RawFinding(
                        node.lineno, node.col_offset,
                        f"`.item()` concretizes a traced value inside a {where}")

    def _structure_check(self, expr) -> bool:
        """`x is None` / `x is not None` (and not/and/or combinations)
        branch on pytree STRUCTURE, which is part of the jit key — legal
        Python control flow even on traced-argument names."""
        if isinstance(expr, ast.Compare):
            return all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops)
        if isinstance(expr, ast.BoolOp):
            return all(self._structure_check(v) for v in expr.values)
        if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.Not):
            return self._structure_check(expr.operand)
        return False

    def _touches_traced(self, ctx, expr, traced_params) -> bool:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Name) and sub.id in traced_params:
                return True
            if isinstance(sub, ast.Call):
                name = ctx.resolve(sub.func)
                if name and name.startswith("jax.numpy."):
                    return True
        return False


@register
class JitStaticConfig(Rule):
    """TRC003: jit boundaries must mark config-like params static.

    Passing an (unhashable, equality-keyed) config object as a traced arg
    either crashes at the boundary or — worse — retraces per call when the
    object is hashable but fresh each time. The repo convention since PR 2:
    `config` / `mesh` / `axes` style params are `static_argnames` at every
    jit boundary.
    """

    meta = RuleMeta(
        id="TRC003", name="jit-static-config",
        summary="jit-decorated functions mark config/mesh params static",
        default_include=("src",))

    _CONFIGY = ("config", "cfg", "mesh", "axes")

    def check(self, ctx):
        for fn, statics, dec in jit_decorated(ctx):
            missing = [p for p in param_names(fn)
                       if (p in self._CONFIGY or p.endswith("_config"))
                       and p not in statics]
            if missing:
                yield RawFinding(
                    dec.lineno, dec.col_offset,
                    f"jit boundary `{fn.name}` takes {missing} without "
                    "static treatment — add static_argnames (trace-once "
                    "discipline, DESIGN.md §6)")
