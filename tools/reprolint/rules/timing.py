"""Timing discipline (DESIGN.md §12.1, PR 9) — formerly tools/check_timing.py.

The serving runtime takes every timestamp through `repro.obs.clock`
(monotonic / monotonic_ns / walltime aliases): mixed clock sources are how
latency accounting silently breaks — a monotonic launch instant subtracted
from a walltime completion instant is garbage, and the bug only shows up
as impossible percentiles much later. The AST port no longer false-flags
clock mentions in comments/docstrings (the regex version did, by design;
the suppression mechanism replaces that bluntness)."""
from __future__ import annotations

from ..registry import RawFinding, Rule, RuleMeta, register

_BARE_CLOCKS = ("time.time", "time.time_ns", "time.perf_counter",
                "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns")


@register
class BareClockInRuntime(Rule):
    """TIM001: bare `time.*` clock reads inside src/repro/runtime/."""

    meta = RuleMeta(
        id="TIM001", name="bare-clock-in-runtime",
        summary="runtime/ reads clocks only via repro.obs.clock",
        default_include=("src/repro/runtime",))

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            if name in _BARE_CLOCKS:
                yield RawFinding(
                    call.lineno, call.col_offset,
                    f"bare `{name}()` in runtime/ — use the repro.obs.clock "
                    "aliases (monotonic/monotonic_ns/walltime) so the clock "
                    "choice stays auditable (DESIGN.md §12.1)")
