"""Built-in rule battery — importing this package registers every rule."""
from . import atomic, collectives, determinism, hostsync, timing, trace  # noqa: F401
