"""Shared AST helpers for the rule battery (jit/loop-body discovery)."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

#: calls that do device work when evaluated (import-time trap, TRC001)
_DEVICE_WORK_EXACT = ("jax.device_put", "jax.make_array_from_callback",
                      "jax.make_array_from_single_device_arrays")
#: jax.numpy attribute *references* (dtypes like jnp.float32) are fine;
#: only calls into the namespace allocate/compute.
_DEVICE_WORK_PREFIX = ("jax.numpy.",)


def is_device_work_call(name: str) -> bool:
    return name in _DEVICE_WORK_EXACT or \
        any(name.startswith(p) for p in _DEVICE_WORK_PREFIX)

#: cross-device collectives (COL001)
COLLECTIVES = ("jax.lax.psum", "jax.lax.pmean", "jax.lax.pmax", "jax.lax.pmin",
               "jax.lax.psum_scatter", "jax.lax.all_gather", "jax.lax.all_to_all",
               "jax.lax.ppermute", "jax.lax.pshuffle")

#: structured control flow: callable-argument index of the traced body
LOOP_BODY_ARG = {"jax.lax.while_loop": (1, "body_fun"),
                 "jax.lax.fori_loop": (2, "body_fun"),
                 "jax.lax.scan": (0, "f")}

_JIT_NAMES = ("jax.jit", "jax.pjit", "jax.experimental.pjit.pjit")


def is_jit_name(name: Optional[str]) -> bool:
    return name in _JIT_NAMES


def _static_names_from_call(call: ast.Call, params: List[str]) -> Set[str]:
    """static_argnames / static_argnums constants of a jit(...) call."""
    static: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    static.add(sub.value)
        elif kw.arg == "static_argnums":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
                    if 0 <= sub.value < len(params):
                        static.add(params[sub.value])
    return static


def param_names(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def jit_decorated(ctx) -> Iterator[Tuple[ast.FunctionDef, Set[str], ast.AST]]:
    """(function, static param names, decorator node) for every function
    decorated ``@jax.jit`` or ``@partial(jax.jit, ...)``."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            if is_jit_name(ctx.resolve(dec)):
                yield node, set(), dec
            elif isinstance(dec, ast.Call):
                fname = ctx.resolve(dec.func)
                if is_jit_name(fname):
                    yield node, _static_names_from_call(dec, param_names(node)), dec
                elif fname == "functools.partial" and dec.args and \
                        is_jit_name(ctx.resolve(dec.args[0])):
                    yield node, _static_names_from_call(dec, param_names(node)), dec


def _functions_by_name(ctx) -> Dict[str, List[ast.FunctionDef]]:
    out: Dict[str, List[ast.FunctionDef]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(node)
    return out


def loop_bodies(ctx) -> Iterator[Tuple[ast.AST, ast.Call, str]]:
    """(body function/lambda node, loop call, loop name) for every
    ``lax.while_loop`` / ``fori_loop`` / ``scan`` call whose traced-body
    argument is a lambda or a function defined in this module. Resolution
    is lexical by design: bodies passed through arbitrary indirection are
    out of reach, the audited-module excludes cover those."""
    by_name = _functions_by_name(ctx)
    for call in ctx.calls():
        fname = ctx.resolve(call.func)
        if fname not in LOOP_BODY_ARG:
            continue
        pos, kwname = LOOP_BODY_ARG[fname]
        body = None
        for kw in call.keywords:
            if kw.arg == kwname:
                body = kw.value
        if body is None and len(call.args) > pos:
            body = call.args[pos]
        if body is None:
            continue
        if isinstance(body, ast.Lambda):
            yield body, call, fname
        elif isinstance(body, ast.Name):
            for fn in by_name.get(body.id, []):
                yield fn, call, fname
