"""Atomic persistence + file-handle hygiene (DESIGN.md §10.2/§11.2, PR 7/8).

Every persistent-cache write in the repo goes tmp+rename (`os.replace`
after `tempfile.mkstemp`, or publish-by-`os.rename` of a staged dir):
concurrent processes must see old-or-new, never a torn file — the spill
tier treats ANY unreadable entry as corruption and deletes it, so a torn
write silently destroys a cache entry."""
from __future__ import annotations

import ast

from ..registry import RawFinding, Rule, RuleMeta, register

#: markers that the enclosing function stages writes atomically
_ATOMIC_MARKERS = ("os.replace", "os.rename", "tempfile.mkstemp",
                   "tempfile.NamedTemporaryFile", "tempfile.mkdtemp")

#: persistent-write call shapes
_NUMPY_WRITERS = ("numpy.save", "numpy.savez", "numpy.savez_compressed")


def _write_mode(call: ast.Call) -> bool:
    """Does this open()/os.fdopen() call use a writing mode?"""
    mode = None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        mode = call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    return isinstance(mode, str) and any(c in mode for c in "wax")


@register
class NonAtomicPersistentWrite(Rule):
    """ATM001: writes in persistence-owning modules without tmp+rename.

    Scope = the modules that own on-disk state (runtime cache/spill tier,
    kernels autotune winners, ckpt, utils disk caches). A write-mode
    `open`/`np.save*`/`write_text` whose enclosing function shows no
    atomic staging marker (mkstemp/NamedTemporaryFile/os.replace/os.rename)
    is flagged. Operator-requested export paths are legitimate
    exceptions — suppress them with the reason.
    """

    meta = RuleMeta(
        id="ATM001", name="non-atomic-persistent-write",
        summary="persistent-state writes go through tmp+rename",
        default_include=("src/repro/runtime", "src/repro/kernels",
                         "src/repro/ckpt", "src/repro/utils.py"))

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            is_write = False
            what = name
            if name in ("open", "os.fdopen") and _write_mode(call):
                is_write, what = True, f"{name}(mode='w')"
            elif name in _NUMPY_WRITERS:
                is_write = True
            elif isinstance(call.func, ast.Attribute) and \
                    call.func.attr in ("write_text", "write_bytes"):
                is_write, what = True, f".{call.func.attr}()"
            if not is_write:
                continue
            fn = ctx.enclosing_function(call)
            scope = fn if fn is not None else ctx.tree
            if not self._has_atomic_marker(ctx, scope):
                yield RawFinding(
                    call.lineno, call.col_offset,
                    f"`{what}` without tmp+rename in a persistence module — "
                    "stage via tempfile.mkstemp + os.replace (see "
                    "utils.disk_cache_update); suppress with a reason for "
                    "non-cache export paths")

    def _has_atomic_marker(self, ctx, scope) -> bool:
        for sub in ast.walk(scope):
            if isinstance(sub, (ast.Attribute, ast.Name)):
                if ctx.resolve(sub) in _ATOMIC_MARKERS:
                    return True
        return False


@register
class OpenWithoutContext(Rule):
    """RES001: `open()` outside a `with` (or explicit close).

    `json.load(open(path))` leaks the handle until GC — on CPython it
    usually works, until a spill-tier test runs on Windows-semantics or a
    long-lived server accumulates fds. Accepted shapes: `with open(...)`,
    `contextlib.closing(open(...))`, or assignment to a name that is
    `.close()`d in the same function.
    """

    meta = RuleMeta(
        id="RES001", name="open-without-context",
        summary="file handles are opened under a context manager")

    def check(self, ctx):
        for call in ctx.calls():
            if ctx.resolve(call.func) not in ("open", "os.fdopen"):
                continue
            if self._managed(ctx, call):
                continue
            yield RawFinding(
                call.lineno, call.col_offset,
                "`open()` without a context manager leaks the handle — "
                "use `with open(...) as f:`")

    def _managed(self, ctx, call) -> bool:
        parent = ctx.parent(call)
        # with open(...) as f:   (withitem's context_expr)
        if isinstance(parent, ast.withitem) and parent.context_expr is call:
            return True
        # contextlib.closing(open(...)) / io wrapper directly under `with`
        if isinstance(parent, ast.Call):
            gp = ctx.parent(parent)
            if isinstance(gp, ast.withitem) and gp.context_expr is parent:
                return True
        # f = open(...) ... f.close()  in the same function
        if isinstance(parent, (ast.Assign, ast.AnnAssign)):
            targets = parent.targets if isinstance(parent, ast.Assign) \
                else [parent.target]
            names = {t.id for t in targets if isinstance(t, ast.Name)}
            fn = ctx.enclosing_function(call) or ctx.tree
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "close" and \
                        isinstance(sub.func.value, ast.Name) and \
                        sub.func.value.id in names:
                    return True
        return False
