"""Determinism rules: every random stream in the library is seeded and
injectable. Global-RNG draws make solver parity runs (the <=1e-10 gates in
validate_artifact) unreproducible, and time-seeded RNGs make CI flakes
undiagnosable."""
from __future__ import annotations

import ast

from ..registry import RawFinding, Rule, RuleMeta, register

#: numpy.random entry points that are NOT the legacy global stream
_NP_RANDOM_OK = ("numpy.random.default_rng", "numpy.random.Generator",
                 "numpy.random.SeedSequence", "numpy.random.PCG64",
                 "numpy.random.PCG64DXSM", "numpy.random.Philox",
                 "numpy.random.MT19937", "numpy.random.RandomState")

_TIME_SOURCES = ("time.time", "time.time_ns", "time.perf_counter",
                 "time.perf_counter_ns", "time.monotonic",
                 "time.monotonic_ns")

_SEEDED_CTORS = ("numpy.random.default_rng", "numpy.random.SeedSequence",
                 "random.Random", "jax.random.PRNGKey", "jax.random.key")


@register
class GlobalRng(Rule):
    """DET001: draws from the process-global RNG.

    `np.random.rand(...)`-style legacy calls and stdlib `random.*` share
    hidden global state across tests/benchmarks; the repo idiom is a
    seeded `np.random.default_rng(seed)` (or `jax.random.key`) passed down
    explicitly.
    """

    meta = RuleMeta(
        id="DET001", name="global-rng",
        summary="no process-global RNG draws (np.random legacy / random.*)",
        default_include=("src", "benchmarks"))

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            if not name:
                continue
            if name.startswith("numpy.random.") and name not in _NP_RANDOM_OK:
                yield RawFinding(
                    call.lineno, call.col_offset,
                    f"`{name}` draws from the global numpy RNG — use a "
                    "seeded np.random.default_rng(seed) passed explicitly")
            elif name.startswith("random.") and name != "random.Random":
                yield RawFinding(
                    call.lineno, call.col_offset,
                    f"`{name}` draws from the global stdlib RNG — use a "
                    "seeded generator object")


@register
class UnseededRng(Rule):
    """DET002: RNG constructed without a seed, or seeded from the clock.

    `default_rng()` (OS entropy) and `default_rng(int(time.time()))` both
    make a run unrepeatable; seeds are explicit constants or flow from
    config/args.
    """

    meta = RuleMeta(
        id="DET002", name="unseeded-rng",
        summary="RNGs take explicit, non-clock seeds",
        default_include=("src", "benchmarks"))

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            if name not in _SEEDED_CTORS:
                continue
            if not call.args and not call.keywords:
                yield RawFinding(
                    call.lineno, call.col_offset,
                    f"`{name}()` without a seed is entropy-seeded — pass an "
                    "explicit seed so runs replay")
                continue
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call) and \
                            ctx.resolve(sub.func) in _TIME_SOURCES:
                        yield RawFinding(
                            call.lineno, call.col_offset,
                            f"`{name}` seeded from the clock — a replayed "
                            "run gets a different stream; use an explicit "
                            "seed")
