"""Collective discipline (DESIGN.md §9, PR 5).

The measured trap: a collective per loop iteration. A partitioner-sharded
vmapped while_loop all-reduces EVERY iteration — ~60x slower than the
fan-out that keeps lanes independent (PR 5, BENCH_path.json dist_solve).
The audited counterexamples (CG with one psum per matvec in
core/distributed.py, the pipeline's per-tick ppermute) are excluded by
pyproject scoping or carry inline justifications."""
from __future__ import annotations

import ast

from ..registry import RawFinding, Rule, RuleMeta, register
from ._common import COLLECTIVES, loop_bodies


@register
class CollectiveInLoopBody(Rule):
    """COL001: psum/all_gather/ppermute lexically inside a
    while_loop/fori_loop/scan body."""

    meta = RuleMeta(
        id="COL001", name="collective-in-loop-body",
        summary="no collectives inside lax loop bodies outside audited "
                "modules (~60x trap, PR 5)",
        # core/distributed.py is the audited home of per-iteration
        # collectives (one psum per CG matvec, priced by the router);
        # the repo pyproject also lists it, this default keeps fixture
        # runs faithful without a config.
        default_exclude=("src/repro/core/distributed.py",))

    def check(self, ctx):
        seen = set()
        for body, loop_call, loop_name in loop_bodies(ctx):
            for sub in ast.walk(body):
                if isinstance(sub, ast.Call):
                    cname = ctx.resolve(sub.func)
                    if cname in COLLECTIVES and id(sub) not in seen:
                        seen.add(id(sub))
                        yield RawFinding(
                            sub.lineno, sub.col_offset,
                            f"`{cname.rsplit('.', 1)[-1]}` inside a "
                            f"`{loop_name.rsplit('.', 1)[-1]}` body (line "
                            f"{loop_call.lineno}) pays one all-reduce per "
                            "iteration — hoist it, or justify the schedule "
                            "with a suppression (measured ~60x, DESIGN.md §9)")


@register
class ShardMapNeedsMesh(Rule):
    """COL002: `shard_map` without an explicit mesh.

    Mesh-less shard_map falls back to ambient/abstract-mesh context; the
    repo's routing layer prices meshes explicitly, so every shard_map call
    names the mesh it spans (positionally or `mesh=`).
    """

    meta = RuleMeta(
        id="COL002", name="shardmap-needs-mesh",
        summary="shard_map always passes its mesh explicitly")

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            if not name or not name.endswith("shard_map"):
                continue
            has_mesh = (len(call.args) >= 2
                        or any(kw.arg == "mesh" for kw in call.keywords))
            if not has_mesh:
                yield RawFinding(
                    call.lineno, call.col_offset,
                    "`shard_map` without an explicit mesh argument — name "
                    "the mesh (routing prices it; DESIGN.md §9.5)")
