"""Host-sync discipline in the serving runtime (DESIGN.md §8, PR 4).

The scheduler hot path stages batches with NUMPY ONLY and blocks exactly
once per bucket chunk, at harvest. Any other device->host sync serializes
the async dispatch pipeline — per-request `jnp` staging was the measured
bottleneck PR 4 removed."""
from __future__ import annotations

import ast

from ..registry import RawFinding, Rule, RuleMeta, register

_RUNTIME = ("src/repro/runtime",)


@register
class HostSyncInRuntime(Rule):
    """SYN001: device->host syncs in the runtime outside harvest.

    Flags `.item()`, `jax.device_get`, and `float()`/`int()`/`bool()`/
    `np.asarray()` applied *directly* to a `jnp.*` call result — each one
    blocks on the device from scheduler code that must stay async.
    (Device-ness of arbitrary names is undecidable statically; syncs on
    harvested buffers after the sanctioned block are fine and unflagged.)
    """

    meta = RuleMeta(
        id="SYN001", name="host-sync-in-runtime",
        summary="no .item()/device_get/scalar-coercion syncs in runtime/",
        default_include=_RUNTIME)

    _COERCERS = ("float", "int", "bool", "numpy.asarray", "numpy.array")

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            if name == "jax.device_get":
                yield RawFinding(call.lineno, call.col_offset,
                                 "`jax.device_get` syncs the device in "
                                 "runtime/ — harvest via the sanctioned "
                                 "block_until_ready site instead")
            elif isinstance(call.func, ast.Attribute) and \
                    call.func.attr == "item" and not call.args:
                yield RawFinding(call.lineno, call.col_offset,
                                 "`.item()` forces a device sync in "
                                 "runtime/ — stage with numpy, harvest once "
                                 "per bucket")
            elif name in self._COERCERS and call.args and \
                    self._is_jnp_call(ctx, call.args[0]):
                yield RawFinding(call.lineno, call.col_offset,
                                 f"`{name}()` on a jnp result syncs the "
                                 "device in runtime/ (numpy-only host "
                                 "staging, DESIGN.md §8)")

    def _is_jnp_call(self, ctx, expr) -> bool:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                n = ctx.resolve(sub.func)
                if n and n.startswith("jax.numpy."):
                    return True
        return False


@register
class UnsanctionedBlock(Rule):
    """SYN002: `block_until_ready` outside the sanctioned harvest sites.

    The runtime blocks exactly once per bucket chunk — at harvest
    (`scheduler._harvest`, suppressed there with justification). Every
    additional block point hides queue time inside service time and
    un-overlaps dispatch.
    """

    meta = RuleMeta(
        id="SYN002", name="unsanctioned-block",
        summary="block_until_ready only at the audited harvest site",
        default_include=_RUNTIME)

    def check(self, ctx):
        for call in ctx.calls():
            name = ctx.resolve(call.func)
            is_block = (name == "jax.block_until_ready"
                        or (isinstance(call.func, ast.Attribute)
                            and call.func.attr == "block_until_ready"))
            if is_block:
                yield RawFinding(
                    call.lineno, call.col_offset,
                    "`block_until_ready` outside the sanctioned harvest "
                    "site — the runtime blocks once per bucket chunk "
                    "(suppress with justification if this IS a harvest "
                    "site)")
