"""Lint engine: parse, shared AST services, suppressions, runner, output.

`FileContext` is the shared visitor infrastructure every rule builds on:

  - ``resolve(node)``   canonical dotted name of a Name/Attribute chain with
                        import aliases folded in — ``jnp.zeros(...)`` and
                        ``jax.numpy.zeros(...)`` both resolve to
                        ``"jax.numpy.zeros"``, ``from jax import lax`` makes
                        ``lax.psum`` resolve to ``"jax.lax.psum"``.
  - ``parent(node)``    lazily-built child -> parent map over the tree.
  - ``calls()``         every ``ast.Call`` in the file.
  - ``enclosing_function(node)``  nearest FunctionDef/AsyncFunctionDef/Lambda.

Suppressions are line comments::

    x = risky()  # reprolint: disable=ATM001 -- export path, not a cache tier

A suppression on its own line applies to the next line. The justification
after ``--`` is MANDATORY: a bare ``# reprolint: disable=X`` is itself a
finding (SUP001) — the repo's contract is that every suppression records
*why* the invariant does not apply at that site.

Exit-code contract (see `__main__`): 0 clean, 1 findings, 2 internal/usage
error (including unparseable files — everything under lint must parse).
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from .config import LintConfig, path_excluded, rule_applies
from .registry import all_rules

SCHEMA_VERSION = 1

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Za-z0-9_, ]+?)\s*(?:--\s*(\S.*))?$")


@dataclass(frozen=True)
class Finding:
    path: str        # posix repo-relative
    line: int        # 1-based
    col: int         # 0-based
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclass(frozen=True)
class Suppression:
    line: int                 # line the comment PHYSICALLY sits on
    applies_to: int           # line whose findings it silences
    rules: Tuple[str, ...]
    reason: Optional[str]


class FileContext:
    """One parsed file plus the shared AST services rules lean on."""

    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        self.aliases = self._collect_aliases()
        self._parents: Optional[Dict[ast.AST, ast.AST]] = None

    # -- import-alias resolution -------------------------------------------
    def _collect_aliases(self) -> Dict[str, str]:
        amap: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    amap[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    amap[a.asname or a.name] = f"{node.module}.{a.name}"
        return amap

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name for a Name/Attribute chain, else None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(self.aliases.get(node.id, node.id))
        return ".".join(reversed(parts))

    # -- tree services ------------------------------------------------------
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        if self._parents is None:
            self._parents = {}
            for p in ast.walk(self.tree):
                for c in ast.iter_child_nodes(p):
                    self._parents[c] = p
        return self._parents.get(node)

    def calls(self) -> Iterable[ast.Call]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                yield node

    def enclosing_function(self, node: ast.AST):
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                return cur
            cur = self.parent(cur)
        return None

    def contains_call_to(self, node: ast.AST, prefixes: Tuple[str, ...]) -> bool:
        """True when `node`'s subtree calls any dotted name matching the
        prefixes (exact id, or `prefix.*` for entries ending in '.')."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = self.resolve(sub.func)
                if name and _name_matches(name, prefixes):
                    return True
        return False

    # -- suppressions -------------------------------------------------------
    def _comment_lines(self) -> List[Tuple[int, str, bool]]:
        """(line, comment text, standalone?) for every REAL comment token —
        tokenize, not string matching, so a directive quoted inside a
        docstring is documentation, not a live suppression."""
        import io
        import tokenize
        out = []
        try:
            for tok in tokenize.generate_tokens(io.StringIO(self.source).readline):
                if tok.type == tokenize.COMMENT:
                    line = tok.start[0]
                    standalone = self.lines[line - 1].lstrip().startswith("#")
                    out.append((line, tok.string, standalone))
        except tokenize.TokenError:  # pragma: no cover - tree already parsed
            pass
        return out

    def suppressions(self) -> List[Suppression]:
        out = []
        for lineno, text, standalone in self._comment_lines():
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            applies_to = lineno
            if standalone:
                # a standalone directive covers the next CODE line, so the
                # justification may continue over further comment lines
                applies_to = lineno + 1
                while applies_to <= len(self.lines) and (
                        not self.lines[applies_to - 1].strip()
                        or self.lines[applies_to - 1].lstrip().startswith("#")):
                    applies_to += 1
            out.append(Suppression(
                line=lineno,
                applies_to=applies_to,
                rules=rules,
                reason=(m.group(2) or "").strip() or None))
        return out


def _name_matches(name: str, prefixes: Tuple[str, ...]) -> bool:
    for p in prefixes:
        if p.endswith("."):
            if name.startswith(p):
                return True
        elif name == p:
            return True
    return False


# ---------------------------------------------------------------------------
# running
# ---------------------------------------------------------------------------

@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    suppressions: List[Tuple[str, Suppression]] = field(default_factory=list)
    files_scanned: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return dict(sorted(out.items()))


def lint_source(source: str, relpath: str, cfg: LintConfig = LintConfig(),
                select: Optional[Tuple[str, ...]] = None) -> LintResult:
    """Lint one in-memory file. `relpath` drives rule scoping, so fixture
    tests can place a snippet "inside" src/repro/runtime/ without touching
    disk."""
    res = LintResult(files_scanned=1)
    if path_excluded(cfg, relpath):
        return res
    ctx = FileContext(relpath, source)
    sups = ctx.suppressions()
    raw: List[Finding] = []
    for rid, rule in all_rules().items():
        if select is not None and rid not in select:
            continue
        if not rule_applies(cfg, rule.meta, relpath):
            continue
        for hit in rule.check(ctx):
            raw.append(Finding(relpath, hit.line, hit.col, rid, hit.message))
    # SUP001 is framework-level: a suppression with no justification.
    if select is None or "SUP001" in select:
        for s in sups:
            if s.reason is None:
                raw.append(Finding(
                    relpath, s.line, 0, "SUP001",
                    "suppression without justification — append "
                    "'-- <why this site is exempt>'"))
    for f in sorted(raw, key=lambda f: (f.line, f.col, f.rule)):
        silenced = any(s.applies_to == f.line and f.rule in s.rules
                       for s in sups)
        (res.suppressed if silenced else res.findings).append(f)
    res.suppressions = [(relpath, s) for s in sups]
    return res


def iter_py_files(root: Path, paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        target = (root / p) if not Path(p).is_absolute() else Path(p)
        if target.is_file() and target.suffix == ".py":
            out.append(target)
        elif target.is_dir():
            out.extend(f for f in sorted(target.rglob("*.py"))
                       if not any(part.startswith(".") for part in
                                  f.relative_to(root).parts))
    return out


def run_paths(root: Path, paths: Iterable[str], cfg: LintConfig,
              select: Optional[Tuple[str, ...]] = None) -> LintResult:
    total = LintResult()
    for f in iter_py_files(root, paths):
        relpath = f.relative_to(root).as_posix()
        if path_excluded(cfg, relpath):
            continue
        one = lint_source(f.read_text(encoding="utf-8"), relpath, cfg, select)
        total.findings.extend(one.findings)
        total.suppressed.extend(one.suppressed)
        total.suppressions.extend(one.suppressions)
        total.files_scanned += 1
    return total


# ---------------------------------------------------------------------------
# output
# ---------------------------------------------------------------------------

def render_text(res: LintResult) -> str:
    lines = [f.render() for f in res.findings]
    counts = res.counts()
    if counts:
        summary = ", ".join(f"{k}: {v}" for k, v in counts.items())
        lines.append(f"reprolint: {len(res.findings)} finding(s) "
                     f"[{summary}] in {res.files_scanned} file(s)")
    else:
        lines.append(f"reprolint: OK ({res.files_scanned} file(s), "
                     f"{len(res.suppressed)} suppressed)")
    return "\n".join(lines)


def render_json(res: LintResult, *, root: str, paths: List[str]) -> str:
    rules = {rid: r.meta.summary for rid, r in all_rules().items()}
    rules["SUP001"] = "suppression comments must carry a justification"
    doc = {
        "version": SCHEMA_VERSION,
        "tool": "reprolint",
        "root": root,
        "paths": paths,
        "rules": rules,
        "files_scanned": res.files_scanned,
        "ok": res.ok,
        "counts": res.counts(),
        "findings": [{"path": f.path, "line": f.line, "col": f.col,
                      "rule": f.rule, "message": f.message}
                     for f in res.findings],
        "suppressed": [{"path": f.path, "line": f.line, "rule": f.rule}
                       for f in res.suppressed],
        "suppressions": [{"path": p, "line": s.line, "rules": list(s.rules),
                          "reason": s.reason}
                         for p, s in res.suppressions],
    }
    return json.dumps(doc, indent=1, sort_keys=True)
