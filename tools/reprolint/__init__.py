"""reprolint — AST-based static analysis enforcing this repo's measured
invariants (DESIGN.md §13).

The rules encode discipline that earlier PRs established the hard way:
trace-once jit boundaries (PR 2), numpy-only host staging + single-block
harvest in the runtime (PR 4), the ~60x collective-per-iteration trap
(PR 5), tmp+rename atomic cache writes (PR 7/8), and obs.clock timing
(PR 9). Run ``python -m tools.reprolint src benchmarks tools``.
"""
from .config import LintConfig, RuleOverride, load_config
from .engine import (Finding, LintResult, lint_source, render_json,
                     render_text, run_paths)
from .registry import all_rules

__version__ = "1.0"

__all__ = ["LintConfig", "RuleOverride", "load_config", "Finding",
           "LintResult", "lint_source", "render_json", "render_text",
           "run_paths", "all_rules", "__version__"]
