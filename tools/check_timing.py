#!/usr/bin/env python
"""Timing-discipline lint (DESIGN.md §12.1): the serving runtime must take
every timestamp through `repro.obs.clock`.

Rejects bare ``time.time()`` / ``time.perf_counter()`` /
``time.perf_counter_ns()`` call sites inside ``src/repro/runtime/`` — mixed
clock sources are how latency accounting silently breaks (a monotonic
launch instant subtracted from a walltime completion instant is garbage,
and the bug only shows up as impossible percentiles much later).
``time.sleep`` and the `obs` aliases themselves stay legal; `repro/obs/`
is where the aliases live and is excluded by construction.

Usage: ``python tools/check_timing.py`` — exits 1 and prints offending
lines when the discipline is violated. Wired into CI and `tests/test_obs.py`.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

#: bare-clock call sites; `time.sleep`, `time.monotonic` via obs aliases etc.
#: are matched narrowly on purpose — this lint pins CLOCK READS only.
_PATTERN = re.compile(r"\btime\.(time|perf_counter)(_ns)?\s*\(")

#: runtime files allowed to say "time.<clock>" in comments/docstrings only —
#: none currently; the regex intentionally also flags strings/comments so
#: the rule stays greppable and zero-config.
_SCOPE = "src/repro/runtime"


def find_violations(root: Path) -> list:
    out = []
    for path in sorted((root / _SCOPE).rglob("*.py")):
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if _PATTERN.search(line):
                out.append((path.relative_to(root), lineno, line.strip()))
    return out


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    violations = find_violations(root)
    for path, lineno, line in violations:
        print(f"{path}:{lineno}: bare clock call (use repro.obs.clock): "
              f"{line}")
    if violations:
        print(f"check_timing: {len(violations)} violation(s) in {_SCOPE}/")
        return 1
    print(f"check_timing: OK ({_SCOPE}/ reads clocks via repro.obs.clock)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
