#!/usr/bin/env python
"""DEPRECATED shim: the timing-discipline lint is now reprolint rule TIM001.

Use ``python -m tools.reprolint src --select TIM001`` (or just run the full
suite). This entry point and `find_violations` stay for callers of the PR 9
interface; both delegate to the AST-based rule, which — unlike the old
regex — no longer flags clock mentions inside comments or docstrings.
"""
from __future__ import annotations

import sys
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent
if str(_REPO_ROOT) not in sys.path:  # script/`import check_timing` runs
    sys.path.insert(0, str(_REPO_ROOT))

from tools.reprolint import LintConfig, run_paths  # noqa: E402

_SCOPE = "src/repro/runtime"


def find_violations(root: Path) -> list:
    """PR 9-compatible surface: [(relpath, lineno, source line), ...]."""
    res = run_paths(Path(root), [_SCOPE], LintConfig(), select=("TIM001",))
    out = []
    for f in res.findings:
        line = (Path(root) / f.path).read_text().splitlines()[f.line - 1]
        out.append((Path(f.path), f.line, line.strip()))
    return out


def main() -> int:
    print("check_timing: deprecated — running `python -m tools.reprolint "
          f"{_SCOPE} --select TIM001` instead", file=sys.stderr)
    violations = find_violations(_REPO_ROOT)
    for path, lineno, line in violations:
        print(f"{path}:{lineno}: bare clock call (use repro.obs.clock): "
              f"{line}")
    if violations:
        print(f"check_timing: {len(violations)} violation(s) in {_SCOPE}/")
        return 1
    print(f"check_timing: OK ({_SCOPE}/ reads clocks via repro.obs.clock)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
