"""Shared benchmark utilities: timing, CSV emission, regime-matched problem
suites standing in for the paper's 12 datasets (offline container)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import make_regression

jax.config.update("jax_enable_x64", True)


def time_call(fn, *args, reps: int = 3, **kw) -> float:
    """Best-of wall time in seconds (after one warmup for jit)."""
    out = fn(*args, **kw)
    jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best


def time_interleaved(fn_a, fn_b, reps: int = 8):
    """Best-of wall times for two rivals measured in ALTERNATING reps.

    Interleaving makes both sides sample the same machine state (thermal
    drift, background load), which matters when the artifact gates on their
    ratio and the true margin is tens of percent.
    """
    block = lambda out: jax.tree.map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    block(fn_a())
    block(fn_b())
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        block(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        block(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


# The paper's p >> n suite (8 datasets: GLI-85 .. E2006) — regime-matched
# synthetic stand-ins (n, p, correlation) scaled for CPU wall-time.
PGGN_SUITE = {
    "gli85_like": dict(n=85, p=4000, rho=0.5),
    "smk_can_like": dict(n=187, p=3000, rho=0.4),
    "gla_bra_like": dict(n=180, p=3500, rho=0.4),
    "arcene_like": dict(n=100, p=5000, rho=0.3),
    "dorothea_like": dict(n=160, p=6000, rho=0.1),
    "scene15_like": dict(n=200, p=2500, rho=0.3),
    "pems_like": dict(n=120, p=2000, rho=0.6),
    "e2006_like": dict(n=150, p=4500, rho=0.2),
}

# n >> p suite (4 datasets: MITFaces, Yahoo-LTR, YearPredictionMSD, FD)
NGGP_SUITE = {
    "mitfaces_like": dict(n=6000, p=150, rho=0.4),
    "yahoo_ltr_like": dict(n=8000, p=120, rho=0.3),
    "ymsd_like": dict(n=10000, p=90, rho=0.2),
    "fd_like": dict(n=7000, p=200, rho=0.5),
}


def make_suite_problem(spec: dict, seed: int = 0):
    X, y, _ = make_regression(spec["n"], spec["p"], k_true=max(5, spec["p"] // 100),
                              rho=spec["rho"], noise=0.3, seed=seed)
    return X, y


def path_settings(X, y, lam2: float, n_points: int):
    """(lambda1, t) settings along the CD regularization path — mirrors the
    paper's protocol of reading t = |beta*|_1 off the glmnet path."""
    from repro.baselines import elastic_net_cd
    from repro.core.elastic_net import lambda1_max
    l1max = float(lambda1_max(X, y))
    settings = []
    beta = None
    for frac in np.geomspace(0.7, 0.08, n_points):
        res = elastic_net_cd(X, y, float(frac * l1max), lam2, beta0=beta)
        beta = res.beta
        t = float(jnp.sum(jnp.abs(beta)))
        if t > 1e-8:
            settings.append((float(frac * l1max), t, beta))
    return settings
