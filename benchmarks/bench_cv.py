"""CV throughput: the fold-chunked (fold x lambda) scan — one compiled
executable driving the fold machines — against the glmnet-shaped sequential
per-fold dispatch loop, plus refit parity against the coordinate-descent
baseline at the selected lambda.

The fold chunk is right-sized per backend (`core.cv._auto_fold_chunk`): on a
single CPU device the k-wide vmap advances every fold at the MAX trip count
of its nested while_loops (Illinois x Newton x CG lockstep) and ran ~0.6x
the sequential loop; chunk=1 keeps the whole surface in ONE executable with
no lockstep and beats the host loop, which is what ships in the artifact —
`validate_artifact.py` flags any speedup < 1. The full-width vmap is still
timed (`cv_vmap_seconds`) to track the lockstep cost the accelerator path
trades against. Returns a dict that benchmarks/run.py serializes into
BENCH_path.json (CI smoke-checks the schema)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_call, time_interleaved
from repro.baselines import elastic_net_cd
from repro.core import cross_validate, cross_validate_reference, cv_folds
from repro.core import reset_trace_counts, trace_counts
from repro.core.api import PathConfig, _enet_path_scan, lambda_grid
from repro.core.cv import _auto_fold_chunk, _enet_cv_scan
from repro.data.synthetic import make_regression


def run(k: int = 5, n_lambdas: int = 16) -> dict:
    # make_regression output is already standardized/centered, so the raw
    # paper-scaled problem is what both CV drivers and CD see.
    X, y, _ = make_regression(120, 32, k_true=8, rho=0.4, seed=11)
    kw = dict(k=k, n_lambdas=n_lambdas, lambda2=1.0,
              standardize=False, fit_intercept=False)

    reset_trace_counts()
    res = cross_validate(X, y, **kw)
    traces = trace_counts()

    # apples-to-apples fold batching: the auto-chunked (fold x lambda) scan
    # as ONE executable vs the glmnet-shaped per-fold dispatch loop (both
    # jit-warm, same splits/grid; selection + refit excluded from both)
    cfg = PathConfig()
    # resolved placement = single device here (the bench times the
    # un-sharded scan); _auto_fold_chunk requires it spelled out
    chunk = _auto_fold_chunk(k, None)
    grid = lambda_grid(X, y, n_lambdas=n_lambdas)
    Xtr, ytr, Xva, yva = cv_folds(X, y, k)
    def batched_scan():
        return _enet_cv_scan(Xtr, ytr, Xva, yva, grid, 1.0, cfg, chunk)

    def per_fold_loop():
        return [_enet_path_scan(Xtr[i], ytr[i], grid, 1.0, cfg).beta
                for i in range(k)]

    # the chunked-vs-loop margin is real but ~1.1-1.3x on CPU, so the two
    # sides are timed INTERLEAVED (alternating reps, best-of-8 each): they
    # see the same machine state, keeping drift and scheduler noise off the
    # speedup >= 1 gate in validate_artifact.py
    t_batched, t_seq = time_interleaved(batched_scan, per_fold_loop, reps=8)
    t_vmap = time_call(
        lambda: _enet_cv_scan(Xtr, ytr, Xva, yva, grid, 1.0, cfg, k))

    _, mse_ref = cross_validate_reference(X, y, **kw)
    mse_dev = float(jnp.max(jnp.abs(res.mse_path - mse_ref)))
    beta_cd = elastic_net_cd(X, y, res.lambda_min, 1.0).beta
    cd_dev = float(jnp.max(jnp.abs(res.beta - beta_cd)))

    emit("cv_batched_vs_sequential", t_batched,
         f"k={k} L={n_lambdas} chunk={chunk} seq={t_seq*1e6:.1f}us "
         f"vmap={t_vmap*1e6:.1f}us "
         f"speedup={t_seq / max(t_batched, 1e-12):.2f}x "
         f"max_dev_vs_cd={cd_dev:.2e}")

    return {
        "k": k,
        "n_lambdas": n_lambdas,
        "fold_chunk": chunk,
        "cv_batched_seconds": t_batched,
        "cv_vmap_seconds": t_vmap,
        "cv_sequential_seconds": t_seq,
        "cv_batched_vs_sequential_speedup": t_seq / max(t_batched, 1e-12),
        "max_dev_vs_cd": cd_dev,
        "mse_dev_vs_reference": mse_dev,
        "cv_scan_traces": traces.get("enet_cv_scan", 0),
        "refit_traces": traces.get("enet", 0),
        "lambda_min": float(res.lambda_min),
    }


if __name__ == "__main__":
    print(run())
