"""CV throughput: the batched (fold x lambda) scan — one compiled executable
driving K warm-started solver machines in lockstep — against the glmnet-shaped
sequential per-fold loop, plus refit parity against the coordinate-descent
baseline at the selected lambda. Returns a dict that benchmarks/run.py
serializes into BENCH_path.json (CI smoke-checks the schema)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.baselines import elastic_net_cd
from repro.core import cross_validate, cross_validate_reference, cv_folds
from repro.core import reset_trace_counts, trace_counts
from repro.core.api import PathConfig, _enet_path_scan, lambda_grid
from repro.core.cv import _enet_cv_scan
from repro.data.synthetic import make_regression


def run(k: int = 5, n_lambdas: int = 16) -> dict:
    # make_regression output is already standardized/centered, so the raw
    # paper-scaled problem is what both CV drivers and CD see.
    X, y, _ = make_regression(120, 32, k_true=8, rho=0.4, seed=11)
    kw = dict(k=k, n_lambdas=n_lambdas, lambda2=1.0,
              standardize=False, fit_intercept=False)

    reset_trace_counts()
    res = cross_validate(X, y, **kw)
    traces = trace_counts()

    # apples-to-apples fold batching: the (fold x lambda) scan as ONE vmapped
    # executable vs the glmnet-shaped per-fold dispatch loop (both jit-warm,
    # same splits/grid; selection + refit excluded from both sides)
    cfg = PathConfig()
    grid = lambda_grid(X, y, n_lambdas=n_lambdas)
    Xtr, ytr, Xva, yva = cv_folds(X, y, k)
    t_batched = time_call(
        lambda: _enet_cv_scan(Xtr, ytr, Xva, yva, grid, 1.0, cfg))

    def per_fold_loop():
        return [_enet_path_scan(Xtr[i], ytr[i], grid, 1.0, cfg).beta
                for i in range(k)]

    t_seq = time_call(per_fold_loop)

    _, mse_ref = cross_validate_reference(X, y, **kw)
    mse_dev = float(jnp.max(jnp.abs(res.mse_path - mse_ref)))
    beta_cd = elastic_net_cd(X, y, res.lambda_min, 1.0).beta
    cd_dev = float(jnp.max(jnp.abs(res.beta - beta_cd)))

    emit("cv_batched_vs_sequential", t_batched,
         f"k={k} L={n_lambdas} seq={t_seq*1e6:.1f}us "
         f"speedup={t_seq / max(t_batched, 1e-12):.2f}x "
         f"max_dev_vs_cd={cd_dev:.2e}")

    return {
        "k": k,
        "n_lambdas": n_lambdas,
        "cv_batched_seconds": t_batched,
        "cv_sequential_seconds": t_seq,
        "cv_batched_vs_sequential_speedup": t_seq / max(t_batched, 1e-12),
        "max_dev_vs_cd": cd_dev,
        "mse_dev_vs_reference": mse_dev,
        "cv_scan_traces": traces.get("enet_cv_scan", 0),
        "refit_traces": traces.get("enet", 0),
        "lambda_min": float(res.lambda_min),
    }


if __name__ == "__main__":
    print(run())
