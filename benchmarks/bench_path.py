"""Figure 1: regularization paths of CD (glmnet stand-in) and SVEN coincide
point-for-point on the prostate-like dataset — plus the engine claim: the
scan-compiled `sven_path` beats the per-point Python loop and traces exactly
once for the whole grid. Returns a dict that benchmarks/run.py serializes to
BENCH_path.json (CI smoke-checks it)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call, path_settings
from repro.core import (reset_trace_counts, sven_path, sven_path_reference,
                        trace_counts)
from repro.data.synthetic import prostate_like


def run(points: int = 12) -> dict:
    X, y, _ = prostate_like()
    lam2 = 0.5
    settings = path_settings(X, y, lam2=lam2, n_points=points)
    ts = jnp.asarray([t for _, t, _ in settings], X.dtype)
    betas_cd = jnp.stack([b for _, _, b in settings])

    # scan-compiled path: one trace for the whole grid
    reset_trace_counts()
    betas_scan = sven_path(X, y, ts, lam2)
    sven_path(X, y, ts * 0.999, lam2)  # same shape, new values: must not retrace
    traces = trace_counts()
    t_scan = time_call(lambda: sven_path(X, y, ts, lam2))

    # reference host loop (same warm-start semantics), per-point dispatch
    betas_loop = sven_path_reference(X, y, ts, lam2)
    t_loop = time_call(lambda: sven_path_reference(X, y, ts, lam2))

    max_dev_cd = float(jnp.max(jnp.abs(betas_scan - betas_cd)))
    scan_loop_dev = float(jnp.max(jnp.abs(betas_scan - betas_loop)))
    n_pts = len(settings)

    emit("fig1_path_match", t_scan / n_pts,
         f"max|beta_sven-beta_cd|={max_dev_cd:.2e} over {n_pts} path points")
    emit("path_scan_vs_loop", t_scan,
         f"loop={t_loop*1e6:.1f}us speedup={t_loop / max(t_scan, 1e-12):.2f}x "
         f"scan_traces={traces.get('sven_path_scan', 0)}")

    return {
        "n_points": n_pts,
        "scan_seconds": t_scan,
        "loop_seconds": t_loop,
        "scan_vs_loop_speedup": t_loop / max(t_scan, 1e-12),
        "scan_trace_count": traces.get("sven_path_scan", 0),
        "retraced_on_new_grid_values": traces.get("sven_path_scan", 0) > 1,
        "max_dev_vs_cd": max_dev_cd,
        "scan_vs_loop_dev": scan_loop_dev,
    }


if __name__ == "__main__":
    print(run())
