"""Figure 1: regularization paths of CD (glmnet stand-in) and SVEN coincide
point-for-point on the prostate-like dataset; reports max path deviation and
per-point solve time."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call, path_settings
from repro.core import sven, SvenConfig
from repro.data.synthetic import prostate_like


def run():
    X, y, _ = prostate_like()
    settings = path_settings(X, y, lam2=0.5, n_points=12)
    max_dev = 0.0
    total_t = 0.0
    for l1, t, beta_cd in settings:
        sol = sven(X, y, t, 0.5)
        max_dev = max(max_dev, float(jnp.max(jnp.abs(sol.beta - beta_cd))))
        total_t += time_call(lambda: sven(X, y, t, 0.5), reps=1)
    emit("fig1_path_match", total_t / len(settings),
         f"max|beta_sven-beta_cd|={max_dev:.2e} over {len(settings)} path points")


if __name__ == "__main__":
    run()
