"""Benchmark harness — one module per paper table/figure plus framework
micro-benches. Prints ``name,us_per_call,derived`` CSV lines and writes the
path-engine artifact ``BENCH_path.json`` (scan-vs-loop wall clock, trace
counts, batch-vs-sequential speedup, CV throughput, serving runtime
latency/throughput, per-backend kernel timings/parity, telemetry overhead
and accounting) whenever the ``path``/``batch``/``cv``/``serve``/
``dist_solve``/``kernels``/``multihost``/``obs`` benches run — CI
validates the artifact schema on CPU via
``benchmarks/validate_artifact.py``.

    PYTHONPATH=src python -m benchmarks.run [--quick] \
        [--only path,batch,cv,serve]
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

ARTIFACT = "BENCH_path.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer path points")
    ap.add_argument("--only", default="", help="comma list of module suffixes")
    ap.add_argument("--artifact", default=ARTIFACT,
                    help="where to write the path/batch JSON artifact")
    args = ap.parse_args()

    from benchmarks import (bench_batch, bench_crossover, bench_cv,
                            bench_dist_solve, bench_distributed,
                            bench_kernels, bench_lm_smoke, bench_nggp,
                            bench_obs, bench_path, bench_pggn,
                            bench_reduction_ops, bench_serve)

    mods = {
        "path": (lambda: bench_path.run(points=6)) if args.quick else bench_path.run,
        "batch": (lambda: bench_batch.run(B=4)) if args.quick else bench_batch.run,
        "cv": (lambda: bench_cv.run(k=4, n_lambdas=8)) if args.quick else bench_cv.run,
        # quick serve uses 32 requests / best-of-3: at 24/2 the sustained
        # ratio sits too close to the 2x gate once the LatencyRecorder fix
        # sped the synchronous reference up — more warm requests amortize
        # the runtime's fixed per-pass costs and de-flake the gate.
        "serve": ((lambda: bench_serve.run(requests=32, reps=3))
                  if args.quick else bench_serve.run),
        "multihost": ((lambda: bench_serve.run_multihost(requests=16))
                      if args.quick else bench_serve.run_multihost),
        # quick obs keeps the full 32-request / best-of-7 measurement: the
        # extra passes are ~20ms each and the 1.10x overhead gate jitters
        # on fewer reps; only the multihost leg is trimmed.
        "obs": ((lambda: bench_obs.run(requests=32, mh_requests=6))
                if args.quick else bench_obs.run),
        "dist_solve": ((lambda: bench_dist_solve.run(n=384, p=32, reps=2))
                       if args.quick else bench_dist_solve.run),
        "kernels": ((lambda: bench_kernels.run(n=384, p=32, reps=2))
                    if args.quick else bench_kernels.run),
        "reduction_ops": bench_reduction_ops.run,
        "crossover": bench_crossover.run,
        "pggn": (lambda: bench_pggn.run(points=2)) if args.quick else bench_pggn.run,
        "nggp": (lambda: bench_nggp.run(points=2)) if args.quick else bench_nggp.run,
        "distributed": bench_distributed.run,
        "lm_smoke": bench_lm_smoke.run,
    }
    picked = [s for s in args.only.split(",") if s] or list(mods)
    print("name,us_per_call,derived")
    failures = 0
    artifact: dict = {}
    for name in picked:
        try:
            out = mods[name]()
            if (name in ("path", "batch", "cv", "serve", "dist_solve",
                         "kernels", "multihost", "obs")
                    and isinstance(out, dict)):
                artifact[name] = out
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    if artifact:
        with open(args.artifact, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"# wrote {args.artifact}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
