"""Benchmark harness — one module per paper table/figure plus framework
micro-benches. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer path points")
    ap.add_argument("--only", default="", help="comma list of module suffixes")
    args = ap.parse_args()

    from benchmarks import (bench_crossover, bench_distributed, bench_lm_smoke,
                            bench_nggp, bench_path, bench_pggn,
                            bench_reduction_ops)

    mods = {
        "path": bench_path.run,
        "reduction_ops": bench_reduction_ops.run,
        "crossover": bench_crossover.run,
        "pggn": (lambda: bench_pggn.run(points=2)) if args.quick else bench_pggn.run,
        "nggp": (lambda: bench_nggp.run(points=2)) if args.quick else bench_nggp.run,
        "distributed": bench_distributed.run,
        "lm_smoke": bench_lm_smoke.run,
    }
    picked = [s for s in args.only.split(",") if s] or list(mods)
    print("name,us_per_call,derived")
    failures = 0
    for name in picked:
        try:
            mods[name]()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
