"""Figure 3: n >> p comparison. SVEN's dual time is dominated by the one-off
kernel build, so per-setting time is ~constant in (t, lambda2) — the paper's
'vertical line' effect. We amortize the Gram across the path (warm-started
dual) and report per-solve times + speedups."""
from __future__ import annotations

import numpy as np

from benchmarks.common import NGGP_SUITE, emit, make_suite_problem, path_settings, time_call
from repro.baselines import elastic_net_cd, elastic_net_fista, elastic_net_shotgun
from repro.core import sven, SvenConfig

LAM2 = 1.0
POINTS = 3


def run(points: int = POINTS):
    cfg = SvenConfig(tol=1e-7)
    for name, spec in NGGP_SUITE.items():
        X, y = make_suite_problem(spec)
        settings = path_settings(X, y, LAM2, points)
        t_sven, t_cd, t_fista, t_sg = [], [], [], []
        for l1, t, beta_cd in settings:
            t_sven.append(time_call(lambda: sven(X, y, t, LAM2, cfg), reps=1))
            t_cd.append(time_call(lambda: elastic_net_cd(X, y, l1, LAM2), reps=1))
            t_fista.append(time_call(lambda: elastic_net_fista(X, y, l1, LAM2), reps=1))
            t_sg.append(time_call(
                lambda: elastic_net_shotgun(X, y, l1, LAM2, parallel=64), reps=1))
        s, c, f, g = map(np.mean, (t_sven, t_cd, t_fista, t_sg))
        emit(f"fig3_{name}", s,
             f"speedup_vs_cd={c / s:.1f}x fista={f / s:.1f}x shotgun={g / s:.1f}x "
             f"time_spread={np.std(t_sven) / max(np.mean(t_sven), 1e-12):.2f} "
             f"n={spec['n']} p={spec['p']}")


if __name__ == "__main__":
    run()
