"""§3 time-complexity claims: primal cost tracks n, dual cost tracks p; the
2p > n dispatch rule picks the faster side. Sweeps the aspect ratio at fixed
n*p and times both modes + the auto choice."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import sven, SvenConfig
from repro.core.elastic_net import lambda1_max
from repro.baselines import elastic_net_cd
from repro.data.synthetic import make_regression

BUDGET = 600_000  # n * p


def run():
    for n in (100, 300, 800, 2000, 6000):
        p = BUDGET // n
        X, y, _ = make_regression(n, p, k_true=min(20, p // 4), rho=0.3, seed=1)
        l1 = 0.3 * float(lambda1_max(X, y))
        beta = elastic_net_cd(X, y, l1, 1.0).beta
        t = float(jnp.sum(jnp.abs(beta)))
        if t <= 0:
            continue
        tp = time_call(lambda: sven(X, y, t, 1.0, SvenConfig(mode="primal")), reps=1)
        td = time_call(lambda: sven(X, y, t, 1.0, SvenConfig(mode="dual")), reps=1)
        auto_mode = "primal" if 2 * p > n else "dual"
        t_auto = tp if auto_mode == "primal" else td
        correct = (tp <= td) == (auto_mode == "primal") or abs(tp - td) / max(tp, td) < 0.3
        emit(f"crossover_n{n}_p{p}", t_auto,
             f"primal={tp * 1e3:.1f}ms dual={td * 1e3:.1f}ms auto={auto_mode} "
             f"dispatch_near_optimal={correct}")


if __name__ == "__main__":
    run()
