"""Batch-vs-sequential multi-problem solves: one vmapped `sven_batch`
executable against a Python loop of per-problem `sven` dispatches (both
jit-warm), over a (t, lambda2) grid sharing one design matrix and over
stacked CV folds — the Rgtsvm-style claim that batching small solves is
where accelerator SVM throughput comes from."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import cv_folds, en_grid, sven, sven_batch
from repro.data.synthetic import make_regression


def run(B: int = 8) -> dict:
    X, y, _ = make_regression(120, 24, k_true=6, rho=0.3, seed=3)
    t_scale = 0.3 * float(jnp.sum(jnp.abs(X.T @ y))) / X.shape[0]
    ts, l2s = en_grid(jnp.linspace(0.3, 1.0, B // 2) * t_scale, jnp.array([0.5, 2.0]))

    t_batch = time_call(lambda: sven_batch(X, y, ts, l2s))

    def sequential():
        return [sven(X, y, float(ts[i]), float(l2s[i])).beta for i in range(ts.shape[0])]

    t_seq = time_call(sequential)
    sol = sven_batch(X, y, ts, l2s)
    dev = max(float(jnp.abs(sol.beta[i] - sven(X, y, float(ts[i]), float(l2s[i])).beta).max())
              for i in range(ts.shape[0]))
    emit("batch_grid_vs_sequential", t_batch,
         f"B={int(ts.shape[0])} seq={t_seq*1e6:.1f}us "
         f"speedup={t_seq / max(t_batch, 1e-12):.2f}x max_dev={dev:.2e}")

    # stacked CV folds (batched X AND y)
    Xtr, ytr, _, _ = cv_folds(X, y, 6)
    t_folds = time_call(lambda: sven_batch(Xtr, ytr, t_scale, 1.0))
    emit("batch_cv_folds", t_folds, f"k=6 n_tr={int(Xtr.shape[1])}")

    return {
        "grid_B": int(ts.shape[0]),
        "batch_seconds": t_batch,
        "sequential_seconds": t_seq,
        "batch_vs_sequential_speedup": t_seq / max(t_batch, 1e-12),
        "max_dev_vs_sequential": dev,
        "cv_folds_seconds": t_folds,
    }


if __name__ == "__main__":
    print(run())
