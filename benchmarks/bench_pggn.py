"""Figure 2: p >> n training-time comparison. For each of the 8 regime-matched
datasets and settings along the path: SVEN (primal Newton-CG) vs coordinate
descent (glmnet stand-in), FISTA (L1_LS stand-in), Shotgun. Reports per-solve
time + speedup of SVEN over each baseline (the paper's markers-vs-diagonal)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PGGN_SUITE, emit, make_suite_problem, path_settings, time_call
from repro.baselines import elastic_net_cd, elastic_net_fista, elastic_net_shotgun
from repro.core import sven, SvenConfig

LAM2 = 1.0
POINTS = 4


def run(points: int = POINTS):
    cfg = SvenConfig(tol=1e-7)
    for name, spec in PGGN_SUITE.items():
        X, y = make_suite_problem(spec)
        settings = path_settings(X, y, LAM2, points)
        t_sven, t_cd, t_fista, t_sg = [], [], [], []
        for l1, t, beta_cd in settings:
            t_sven.append(time_call(lambda: sven(X, y, t, LAM2, cfg), reps=1))
            t_cd.append(time_call(lambda: elastic_net_cd(X, y, l1, LAM2), reps=1))
            t_fista.append(time_call(lambda: elastic_net_fista(X, y, l1, LAM2), reps=1))
            t_sg.append(time_call(
                lambda: elastic_net_shotgun(X, y, l1, LAM2, parallel=128), reps=1))
        s, c, f, g = map(np.mean, (t_sven, t_cd, t_fista, t_sg))
        emit(f"fig2_{name}", s,
             f"speedup_vs_cd={c / s:.1f}x fista={f / s:.1f}x shotgun={g / s:.1f}x "
             f"n={spec['n']} p={spec['p']} pts={len(settings)}")


if __name__ == "__main__":
    run()
