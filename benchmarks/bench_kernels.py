"""Per-backend kernel micro-bench — the ``kernels`` section of BENCH_path.json.

Times the fused shifted-Gram and hinge-stats kernels through every registered
Pallas body (TPU and GPU/Triton bodies run in interpret mode on CPU hosts,
compiled natively when the matching accelerator is present) against the
jitted pure-jnp oracle, records the autotuned tile choice, and runs the
bf16-storage + iterative-refinement solve probe. ``validate_artifact.py``
gates the section:

  - CPU runners:  every measured body at interpret-mode parity with the
    oracle (relative deviation <= 1e-4, i.e. f32 accumulation roundoff);
  - GPU runners:  fused gram >= 1.5x over the unfused
    materialize-then-matmul reference (interpret timing is pathological,
    so no speed gate on CPU);
  - everywhere:   the bf16+refinement dual solve within 1e-10 of the
    full-precision solve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call


def _rel_dev(a, b) -> float:
    scale = max(1.0, float(jnp.max(jnp.abs(b))))
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float64) -
                                jnp.asarray(b, jnp.float64)))) / scale


@jax.jit
def _unfused_gram(X, y, t):
    """Materialize-then-matmul reference: build Zhat (n, 2p) explicitly and
    take one big Gram — what the fused kernel's one-pass 4-quadrant identity
    (and its GPU >= 1.5x gate) is measured against."""
    yt = y[:, None] / t
    Z = jnp.concatenate([X - yt, -(X + yt)], axis=1)
    return Z.T @ Z


def run(n: int = 768, p: int = 64, reps: int = 3) -> dict:
    from repro.core.sven import SvenConfig, sven
    from repro.kernels import autotune, ops, registry

    platform = jax.default_backend()
    resolved = registry.resolve_kernel_backend(None)
    _, interp = registry.split_backend(resolved)

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    t, C = 1.7, 0.5

    # Bodies measured: always the oracle and both Pallas bodies (interpret
    # mode off-accelerator), plus the compiled resolved backend on hardware.
    backends = ["ref", "tpu_interpret", "gpu_interpret"]
    if not interp and resolved != "ref":
        backends.append(resolved)

    tiles = {}
    for op in ("shifted_gram", "hinge_stats"):
        chosen, source = autotune.resolve_tiles(op, resolved, n, p)
        tiles[op] = {"tiles": chosen, "source": source}

    K_ref = ops.shifted_gram(X, y, t, backend="ref")
    stats_ref = ops.hinge_stats(X, y, t, w, C, backend="ref")

    gram_seconds, hinge_seconds = {}, {}
    gram_parity, hinge_parity = {}, {}
    for be in backends:
        K = ops.shifted_gram(X, y, t, backend=be)
        gram_parity[be] = _rel_dev(K, K_ref)
        gram_seconds[be] = time_call(
            lambda be=be: ops.shifted_gram(X, y, t, backend=be), reps=reps)
        st = ops.hinge_stats(X, y, t, w, C, backend=be)
        hinge_parity[be] = max(_rel_dev(a, b) for a, b in zip(st, stats_ref))
        hinge_seconds[be] = time_call(
            lambda be=be: ops.hinge_stats(X, y, t, w, C, backend=be),
            reps=reps)
        emit(f"kernels_gram_{be}", gram_seconds[be],
             f"rel_dev={gram_parity[be]:.1e}")
        emit(f"kernels_hinge_stats_{be}", hinge_seconds[be],
             f"rel_dev={hinge_parity[be]:.1e}")

    unfused_s = time_call(_unfused_gram, X, y, jnp.asarray(t, X.dtype),
                          reps=reps)
    unfused_parity = _rel_dev(_unfused_gram(X, y, jnp.asarray(t, X.dtype)),
                              K_ref)
    emit("kernels_gram_unfused", unfused_s, f"rel_dev={unfused_parity:.1e}")

    # bf16 storage + one full-precision refinement re-solve vs the plain
    # XLA solve, both driven to tol=1e-12 on the same dual problem.
    nn, pp = 256, 24
    Xs = jnp.asarray(rng.standard_normal((nn, pp)) / np.sqrt(nn))
    ys = jnp.asarray(rng.standard_normal((nn,)))
    ts = 1.3
    beta_ref = sven(Xs, ys, ts, 0.5,
                    SvenConfig(mode="dual", backend="xla", tol=1e-12)).beta
    beta_bf16 = sven(Xs, ys, ts, 0.5,
                     SvenConfig(mode="dual", backend=resolved,
                                precision="bf16", tol=1e-12)).beta
    bf16_dev = float(jnp.max(jnp.abs(beta_bf16 - beta_ref)))
    emit("kernels_bf16_refined", 0.0, f"max_dev={bf16_dev:.1e}")

    measured_parities = (list(gram_parity.values())
                         + list(hinge_parity.values()) + [unfused_parity])
    parity_ok = max(measured_parities) <= 1e-4
    on_gpu = platform in ("gpu", "cuda", "rocm")
    gpu_speedup = (unfused_s / gram_seconds[resolved]
                   if on_gpu and resolved in gram_seconds else None)
    speedup_ok = None if gpu_speedup is None else bool(gpu_speedup >= 1.5)
    kernels_ok = bool(parity_ok and bf16_dev <= 1e-10
                      and speedup_ok is not False)

    return {
        "platform": platform,
        "kernel_backend": resolved,
        "n": n,
        "p": p,
        "tiles": tiles,
        "gram_seconds": gram_seconds,
        "hinge_stats_seconds": hinge_seconds,
        "unfused_gram_seconds": unfused_s,
        "gram_parity_rel": gram_parity,
        "hinge_parity_rel": hinge_parity,
        "unfused_parity_rel": unfused_parity,
        "bf16_refined_max_dev": bf16_dev,
        "gpu_speedup": gpu_speedup,
        "parity_ok": bool(parity_ok),
        "speedup_ok": speedup_ok,
        "kernels_ok": kernels_ok,
    }


if __name__ == "__main__":
    print("name,us_per_call,derived")
    import json

    print(json.dumps(run(), indent=2, sort_keys=True))
