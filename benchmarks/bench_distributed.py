"""Distributed SVEN scaling check (§Discussion's 'distributed systems' row):
runs the shard_map gram + primal solve on a simulated 8-device host mesh in
a subprocess (the bench process itself keeps the real single device) and
reports correctness + timing vs the single-device path."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = textwrap.dedent("""
    import os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, numpy as np, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro.core.distributed import (distributed_gram, distributed_gram_rs,
                                        sven_primal_distributed)
    from repro.core.reduction import gram_blocks
    from repro.data.synthetic import make_regression

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    X, y, _ = make_regression(4096, 256, seed=0)

    f_local = jax.jit(lambda X, y: gram_blocks(X, y, 1.5))
    f_dist = jax.jit(lambda X, y: distributed_gram(mesh, X, y, 1.5, row_shard_out=False))
    f_rs = jax.jit(lambda X, y: distributed_gram_rs(mesh, X, y, 1.5))
    for name, f in [("local", f_local), ("dist_psum", f_dist), ("dist_rs", f_rs)]:
        out = f(X, y).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            out = f(X, y).block_until_ready()
        print(f"GRAM {name} {(time.perf_counter()-t0)/3*1e6:.1f}")
    err = float(jnp.abs(f_dist(X, y) - f_local(X, y)).max())
    print(f"GRAMERR {err:.3e}")
""")


def run():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _CODE], env=env, cwd=os.getcwd(),
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-1000:])
    times, err = {}, None
    for line in r.stdout.splitlines():
        if line.startswith("GRAM "):
            _, name, us = line.split()
            times[name] = float(us)
        elif line.startswith("GRAMERR"):
            err = line.split()[1]
    for name, us in times.items():
        emit(f"dist_gram_{name}", us / 1e6,
             f"8dev_host_mesh n=4096 p=256 max_err_vs_local={err}")


if __name__ == "__main__":
    run()
