"""Serving throughput: the continuous-batching runtime (async dispatch +
warm-start cache, `repro.runtime`) against the seed engine's synchronous
`drain_reference()` on the SAME adjacent-lambda request stream at a fixed
concurrency. Emits the ``serve`` section of BENCH_path.json: latency
percentiles, sustained req/s both ways, cache hit rate, and the
steady-state trace count (asserted constant across measured passes —
continuous traffic must never recompile). CI schema-checks the section and
gates on runtime >= 2x reference throughput."""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import reset_trace_counts, sven, trace_counts
from repro.core.api import enet
from repro.runtime import (PENALIZED, ContinuousScheduler, LoadSpec,
                           make_workload, run_open_loop)
from repro.serve import ElasticNetEngine


def _run_reference(engine: ElasticNetEngine, workload, concurrency: int):
    """The synchronous serving shape drain_reference preserves: admit one
    wave of `concurrency` requests, block until it is fully solved, repeat."""
    results = {}
    ids = []
    for lo in range(0, len(workload), concurrency):
        for item in workload[lo:lo + concurrency]:
            if item.form == PENALIZED:
                ids.append(engine.submit_penalized(item.X, item.y, item.lam,
                                                   item.lambda2))
            else:
                ids.append(engine.submit(item.X, item.y, item.lam,
                                         item.lambda2))
        results.update(engine.drain_reference())
    return results, ids


def run(requests: int = 48, concurrency: int = 8, reps: int = 3) -> dict:
    spec = LoadSpec(n_requests=requests, n_datasets=3,
                    penalized_fraction=0.25, pattern="adjacent", seed=7)
    workload = make_workload(spec)
    # max_wait=None: buckets launch async the moment they FILL, the closing
    # drain flushes the rest — the launch pattern is a pure function of the
    # workload (no wall-clock deadline races), so the steady-state
    # trace-constancy gate is exact. Deadline-driven launches are exercised
    # by serve_en / the loadgen smoke instead.
    sched = ContinuousScheduler(max_batch=concurrency, max_wait=None)
    reference = ElasticNetEngine(max_batch=concurrency, cache=None)

    # Warmup pass on both paths: compiles every bucket executable and fills
    # the runtime's warm-start cache — what "sustaining" means in steady
    # state. The measured passes below must add ZERO traces.
    run_open_loop(sched, workload)
    _run_reference(reference, workload, concurrency)

    traces0 = dict(trace_counts())
    sched.cache.reset_counters()
    best_runtime, best_reference = float("inf"), float("inf")
    out = None
    for _ in range(reps):
        out = run_open_loop(sched, workload)
        best_runtime = min(best_runtime, out["wall_seconds"])
        t0 = time.perf_counter()
        ref_results, ref_ids = _run_reference(reference, workload, concurrency)
        best_reference = min(best_reference, time.perf_counter() - t0)
    traces1 = dict(trace_counts())

    # exactness: warm-started runtime results vs reference and direct solves
    max_dev = 0.0
    for item, rid, ref_rid in list(zip(workload, out["ids"], ref_ids))[:8]:
        direct = (enet(item.X, item.y, item.lam, item.lambda2).beta
                  if item.form == PENALIZED
                  else sven(item.X, item.y, item.lam, item.lambda2).beta)
        max_dev = max(max_dev,
                      float(jnp.abs(out["results"][rid].beta - direct).max()),
                      float(jnp.abs(ref_results[ref_rid].beta - direct).max()))

    # Retracing is a DELTA, not a total: `steady_state_trace_count` used to
    # report the cumulative number of traces since process start (24 traces
    # for 24 warmup requests is normal), which says nothing about whether
    # the measured passes recompiled. The gate is per-entry-point trace
    # deltas between the warmup snapshot and the end of the measured passes
    # — all zero == zero retrace in steady state.
    trace_deltas = {k: traces1.get(k, 0) - traces0.get(k, 0)
                    for k in set(traces0) | set(traces1)}
    steady_deltas = {k: v for k, v in sorted(trace_deltas.items()) if v}
    speedup = best_reference / max(best_runtime, 1e-12)
    result = {
        "n_requests": requests,
        "concurrency": concurrency,
        "runtime_seconds": best_runtime,
        "reference_seconds": best_reference,
        "runtime_req_per_s": requests / max(best_runtime, 1e-12),
        "reference_req_per_s": requests / max(best_reference, 1e-12),
        "throughput_vs_reference": speedup,
        "p50_latency_s": out["p50_latency_s"],
        "p99_latency_s": out["p99_latency_s"],
        "cache_hit_rate": sched.cache.hit_rate,
        "cache_hits": sched.cache.hits,
        "warmup_trace_count": sum(traces0.values()),
        "steady_state_trace_deltas": steady_deltas,
        "steady_state_traces_constant": not steady_deltas,
        "bucket_executables": sched.stats.bucket_shapes,
        "max_dev_vs_direct": max_dev,
    }
    emit("serve_runtime_vs_reference", best_runtime,
         f"B={concurrency} N={requests} ref={best_reference*1e6:.1f}us "
         f"speedup={speedup:.2f}x hit_rate={sched.cache.hit_rate:.2f} "
         f"p99={out['p99_latency_s']*1e3:.1f}ms max_dev={max_dev:.2e}")
    return result


def run_multihost(requests: int = 24, hosts: int = 2,
                  max_batch: int = 4) -> dict:
    """p99 under multi-process load with one injected host kill — the
    ``multihost`` section of BENCH_path.json (DESIGN.md §11).

    Three waves of the same seeded single-bucket stream on one
    `MultiHostCoordinator` over `hosts` worker processes sharing a
    persistent spill tier: warmup (every host compiles the one bucket
    executable), a measured no-fault wave, then a measured wave with one
    host SIGKILLed mid-stream while it holds in-flight batches. Gates
    (validate_artifact): every admitted request of every wave reaches a
    terminal result with zero losses, the fault wave's p99 stays within 3x
    the no-fault p99 (failover cost is re-solving the dead host's work,
    never recompiling — the survivor compiled at warmup and warm-starts
    from the shared spill), and solutions stay <= 1e-10 of direct solves.
    """
    import tempfile

    from repro.runtime.multihost import MultiHostCoordinator

    # one bucket shape: every host's single executable is compiled by the
    # warmup wave, so the kill never pays a compile on the survivor and the
    # p99 ratio measures pure failover cost
    spec = LoadSpec(n_requests=requests, n_datasets=2,
                    shapes=((48, 24), (48, 24)), penalized_fraction=0.0,
                    pattern="adjacent", seed=11)
    workload = make_workload(spec)
    statuses: dict = {}
    lost = 0
    with tempfile.TemporaryDirectory() as tmp:
        coord = MultiHostCoordinator(n_hosts=hosts, max_batch=max_batch,
                                     cache_dir=tmp)
        try:
            for out in (run_open_loop(coord, workload),      # warmup/compile
                        run_open_loop(coord, workload)):     # measured
                lost += len(set(out["ids"]) - set(out["results"]))
            p99_nofault = out["p99_latency_s"]

            # fault wave: submit half, flush so the doomed host holds
            # in-flight batches, SIGKILL it, keep submitting — detection,
            # requeue and re-solve all land inside the measured window
            coord.metrics.reset()
            kill_at = len(workload) // 2
            ids = []
            for i, item in enumerate(workload):
                if i == kill_at:
                    coord.flush()
                    coord.kill_host(0)
                ids.append(coord.submit(item.X, item.y, t=item.lam,
                                        lambda2=item.lambda2,
                                        priority=item.priority))
            results = coord.drain()
            summary = coord.metrics.summary()
            p99_fault = summary["p99_latency_s"]
            lost += len(set(ids) - set(results))
            for res in results.values():
                statuses[res.status] = statuses.get(res.status, 0) + 1

            max_dev = 0.0
            for item, rid in list(zip(workload, ids))[:8]:
                if results[rid].status != "ok":
                    continue
                direct = sven(item.X, item.y, item.lam, item.lambda2).beta
                max_dev = max(max_dev, float(jnp.abs(
                    jnp.asarray(results[rid].beta) - direct).max()))
            hosts_lost = coord.hosts_lost
            requeued = coord.requeued_batches
        finally:
            worker_stats = coord.shutdown()

    ratio = p99_fault / max(p99_nofault, 1e-9)
    spill_hits = sum(s.get("spill_hits", 0) for s in worker_stats)
    result = {
        "n_requests": requests,
        "hosts": hosts,
        "max_batch": max_batch,
        "p99_nofault_s": p99_nofault,
        "p99_fault_s": p99_fault,
        "fault_over_nofault_p99": ratio,
        "hosts_lost": hosts_lost,
        "requeued_batches": requeued,
        "statuses": statuses,
        "lost_requests": lost,
        "all_accounted": lost == 0,
        "spill_hits": spill_hits,
        "max_dev_vs_direct": max_dev,
        "multihost_ok": (lost == 0 and hosts_lost == 1 and ratio <= 3.0
                         and statuses.get("ok", 0) == requests
                         and max_dev <= 1e-10),
    }
    emit("serve_multihost_fault_p99", p99_fault,
         f"hosts={hosts} kill=1 p99_nofault={p99_nofault*1e3:.1f}ms "
         f"ratio={ratio:.2f}x requeued={requeued} "
         f"statuses={statuses} max_dev={max_dev:.2e}")
    return result


if __name__ == "__main__":
    reset_trace_counts()
    print(run())
    print(run_multihost())
