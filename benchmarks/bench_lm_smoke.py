"""Framework-side micro-bench: reduced-config train/decode step wall time for
three representative architectures (dense / moe / ssm) on CPU — a smoke-level
throughput tracker for the LM substrate (the real perf story is the dry-run
roofline in EXPERIMENTS.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.models import model as M
from repro.optim import adamw_init
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.step import make_train_step


def run():
    for arch in ("internlm2-1.8b", "mixtral-8x7b", "mamba2-130m"):
        cfg = get_config(arch, smoke=True)
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        B, S = 4, 64
        if cfg.frontend == "codebooks":
            batch = {"tokens": jnp.zeros((B, S, cfg.n_codebooks), jnp.int32)}
        elif cfg.frontend == "patches":
            batch = {"tokens": jnp.zeros((B, S - cfg.vision_tokens), jnp.int32),
                     "patch_embeds": jnp.zeros((B, cfg.vision_tokens, cfg.d_model), cfg.dtype)}
        else:
            batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, microbatches=1))
        t_train = time_call(step, params, opt, batch, reps=2)
        tok_s = B * S / t_train
        emit(f"lm_train_smoke_{arch}", t_train, f"tokens_per_s={tok_s:.0f}")

        pre = jax.jit(make_prefill_step(cfg, max_len=S + 8))
        logits, caches = pre(params, batch)
        dec = jax.jit(make_decode_step(cfg))
        tok = jnp.zeros((B, cfg.n_codebooks), jnp.int32) if cfg.frontend == "codebooks" \
            else jnp.zeros((B,), jnp.int32)
        t_dec = time_call(lambda: dec(params, tok, caches), reps=2)
        emit(f"lm_decode_smoke_{arch}", t_dec, f"tokens_per_s={B / t_dec:.0f}")


if __name__ == "__main__":
    run()
