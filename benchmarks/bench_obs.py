"""Telemetry-layer benchmark: what does observability cost, and does it
account for everything? Emits the ``obs`` section of BENCH_path.json
(DESIGN.md §12).

Three measurements on the same adjacent-lambda serving load:

1. **Overhead** — interleaved best-of passes over one warmed scheduler with
   structured tracing disabled vs enabled. The gate (validate_artifact) is
   enabled <= 1.10x disabled wall time: spans are host-side monotonic-clock
   reads and never force a device sync, so telemetry must be ~free next to
   millisecond solves.
2. **Trace + solve log** — the enabled passes' Chrome-trace export must
   parse and carry the span taxonomy; the per-solve log must price every
   dispatch (cost-model residual report by routed path).
3. **Multihost accounting** — a 2-process coordinator run where the merged
   fleet counters (workers piggyback registry deltas on result messages)
   must agree with the coordinator's own admission/terminal accounting:
   every admitted request lands in exactly one terminal-status counter and
   the fleet saw exactly the admitted requests.
"""
from __future__ import annotations

import json
import os
import tempfile

from benchmarks.common import emit
from repro.obs import (default_events, disable_tracing, enable_tracing,
                       get_tracer)
from repro.runtime import (ContinuousScheduler, LoadSpec, make_workload,
                           run_open_loop)


def _trace_valid(tracer, path: str) -> bool:
    """Export + re-parse: Chrome-trace JSON with only complete/instant
    events, every one timestamped."""
    tracer.export(path)
    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"]
    return bool(events) and all(
        ev.get("ph") in ("X", "i") and "ts" in ev and "name" in ev
        for ev in events)


def _multihost_accounting(requests: int, hosts: int = 2) -> dict:
    """Fleet-merged worker counters vs the coordinator's own books."""
    from repro.runtime.multihost import MultiHostCoordinator

    spec = LoadSpec(n_requests=requests, n_datasets=2,
                    shapes=((48, 24), (48, 24)), penalized_fraction=0.0,
                    pattern="adjacent", seed=13)
    workload = make_workload(spec)
    coord = MultiHostCoordinator(n_hosts=hosts, max_batch=4)
    try:
        run_open_loop(coord, workload)
        acct = coord.accounting()
        fleet_requests = int(coord.fleet.counter(
            "runtime_requests_total", "").total())
    finally:
        coord.shutdown()
    return {
        "requests_admitted": acct["admitted"],
        "terminal_statuses": acct["terminals"],
        "accounting_balanced": bool(acct["balanced"]),
        "fleet_requests_total": fleet_requests,
        # no fault injected: the fleet must have solved exactly what was
        # admitted (requeues/speculation would legitimately raise this;
        # bench_serve.run_multihost covers the faulted path)
        "fleet_matches_accounting": fleet_requests == acct["admitted"],
    }


def run(requests: int = 32, concurrency: int = 8, reps: int = 7,
        mh_requests: int = 8) -> dict:
    # reps is cheap (each pass is ~20ms of warmed serving) and the 1.10x
    # gate needs the interleaved best-of to converge: at reps<=3 a single
    # lucky disabled pass can fake a >10% "overhead" out of pure jitter.
    spec = LoadSpec(n_requests=requests, n_datasets=3,
                    penalized_fraction=0.25, pattern="adjacent", seed=19)
    workload = make_workload(spec)
    # max_wait=None as in bench_serve: launches are a pure function of the
    # workload, so enabled and disabled passes run identical schedules.
    sched = ContinuousScheduler(max_batch=concurrency, max_wait=None)

    disable_tracing()
    run_open_loop(sched, workload)            # warmup: compile + warm cache
    tracer = get_tracer()

    # Interleave enabled/disabled passes and keep each mode's best wall
    # time — back-to-back best-of cancels machine-load drift that a
    # "first all-disabled then all-enabled" schedule would bake in.
    best = {False: float("inf"), True: float("inf")}
    p99 = {False: float("inf"), True: float("inf")}
    spans_before = len(tracer.spans())
    events_before = len(default_events().records())
    solve_records0 = sched.solve_log.recorded
    try:
        for _ in range(reps):
            for enabled in (False, True):
                (enable_tracing if enabled else disable_tracing)()
                out = run_open_loop(sched, workload)
                if out["wall_seconds"] < best[enabled]:
                    best[enabled] = out["wall_seconds"]
                    p99[enabled] = out["p99_latency_s"]
    finally:
        disable_tracing()

    span_count = len(tracer.spans()) - spans_before
    span_counts = {k: int(v) for k, v in sorted(tracer.counts().items())}
    with tempfile.TemporaryDirectory() as tmp:
        trace_valid = _trace_valid(tracer, os.path.join(tmp, "trace.json"))

    report = sched.solve_log.residual_report()
    mh = _multihost_accounting(mh_requests)

    overhead = best[True] / max(best[False], 1e-12)
    result = {
        "n_requests": requests,
        "reps": reps,
        "disabled_seconds": best[False],
        "enabled_seconds": best[True],
        "overhead_ratio": overhead,
        "p99_disabled_s": p99[False],
        "p99_enabled_s": p99[True],
        "span_count": span_count,
        "span_counts": span_counts,
        "event_count": len(default_events().records()) - events_before,
        "trace_valid": trace_valid,
        "n_solve_records": sched.solve_log.recorded - solve_records0,
        "n_unmodeled_solves": report["n_unmodeled"],
        "residual_by_path": report["by_path"],
        **mh,
        "obs_ok": (overhead <= 1.10 and trace_valid and span_count > 0
                   and report["n_unmodeled"] == 0
                   and mh["accounting_balanced"]
                   and mh["fleet_matches_accounting"]),
    }
    emit("obs_overhead", best[True],
         f"disabled={best[False]*1e6:.1f}us ratio={overhead:.3f}x "
         f"spans={span_count} trace_valid={trace_valid} "
         f"mh_balanced={mh['accounting_balanced']}")
    return result


if __name__ == "__main__":
    print(json.dumps(run(requests=16, reps=2), indent=2))
