"""Sharded solve path vs single device (DESIGN.md §9): parity and speedup.

Runs the production multi-device paths on a simulated 8-device host mesh in
a subprocess (the bench process itself keeps its real device set) and emits
the ``dist_solve`` section of BENCH_path.json:

  - `sven_sharded` (rows of Zhat sharded, psum-reduced Gram / matvecs)
    against single-device `sven` in both dual and primal regimes — the
    parity numbers the <= 1e-10 acceptance gate checks;
  - `sven_routed` — the cost-model router (core/routing.py) — against the
    same single-device baseline: `routed_speedup` is THE regression gate
    for the PR 5 "always shard" bug (a lone solve ran 0.10x sharded); a
    routed solve must never be meaningfully slower than single-device;
  - batch-axis sharding: the same stacked `sven_batch` launch with and
    without a `dist.mesh_context` (fan-out pinned via route="batch" so the
    sharded path stays exercised even where the router would decline it),
    wall-clock both ways.

The artifact gates are SPEEDUP-OR-PARITY: simulated host devices share the
machine's cores, so an N-way mesh on an M < N core runner may not beat one
device — the batch gate then rests on exact parity, and the routed gate on
the router picking "single" with bit-identical results (same executable)
plus a hard speedup floor that the 0.10x class can never pass.
`validate_artifact.py` enforces all of it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = textwrap.dedent("""
    import json, os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    from repro import dist
    from repro.core import sven, sven_batch, sven_sharded
    from repro.core.routing import route_solve, sven_routed
    from repro.data.synthetic import make_regression

    n, p, B, reps = %(n)d, %(p)d, %(B)d, %(reps)d
    mesh = dist.data_mesh()

    def best_of(fn, reps):
        jax.block_until_ready(fn())            # compile + warm
        b = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            b = min(b, time.perf_counter() - t0)
        return b

    # --- single-problem parity + timing: dual (n >> p) and primal (2p > n)
    Xd, yd, _ = make_regression(n, p, seed=0)
    Xp_, yp_, _ = make_regression(max(p, 48), 2 * n // 3 + p, seed=1)
    devs = []
    s0d = sven(Xd, yd, 1.4, 1.0)
    s1d = sven_sharded(Xd, yd, 1.4, 1.0, mesh=mesh)
    devs.append(float(jnp.abs(s1d.beta - s0d.beta).max()))
    s0p = sven(Xp_, yp_, 0.9, 0.8)
    s1p = sven_sharded(Xp_, yp_, 0.9, 0.8, mesh=mesh)
    devs.append(float(jnp.abs(s1p.beta - s0p.beta).max()))
    solve_sharded = best_of(
        lambda: sven_sharded(Xd, yd, 1.4, 1.0, mesh=mesh).beta, reps)

    # --- routed solve (core/routing.py): the cost model picks the layout.
    # single vs routed is a sub-ms pair on host sims, where run-to-run
    # drift on oversubscribed shared cores can exceed the gap itself —
    # measure them INTERLEAVED at >= 10 reps so drift hits both equally.
    decision = route_solve(n, p, mesh=mesh)
    s_routed = sven_routed(Xd, yd, 1.4, 1.0, mesh=mesh)
    dev_routed = float(jnp.abs(s_routed.beta - s0d.beta).max())
    single_fn = lambda: sven(Xd, yd, 1.4, 1.0).beta
    routed_fn = lambda: sven_routed(Xd, yd, 1.4, 1.0, mesh=mesh).beta
    jax.block_until_ready(single_fn())
    jax.block_until_ready(routed_fn())
    solve_single = solve_routed = float("inf")
    for _ in range(max(reps, 10)):
        t0 = time.perf_counter()
        jax.block_until_ready(single_fn())
        solve_single = min(solve_single, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(routed_fn())
        solve_routed = min(solve_routed, time.perf_counter() - t0)

    # --- batch-axis sharding: one stacked launch, with/without the mesh
    # (route="batch" pins the fan-out: this measurement exists to keep the
    # sharded lanes exercised and parity-checked even on host-sim meshes
    # where the router would — correctly — decline them)
    Xb = jnp.stack([make_regression(n, p, seed=7 + i)[0] for i in range(B)])
    yb = jnp.stack([make_regression(n, p, seed=7 + i)[1] for i in range(B)])
    tb = jnp.linspace(0.8, 1.6, B)
    l2b = jnp.full((B,), 1.0)
    sol_single = sven_batch(Xb, yb, tb, l2b)
    with dist.mesh_context(mesh):
        sol_sharded = sven_batch(Xb, yb, tb, l2b, route="batch")
    dev_batch = float(jnp.abs(sol_sharded.beta - sol_single.beta).max())
    batch_single = best_of(lambda: sven_batch(Xb, yb, tb, l2b).beta, reps)
    def sharded_batch():
        with dist.mesh_context(mesh):
            return sven_batch(Xb, yb, tb, l2b, route="batch").beta
    batch_sharded = best_of(sharded_batch, reps)

    out = {
        "devices": jax.device_count(),
        "n": n, "p": p, "grid_B": B,
        "solve_single_seconds": solve_single,
        "solve_sharded_seconds": solve_sharded,
        "solve_speedup": solve_single / max(solve_sharded, 1e-12),
        "solve_routed_seconds": solve_routed,
        "routed_speedup": solve_single / max(solve_routed, 1e-12),
        "routed_path": decision.path,
        "max_dev_routed": dev_routed,
        "batch_single_seconds": batch_single,
        "batch_sharded_seconds": batch_sharded,
        "batch_speedup": batch_single / max(batch_sharded, 1e-12),
        "max_dev_sharded_solve": max(devs),
        "max_dev_sharded_batch": dev_batch,
    }
    out["speedup_or_parity"] = bool(
        out["batch_speedup"] >= 1.0
        or (out["max_dev_sharded_solve"] <= 1e-10
            and out["max_dev_sharded_batch"] <= 1e-10))
    # the routed gate: >= 1.0, or the router picked "single" and returned
    # the SAME executable's bit-identical answer with only timing noise
    # (>= 0.8 floor) between the runs — the 0.10x class fails both arms.
    out["routed_ok"] = bool(
        out["routed_speedup"] >= 1.0
        or (out["routed_path"] == "single" and out["max_dev_routed"] == 0.0
            and out["routed_speedup"] >= 0.8))
    print("DIST_SOLVE_JSON=" + json.dumps(out))
""")


def run(n: int = 768, p: int = 48, B: int = 8, reps: int = 3) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    code = _CODE % {"n": n, "p": p, "B": B, "reps": reps}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=1200, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"bench_dist_solve subprocess failed:\n"
                           f"{r.stdout}\n{r.stderr}")
    line = [l for l in r.stdout.splitlines()
            if l.startswith("DIST_SOLVE_JSON=")][-1]
    result = json.loads(line.split("=", 1)[1])
    emit("dist_batch_sharded_vs_single", result["batch_sharded_seconds"],
         f"devices={result['devices']} B={B} n={n} p={p} "
         f"speedup={result['batch_speedup']:.2f}x "
         f"max_dev={max(result['max_dev_sharded_solve'], result['max_dev_sharded_batch']):.2e}")
    emit("dist_solve_sharded_vs_single", result["solve_sharded_seconds"],
         f"devices={result['devices']} n={n} p={p} "
         f"speedup={result['solve_speedup']:.2f}x")
    emit("dist_solve_routed_vs_single", result["solve_routed_seconds"],
         f"devices={result['devices']} n={n} p={p} "
         f"path={result['routed_path']} "
         f"speedup={result['routed_speedup']:.2f}x")
    return result


if __name__ == "__main__":
    print(run(n=384, p=32, reps=2))
