"""Beyond-paper optimization accounting (DESIGN.md §9): wall-time + FLOP
comparison of the three Gram strategies on CPU/XLA —
  1. paper-faithful: materialize Xnew (2p, n), K = Z^T Z        (4 p^2 n MACs)
  2. block identity (ours): G = X^T X + rank-1 assembly          (p^2 n MACs)
  3. matrix-free operator path (no K at all; per-matvec O(np))
and of the primal mat-vec: materialized vs implicit. The Pallas kernels
realize (2) on TPU with the shift fused (validated in interpret mode;
wall-clock timing of interpret mode is meaningless, so the TPU claim is the
FLOP/byte ledger + the identical-output check)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.reduction import SvenOperator, build_svm_dataset, gram_blocks, gram_reference
from repro.data.synthetic import make_regression


def run():
    n, p, t = 2000, 600, 1.5
    X, y, _ = make_regression(n, p, seed=0, dtype=jnp.float32)

    ref = jax.jit(lambda X, y: gram_reference(X, y, t))
    blk = jax.jit(lambda X, y: gram_blocks(X, y, t))
    t_ref = time_call(ref, X, y)
    t_blk = time_call(blk, X, y)
    emit("gram_paper_faithful", t_ref, f"macs={4 * p * p * n:.2e}")
    emit("gram_block_identity", t_blk,
         f"macs={p * p * n:.2e} speedup={t_ref / t_blk:.2f}x (4x fewer MACs)")

    op = SvenOperator(X=X, y=y, t=t)
    Xhat, yhat = build_svm_dataset(X, y, t)
    w = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    mv_mat = jax.jit(lambda w: Xhat @ w)
    mv_imp = jax.jit(op.xhat_matvec)
    t_mat = time_call(mv_mat, w)
    t_imp = time_call(mv_imp, w)
    emit("primal_matvec_materialized", t_mat, f"bytes~{Xhat.size * 4:.2e}")
    emit("primal_matvec_implicit", t_imp,
         f"bytes~{X.size * 4:.2e} speedup={t_mat / t_imp:.2f}x (2x fewer reads)")


if __name__ == "__main__":
    run()
