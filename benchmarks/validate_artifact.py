"""Validate the BENCH_path.json artifact emitted by ``benchmarks/run.py``.

Checks both shape (every section the path/batch/cv/serve/dist_solve benches
write carries its full key set) and the engine invariants CI cares about:
single-trace scans, no retrace on new grid values (steady-state serving is
gated on per-entry-point trace DELTAS between warmup and the measured
passes), exactness vs the sequential / coordinate-descent oracles, batched
CV at least matching the sequential loop, the continuous-batching runtime
sustaining >= 2x the synchronous drain_reference throughput with warm-start
cache hits under the adjacent-lambda load, the sharded solve path at
<= 1e-10 parity with (and speedup-or-parity against) the single-device
path on the 8-device host mesh, the cost-model-routed solve never
landing meaningfully below single-device speed (`routed_ok` — the gate
that keeps the always-shard 0.10x lone-solve regression from recurring),
and the per-backend kernel section: Pallas bodies at interpret-mode
parity with the ref oracle on CPU runners, fused gram >= 1.5x over the
unfused materialize-then-matmul reference on GPU runners, and the
bf16+iterative-refinement solve within 1e-10 everywhere. The ``obs``
section (DESIGN.md §12) gates telemetry: tracing-enabled serving within
1.10x of disabled, a round-trippable Chrome-trace export, every dispatch
priced by the cost model, and multihost fleet counters agreeing with the
coordinator's admission/terminal books. `--baseline` additionally diffs
watched wall-clock keys against the committed previous-run artifact and
WARNs (never fails) past +20%.

    python benchmarks/validate_artifact.py [BENCH_path.json] \
        [--baseline benchmarks/BENCH_baseline.json]
"""
from __future__ import annotations

import json
import sys

REQUIRED_KEYS = {
    "path": {
        "n_points", "scan_seconds", "loop_seconds", "scan_vs_loop_speedup",
        "scan_trace_count", "retraced_on_new_grid_values", "max_dev_vs_cd",
        "scan_vs_loop_dev",
    },
    "batch": {
        "grid_B", "batch_seconds", "sequential_seconds",
        "batch_vs_sequential_speedup", "max_dev_vs_sequential",
        "cv_folds_seconds",
    },
    "cv": {
        "k", "n_lambdas", "fold_chunk", "cv_batched_seconds",
        "cv_vmap_seconds", "cv_sequential_seconds",
        "cv_batched_vs_sequential_speedup", "max_dev_vs_cd",
        "mse_dev_vs_reference", "cv_scan_traces", "refit_traces", "lambda_min",
    },
    "serve": {
        "n_requests", "concurrency", "runtime_seconds", "reference_seconds",
        "runtime_req_per_s", "reference_req_per_s", "throughput_vs_reference",
        "p50_latency_s", "p99_latency_s", "cache_hit_rate", "cache_hits",
        "warmup_trace_count", "steady_state_trace_deltas",
        "steady_state_traces_constant", "bucket_executables",
        "max_dev_vs_direct",
    },
    "dist_solve": {
        "devices", "n", "p", "grid_B", "solve_single_seconds",
        "solve_sharded_seconds", "solve_speedup", "solve_routed_seconds",
        "routed_speedup", "routed_path", "max_dev_routed",
        "batch_single_seconds", "batch_sharded_seconds", "batch_speedup",
        "max_dev_sharded_solve", "max_dev_sharded_batch", "speedup_or_parity",
        "routed_ok",
    },
    "kernels": {
        "platform", "kernel_backend", "n", "p", "tiles", "gram_seconds",
        "hinge_stats_seconds", "unfused_gram_seconds", "gram_parity_rel",
        "hinge_parity_rel", "unfused_parity_rel", "bf16_refined_max_dev",
        "gpu_speedup", "parity_ok", "speedup_ok", "kernels_ok",
    },
    "multihost": {
        "n_requests", "hosts", "max_batch", "p99_nofault_s", "p99_fault_s",
        "fault_over_nofault_p99", "hosts_lost", "requeued_batches",
        "statuses", "lost_requests", "all_accounted", "spill_hits",
        "max_dev_vs_direct", "multihost_ok",
    },
    "obs": {
        "n_requests", "reps", "disabled_seconds", "enabled_seconds",
        "overhead_ratio", "p99_disabled_s", "p99_enabled_s", "span_count",
        "span_counts", "event_count", "trace_valid", "n_solve_records",
        "n_unmodeled_solves", "residual_by_path", "requests_admitted",
        "terminal_statuses", "accounting_balanced", "fleet_requests_total",
        "fleet_matches_accounting", "obs_ok",
    },
}

#: baseline regression watch (satellite, non-fatal): wall-clock keys whose
#: value growing past +20% over the committed BENCH_baseline.json prints a
#: WARN — timings, not invariants, so machine variance must not fail CI.
BASELINE_TIMING_KEYS = {
    "serve": ("runtime_seconds", "p99_latency_s"),
    "dist_solve": ("solve_sharded_seconds", "solve_routed_seconds",
                   "batch_sharded_seconds"),
    "kernels": ("gram_seconds", "hinge_stats_seconds"),
}
BASELINE_TOLERANCE = 1.20


def validate(artifact: dict) -> list:
    errors = []
    for section, keys in REQUIRED_KEYS.items():
        if section not in artifact:
            errors.append(f"missing section {section!r}")
            continue
        missing = keys - set(artifact[section])
        if missing:
            errors.append(f"{section}: missing keys {sorted(missing)}")

    def check(section, cond, msg):
        if section in artifact and not cond:
            errors.append(f"{section}: {msg} ({artifact[section]})")

    path, batch, cv, serve, dist_solve = (
        artifact.get(s, {})
        for s in ("path", "batch", "cv", "serve", "dist_solve"))
    check("path", path.get("scan_trace_count") == 1,
          "regularization-path scan must compile exactly once")
    check("path", not path.get("retraced_on_new_grid_values"),
          "new grid values must not retrace the scan")
    check("path", path.get("scan_vs_loop_dev", 1.0) < 1e-6,
          "scan and reference loop diverged")
    check("batch", batch.get("max_dev_vs_sequential", 1.0) < 1e-6,
          "batched solves diverged from sequential sven()")
    check("cv", cv.get("cv_scan_traces") == 1,
          "screening-fused CV scan must compile exactly once")
    check("cv", cv.get("refit_traces", 99) <= 1,
          "CV refit must cost at most one extra trace")
    check("cv", cv.get("max_dev_vs_cd", 1.0) < 1e-5,
          "CV refit diverged from the coordinate-descent baseline")
    check("cv", cv.get("mse_dev_vs_reference", 1.0) < 1e-8,
          "batched CV MSE surface diverged from the per-fold loop")
    check("cv", cv.get("cv_batched_vs_sequential_speedup", 0.0) >= 1.0,
          "batched CV slower than the sequential per-fold loop — the fold "
          "chunk is wrong-sized for this backend")
    check("serve", serve.get("throughput_vs_reference", 0.0) >= 2.0,
          "continuous-batching runtime below 2x the synchronous "
          "drain_reference throughput")
    check("serve", serve.get("cache_hits", 0) > 0,
          "adjacent-lambda load produced no warm-start cache hits")
    check("serve", serve.get("steady_state_trace_deltas", {"_": 1}) == {},
          "measured serving passes added traces over the warmup snapshot")
    check("serve", serve.get("steady_state_traces_constant") is True,
          "steady-state serving retraced")
    check("serve", serve.get("max_dev_vs_direct", 1.0) < 1e-6,
          "runtime solves diverged from direct sven()/enet()")
    check("dist_solve", dist_solve.get("max_dev_sharded_solve", 1.0) <= 1e-10,
          "sharded sven diverged from the single-device solve")
    check("dist_solve", dist_solve.get("max_dev_sharded_batch", 1.0) <= 1e-10,
          "mesh-placed sven_batch diverged from the single-device launch")
    check("dist_solve", dist_solve.get("speedup_or_parity") is True,
          "sharded path is neither faster than nor exactly at parity with "
          "the single-device path")
    check("dist_solve", dist_solve.get("max_dev_routed", 1.0) <= 1e-10,
          "routed sven diverged from the single-device solve")
    check("dist_solve", dist_solve.get("routed_ok") is True,
          "routed single-solve regression: the cost-model router picked a "
          "path slower than single-device (the PR 5 always-shard 0.10x "
          "class) — routed_speedup must be >= 1.0, or >= 0.8 with the "
          "router on the bit-identical single path")
    mh = artifact.get("multihost", {})
    check("multihost", mh.get("all_accounted") is True,
          "a host kill lost admitted requests — every request must end in "
          "a terminal result")
    check("multihost", mh.get("hosts_lost") == 1,
          "the injected SIGKILL was not detected as exactly one dead host")
    check("multihost", mh.get("fault_over_nofault_p99", 99.0) <= 3.0,
          "p99 with one host killed mid-stream exceeded 3x the no-fault p99")
    check("multihost", mh.get("max_dev_vs_direct", 1.0) <= 1e-10,
          "multi-host solves diverged from direct sven() beyond 1e-10")
    check("multihost", mh.get("multihost_ok") is True,
          "multihost section gate failed")
    kernels = artifact.get("kernels", {})
    check("kernels", kernels.get("parity_ok") is True,
          "a Pallas kernel body diverged from the ref oracle beyond f32 "
          "accumulation roundoff (interpret-mode parity is the CPU gate)")
    check("kernels", kernels.get("bf16_refined_max_dev", 1.0) <= 1e-10,
          "bf16-storage solve with one full-precision refinement re-solve "
          "drifted beyond 1e-10 of the full-precision solve")
    check("kernels", kernels.get("speedup_ok") in (None, True),
          "GPU fused shifted-gram below 1.5x over the unfused "
          "materialize-then-matmul reference")
    check("kernels", kernels.get("kernels_ok") is True,
          "kernel section gate failed")
    obs = artifact.get("obs", {})
    check("obs", obs.get("overhead_ratio", 99.0) <= 1.10,
          "structured tracing cost more than 10% of serving wall time — "
          "spans must stay host-side clock reads, never device syncs")
    check("obs", obs.get("trace_valid") is True,
          "Chrome-trace export did not round-trip as valid trace JSON")
    check("obs", obs.get("span_count", 0) > 0,
          "enabled passes recorded no spans")
    check("obs", obs.get("n_unmodeled_solves", 99) == 0,
          "a dispatch reached the solve log without a cost-model price")
    check("obs", obs.get("accounting_balanced") is True,
          "coordinator books unbalanced: an admitted request is missing "
          "from the terminal-status counters (or counted twice)")
    check("obs", obs.get("fleet_matches_accounting") is True,
          "fleet-merged worker counters disagree with the coordinator's "
          "admission count on a fault-free run")
    check("obs", obs.get("obs_ok") is True,
          "obs section gate failed")
    return errors


def compare_baseline(artifact: dict, baseline: dict) -> list:
    """Per-section timing deltas vs the committed baseline artifact.

    Returns WARN strings for any watched timing that regressed past
    +20%; sections or keys absent from either side are skipped (the
    committed baseline may predate newer benches, and partial ``--only``
    runs may omit sections). Never fatal — see BASELINE_TIMING_KEYS.
    """
    warnings = []
    for section, keys in BASELINE_TIMING_KEYS.items():
        cur, base = artifact.get(section), baseline.get(section)
        if not cur or not base:
            continue
        for key in keys:
            c, b = cur.get(key), base.get(key)
            if not (isinstance(c, (int, float))
                    and isinstance(b, (int, float)) and b > 0):
                continue
            ratio = c / b
            if ratio > BASELINE_TOLERANCE:
                warnings.append(
                    f"{section}.{key} regressed {ratio:.2f}x vs baseline "
                    f"({b:.4g}s -> {c:.4g}s; tolerance "
                    f"{BASELINE_TOLERANCE:.2f}x)")
    return warnings


def main() -> None:
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", nargs="?", default="BENCH_path.json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json",
                    help="committed previous-run artifact to diff timings "
                         "against (>20%% slower prints a non-fatal WARN); "
                         "skipped when the file is absent")
    args = ap.parse_args()
    fname = args.artifact
    with open(fname) as fh:
        artifact = json.load(fh)
    errors = validate(artifact)
    if errors:
        for e in errors:
            print(f"[validate_artifact] FAIL: {e}")
        sys.exit(1)
    if os.path.exists(args.baseline):
        with open(args.baseline) as fh:
            warnings = compare_baseline(artifact, json.load(fh))
        for w in warnings:
            print(f"[validate_artifact] WARN: {w}")
        if not warnings:
            print(f"[validate_artifact] baseline {args.baseline}: "
                  f"no timing regressions past {BASELINE_TOLERANCE:.2f}x")
    ds = artifact.get("dist_solve")
    dist_note = (f", dist batch {ds['batch_speedup']:.2f}x on "
                 f"{ds['devices']} devices "
                 f"(max dev {ds['max_dev_sharded_solve']:.1e}, "
                 f"routed->{ds['routed_path']} "
                 f"{ds['routed_speedup']:.2f}x)" if ds else "")
    mh = artifact.get("multihost")
    if mh:
        dist_note += (f", multihost fault p99 "
                      f"{mh['fault_over_nofault_p99']:.2f}x no-fault "
                      f"({mh['hosts']} hosts, {mh['requeued_batches']} "
                      f"requeued)")
    kn = artifact.get("kernels")
    if kn:
        spd = (f", gpu {kn['gpu_speedup']:.2f}x"
               if kn.get("gpu_speedup") else "")
        dist_note += (f", kernels {kn['kernel_backend']} "
                      f"(bf16 dev {kn['bf16_refined_max_dev']:.1e}{spd})")
    ob = artifact.get("obs")
    if ob:
        dist_note += (f", telemetry {ob['overhead_ratio']:.3f}x overhead "
                      f"({ob['span_count']} spans, accounting "
                      f"{'balanced' if ob['accounting_balanced'] else 'OFF'})")
    print(f"[validate_artifact] {fname} OK: "
          f"path scan {artifact['path']['scan_vs_loop_speedup']:.2f}x, "
          f"cv batched {artifact['cv']['cv_batched_vs_sequential_speedup']:.2f}x, "
          f"serve {artifact['serve']['throughput_vs_reference']:.2f}x "
          f"(hit rate {artifact['serve']['cache_hit_rate']:.2f})"
          f"{dist_note}")


if __name__ == "__main__":
    main()
