"""Batched serving example: prefill a batch of prompts, then greedy-decode
new tokens with the KV/SSM caches (the decode_* cells' code path).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --steps 16
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "codebooks":
        prompts = jax.random.randint(key, (args.batch, args.prompt_len, cfg.n_codebooks),
                                     0, cfg.vocab_size)
        batch = {"tokens": prompts}
    elif cfg.frontend == "patches":
        P = cfg.vision_tokens
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size),
                 "patch_embeds": jax.random.normal(key, (args.batch, P, cfg.d_model), cfg.dtype)}
    else:
        batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}

    out = greedy_generate(params, cfg, batch, steps=args.steps,
                          max_len=args.prompt_len + args.steps + cfg.vision_tokens + 4)
    print(f"arch={cfg.name} generated token ids, shape {out.shape}:")
    print(out[:, :10])


if __name__ == "__main__":
    main()
