"""Distributed SVEN on a (simulated) 8-device mesh: the paper's solver with
feature-sharded Hessian mat-vecs and the sample-sharded Gram build.

    python examples/distributed_sven.py     (sets its own XLA device flag)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.baselines import elastic_net_cd
from repro.core.distributed import distributed_gram, sven_primal_distributed
from repro.core.elastic_net import lambda1_max
from repro.core.reduction import gram_reference
from repro.data.synthetic import make_regression


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    # p >> n: feature-sharded primal solve
    X, y, _ = make_regression(48, 512, k_true=10, rho=0.3, seed=0)
    l1 = 0.3 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, 1.0).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    beta, res = sven_primal_distributed(mesh, X, y, t, 1.0)
    print(f"primal: iters={int(res.iters)} "
          f"max|beta - beta_cd|={float(jnp.abs(beta - beta_cd).max()):.2e}")

    # n >> p: sample-sharded Gram build (one psum of G/u/s)
    X2, y2, _ = make_regression(4096, 64, seed=1)
    K = distributed_gram(mesh, X2, y2, 1.2, row_shard_out=False)
    K_ref = gram_reference(X2, y2, 1.2)
    print(f"gram:   max err vs reference = {float(jnp.abs(K - K_ref).max()):.2e}")

    # the production sharded solve path (DESIGN.md §9): rows of Zhat over a
    # data mesh, exact parity with the single-device engine
    from repro import dist
    from repro.core import sven, sven_sharded

    data = dist.data_mesh()
    X3, y3, _ = make_regression(600, 48, seed=2)
    s0 = sven(X3, y3, 1.3, 1.0)
    s1 = sven_sharded(X3, y3, 1.3, 1.0, mesh=data)
    print(f"sharded: mode={s1.mode} iters={int(s1.iters)} "
          f"max|beta_sharded - beta| = {float(jnp.abs(s1.beta - s0.beta).max()):.2e}")


if __name__ == "__main__":
    main()
