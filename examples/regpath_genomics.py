"""End-to-end driver of the paper's kind: a full regularization path on a
genomics-scale p >> n problem, warm-started across the t grid, with
correctness audits (KKT residuals per point) and timing vs the CD baseline.

    PYTHONPATH=src python examples/regpath_genomics.py [--p 20000] [--n 200]
"""
import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.baselines import elastic_net_cd
from repro.core import sven, SvenConfig
from repro.core.elastic_net import kkt_violation, lambda1_max
from repro.data.synthetic import make_regression


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=150)
    ap.add_argument("--p", type=int, default=8000)
    ap.add_argument("--points", type=int, default=10)
    ap.add_argument("--lam2", type=float, default=1.0)
    args = ap.parse_args()

    print(f"generating gene-expression-like problem n={args.n} p={args.p} ...")
    X, y, _ = make_regression(args.n, args.p, k_true=30, rho=0.5, noise=0.3, seed=7)
    l1max = float(lambda1_max(X, y))

    print(f"{'frac':>6} {'t':>9} {'nnz':>5} {'kkt':>9} {'sven_ms':>8} {'cd_ms':>8} {'dev':>9}")
    warm_w = None
    beta_cd = None
    for frac in np.geomspace(0.7, 0.05, args.points):
        t0 = time.perf_counter()
        res = elastic_net_cd(X, y, float(frac * l1max), args.lam2, beta0=beta_cd)
        beta_cd = res.beta
        cd_ms = (time.perf_counter() - t0) * 1e3
        t = float(jnp.sum(jnp.abs(beta_cd)))
        if t < 1e-8:
            continue
        t0 = time.perf_counter()
        sol = sven(X, y, t, args.lam2, SvenConfig(tol=1e-8), warm_w=warm_w)
        sven_ms = (time.perf_counter() - t0) * 1e3
        warm_w = sol.w
        dev = float(jnp.abs(sol.beta - beta_cd).max())
        nnz = int((jnp.abs(sol.beta) > 1e-8).sum())
        print(f"{frac:6.3f} {t:9.3f} {nnz:5d} {float(sol.kkt):9.2e} "
              f"{sven_ms:8.1f} {cd_ms:8.1f} {dev:9.2e}")
    print("path complete — SVEN reproduces the CD path exactly (dev column).")


if __name__ == "__main__":
    main()
