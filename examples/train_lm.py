"""End-to-end LM training driver example: trains a reduced-config model via
the full launcher stack (sharded init, AdamW, checkpointing, supervised
retries, deterministic data) and prints the loss curve.

    PYTHONPATH=src python examples/train_lm.py --arch mamba2-130m --steps 60

Full-size runs use the same entry point on a real pod:
    python -m repro.launch.train --arch mamba2-130m --steps 500 --batch 64 ...
"""
import argparse
import sys

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    loss = run(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_example_ckpt",
                "--log-every", "5"])
    print(f"final loss: {loss:.4f}")


if __name__ == "__main__":
    main()
