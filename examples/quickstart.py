"""Quickstart: solve an Elastic Net with SVEN (the paper's Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.baselines import elastic_net_cd
from repro.core import sven, SvenConfig
from repro.core.elastic_net import lambda1_max
from repro.data.synthetic import make_regression


def main():
    # A p >> n problem (the Elastic Net's home turf: genomics/fMRI shapes)
    X, y, beta_true = make_regression(n=60, p=500, k_true=8, rho=0.4, seed=0)

    # pick the L1 budget off the penalized path, as the paper does with glmnet
    lam2 = 1.0
    lam1 = 0.3 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, lam1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))

    sol = sven(X, y, t, lam2)   # auto-dispatches: 2p > n -> primal Newton-CG
    print(f"mode={sol.mode}  newton_iters={int(sol.iters)}  "
          f"kkt_violation={float(sol.kkt):.2e}")
    print(f"selected {int((jnp.abs(sol.beta) > 1e-8).sum())} / 500 features")
    print(f"max |beta_sven - beta_cd| = {float(jnp.abs(sol.beta - beta_cd).max()):.2e}")

    # the same solve through the Pallas kernel backend (interpret mode on CPU)
    sol_k = sven(X, y, t, lam2, SvenConfig(backend="pallas", tol=1e-6))
    print(f"pallas backend agreement: {float(jnp.abs(sol_k.beta - sol.beta).max()):.2e}")


if __name__ == "__main__":
    main()
