"""Quickstart: the penalized glmnet-parity API end-to-end, then the paper's
raw constrained form (Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.baselines import elastic_net_cd
from repro.core import (ElasticNet, ElasticNetCV, SvenConfig, enet_path,
                        sven)
from repro.core.elastic_net import lambda1_max
from repro.data.synthetic import make_regression


def main():
    # A p >> n problem (the Elastic Net's home turf: genomics/fMRI shapes)
    X, y, beta_true = make_regression(n=60, p=500, k_true=8, rho=0.4, seed=0)

    # --- penalized API (what glmnet users write) ---------------------------
    lam2 = 1.0
    lam1 = 0.3 * float(lambda1_max(X, y))
    model = ElasticNet(lambda1=lam1, lambda2=lam2).fit(X, y)
    nnz = int((jnp.abs(model.coef_) > 1e-8).sum())
    print(f"ElasticNet(lambda1={lam1:.2f}): {nnz} / 500 features, "
          f"intercept={float(model.intercept_):.2e}, mapped to t={float(model.t_):.3f}")

    # parity with the coordinate-descent baseline (the glmnet stand-in)
    beta_cd = elastic_net_cd(X, y, lam1, lam2).beta
    res = ElasticNet(lam1, lam2, standardize=False, fit_intercept=False).fit(X, y)
    print(f"max |beta_sven - beta_cd| = {float(jnp.abs(res.coef_ - beta_cd).max()):.2e}")

    # full regularization path: ONE compiled scan over the glmnet grid,
    # gap-safe screening fused at every point
    path = enet_path(X, y, n_lambdas=20, lambda2=lam2)
    print(f"enet_path: {path.betas.shape[0]} lambdas, screened problem sizes "
          f"{int(path.n_kept.min())}..{int(path.n_kept.max())} of 500")

    # K-fold CV, all folds batched through one vmapped scan
    cv = ElasticNetCV(k=5, n_lambdas=20, lambda2=lam2).fit(X, y)
    print(f"ElasticNetCV: lambda_min={cv.lambda_min_:.3f} "
          f"(grid point {int(jnp.argmin(cv.mean_mse_))}/20), "
          f"cv_mse={float(cv.mean_mse_.min()):.4f}")

    # --- constrained API (the paper's Algorithm 1) -------------------------
    t = float(jnp.sum(jnp.abs(beta_cd)))
    sol = sven(X, y, t, lam2)   # auto-dispatches: 2p > n -> primal Newton-CG
    print(f"sven: mode={sol.mode}  newton_iters={int(sol.iters)}  "
          f"kkt_violation={float(sol.kkt):.2e}")

    # the same solve through the Pallas kernel backend (interpret mode on CPU)
    sol_k = sven(X, y, t, lam2, SvenConfig(backend="pallas", tol=1e-6))
    print(f"pallas backend agreement: {float(jnp.abs(sol_k.beta - sol.beta).max()):.2e}")


if __name__ == "__main__":
    main()
