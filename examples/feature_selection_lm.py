"""The paper's use case transplanted onto the LM substrate: use SVEN to
select a sparse set of hidden-state features that linearly predict a target
signal from a frozen LM's activations (the fMRI/genetics workflow with
activations as the design matrix: n = examples, p = hidden features).

    PYTHONPATH=src python examples/feature_selection_lm.py
"""
import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from repro.baselines import elastic_net_cd
from repro.configs import get_config
from repro.core import sven
from repro.core.elastic_net import lambda1_max
from repro.models import model as M


def main():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)

    # collect final-layer activations over a batch of sequences
    B, S = 48, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    _, _, h = M.forward(params, cfg, {"tokens": toks}, return_hidden=True)
    X = jnp.asarray(h[:, -1, :], jnp.float64)              # (n=B, p=d_model)
    X = (X - X.mean(0)) / (X.std(0) + 1e-9)

    # target: a synthetic signal driven by a sparse set of hidden units
    key = jax.random.PRNGKey(2)
    true_idx = jax.random.choice(key, cfg.d_model, (5,), replace=False)
    w = jax.random.normal(jax.random.fold_in(key, 1), (5,))
    y = X[:, true_idx] @ w + 0.05 * jax.random.normal(jax.random.fold_in(key, 2), (B,))
    y = y - y.mean()

    lam2 = 0.5
    l1 = 0.25 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    sol = sven(X, y, t, lam2)

    picked = jnp.where(jnp.abs(sol.beta) > 1e-6)[0]
    print(f"true feature ids:   {sorted(int(i) for i in true_idx)}")
    print(f"SVEN selected ids:  {sorted(int(i) for i in picked)}")
    hit = len(set(map(int, true_idx)) & set(map(int, picked)))
    print(f"recovered {hit}/5 true features; "
          f"agreement with CD: {float(jnp.abs(sol.beta - beta_cd).max()):.2e}")


if __name__ == "__main__":
    main()
