"""glmnet-parity front-end (core/api.py, core/cv.py): scaling conversions,
standardization round-trip, penalized<->constrained mapping (t = |beta*|_1,
nu = lambda1 KKT), screening-fused path scans, batched CV vs the sequential
per-fold reference, keep-mask wiring, and the engine's penalized requests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import elastic_net_cd
from repro.baselines.coordinate_descent import cd_path
from repro.core import (ElasticNet, ElasticNetCV, api, cross_validate,
                        cross_validate_reference, enet, enet_path,
                        gap_safe_screen, lambda_grid, penalized_from_glmnet,
                        penalized_from_sklearn, penalized_to_glmnet,
                        reset_trace_counts, sven, sven_batch, trace_counts)
from repro.core.elastic_net import kkt_multiplier, lambda1_max
from repro.data.synthetic import make_regression


# ---------------------------------------------------------------------------
# scaling conventions
# ---------------------------------------------------------------------------

def test_lambda_conversions_roundtrip():
    n = 73
    for lam, alpha in [(0.3, 0.5), (1.7, 0.9), (0.05, 0.1)]:
        l1, l2 = penalized_from_glmnet(lam, alpha, n)
        assert l1 == 2.0 * n * lam * alpha and l2 == n * lam * (1 - alpha)
        lam_back, alpha_back = penalized_to_glmnet(l1, l2, n)
        assert abs(lam_back - lam) < 1e-12 and abs(alpha_back - alpha) < 1e-12
    # sklearn's (alpha, l1_ratio) is glmnet's (lambda, alpha)
    assert penalized_from_sklearn(0.3, 0.5, n) == penalized_from_glmnet(0.3, 0.5, n)


def test_conversion_argmin_invariance():
    """Minimizing the paper objective at the converted (lambda1, lambda2)
    reproduces the glmnet-objective minimizer (same argmin, checked via CD on
    the explicitly rescaled problem)."""
    X, y, _ = make_regression(50, 20, k_true=5, seed=2)
    n = X.shape[0]
    lam, alpha = 0.02, 0.7
    l1, l2 = penalized_from_glmnet(lam, alpha, n)
    beta = elastic_net_cd(X, y, l1, l2).beta
    # glmnet stationarity: 1/n x_j^T r = lam*alpha*sign + lam*(1-alpha)*b_j
    r = y - X @ beta
    act = np.asarray(jnp.abs(beta) > 1e-10)
    lhs = np.asarray((X.T @ r) / n - lam * (1 - alpha) * beta)
    rhs = lam * alpha * np.sign(np.asarray(beta))
    np.testing.assert_allclose(lhs[act], rhs[act], atol=1e-8)


# ---------------------------------------------------------------------------
# penalized -> constrained mapping
# ---------------------------------------------------------------------------

def test_enet_matches_cd_and_kkt():
    """Single penalized solves match CD to 1e-5 (dual-mode shape), and the
    mapping invariants hold: t = |beta*|_1 and the constrained-form
    multiplier at beta* equals lambda1."""
    X, y, _ = make_regression(80, 25, k_true=6, rho=0.3, seed=0)
    l1max = float(lambda1_max(X, y))
    for frac, lam2 in [(0.5, 1.0), (0.2, 0.5), (0.05, 2.0)]:
        lam1 = frac * l1max
        beta_cd = elastic_net_cd(X, y, lam1, lam2).beta
        res = enet(X, y, lam1, lam2)
        np.testing.assert_allclose(np.asarray(res.beta), np.asarray(beta_cd),
                                   atol=1e-5)
        assert abs(float(res.t) - float(jnp.abs(beta_cd).sum())) < 1e-6
        assert abs(float(res.nu) - lam1) / l1max < 1e-7
        nu_kkt = float(kkt_multiplier(X, y, res.beta, lam2))
        assert abs(nu_kkt - lam1) / l1max < 1e-6


def test_enet_path_matches_cd_40_points():
    """Acceptance: the screening-fused scan path matches warm-started CD to
    1e-5 across a 40-point lambda grid (primal-mode shape), in one trace."""
    X, y, _ = make_regression(60, 40, k_true=8, rho=0.4, seed=1)
    grid = lambda_grid(X, y, n_lambdas=40)
    reset_trace_counts()
    path = enet_path(X, y, lambda1s=grid, lambda2=1.0)
    betas_cd = cd_path(X, y, grid, 1.0)
    np.testing.assert_allclose(np.asarray(path.betas), np.asarray(betas_cd),
                               atol=1e-5)
    # top of the path: beta identically zero at lambda1_max
    assert float(jnp.abs(path.betas[0]).max()) == 0.0
    # budgets increase down the path and equal |beta|_1
    np.testing.assert_allclose(np.asarray(path.ts),
                               np.abs(np.asarray(path.betas)).sum(1), atol=1e-12)
    # one executable for the whole grid; new grid values must not retrace
    enet_path(X, y, lambda1s=grid * 0.999, lambda2=1.0)
    assert trace_counts().get("enet_path_scan") == 1


def test_enet_path_screen_on_off_identical():
    X, y, _ = make_regression(40, 90, k_true=6, rho=0.3, seed=4)
    grid = lambda_grid(X, y, n_lambdas=12)
    on = enet_path(X, y, lambda1s=grid, lambda2=0.7)
    off = enet_path(X, y, lambda1s=grid, lambda2=0.7,
                    config=api.PathConfig(screen=False))
    np.testing.assert_allclose(np.asarray(on.betas), np.asarray(off.betas),
                               atol=1e-7)
    assert int(on.n_kept.min()) < 90          # the screen actually fired
    assert int(off.n_kept.min()) == 90


# ---------------------------------------------------------------------------
# standardization / intercept round trip
# ---------------------------------------------------------------------------

def _raw_problem(seed=3):
    """Un-standardized data: scaled/shifted columns, offset response."""
    rng = np.random.default_rng(seed)
    Xs, ys, _ = make_regression(70, 15, k_true=5, seed=seed)
    scales = rng.uniform(0.5, 8.0, 15)
    shifts = rng.uniform(-3.0, 3.0, 15)
    X = np.asarray(Xs) * scales + shifts
    y = np.asarray(ys) + 4.2
    return jnp.asarray(X), jnp.asarray(y)


def test_standardize_intercept_roundtrip():
    """Fitting with standardize+intercept equals solving the manually
    standardized problem with CD and un-scaling by hand — exact round trip."""
    X, y = _raw_problem()
    lam2 = 1.0
    Xs, ys, scaler = api.standardize_fit(X, y)
    lam1 = 0.3 * float(lambda1_max(Xs, ys))

    model = ElasticNet(lam1, lam2).fit(X, y)
    beta_std = elastic_net_cd(Xs, ys, lam1, lam2).beta
    beta_ref, b0_ref = api.unscale_coef(beta_std, scaler)
    np.testing.assert_allclose(np.asarray(model.coef_), np.asarray(beta_ref),
                               atol=1e-6)
    assert abs(float(model.intercept_) - float(b0_ref)) < 1e-6
    # prediction identity: original-scale predict == standardized-space predict
    pred = model.predict(X)
    pred_std = Xs @ beta_std + scaler.y_mean
    np.testing.assert_allclose(np.asarray(pred), np.asarray(pred_std), atol=1e-6)
    # centered design => residuals are mean-zero (the intercept is unpenalized)
    assert abs(float(jnp.mean(y - pred))) < 1e-8


def test_standardize_fit_statistics():
    X, y = _raw_problem(seed=9)
    Xs, ys, scaler = api.standardize_fit(X, y)
    np.testing.assert_allclose(np.asarray(Xs.mean(0)), 0.0, atol=1e-10)
    np.testing.assert_allclose(np.asarray(jnp.sqrt(jnp.mean(Xs * Xs, 0))), 1.0,
                               atol=1e-10)
    assert abs(float(ys.mean())) < 1e-10
    # no-op mode returns the data untouched
    X2, y2, s2 = api.standardize_fit(X, y, standardize=False, fit_intercept=False)
    assert (np.asarray(X2) == np.asarray(X)).all()
    np.testing.assert_allclose(np.asarray(s2.x_scale), 1.0)


# ---------------------------------------------------------------------------
# keep-mask wiring through sven / sven_batch
# ---------------------------------------------------------------------------

def test_sven_keep_mask_matches_full_solve():
    X, y, _ = make_regression(36, 100, k_true=6, seed=7)
    lam2 = 1.0
    lam1 = 0.35 * float(lambda1_max(X, y))
    beta_star = elastic_net_cd(X, y, lam1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_star)))
    keep = gap_safe_screen(X, y, beta_star, lam1, lam2).keep
    assert 0 < int(keep.sum()) < 100
    masked = sven(X, y, t, lam2, keep=keep)
    full = sven(X, y, t, lam2)
    np.testing.assert_allclose(np.asarray(masked.beta), np.asarray(full.beta),
                               atol=1e-6)
    assert (np.asarray(masked.beta)[~np.asarray(keep)] == 0.0).all()


def test_sven_batch_keep_mask():
    """Batched keep (B, p) masks each stacked problem independently."""
    X, y, _ = make_regression(80, 24, k_true=5, seed=8)
    lam2 = 1.0
    fracs = [0.5, 0.3, 0.2]
    ts, keeps = [], []
    for f in fracs:
        lam1 = f * float(lambda1_max(X, y))
        b = elastic_net_cd(X, y, lam1, lam2).beta
        ts.append(float(jnp.abs(b).sum()))
        keeps.append(gap_safe_screen(X, y, b, lam1, lam2).keep)
    keep_b = jnp.stack(keeps)
    sol = sven_batch(X, y, jnp.asarray(ts), lam2, keep=keep_b)
    for i, t in enumerate(ts):
        ref = sven(X, y, t, lam2).beta
        np.testing.assert_allclose(np.asarray(sol.beta[i]), np.asarray(ref),
                                   atol=1e-6)
        assert (np.asarray(sol.beta[i])[~np.asarray(keep_b[i])] == 0.0).all()


# ---------------------------------------------------------------------------
# batched cross-validation
# ---------------------------------------------------------------------------

def test_cv_matches_sequential_reference_and_trace_budget():
    """Acceptance: the batched CV surface equals the sequential per-fold loop,
    lambda selection agrees, the refit matches CD to 1e-5, and the whole
    screening-fused CV driver costs at most 2 traces (scan + refit)."""
    X, y, _ = make_regression(84, 30, k_true=6, rho=0.3, seed=5)
    kw = dict(k=4, n_lambdas=40, lambda2=1.0,
              standardize=False, fit_intercept=False)
    reset_trace_counts()
    res = cross_validate(X, y, **kw)
    counts = trace_counts()
    assert counts.get("enet_cv_scan", 0) == 1
    assert counts.get("enet_cv_scan", 0) + counts.get("enet", 0) <= 2

    lam1s, mse_ref = cross_validate_reference(X, y, **kw)
    np.testing.assert_allclose(np.asarray(res.mse_path), np.asarray(mse_ref),
                               atol=1e-10)
    assert res.index_min == int(jnp.argmin(mse_ref.mean(1)))

    beta_cd = elastic_net_cd(X, y, res.lambda_min, 1.0).beta
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(beta_cd),
                               atol=1e-5)


def test_elastic_net_cv_estimator():
    X, y = _raw_problem(seed=6)
    cv = ElasticNetCV(k=4, n_lambdas=12, lambda2=1.0).fit(X, y)
    assert cv.mse_path_.shape == (12, 4)
    assert float(cv.mean_mse_.min()) == float(cv.mean_mse_[int(jnp.argmin(cv.mean_mse_))])
    assert cv.lambda_min_ == float(cv.lambda1s_[int(jnp.argmin(cv.mean_mse_))])
    # predictions at lambda_min beat the null model on the training data
    mse_fit = float(jnp.mean((cv.predict(X) - y) ** 2))
    assert mse_fit < float(jnp.var(y))


# ---------------------------------------------------------------------------
# serving: penalized-form requests
# ---------------------------------------------------------------------------

def test_engine_penalized_requests():
    from repro.serve import ElasticNetEngine

    engine = ElasticNetEngine()
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(3):
        n = int(rng.integers(24, 60))
        p = int(rng.integers(10, 40))
        X, y, _ = make_regression(n, p, k_true=5, seed=i)
        lam1 = 0.3 * float(lambda1_max(X, y))
        reqs.append((X, y, lam1, 1.0))
    # mix forms in one drain: penalized and constrained bucket separately
    ids_pen = [engine.submit_penalized(*r) for r in reqs]
    X0, y0, lam10, _ = reqs[0]
    id_con = engine.submit(X0, y0, 1.0, 1.0)
    out = engine.drain()
    for (X, y, lam1, lam2), rid in zip(reqs, ids_pen):
        beta_cd = elastic_net_cd(X, y, lam1, lam2).beta
        got = np.asarray(out[rid].beta)
        np.testing.assert_allclose(got, np.asarray(beta_cd), atol=1e-5)
        assert got.shape == (X.shape[1],)      # unpadded back to the request p
    ref = sven(X0, y0, 1.0, 1.0).beta
    np.testing.assert_allclose(np.asarray(out[id_con].beta), np.asarray(ref),
                               atol=1e-6)
