"""Contracts of the continuous-batching serving runtime (DESIGN.md §8):

- scheduler admission: full buckets launch immediately, expired deadlines
  launch partial buckets, priorities order overflow, failed dispatches
  re-queue;
- runtime drain == drain_reference == direct solves, for both problem forms;
- warm-start cache: neighborhood hit/miss semantics, eviction bounds, and
  the serving property that a warm re-solve returns the same solution in
  fewer solver iterations;
- warm operands on the batch entry points (`sven_batch` warm_alpha/warm_w,
  `enet_batch` warm/has_warm) leave solutions unchanged;
- penalized-form padding invariance (ISSUE 4 satellite): zero-row/zero-
  column padding through `submit_penalized` returns the exact unpadded
  `enet` solution — the penalized mirror of the constrained padding test;
- online rank-1 updates == from-scratch solves on the accumulated rows;
- metrics percentiles and loadgen reproducibility.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import enet, sven, sven_batch
from repro.core.api import EnetCarry, enet_batch
from repro.core.elastic_net import lambda1_max
from repro.data.synthetic import make_regression
from repro.runtime import (ContinuousScheduler, LoadSpec, OnlineElasticNet,
                           SolutionCache, WarmEntry, fingerprint_problem,
                           make_workload, percentile, run_open_loop)
from repro.runtime.cache import CONSTRAINED
from repro.serve import ElasticNetEngine

ATOL = 1e-6


def _problem(n, p, seed=0):
    X, y, _ = make_regression(n, p, k_true=max(3, p // 6), rho=0.3, seed=seed)
    t_scale = 0.2 * float(jnp.sum(jnp.abs(X.T @ y))) / n
    return X, y, t_scale


# ---------------------------------------------------------------------------
# scheduler admission / launch policy
# ---------------------------------------------------------------------------

def test_full_bucket_launches_on_submit():
    sched = ContinuousScheduler(max_batch=4, max_wait=None, min_n=16, min_p=8)
    X, y, t = _problem(20, 10, seed=0)
    for i in range(4):
        sched.submit(X, y, t=t * (1 + 0.01 * i), lambda2=1.0)
    assert sched.stats.launched_full == 1      # 4th submit filled the bucket
    assert sched.pending_requests == []
    assert sched.in_flight_count + len(sched.harvest(block=True)) >= 4


def test_deadline_launch_on_poll():
    sched = ContinuousScheduler(max_batch=64, max_wait=0.01)
    X, y, t = _problem(20, 10, seed=1)
    sched.submit(X, y, t=t, lambda2=1.0)
    assert sched.stats.launched_deadline == 0  # window still open
    time.sleep(0.02)
    sched.poll()
    assert sched.stats.launched_deadline == 1  # expired -> partial launch
    out = sched.harvest(block=True)
    assert len(out) == 1


def test_priority_orders_overflowing_bucket():
    sched = ContinuousScheduler(max_batch=2, max_wait=None)
    X, y, t = _problem(20, 10, seed=2)
    low = sched.submit(X, y, t=t, lambda2=1.0, priority=0)
    mid = sched.submit(X, y, t=t * 1.1, lambda2=1.0, priority=1)
    # bucket is full (max_batch=2): the two highest-priority requests must
    # have launched together, leaving nothing pending before the third
    hi = sched.submit(X, y, t=t * 1.2, lambda2=1.0, priority=5)
    pending = [r.req_id for r in sched.pending_requests]
    assert pending == [hi]
    out = sched.drain()
    assert set(out) == {low, mid, hi}


def test_expired_low_priority_request_not_stranded_by_overflow():
    """A deadline pop whose request gets priority-bumped out of the launch
    chunk must re-arm, so the remainder launches on the same poll — the
    request can't be stranded with no heap entry until a manual flush."""
    sched = ContinuousScheduler(max_batch=2, max_wait=0.01,
                                auto_launch_full=False)
    X, y, t = _problem(20, 10, seed=20)
    low = sched.submit(X, y, t=t, lambda2=1.0, priority=0)
    sched.submit(X, y, t=t * 1.1, lambda2=1.0, priority=5)
    sched.submit(X, y, t=t * 1.2, lambda2=1.0, priority=5)
    time.sleep(0.02)
    sched.poll()
    assert sched.pending_requests == []        # low launched too, same poll
    assert low in sched.harvest(block=True)


def test_metrics_survive_reset_with_preexisting_requests():
    """run_open_loop resets the recorder; requests submitted BEFORE the run
    must still drain (untracked, not KeyError)."""
    from repro.runtime import make_workload as mw
    sched = ContinuousScheduler(max_batch=4, max_wait=None)
    X, y, t = _problem(20, 10, seed=21)
    old = sched.submit(X, y, t=t, lambda2=1.0)
    spec = LoadSpec(n_requests=4, n_datasets=1, shapes=((20, 10),), seed=5)
    out = run_open_loop(sched, mw(spec))
    assert out["n_completed"] == 4             # reset scoped to the run
    assert old in out["results"]               # old request drained fine


def test_dispatch_failure_requeues(monkeypatch):
    sched = ContinuousScheduler(max_batch=8, max_wait=None)
    X, y, t = _problem(20, 10, seed=3)
    rid = sched.submit(X, y, t=t, lambda2=1.0)

    def boom(*a, **k):
        raise RuntimeError("boom")

    monkeypatch.setattr(sched, "_dispatch", boom)
    with pytest.raises(RuntimeError, match="boom"):
        sched.drain()
    assert [r.req_id for r in sched.pending_requests] == [rid]
    monkeypatch.undo()
    out = sched.drain()
    np.testing.assert_allclose(out[rid].beta, sven(X, y, t, 1.0).beta,
                               atol=ATOL)


def test_requeue_rechecks_deadline(monkeypatch):
    """Regression (ISSUE 6 satellite): requeue-on-failure used to re-admit
    without re-checking the deadline, so an expired request went straight
    back into the launch that just failed — a tight retry loop. An expired
    request must instead complete terminally with status="deadline_exceeded"
    (beta None), while unexpired requests are re-admitted and still solve."""
    fake_now = [0.0]
    sched = ContinuousScheduler(max_batch=8, max_wait=0.5,
                                clock=lambda: fake_now[0])
    X, y, t = _problem(20, 10, seed=22)
    rid_live = sched.submit(X, y, t=t, lambda2=1.0)
    rid_dead = sched.submit(X, y, t=t * 1.1, lambda2=1.0, deadline=1.0)

    calls = []

    def boom(*a, **k):
        calls.append(1)
        raise RuntimeError("boom")

    monkeypatch.setattr(sched, "_dispatch", boom)
    fake_now[0] = 2.0   # both deadlines (0 + max_wait = 0.5, and 1.0) passed
    with pytest.raises(RuntimeError, match="boom"):
        sched.flush()
    monkeypatch.undo()
    assert sched.pending_requests == []
    res_dead = sched.result(rid_dead)
    assert res_dead.status == "deadline_exceeded" and res_dead.beta is None
    res_live = sched.result(rid_live)
    assert res_live.status == "deadline_exceeded" and res_live.beta is None

    # unexpired arm: deadline far in the (fake) future survives the failed
    # dispatch, stays pending, and solves once dispatch works again
    rid2 = sched.submit(X, y, t=t, lambda2=1.0, deadline=100.0)
    monkeypatch.setattr(sched, "_dispatch", boom)
    with pytest.raises(RuntimeError, match="boom"):
        sched.flush()
    monkeypatch.undo()
    assert [r.req_id for r in sched.pending_requests] == [rid2]
    out = sched.drain()
    assert out[rid2].status == "ok"
    np.testing.assert_allclose(out[rid2].beta, sven(X, y, t, 1.0).beta,
                               atol=ATOL)


def test_submit_validation():
    sched = ContinuousScheduler()
    X, y, t = _problem(20, 10, seed=4)
    with pytest.raises(ValueError, match="exactly one"):
        sched.submit(X, y, t=t, lambda1=1.0)
    with pytest.raises(ValueError, match="exactly one"):
        sched.submit(X, y)
    with pytest.raises(ValueError, match="bad shapes"):
        sched.submit(X, y[:-1], t=t)
    with pytest.raises(ValueError, match="lambda1 >= 0"):
        sched.submit(X, y, lambda1=-1.0)
    with pytest.raises(ValueError, match="lambda2 >= 0"):
        sched.submit(X, y, lambda1=1.0, lambda2=-1.0)


def test_result_blocks_for_one_request_only():
    sched = ContinuousScheduler(max_batch=8, max_wait=None)
    Xa, ya, ta = _problem(20, 10, seed=5)
    Xb, yb, tb = _problem(40, 20, seed=6)     # different bucket
    other = sched.submit(Xa, ya, t=ta, lambda2=1.0)
    mine = sched.submit(Xb, yb, t=tb, lambda2=2.0)
    res = sched.result(mine)
    np.testing.assert_allclose(res.beta, sven(Xb, yb, tb, 2.0).beta, atol=ATOL)
    # the other bucket was left alone and still drains
    assert [r.req_id for r in sched.pending_requests] == [other]
    assert set(sched.drain()) == {other}


# ---------------------------------------------------------------------------
# runtime drain == reference drain == direct solves
# ---------------------------------------------------------------------------

def test_drain_matches_reference_and_direct_mixed_forms():
    engine = ElasticNetEngine(max_batch=8)
    reference = ElasticNetEngine(max_batch=8, cache=None)
    items = []
    for s, (n, p) in enumerate([(26, 12), (26, 12), (33, 17), (40, 9)]):
        X, y, t = _problem(n, p, seed=30 + s)
        lam1 = 0.35 * float(lambda1_max(X, y))
        items.append((X, y, t, lam1, 0.5 + s))
    ids, ref_ids = [], []
    for X, y, t, lam1, lam2 in items:
        ids.append((engine.submit(X, y, t, lam2),
                    engine.submit_penalized(X, y, lam1, lam2)))
        ref_ids.append((reference.submit(X, y, t, lam2),
                        reference.submit_penalized(X, y, lam1, lam2)))
    out = engine.drain()
    ref_out = reference.drain_reference()
    for (X, y, t, lam1, lam2), (cid, pid), (rc, rp) in zip(items, ids, ref_ids):
        np.testing.assert_allclose(out[cid].beta, sven(X, y, t, lam2).beta,
                                   atol=ATOL)
        np.testing.assert_allclose(out[pid].beta, enet(X, y, lam1, lam2).beta,
                                   atol=ATOL)
        np.testing.assert_allclose(out[cid].beta, ref_out[rc].beta, atol=ATOL)
        np.testing.assert_allclose(out[pid].beta, ref_out[rp].beta, atol=ATOL)


# ---------------------------------------------------------------------------
# warm-start cache
# ---------------------------------------------------------------------------

def test_cache_neighborhood_and_eviction():
    cache = SolutionCache(max_problems=2, per_problem=2, neighborhood=0.5)
    z = np.zeros(4)

    def entry(lam):
        return WarmEntry(lam=lam, lambda2=1.0, alpha=z, w=z, beta=z,
                         t=lam, nu=0.0)

    cache.insert("fpA", CONSTRAINED, entry(1.0))
    assert cache.lookup("fpA", CONSTRAINED, 1.2, 1.0).lam == 1.0   # near hit
    assert cache.lookup("fpA", CONSTRAINED, 3.0, 1.0) is None      # too far
    assert cache.lookup("fpA", CONSTRAINED, 1.0, 10.0) is None     # l2 far
    assert cache.lookup("fpB", CONSTRAINED, 1.0, 1.0) is None      # no data
    assert (cache.hits, cache.misses) == (1, 3)
    # per-problem bound: 3 distinct lambdas keep only the latest 2
    cache.insert("fpA", CONSTRAINED, entry(2.0))
    cache.insert("fpA", CONSTRAINED, entry(4.0))
    assert len(cache) == 2
    assert cache.lookup("fpA", CONSTRAINED, 1.0, 1.0) is None      # evicted
    # same-lambda re-insert replaces, never grows
    cache.insert("fpA", CONSTRAINED, entry(4.0))
    assert len(cache) == 2
    # LRU problem bound
    cache.insert("fpB", CONSTRAINED, entry(1.0))
    cache.insert("fpC", CONSTRAINED, entry(1.0))
    assert len(cache._store) == 2


def test_fingerprint_sensitivity():
    X, y, _ = _problem(20, 10, seed=7)
    fp1 = fingerprint_problem(X, y)
    assert fp1 == fingerprint_problem(np.asarray(X), np.asarray(y))
    X2 = np.asarray(X).copy()
    X2[0, 0] += 1e-12
    assert fp1 != fingerprint_problem(X2, y)


def test_warm_resolve_same_solution_fewer_iters():
    """The serving property: adjacent-lambda traffic re-solves warm to the
    SAME answer with less solver work."""
    X, y, t = _problem(48, 16, seed=8)
    cold = ContinuousScheduler(max_batch=4, max_wait=None, cache=None)
    warm = ContinuousScheduler(max_batch=4, max_wait=None)
    lams = [t, t * 1.05, t * 0.95, t * 1.02]
    cold_ids = [cold.submit(X, y, t=l, lambda2=1.0) for l in lams]
    cold_out = cold.drain()
    warm_first = warm.submit(X, y, t=t, lambda2=1.0)
    warm.drain()                                   # seeds the cache
    warm_ids = [warm.submit(X, y, t=l, lambda2=1.0) for l in lams[1:]]
    warm_out = warm.drain()
    assert warm.cache.hits >= 3
    cold_iters = warm_iters = 0
    for wid, cid, lam in zip(warm_ids, cold_ids[1:], lams[1:]):
        np.testing.assert_allclose(warm_out[wid].beta, cold_out[cid].beta,
                                   atol=ATOL)
        np.testing.assert_allclose(warm_out[wid].beta,
                                   sven(X, y, lam, 1.0).beta, atol=ATOL)
        cold_iters += int(cold_out[cid].iters)
        warm_iters += int(warm_out[wid].iters)
    assert warm_iters <= cold_iters, (warm_iters, cold_iters)


def test_speculative_presolve_rides_the_crawl():
    """DESIGN.md §11.3: a geometric lambda crawl (the glmnet grid shape)
    gets its NEXT point pre-solved in a padding slot, so by the time the
    client asks for it the exact point is already cached — and speculation
    never changes the answer or the client-facing hit accounting."""
    X, y, t = _problem(40, 12, seed=12)
    fp = fingerprint_problem(X, y)
    sched = ContinuousScheduler(max_batch=4, max_wait=None, speculate=True)
    lams = [t, 0.8 * t, 0.8 * 0.8 * t]     # exact ratio-0.8 crawl

    sched.submit(X, y, t=lams[0], lambda2=1.0)
    sched.drain()
    assert sched.stats.speculative_slots == 0, "one point is not a crawl"
    assert sched.cache.hits + sched.cache.misses == 1, (
        "speculative probes must not touch the client hit/miss counters")

    sched.submit(X, y, t=lams[1], lambda2=1.0)
    sched.drain()
    assert sched.stats.speculative_slots >= 1, (
        "two crawl points must trigger a padding-slot pre-solve")
    assert sched.cache.hits + sched.cache.misses == 2
    # the geometric continuation last*(last/prev) = 0.64*t is solved ALREADY
    assert sched.cache.probe(fp, CONSTRAINED, lams[2], 1.0)

    hits_before = sched.cache.hits
    r2 = sched.submit(X, y, t=lams[2], lambda2=1.0)
    out = sched.drain()
    assert sched.cache.hits == hits_before + 1, (
        "the crawl's next request must warm-start off the speculation")
    np.testing.assert_allclose(out[r2].beta, sven(X, y, lams[2], 1.0).beta,
                               atol=ATOL)


def test_batch_warm_operands_leave_solution_unchanged():
    X, y, t = _problem(30, 10, seed=9)
    ts = jnp.asarray([t, t * 1.1])
    base = sven_batch(X, y, ts, 1.0)
    warm = sven_batch(X, y, ts, 1.0, warm_alpha=base.alpha, warm_w=base.w)
    np.testing.assert_allclose(warm.beta, base.beta, atol=ATOL)

    lam1s = 0.4 * float(lambda1_max(X, y)) * jnp.asarray([1.0, 0.9])
    pts, carry = enet_batch(X, y, lam1s, 1.0, return_carry=True)
    # has_warm=False must be EXACTLY the cold path
    zeros = EnetCarry(*(jnp.zeros_like(f) for f in carry))
    pts_cold = enet_batch(X, y, lam1s, 1.0, warm=zeros,
                          has_warm=jnp.zeros(2, bool))
    np.testing.assert_allclose(pts_cold.beta, pts.beta, atol=0)
    pts_warm = enet_batch(X, y, lam1s, 1.0, warm=carry,
                          has_warm=jnp.ones(2, bool))
    np.testing.assert_allclose(pts_warm.beta, pts.beta, atol=ATOL)
    with pytest.raises(ValueError, match="given together"):
        enet_batch(X, y, lam1s, 1.0, warm=carry)


# ---------------------------------------------------------------------------
# penalized-form padding invariance (satellite): zero rows/columns through
# submit_penalized leave the solution exactly the unpadded enet solution
# ---------------------------------------------------------------------------

def _assert_penalized_padding_exact(n, p, seed, lam_frac, lam2):
    X, y, _ = make_regression(n, p, k_true=max(2, p // 4), rho=0.3, seed=seed)
    lam1 = lam_frac * float(lambda1_max(X, y))
    engine = ElasticNetEngine(min_n=16, min_p=8, cache=None)
    rid = engine.submit_penalized(X, y, lam1, lam2)
    res = engine.drain()[rid]
    bn, bp = res.bucket
    assert bn > n or bp > p or (bn, bp) == (n, p)  # really padded (or exact)
    ref = enet(X, y, lam1, lam2)
    assert res.beta.shape == (p,)
    np.testing.assert_allclose(res.beta, ref.beta, atol=ATOL)
    # screened-out coordinates survive the padding as EXACT zeros
    np.testing.assert_array_equal(np.asarray(res.beta) == 0.0,
                                  np.asarray(ref.beta) == 0.0)


@pytest.mark.parametrize("n,p,lam_frac,lam2",
                         [(19, 7, 0.5, 1.0),    # pads rows and columns
                          (23, 11, 0.25, 0.5),  # pads both, light penalty
                          (32, 8, 0.6, 2.0)])   # exact-n bucket, pads p only
def test_penalized_padding_invariance(n, p, lam_frac, lam2):
    _assert_penalized_padding_exact(n, p, seed=50 + n, lam_frac=lam_frac,
                                    lam2=lam2)


@settings(max_examples=6, deadline=None)
@given(st.integers(10, 40), st.integers(4, 20), st.integers(0, 99),
       st.floats(0.15, 0.7), st.floats(0.1, 3.0))
def test_penalized_padding_invariance_property(n, p, seed, lam_frac, lam2):
    _assert_penalized_padding_exact(n, p, seed, lam_frac, lam2)


# ---------------------------------------------------------------------------
# online rank-1 updates
# ---------------------------------------------------------------------------

def test_online_matches_from_scratch_solves():
    X, y, t = _problem(60, 12, seed=10)
    online = OnlineElasticNet(p=12)
    online.update(X[:40], y[:40])
    s1 = online.solve(t, 1.0)
    np.testing.assert_allclose(s1.beta, sven(X[:40], y[:40], t, 1.0).beta,
                               atol=ATOL)
    np.testing.assert_allclose(s1.kkt, sven(X[:40], y[:40], t, 1.0).kkt,
                               atol=1e-6)
    for i in range(40, 60):                      # rank-1 row arrivals
        online.update(X[i], y[i])
    assert online.n == 60
    s2 = online.solve(t, 1.0)
    ref = sven(X, y, t, 1.0)
    np.testing.assert_allclose(s2.beta, ref.beta, atol=ATOL)
    # warm re-solve at a nearby budget: same answer as cold, fewer iters
    s3 = online.solve(t * 1.03, 1.0)
    cold = sven(X, y, t * 1.03, 1.0)
    np.testing.assert_allclose(s3.beta, cold.beta, atol=ATOL)
    assert int(s3.iters) <= int(cold.iters)


def test_online_validation():
    online = OnlineElasticNet(p=5)
    with pytest.raises(ValueError, match="no rows"):
        online.solve(1.0)
    with pytest.raises(ValueError, match="bad shapes"):
        online.update(np.zeros((3, 4)), np.zeros(3))


# ---------------------------------------------------------------------------
# metrics + loadgen
# ---------------------------------------------------------------------------

def test_percentile_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == 2.5
    with pytest.raises(ValueError):
        percentile([], 50)


def test_loadgen_reproducible_and_complete():
    spec = LoadSpec(n_requests=10, n_datasets=2, penalized_fraction=0.3,
                    shapes=((20, 10), (30, 14)), seed=3)
    w1, w2 = make_workload(spec), make_workload(spec)
    assert [i.lam for i in w1] == [i.lam for i in w2]
    assert all((a.X == b.X).all() for a, b in zip(w1, w2))
    # data_seed pins datasets while the lambda stream moves
    w3 = make_workload(LoadSpec(n_requests=10, n_datasets=2,
                                penalized_fraction=0.3,
                                shapes=((20, 10), (30, 14)), seed=4,
                                data_seed=3))
    fp1 = {fingerprint_problem(i.X, i.y) for i in w1}
    fp3 = {fingerprint_problem(i.X, i.y) for i in w3}
    assert fp3 <= fp1 and [i.lam for i in w3] != [i.lam for i in w1]

    sched = ContinuousScheduler(max_batch=4, max_wait=0.002)
    out = run_open_loop(sched, w1)
    assert out["n_completed"] == 10 and len(out["results"]) == 10
    assert out["p99_latency_s"] >= out["p50_latency_s"] > 0
    for item, rid in zip(w1, out["ids"]):
        ref = (enet(item.X, item.y, item.lam, item.lambda2).beta
               if item.form == "penalized"
               else sven(item.X, item.y, item.lam, item.lambda2).beta)
        np.testing.assert_allclose(out["results"][rid].beta, ref, atol=ATOL)
