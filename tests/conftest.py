"""Test configuration. NOTE: no XLA device-count flags here — tests must see
the real single CPU device; only launch/dryrun.py forces 512 host devices."""
import jax

# Convex-solver exactness tests need f64 on CPU; model code pins its own dtypes.
jax.config.update("jax_enable_x64", True)
