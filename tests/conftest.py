"""Test configuration. NOTE: no XLA device-count flags here — tests must see
the real single CPU device; only launch/dryrun.py forces 512 host devices."""
import jax
import pytest

# Convex-solver exactness tests need f64 on CPU; model code pins its own dtypes.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True)
def _isolated_disk_caches(tmp_path, monkeypatch):
    """Point every on-disk cache (`utils.cache_dir()`: autotune tiles,
    routing calibrations, warm-start spill tiers) at this test's private
    tmp dir. Without this, tests leak persisted state into each other AND
    into the developer's real ~/.cache/repro-sven — a test that measures a
    calibration pollutes every later test's routing, and a spill-tier test
    could serve a stale entry written by a previous session. Subprocesses
    launched through tests/_subprocess.py inherit the env var, so their
    disk caches land in the same per-test sandbox."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "repro-cache"))
