"""The jit-native engine's contracts (DESIGN.md §6):

- `sven_path` (lax.scan) == `sven_path_reference` (host loop) to 1e-6, in
  both dispatch modes, with the warm w AND alpha genuinely carried;
- the scan compiles exactly once for a 40-point path and never retraces on
  new grid values (trace-count instrumentation);
- `sven()` itself never retraces across (t, lambda2) sweeps at fixed shape;
- `sven_batch` == per-problem `sven` loops for every stacking pattern
  (multi-response, (t, lambda2) grid, stacked CV folds);
- ElasticNetEngine padded/bucketed solves == direct unpadded solves, and
  steady-state traffic adds no new executables.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cv_folds, en_grid, reset_trace_counts, sven,
                        sven_batch, sven_path, sven_path_reference,
                        trace_counts)
from repro.core.elastic_net import lambda1_max
from repro.core.svm import (Hyper, dual_newton_machine, make_hyper,
                            primal_newton_machine)
from repro.data.synthetic import make_regression
from repro.serve import ElasticNetEngine

PATH_ATOL = 1e-6


def _problem(n, p, seed=0):
    X, y, _ = make_regression(n, p, k_true=max(3, p // 6), rho=0.3, seed=seed)
    t_scale = 0.2 * float(jnp.sum(jnp.abs(X.T @ y))) / n
    return X, y, t_scale


# ---------------------------------------------------------------------------
# scan path vs reference loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p", [(80, 24), (30, 70)])  # dual and primal modes
def test_scan_path_matches_reference_loop(n, p):
    X, y, t_scale = _problem(n, p, seed=1)
    ts = jnp.linspace(0.2, 1.5, 9) * t_scale
    betas_scan = sven_path(X, y, ts, 1.0)
    betas_loop = sven_path_reference(X, y, ts, 1.0)
    np.testing.assert_allclose(betas_scan, betas_loop, atol=PATH_ATOL)
    # and each point agrees with an independent cold solve
    sol_mid = sven(X, y, float(ts[4]), 1.0)
    np.testing.assert_allclose(betas_scan[4], sol_mid.beta, atol=PATH_ATOL)


def test_path_warm_start_carries_w_and_alpha():
    """The reference loop must feed BOTH warm starts back (the seed repo's
    `warm_w` was dead); regression-test via solution-identity at every point
    and via the solver doing less work warm than cold."""
    X, y, t_scale = _problem(26, 60, seed=2)  # primal mode: w is the carry
    ts = jnp.linspace(0.3, 1.2, 6) * t_scale
    betas = sven_path_reference(X, y, ts, 1.0)
    cold_iters, warm_iters = [], []
    warm_a = warm_w = None
    for i, t in enumerate(ts):
        cold = sven(X, y, float(t), 1.0)
        warm = sven(X, y, float(t), 1.0, warm_alpha=warm_a, warm_w=warm_w)
        cold_iters.append(int(cold.iters))
        warm_iters.append(int(warm.iters))
        warm_a, warm_w = warm.alpha, warm.w
        np.testing.assert_allclose(betas[i], cold.beta, atol=PATH_ATOL)
    assert sum(warm_iters) <= sum(cold_iters), (warm_iters, cold_iters)


def test_path_compiles_once_for_40_points():
    X, y, t_scale = _problem(40, 10, seed=3)  # small dual problem: fast scan
    ts40 = jnp.linspace(0.25, 1.25, 40) * t_scale
    reset_trace_counts()
    betas = sven_path(X, y, ts40, 1.0)
    assert betas.shape == (40, 10)
    assert trace_counts().get("sven_path_scan", 0) == 1
    # new grid VALUES and new lambda2, same shapes: zero additional traces
    sven_path(X, y, ts40 * 0.93, 2.0)
    assert trace_counts().get("sven_path_scan", 0) == 1
    # a different grid LENGTH is a new shape, hence one (and only one) more
    sven_path(X, y, ts40[:16], 1.0)
    assert trace_counts().get("sven_path_scan", 0) == 2


def test_sven_never_retraces_across_regularization_sweeps():
    X, y, t_scale = _problem(33, 21, seed=4)
    reset_trace_counts()
    for i, (t, lam2) in enumerate([(1.0, 1.0), (0.7, 2.0), (0.4, 0.25), (1.3, 5.0)]):
        sven(X, y, t * t_scale, lam2)
        assert trace_counts().get("sven", 0) == 1, f"retraced at sweep point {i}"


# ---------------------------------------------------------------------------
# solver machines: traced hyperparameters
# ---------------------------------------------------------------------------

def test_solver_machines_accept_traced_hyperparameters():
    """init/step/run jit with (C, tol) as operands — changing them must not
    retrace, and results must match the eager wrappers."""
    X, y, _ = _problem(50, 8, seed=5)
    from repro.core.reduction import gram_blocks
    K = gram_blocks(X, y, 1.0)
    machine = dual_newton_machine(lambda v: K @ v, m=K.shape[0], dtype=X.dtype)

    n_traces = [0]

    @jax.jit
    def run(C, tol):
        n_traces[0] += 1
        return machine.run(Hyper(C=C, tol=tol))

    s1 = run(jnp.asarray(0.5, X.dtype), jnp.asarray(1e-8, X.dtype))
    s2 = run(jnp.asarray(5.0, X.dtype), jnp.asarray(1e-10, X.dtype))
    assert n_traces[0] == 1
    assert bool(s1.converged) and bool(s2.converged)
    assert not np.allclose(np.asarray(s1.x), np.asarray(s2.x))  # C really traced

    eager = machine.run(make_hyper(5.0, 1e-10, X.dtype))
    np.testing.assert_allclose(s2.x, eager.x, atol=1e-9)


def test_primal_machine_state_protocol():
    X, y, _ = _problem(20, 40, seed=6)
    from repro.core.reduction import SvenOperator
    op = SvenOperator(X=X, y=y, t=jnp.asarray(1.0, X.dtype))
    p = X.shape[1]
    yhat = jnp.concatenate([jnp.ones((p,), X.dtype), -jnp.ones((p,), X.dtype)])
    machine = primal_newton_machine(op.xhat_matvec, op.xhat_rmatvec, yhat, X.shape[0])
    hyper = make_hyper(0.5, 1e-8, X.dtype)
    state = machine.init(hyper)
    assert not bool(state.converged) and int(state.iters) == 0
    stepped = machine.step(state, hyper)
    assert int(stepped.iters) == 1
    final = machine.run(hyper)
    assert bool(final.converged)
    assert float(final.residual) <= 1e-8


# ---------------------------------------------------------------------------
# sven_batch stacking patterns
# ---------------------------------------------------------------------------

def test_batch_grid_matches_sequential():
    X, y, t_scale = _problem(60, 16, seed=7)
    ts, l2s = en_grid(jnp.linspace(0.4, 1.2, 3) * t_scale, jnp.array([0.5, 1.0, 4.0]))
    sol = sven_batch(X, y, ts, l2s)
    assert sol.beta.shape == (9, 16)
    for i in range(ts.shape[0]):
        ref = sven(X, y, float(ts[i]), float(l2s[i]))
        np.testing.assert_allclose(sol.beta[i], ref.beta, atol=PATH_ATOL)
        np.testing.assert_allclose(sol.kkt[i], ref.kkt, atol=1e-6)


def test_batch_multi_response_and_stacked_X():
    X, y, t_scale = _problem(48, 12, seed=8)
    # multi-response: shared X, stacked y
    Y = jnp.stack([y, -y, y * 0.5 + 0.1])
    sol = sven_batch(X, Y, t_scale, 1.0)
    for i in range(3):
        ref = sven(X, Y[i], t_scale, 1.0)
        np.testing.assert_allclose(sol.beta[i], ref.beta, atol=PATH_ATOL)
    # stacked CV folds: batched X AND y
    Xtr, ytr, Xva, yva = cv_folds(X, y, 4)
    assert Xtr.shape == (4, 36, 12) and Xva.shape == (4, 12, 12)
    solf = sven_batch(Xtr, ytr, t_scale, 1.0)
    for i in range(4):
        ref = sven(Xtr[i], ytr[i], t_scale, 1.0)
        np.testing.assert_allclose(solf.beta[i], ref.beta, atol=PATH_ATOL)


def test_batch_input_validation():
    X, y, t_scale = _problem(30, 10, seed=9)
    with pytest.raises(ValueError, match="no batched operand"):
        sven_batch(X, y, t_scale, 1.0)
    with pytest.raises(ValueError, match="inconsistent batch sizes"):
        sven_batch(X, jnp.stack([y, y]), jnp.ones((3,)) * t_scale, 1.0)


def test_batch_compiles_once_per_stacking_pattern():
    X, y, t_scale = _problem(44, 14, seed=10)
    ts = jnp.linspace(0.5, 1.0, 4) * t_scale
    reset_trace_counts()
    sven_batch(X, y, ts, 1.0)
    sven_batch(X, y, ts * 0.8, 3.0)          # new values, same pattern
    assert trace_counts().get("sven_batch", 0) == 1


# ---------------------------------------------------------------------------
# ElasticNetEngine: bucketing, padding exactness, executable reuse
# ---------------------------------------------------------------------------

def test_engine_padded_solves_match_direct():
    engine = ElasticNetEngine(max_batch=8)
    reqs, ids = [], []
    for seed, (n, p) in enumerate([(23, 11), (30, 9), (19, 14), (40, 20)]):
        X, y, t_scale = _problem(n, p, seed=20 + seed)
        reqs.append((X, y, t_scale, 1.0 + seed))
        ids.append(engine.submit(X, y, t_scale, 1.0 + seed))
    out = engine.drain()
    assert engine._queue == []
    for rid, (X, y, t, lam2) in zip(ids, reqs):
        res = out[rid]
        ref = sven(X, y, t, lam2)
        assert res.beta.shape == (X.shape[1],)
        np.testing.assert_allclose(res.beta, ref.beta, atol=PATH_ATOL)
        # bucket really padded: executable shape >= request shape, pow2-ish
        assert res.bucket[0] >= X.shape[0] and res.bucket[1] >= X.shape[1]


def test_engine_reuses_executables_across_waves():
    engine = ElasticNetEngine(max_batch=8)

    def wave(seed0):
        ids = []
        for s in range(4):
            X, y, t_scale = _problem(20 + s, 10 + s, seed=40 + seed0 + s)
            ids.append(engine.submit(X, y, t_scale, 1.0))
        return engine.drain()

    wave(0)
    compiled_after_first = engine.stats.bucket_shapes
    wave(100)   # same shape distribution, new data/values
    assert engine.stats.bucket_shapes == compiled_after_first
    assert engine.stats.requests == 8


def test_engine_solve_convenience_and_validation():
    X, y, t_scale = _problem(25, 7, seed=60)
    engine = ElasticNetEngine()
    res = engine.solve(X, y, t_scale, 1.0)
    np.testing.assert_allclose(res.beta, sven(X, y, t_scale, 1.0).beta,
                               atol=PATH_ATOL)
    with pytest.raises(ValueError, match="bad shapes"):
        engine.submit(X, y[:-1], t_scale, 1.0)
    with pytest.raises(ValueError, match="t > 0"):
        engine.submit(X, y, -1.0, 1.0)


def test_solver_exits_promptly_on_nan():
    """A diverged (NaN) residual is terminal: the machine must stop, not spin
    to max_iters re-iterating on a NaN iterate."""
    for n, p, seed in [(40, 10, 70), (20, 40, 71)]:   # dual and primal
        X, y, t_scale = _problem(n, p, seed=seed)
        X = X.at[0, 0].set(jnp.nan)
        sol = sven(X, y, t_scale, 1.0)
        assert bool(jnp.isnan(sol.opt_residual))
        assert int(sol.iters) <= 2, f"spun {int(sol.iters)} iters on NaN input"


def test_engine_drain_failure_preserves_queue(monkeypatch):
    """Both drain paths — the runtime scheduler's async dispatch and the
    synchronous drain_reference — must re-queue on a failed launch."""
    X1, y1, t1 = _problem(21, 8, seed=71)
    engine = ElasticNetEngine()
    rid = engine.submit(X1, y1, t1, 1.0)

    def boom(*a, **k):
        raise RuntimeError("boom")

    monkeypatch.setattr(engine.scheduler, "_dispatch", boom)
    with pytest.raises(RuntimeError, match="boom"):
        engine.drain()
    assert [r.req_id for r in engine._queue] == [rid]  # nothing lost
    monkeypatch.undo()

    monkeypatch.setattr(engine, "_drain_chunk", boom)
    with pytest.raises(RuntimeError, match="boom"):
        engine.drain_reference()
    assert [r.req_id for r in engine._queue] == [rid]  # nothing lost
    monkeypatch.undo()

    out = engine.drain()   # and the request is still solvable afterwards
    np.testing.assert_allclose(out[rid].beta, sven(X1, y1, t1, 1.0).beta,
                               atol=PATH_ATOL)


def test_engine_rejects_degenerate_bucket_floors():
    with pytest.raises(ValueError, match="must be >= 1"):
        ElasticNetEngine(min_n=0)


def test_engine_solve_does_not_lose_pending_requests():
    """A solve() that drains ride-along requests must hold their results for
    the next drain(), not drop them."""
    X1, y1, t1 = _problem(22, 9, seed=61)
    X2, y2, t2 = _problem(31, 13, seed=62)
    engine = ElasticNetEngine()
    rid = engine.submit(X1, y1, t1, 1.0)
    res2 = engine.solve(X2, y2, t2, 2.0)
    np.testing.assert_allclose(res2.beta, sven(X2, y2, t2, 2.0).beta,
                               atol=PATH_ATOL)
    held = engine.drain()
    assert set(held) == {rid}
    np.testing.assert_allclose(held[rid].beta, sven(X1, y1, t1, 1.0).beta,
                               atol=PATH_ATOL)
