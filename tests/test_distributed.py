"""Distribution-layer tests: distributed SVEN solver equivalence, ZeRO spec
widening, gradient compression, sharding-tree resolution, and (in a
subprocess with forced host devices) the pipeline combinator + a real
multi-device shard_map run of the distributed gram."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dist
from repro.core.distributed import distributed_gram, sven_primal_distributed
from repro.core.reduction import gram_reference
from repro.data.synthetic import make_regression


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_distributed_gram_single_device():
    X, y, _ = make_regression(64, 24, seed=0)
    mesh = _mesh11()
    K = distributed_gram(mesh, X, y, 1.3, row_shard_out=False)
    K_ref = gram_reference(X, y, 1.3)
    np.testing.assert_allclose(np.asarray(K), np.asarray(K_ref), atol=1e-9)


def test_distributed_primal_sven_matches_cd():
    from repro.baselines import elastic_net_cd
    from repro.core.elastic_net import lambda1_max
    X, y, _ = make_regression(40, 120, seed=4)
    l1 = 0.3 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, 1.0).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    mesh = _mesh11()
    beta, res = sven_primal_distributed(mesh, X, y, t, 1.0)
    np.testing.assert_allclose(np.asarray(beta), np.asarray(beta_cd), atol=1e-7)


def test_zero_widen_spec():
    from jax.sharding import PartitionSpec as P
    from repro.dist.zero import _widen_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = _widen_spec(P(None, "model"), (64, 32), "data", mesh)
    assert out == P("data", "model")
    out2 = _widen_spec(P("data",), (64,), "data", mesh)  # already data-sharded
    assert out2 == P("data")


def test_bf16_compression_roundtrip():
    from repro.dist.compress import bf16_compress, bf16_decompress
    g = {"w": jnp.linspace(-2, 2, 64).reshape(8, 8)}
    out = bf16_decompress(bf16_compress(g), g)
    assert out["w"].dtype == g["w"].dtype
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=2e-2)


def test_topk_error_feedback_unbiased_over_steps():
    """With constant gradient g, sum of compressed emissions -> n*g (error
    feedback drains the residual)."""
    from repro.dist.compress import topk_compress, topk_decompress, topk_init
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64,)), jnp.float32)}
    state = topk_init(g)
    acc = jnp.zeros((64,))
    steps = 60
    for _ in range(steps):
        vals, idx, state = topk_compress(g, state, frac=0.05)
        acc = acc + topk_decompress(vals, idx, g)["w"]
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(g["w"]),
                               atol=0.12 * float(jnp.abs(g["w"]).max()))


def test_params_shardings_paths():
    """Sharding resolver assigns sane specs on a trivial mesh (spec names
    resolve; actual axis sizes are 1 here so everything divides)."""
    from repro.configs import get_config
    from repro.dist.shardings import params_shardings
    from repro.models import model as M
    cfg = get_config("mixtral-8x7b", smoke=True)
    mesh = _mesh11()
    with dist.mesh_context(mesh, rules={**dist.DEFAULT_RULES, **cfg.rules_override}):
        shapes = jax.eval_shape(lambda: M.init_model(jax.random.PRNGKey(0), cfg))
        tree = params_shardings(shapes)
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: x is None)
    assert all(l is not None for l in leaves)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")

    # 1) pipeline combinator == sequential composition (4-stage pipe mesh)
    from repro.dist.pipeline import pipeline_apply, sequential_reference
    mesh = jax.make_mesh((4,), ("pipe",))
    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"]) + p["b"]
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (4, 16, 16)) * 0.3,
              "b": jax.random.normal(jax.random.fold_in(k, 1), (4, 16)) * 0.1}
    x = jax.random.normal(jax.random.fold_in(k, 2), (6, 3, 16))  # (M, Bm, d)
    got = pipeline_apply(mesh, stage_fn, params, x)
    want = sequential_reference(stage_fn, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-8)
    print("pipeline OK")

    # 2) distributed gram on a REAL 8-device mesh == reference
    from repro.core.distributed import distributed_gram
    from repro.core.reduction import gram_reference
    from repro.data.synthetic import make_regression
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    X, y, _ = make_regression(64, 16, seed=0)
    K = distributed_gram(mesh2, X, y, 1.1, row_shard_out=True)
    np.testing.assert_allclose(np.asarray(K), np.asarray(gram_reference(X, y, 1.1)), atol=1e-9)
    print("gram8 OK")

    # 2b) reduce-scatter grams: rows come out feature-interleaved — device r
    # emits [+rows_r ; -rows_r] — and interleaved_labels matches that order
    from repro.core.distributed import (distributed_gram_rs,
                                        distributed_gram_rs_syrk,
                                        interleaved_labels)
    K_ref = np.asarray(gram_reference(X, y, 1.1))
    p, n_dev = X.shape[1], 8
    rows = p // n_dev
    perm = np.concatenate([
        np.concatenate([np.arange(r * rows, (r + 1) * rows),
                        p + np.arange(r * rows, (r + 1) * rows)])
        for r in range(n_dev)])
    K_rs = distributed_gram_rs(mesh2, X, y, 1.1)
    np.testing.assert_allclose(np.asarray(K_rs), K_ref[perm, :], atol=1e-9)
    K_syrk = distributed_gram_rs_syrk(mesh2, X, y, 1.1)
    np.testing.assert_allclose(np.asarray(K_syrk), K_ref[perm, :], atol=1e-9)
    yhat = np.concatenate([np.ones(p), -np.ones(p)])
    np.testing.assert_array_equal(
        np.asarray(interleaved_labels(p, n_dev, X.dtype)), yhat[perm])
    print("gram_rs OK")

    # 3) distributed hessian matvec on 8 devices == oracle
    from repro.core.distributed import make_distributed_hessian_matvec
    from repro.kernels.ref import hessian_matvec_ref
    X2, y2, _ = make_regression(32, 64, seed=1)
    hv_fn = make_distributed_hessian_matvec(mesh2, X2, y2, 1.5, 3.0)
    v = jax.random.normal(jax.random.PRNGKey(3), (32,))
    act = (jax.random.uniform(jax.random.PRNGKey(4), (128,)) > 0.5).astype(X2.dtype)
    got = hv_fn(v, act)
    want = hessian_matvec_ref(X2, y2, 1.5, 3.0, act[:64], act[64:], v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-8)
    print("hess8 OK")
""")


def test_multidevice_subprocess():
    """Real multi-device checks need forced host devices — run in a child
    process so the test session keeps its single real device."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.getcwd(),
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "pipeline OK" in r.stdout
    assert "gram8 OK" in r.stdout
    assert "gram_rs OK" in r.stdout
    assert "hess8 OK" in r.stdout
