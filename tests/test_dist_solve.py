"""ISSUE 5 + ISSUE 6: the sharded solve path, cost-model routing, and
their bugfix satellites.

Single-process tests cover the 1-device-mesh bitwise-parity contract of
`sven_sharded` and `sven_routed`, the router's trivial/pinned semantics,
the CV fold-chunk keying on RESOLVED placement (the nested-context
regression), the explicit kernel backend/interpret threading (the
`_on_cpu()` trace-time sniffing regression), the SolutionCache lambda-edge
keying (lasso-only / pure-ridge repeat traffic) and the lambda1 = 0
screening guard. Real multi-device behavior — cross-device parity for
sven / sven_routed / enet_path / CV at <= 1e-10, the routing decision
table never pricing the chosen path above single-device, and the property
that bucket placement never reorders results across device counts 1/2/8 —
runs in subprocesses with forced host devices, so this test session keeps
its real device set.
"""
import json
import math
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subprocess import run_python
from repro import dist
from repro.core import cross_validate, sven, sven_routed, sven_sharded
from repro.core.api import enet
from repro.core.routing import route_batch, route_solve
from repro.core.screening import gap_safe_screen
from repro.core.sven import SvenConfig, resolve_backend, trace_counts
from repro.data.synthetic import make_regression
from repro.kernels.ops import resolve_interpret
from repro.runtime.cache import _log_distance
from repro.runtime.scheduler import ContinuousScheduler


# ---------------------------------------------------------------------------
# kernel backend-selection threading (bugfix: trace-time _on_cpu sniffing)
# ---------------------------------------------------------------------------

def test_resolve_interpret_explicit_wins():
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # explicit beats whatever the operands say
    x = jnp.ones((4,))
    assert resolve_interpret(False, x) is False


def test_resolve_interpret_from_committed_device():
    x = jax.device_put(jnp.ones((4,)), jax.devices("cpu")[0])
    assert resolve_interpret(None, x) is True
    # numpy operands carry no device: process default backend fallback
    assert resolve_interpret(None, np.ones(4)) == (
        jax.default_backend() == "cpu")


def test_resolve_backend_pins_enum_into_config():
    X, y, _ = make_regression(24, 10, seed=0)
    cfg = SvenConfig(backend="pallas")      # deprecated alias of "auto"
    assert cfg.interpret is None
    resolved = resolve_backend(cfg, X, y)
    # CPU-committed operands -> the TPU body under interpret mode, as ONE
    # resolved enum value; the legacy interpret field is normalized away
    assert resolved.backend == "tpu_interpret"
    assert resolved.interpret is None
    assert resolve_backend(SvenConfig(backend="auto"), X, y) == resolved
    # the deprecated interpret flag folds into the enum, not a second field
    folded = resolve_backend(SvenConfig(backend="pallas", interpret=True),
                             X, y)
    assert folded == resolved
    # xla configs are untouched (identity object: resolve_path_config
    # depends on the no-op returning the SAME config)
    plain = SvenConfig()
    assert resolve_backend(plain, X, y) is plain
    # already-resolved configs are identity too
    pinned = SvenConfig(backend="gpu_interpret")
    assert resolve_backend(pinned, X, y) is pinned


def test_sven_pallas_threading_no_retrace_and_parity():
    """An unresolved pallas config and the explicitly-resolved one must hit
    the SAME executable (the resolution happens before the jit key is
    formed), and agree with the xla backend."""
    X, y, _ = make_regression(96, 16, seed=1)  # dual regime (2p < n)
    X, y = X.astype(jnp.float64), y.astype(jnp.float64)
    base = sven(X, y, 1.1, 1.0)
    n0 = trace_counts().get("sven", 0)
    s_auto = sven(X, y, 1.1, 1.0, SvenConfig(backend="pallas"))
    n1 = trace_counts().get("sven", 0)
    s_expl = sven(X, y, 1.1, 1.0, SvenConfig(backend="pallas",
                                             interpret=True))
    n2 = trace_counts().get("sven", 0)
    assert n1 == n0 + 1
    assert n2 == n1, "explicit interpret=True retraced: resolution did not " \
                     "pin the choice into the jit key"
    # pallas gram runs in f32; parity at f32 tolerance
    np.testing.assert_allclose(np.asarray(s_auto.beta), np.asarray(base.beta),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(s_expl.beta),
                               np.asarray(s_auto.beta), atol=0)


# ---------------------------------------------------------------------------
# SolutionCache lambda-edge keying (bugfix)
# ---------------------------------------------------------------------------

def test_log_distance_edges():
    assert _log_distance(0.0, 0.0) == 0.0
    assert _log_distance(0.0, 1e-3) == math.inf
    assert _log_distance(2.0, 0.0) == math.inf
    # exact on the positive axis — no eps-floor distortion: 1e-13 vs 1e-14
    # are an e-fold-sized decade apart, not "adjacent"
    assert abs(_log_distance(1e-13, 1e-14) - math.log(10.0)) < 1e-12
    assert _log_distance(3.0, 3.0) == 0.0


def test_cache_lasso_repeat_traffic_warm_hits():
    """Lasso-only (lambda2 = 0) repeat traffic must warm-start itself."""
    X, y, _ = make_regression(40, 12, seed=3)
    X, y = np.asarray(X), np.asarray(y)
    direct = enet(X, y, 0.5, 0.0).beta
    sched = ContinuousScheduler(max_batch=4, max_wait=None)
    first = [sched.submit(X, y, lambda1=0.5, lambda2=0.0) for _ in range(4)]
    sched.drain()
    assert sched.cache.hits == 0
    again = [sched.submit(X, y, lambda1=0.5, lambda2=0.0) for _ in range(4)]
    out = sched.drain()
    assert sched.cache.hits == len(again), "lasso repeats missed the cache"
    for rid in again:
        np.testing.assert_allclose(np.asarray(out[rid].beta[:12]),
                                   np.asarray(direct), atol=1e-8)
    # constrained-form lasso repeats hit too
    t = float(jnp.sum(jnp.abs(direct)))
    sched.submit(X, y, t=t, lambda2=0.0)
    sched.drain()
    rid = sched.submit(X, y, t=t, lambda2=0.0)
    out = sched.drain()
    assert sched.cache.hits > len(again)
    np.testing.assert_allclose(np.asarray(out[rid].beta[:12]),
                               np.asarray(sven(X, y, t, 0.0).beta), atol=1e-8)


def test_cache_pure_ridge_lambda1_zero():
    """lambda1 = 0 (pure ridge) is admissible, solves to the ridge solution
    and repeat traffic warm-hits — no log(0) anywhere in the key."""
    X, y, _ = make_regression(40, 12, seed=4)
    X, y = np.asarray(X), np.asarray(y)
    b_ridge = jnp.linalg.solve(X.T @ X + 1.5 * jnp.eye(12), X.T @ y)
    sched = ContinuousScheduler(max_batch=2, max_wait=None)
    sched.submit(X, y, lambda1=0.0, lambda2=1.5)
    sched.drain()
    rid = sched.submit(X, y, lambda1=0.0, lambda2=1.5)
    out = sched.drain()
    assert sched.cache.hits >= 1
    np.testing.assert_allclose(np.asarray(out[rid].beta[:12]),
                               np.asarray(b_ridge), atol=1e-5)
    # a ridge entry must NOT answer a nearby-but-penalized request's key as
    # "adjacent" purely through an eps floor; a positive lambda1 is a
    # different axis point with finite distance, lambda1=0 is its own point
    assert _log_distance(0.0, 1e-9) == math.inf


def test_screen_keeps_everything_at_lambda1_zero():
    X, y, _ = make_regression(30, 8, seed=5)
    scr = gap_safe_screen(X, y, jnp.zeros((8,)), 0.0, 1.0)
    assert bool(jnp.all(scr.keep)), "lambda1=0 screen must discard nothing"
    assert bool(jnp.isfinite(scr.gap))
    r = enet(X, y, 0.0, 1.5)
    b_ridge = jnp.linalg.solve(X.T @ X + 1.5 * jnp.eye(8), X.T @ y)
    np.testing.assert_allclose(np.asarray(r.beta), np.asarray(b_ridge),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# sharded solve path: 1-device-mesh contract (multi-device in subprocess)
# ---------------------------------------------------------------------------

def test_sven_sharded_one_device_mesh_matches_sven():
    X, y, _ = make_regression(100, 24, seed=0)    # dual; 100 % 1 == 0
    s0 = sven(X, y, 1.5, 1.0)
    s1 = sven_sharded(X, y, 1.5, 1.0, mesh=dist.data_mesh(1))
    np.testing.assert_allclose(np.asarray(s1.beta), np.asarray(s0.beta),
                               atol=1e-12)
    X2, y2, _ = make_regression(50, 64, seed=1)   # primal, row padding
    p0 = sven(X2, y2, 0.8, 0.7)
    p1 = sven_sharded(X2, y2, 0.8, 0.7, mesh=dist.data_mesh(1))
    assert p1.mode == p0.mode == "primal"
    np.testing.assert_allclose(np.asarray(p1.beta), np.asarray(p0.beta),
                               atol=1e-12)


def test_batch_mesh_graceful_fallback():
    from repro.core.batch import batch_mesh
    assert batch_mesh(8) is None                  # no context
    with dist.mesh_context(dist.data_mesh(1)):
        assert batch_mesh(8) is None              # 1-device mesh
    # a mesh that does not divide the batch falls back too (subprocess runs
    # exercise the >1-device divide case)
    mesh = dist.data_mesh(jax.device_count())
    if mesh.size > 1:
        with dist.mesh_context(mesh):
            assert batch_mesh(mesh.size + 1) is None


# ---------------------------------------------------------------------------
# cost-model routing (core/routing.py): in-process contracts; the >1-device
# decision table runs in subprocesses below
# ---------------------------------------------------------------------------

def test_route_one_device_trivial_and_validation():
    d = route_solve(100, 24, mesh=dist.data_mesh(1))
    assert d.path == "single" and d.costs == {"single": 0.0}
    d = route_batch(48, 12, 8, dist.data_mesh(1), form="penalized")
    assert d.path == "single"
    with pytest.raises(ValueError, match="route must be"):
        route_solve(100, 24, route="fastest")
    with pytest.raises(ValueError, match="route must be"):
        route_batch(100, 24, 8, route="sharded")


def test_sven_routed_one_device_matches_sven_bitwise():
    """On a 1-device mesh every route pin degenerates to plain `sven` (the
    same executable), so parity is bitwise, not approximate."""
    X, y, _ = make_regression(100, 24, seed=0)
    s0 = sven(X, y, 1.5, 1.0)
    for route in ("auto", "single", "sharded"):
        s1 = sven_routed(X, y, 1.5, 1.0, mesh=dist.data_mesh(1), route=route)
        np.testing.assert_array_equal(np.asarray(s1.beta),
                                      np.asarray(s0.beta))


def test_auto_fold_chunk_keys_on_resolved_placement():
    """Regression (ISSUE 6 satellite): the lockstep width keys on where the
    folds are PLACED, never on process-global device counts."""
    from repro.core.cv import _auto_fold_chunk
    if jax.default_backend() == "cpu":
        assert _auto_fold_chunk(8, None) == 1
        assert _auto_fold_chunk(8, dist.data_mesh(1)) == 1
    mesh = dist.data_mesh(jax.device_count())
    if mesh.size > 1:
        assert _auto_fold_chunk(8, mesh) == 8


def test_cv_auto_mesh_inside_one_device_context():
    """The nested-context case: an outer 1-device `mesh_context` with
    mesh="auto" must resolve to single-device placement (chunk keyed on the
    RESOLVED mesh, not on the context's existence) and match the
    no-context run exactly."""
    X, y, _ = make_regression(40, 8, seed=6)
    cv0 = cross_validate(X, y, k=4, n_lambdas=5, mesh=None)
    with dist.mesh_context(dist.data_mesh(1)):
        cv1 = cross_validate(X, y, k=4, n_lambdas=5, mesh="auto")
    np.testing.assert_allclose(np.asarray(cv1.mse_path),
                               np.asarray(cv0.mse_path), atol=1e-12)
    assert cv1.lambda_min == cv0.lambda_min


# ---------------------------------------------------------------------------
# real multi-device runs (subprocess with forced host devices)
# ---------------------------------------------------------------------------

_PARITY_8DEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    jax.config.update("jax_enable_x64", True)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import dist
    from repro.core import cross_validate, sven, sven_batch, sven_sharded
    from repro.core.api import enet_batch, enet_path
    from repro.core.distributed import shard_rows, sharded_hinge_stats
    from repro.core.sven import SvenConfig
    from repro.kernels import ref
    from repro.data.synthetic import make_regression

    TOL = 1e-10
    mesh = dist.data_mesh()
    assert mesh.size == 8

    # 1) sven_sharded parity, dual (with row padding) and primal regimes
    X, y, _ = make_regression(100, 24, seed=0)
    d = float(jnp.abs(sven_sharded(X, y, 1.5, 1.0, mesh=mesh).beta
                      - sven(X, y, 1.5, 1.0).beta).max())
    assert d <= TOL, f"dual sharded dev {d}"
    Xp, yp, _ = make_regression(50, 64, seed=1)
    d = float(jnp.abs(sven_sharded(Xp, yp, 0.8, 0.7, mesh=mesh).beta
                      - sven(Xp, yp, 0.8, 0.7).beta).max())
    assert d <= TOL, f"primal sharded dev {d}"
    # pallas-backed sharded gram (interpret pinned outside the shard_map)
    cfg = SvenConfig(backend="pallas")
    s3 = sven_sharded(X, y, 1.5, 1.0, cfg, mesh=mesh)
    d = float(jnp.abs(s3.beta - sven(X, y, 1.5, 1.0).beta).max())
    assert d <= 5e-5, f"pallas sharded dev {d}"     # f32 kernel
    print("sven_sharded8 OK")

    # 2) batch-axis sharding: stacked solves, order MUST be preserved
    B = 8
    Xb = jnp.stack([make_regression(48, 12, seed=10 + i)[0] for i in range(B)])
    yb = jnp.stack([make_regression(48, 12, seed=10 + i)[1] for i in range(B)])
    tb = jnp.linspace(0.7, 1.8, B)
    l2b = jnp.linspace(0.5, 2.0, B)
    plain = sven_batch(Xb, yb, tb, l2b)
    with dist.mesh_context(mesh):
        sharded = sven_batch(Xb, yb, tb, l2b)
    d = float(jnp.abs(sharded.beta - plain.beta).max())
    assert d <= TOL, f"sven_batch sharded dev {d}"
    lam1 = jnp.linspace(0.8, 0.2, B)
    pl = enet_batch(Xb, yb, lam1, l2b)
    with dist.mesh_context(mesh):
        sh = enet_batch(Xb, yb, lam1, l2b)
    d = float(jnp.abs(sh.beta - pl.beta).max())
    assert d <= TOL, f"enet_batch sharded dev {d}"
    print("batch8 OK")

    # 3) enet_path with row-sharded X (partitioner-driven data parallelism)
    Xe, ye, _ = make_regression(64, 16, seed=2)
    path0 = enet_path(Xe, ye, n_lambdas=8, lambda2=1.0)
    Xs = jax.device_put(Xe, NamedSharding(mesh, P("data", None)))
    ys = jax.device_put(ye, NamedSharding(mesh, P("data")))
    path1 = enet_path(Xs, ys, n_lambdas=8, lambda2=1.0)
    d = float(jnp.abs(path1.betas - path0.betas).max())
    assert d <= TOL, f"enet_path sharded dev {d}"
    print("enet_path8 OK")

    # 4) device-parallel CV (k = 8 folds -> one per device) vs single-device
    Xc, yc, _ = make_regression(64, 10, seed=3)
    cv1 = cross_validate(Xc, yc, k=8, n_lambdas=6, mesh=mesh)
    cv0 = cross_validate(Xc, yc, k=8, n_lambdas=6, mesh=None)
    d = float(jnp.abs(cv1.mse_path - cv0.mse_path).max())
    assert d <= TOL, f"cv sharded mse dev {d}"
    assert cv1.lambda_min == cv0.lambda_min
    print("cv8 OK")

    # 4b) nested context with k = 6 NOT divisible by the 8-device mesh:
    # auto resolution must decline the mesh (resolved placement = single
    # device) and return the no-context answer exactly
    with dist.mesh_context(mesh):
        cv6a = cross_validate(Xc, yc, k=6, n_lambdas=6, mesh="auto")
    cv6b = cross_validate(Xc, yc, k=6, n_lambdas=6, mesh=None)
    d = float(jnp.abs(cv6a.mse_path - cv6b.mse_path).max())
    assert d <= TOL, f"cv nested-context dev {d}"
    assert cv6a.lambda_min == cv6b.lambda_min
    print("cv_nested8 OK")

    # 5) routed solves (ISSUE 6): every route pin — including the forced
    # sharded layout — matches the single-device answer in both regimes
    from repro.core.routing import route_solve, sven_routed
    for route in ("auto", "single", "sharded"):
        d = float(jnp.abs(
            sven_routed(X, y, 1.5, 1.0, mesh=mesh, route=route).beta
            - sven(X, y, 1.5, 1.0).beta).max())
        assert d <= TOL, f"routed({route}) dual dev {d}"
        d = float(jnp.abs(
            sven_routed(Xp, yp, 0.8, 0.7, mesh=mesh, route=route).beta
            - sven(Xp, yp, 0.8, 0.7).beta).max())
        assert d <= TOL, f"routed({route}) primal dev {d}"
    dec = route_solve(100, 24, mesh=mesh)
    assert dec.costs[dec.path] <= dec.costs["single"] + 1e-12
    print("routed8 OK")

    # 6) psum-reduced hinge stats vs the jnp oracle
    Xs2, ys2 = shard_rows(mesh, X, y)
    w = jax.random.normal(jax.random.PRNGKey(0), (Xs2.shape[0],))
    m, a, l, g = sharded_hinge_stats(mesh, Xs2, ys2, 1.5, w, 2.0)
    m0, a0, l0, g0 = ref.hinge_stats_ref(np.asarray(Xs2), np.asarray(ys2),
                                         1.5, np.asarray(w), 2.0)
    for got, want in ((m, m0), (a, a0), (l, l0), (g, g0)):
        assert float(jnp.abs(got - jnp.asarray(want)).max()) <= 1e-12
    print("hinge_stats8 OK")
""")


def test_multidevice_parity_subprocess():
    r = run_python(snippet=_PARITY_8DEV, timeout=900)
    for tag in ("sven_sharded8", "batch8", "enet_path8", "cv8",
                "cv_nested8", "routed8", "hinge_stats8"):
        assert f"{tag} OK" in r.stdout


_ROUTING_DECISIONS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(dc)d"
    import sys; sys.path.insert(0, "src")
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro import dist
    from repro.core.routing import calibrate, route_batch, route_solve

    mesh = dist.data_mesh()
    assert mesh.size == %(dc)d
    cal = calibrate(mesh)
    assert cal.flops_per_s > 0 and cal.psum_latency_s >= 0.0
    assert cal.fanout_speedup > 0 and cal.replicated_slowdown > 0

    EPS = 1e-12
    for n, p in [(64, 8), (256, 16), (768, 48), (4096, 16), (32768, 8),
                 (50, 64)]:
        d = route_solve(n, p, mesh=mesh)
        assert d.path in d.costs, (n, p, d)
        assert d.costs[d.path] <= d.costs["single"] + EPS, (n, p, d)
        # pins are honored while still reporting the model's prices
        assert route_solve(n, p, mesh=mesh, route="single").path == "single"
        s = route_solve(n, p, mesh=mesh, route="sharded")
        assert s.path == "sharded" and "sharded" in s.costs
    for n, p, B in [(48, 12, %(dc)d), (256, 16, 2 * %(dc)d), (64, 10, 64)]:
        d = route_batch(n, p, B, mesh, form="penalized", points=8)
        assert d.costs[d.path] <= d.costs["single"] + EPS, (n, p, B, d)
        assert route_batch(n, p, B, mesh, route="batch").path == "batch"
    # the regression shape: a tiny lone solve must stay single-device —
    # collective latency + multi-device dispatch can never pay for 64x8
    assert route_solve(64, 8, mesh=mesh).path == "single"
    print("ROUTING OK")
""")


def test_routing_decisions_never_price_worse_than_single():
    """Property (ISSUE 6 satellite): on 2 and 8 devices, across dual/primal
    shapes and batch sizes, the router never picks a path the calibrated
    cost model prices above single-device, pinned routes are honored, and
    the tiny-lone-solve regression shape always routes single. (The
    1-device table is trivial and covered in-process above.)"""
    for dc in (2, 8):
        r = run_python(snippet=_ROUTING_DECISIONS % {"dc": dc}, timeout=900)
        assert "ROUTING OK" in r.stdout, f"dc={dc}:\n{r.stdout}"


_BUCKET_ORDER = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(dc)d"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    jax.config.update("jax_enable_x64", True)
    from repro.runtime import ContinuousScheduler, LoadSpec, make_workload

    assert jax.device_count() == %(dc)d
    sched = ContinuousScheduler(max_batch=4, max_wait=None, cache=None)
    spec = LoadSpec(n_requests=12, n_datasets=2, shapes=((24, 10), (32, 14)),
                    penalized_fraction=0.5, seed=11)
    ids = []
    for item in make_workload(spec):
        kw = {"lambda1": item.lam} if item.form == "penalized" else {"t": item.lam}
        ids.append(sched.submit(item.X, item.y, lambda2=item.lambda2, **kw))
    out = sched.drain()
    assert sorted(out) == sorted(ids), "lost or reordered request ids"
    betas = [np.asarray(out[i].beta).tolist() for i in ids]
    print("BETAS=" + json.dumps(betas))
""")


def test_bucket_placement_order_invariant_across_device_counts():
    """Property: the SAME workload solved on 1 / 2 / 8 devices returns the
    SAME beta for every request id — mesh placement must never permute
    results within a bucket (slot order is the contract `_complete` unpads
    by)."""
    results = {}
    for dc in (1, 2, 8):
        r = run_python(snippet=_BUCKET_ORDER % {"dc": dc}, timeout=900)
        line = [l for l in r.stdout.splitlines()
                if l.startswith("BETAS=")][-1]
        results[dc] = json.loads(line.split("=", 1)[1])
    for dc in (2, 8):
        assert len(results[dc]) == len(results[1])
        for i, (a, b) in enumerate(zip(results[dc], results[1])):
            dev = float(np.abs(np.asarray(a) - np.asarray(b)).max())
            assert dev <= 1e-10, (f"request {i} differs between 1 and {dc} "
                                  f"devices by {dev}")
