"""The kernels package's PUBLIC surface (ISSUE 4 satellite): `repro.kernels`
re-exports the ops/ref entry points, and the Pallas kernels agree with the
pure-jnp oracles when forced through interpret mode — the explicit
ref-vs-pallas parity contract for `hinge_hessian_matvec` and `shifted_gram`
(test_kernels.py sweeps shapes/dtypes via the module paths; this file pins
the package-level API and the interpret-mode escape hatches). The
`use_pallas=`/`interpret=` spellings are the DEPRECATED two-flag era —
kept here on purpose as the shim's behavioral contract (must warn, must
still route to the same bodies as the `backend=` enum)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as kernels
from repro.data.synthetic import make_regression


def _problem(n, p, seed=0):
    X, y, _ = make_regression(n, p, k_true=min(5, p), seed=seed,
                              dtype=jnp.float32)
    return X.astype(jnp.float32), y.astype(jnp.float32)


def test_public_surface_exports():
    for name in kernels.__all__:
        assert hasattr(kernels, name), f"missing export {name}"
    # the package-level ops ARE the ops-module entry points
    assert kernels.shifted_gram is kernels.ops.shifted_gram
    assert kernels.hinge_hessian_matvec is kernels.ops.hinge_hessian_matvec
    assert kernels.hinge_stats is kernels.ops.hinge_stats


def test_shifted_gram_pallas_interpret_matches_ref():
    X, y = _problem(72, 50, seed=1)
    t = 1.3
    with pytest.warns(DeprecationWarning):
        K_pallas = kernels.shifted_gram(X, y, t, bm=32, bn=32, bk=32,
                                        use_pallas=True, interpret=True)
    K_ref = kernels.ref.flatten_gram(kernels.ref.gram_blocks_ref(X, y, t))
    with pytest.warns(DeprecationWarning):
        K_escape = kernels.shifted_gram(X, y, t, use_pallas=False)
    assert K_pallas.shape == (100, 100)
    scale = float(jnp.abs(K_ref).max())
    np.testing.assert_allclose(np.asarray(K_pallas), np.asarray(K_ref),
                               atol=3e-6 * scale)
    # escape hatch runs the same jnp oracle under jit: only fusion-level
    # f32 reassociation apart from K_ref
    np.testing.assert_allclose(np.asarray(K_escape), np.asarray(K_ref),
                               atol=1e-6 * scale)


def test_hinge_hessian_matvec_pallas_interpret_matches_ref():
    X, y = _problem(60, 44, seed=2)
    t, C = 0.9, 2.5
    v = jax.random.normal(jax.random.PRNGKey(3), (60,), jnp.float32)
    at = (jax.random.uniform(jax.random.PRNGKey(4), (44,)) > 0.5).astype(
        jnp.float32)
    ab = 1.0 - at
    with pytest.warns(DeprecationWarning):
        hv_pallas = kernels.hinge_hessian_matvec(X, y, t, C, at, ab, v,
                                                 bp=32, bn=32, bk=32,
                                                 use_pallas=True,
                                                 interpret=True)
    hv_ref = kernels.ref.hessian_matvec_ref(X, y, t, C, at, ab, v)
    with pytest.warns(DeprecationWarning):
        hv_escape = kernels.hinge_hessian_matvec(X, y, t, C, at, ab, v,
                                                 use_pallas=False)
    scale = max(1.0, float(jnp.abs(hv_ref).max()))
    np.testing.assert_allclose(np.asarray(hv_pallas), np.asarray(hv_ref),
                               atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(hv_escape), np.asarray(hv_ref),
                               atol=2e-6 * scale)
