"""Checkpointing (atomicity, integrity, retention, elastic restore) and the
deterministic data pipeline (resume/shard contracts)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, MemmapTokens, SyntheticStream, write_token_file


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(12, dtype=jnp.int32).reshape(3, 4)},
            "lst": [jnp.ones((5,)), jnp.zeros((2, 2), jnp.bfloat16)]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    restored, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_integrity_check_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 1, t)
    victim = os.path.join(path, "leaf_00000.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), t)


def test_retention_and_tmp_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    t = _tree()
    # leave a fake torn write behind
    os.makedirs(os.path.join(tmp_path, "step_00000001.tmp-zzz"))
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_restore_latest_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(5, t)
    t2 = jax.tree.map(lambda x: x * 0, t)
    mgr.save(9, t2)
    restored, step, _ = mgr.restore(t)
    assert step == 9
    assert float(jnp.abs(restored["a"]).sum()) == 0.0


def test_elastic_restore_with_sharding(tmp_path):
    """Restore onto an explicit (trivial) mesh sharding — the elastic path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert restored["a"].sharding == NamedSharding(mesh, P())


def test_synthetic_determinism_and_resume():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    s1 = SyntheticStream(cfg)
    batches = [next(s1) for _ in range(5)]
    s2 = SyntheticStream(cfg, start_step=3)  # resume at step 3
    np.testing.assert_array_equal(np.asarray(next(s2)["tokens"]),
                                  np.asarray(batches[3]["tokens"]))
    assert not np.array_equal(np.asarray(batches[0]["tokens"]),
                              np.asarray(batches[1]["tokens"]))


def test_host_sharding_disjoint():
    full = DataConfig(vocab_size=500, seq_len=32, global_batch=8, seed=1)
    h0 = DataConfig(vocab_size=500, seq_len=32, global_batch=8, seed=1, n_hosts=2, host_id=0)
    h1 = DataConfig(vocab_size=500, seq_len=32, global_batch=8, seed=1, n_hosts=2, host_id=1)
    b0 = next(SyntheticStream(h0))["tokens"]
    b1 = next(SyntheticStream(h1))["tokens"]
    assert b0.shape == (4, 32) and b1.shape == (4, 32)
    assert not np.array_equal(np.asarray(b0), np.asarray(b1))


def test_memmap_pipeline(tmp_path):
    toks = np.random.default_rng(0).integers(0, 777, size=10_000).astype(np.int32)
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, toks)
    cfg = DataConfig(vocab_size=777, seq_len=128, global_batch=4, seed=2)
    ds = MemmapTokens(path, cfg)
    b = next(ds)
    assert b["tokens"].shape == (4, 128)
    assert int(b["tokens"].max()) < 777
    # resume determinism
    ds2 = MemmapTokens(path, cfg, start_step=0)
    np.testing.assert_array_equal(np.asarray(next(ds2)["tokens"]), np.asarray(b["tokens"]))
