"""GPU (Triton) kernel bodies, the per-backend registry, the tile autotuner,
and the bf16 + iterative-refinement precision path.

Everything here runs on CPU: the GPU bodies execute in Pallas interpret mode
(``backend="gpu_interpret"``), which is the CPU-side parity gate the ISSUE
specifies — the compiled path reuses the identical kernel body, so interpret
parity plus the compile-only plumbing covers the contract a CPU runner can
check. The optional real-GPU job (``-m gpu``) re-runs the compiled variants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.data.synthetic import make_regression
from repro.kernels import autotune, ops, ref, registry

pytestmark = []  # module runs everywhere; see test_gpu_compiled for the marker


def _problem(n, p, dtype=jnp.float32, seed=0):
    X, y, _ = make_regression(n, p, k_true=min(5, p), seed=seed,
                              dtype=jnp.float32)
    return X.astype(dtype), y.astype(dtype)


# -- registry ---------------------------------------------------------------

def test_registry_tables():
    assert set(registry.registered_ops()) >= {
        "shifted_gram", "hinge_stats", "hinge_xtv", "hinge_xd"}
    assert set(registry.kernel_backends("shifted_gram")) == {
        "tpu", "gpu", "ref"}
    assert set(registry.kernel_backends("hinge_stats")) == {
        "tpu", "gpu", "ref"}
    # the two-pass hinge matvec has no GPU body — GEMV-shaped and
    # memory-bound, cuBLAS via the ref oracle is the honest choice
    assert "gpu" not in registry.kernel_backends("hinge_xtv")


def test_registry_lookup_falls_back_to_ref():
    impl, body, interp = registry.lookup("hinge_xtv", "gpu")
    assert body == "ref" and not interp
    impl_i, body_i, interp_i = registry.lookup("hinge_xtv", "gpu_interpret")
    assert body_i == "ref" and not interp_i
    impl_g, body_g, interp_g = registry.lookup("shifted_gram", "gpu_interpret")
    assert body_g == "gpu" and interp_g


def test_resolve_kernel_backend_cpu_default():
    X = jnp.ones((8, 4))
    assert registry.resolve_kernel_backend(None, X) == "tpu_interpret"
    assert registry.resolve_kernel_backend("auto", X) == "tpu_interpret"
    # explicit resolved values pass through untouched
    for be in registry.RESOLVED_BACKENDS:
        assert registry.resolve_kernel_backend(be, X) == be


def test_split_backend():
    assert registry.split_backend("gpu_interpret") == ("gpu", True)
    assert registry.split_backend("tpu") == ("tpu", False)
    assert registry.split_backend("ref") == ("ref", False)


# -- GPU gram body (interpret-mode parity) ----------------------------------

GPU_GRAM_SHAPES = [(64, 64), (96, 48), (33, 57), (130, 96), (256, 64)]


@pytest.mark.parametrize("n,p", GPU_GRAM_SHAPES)
def test_gpu_gram_parity(n, p):
    X, y = _problem(n, p)
    t = 1.3
    K = ops.shifted_gram(X, y, t, backend="gpu_interpret")
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, t))
    np.testing.assert_allclose(
        np.asarray(K), np.asarray(K_ref),
        atol=3e-6 * max(1.0, float(jnp.abs(K_ref).max())))


def test_gpu_gram_f64_operands_are_cast():
    # preferred_element_type=f32 must not silently widen/narrow: the body
    # casts f64 operands to its f32 compute dtype, so parity holds at f32.
    X, y = _problem(64, 48, jnp.float64)
    K = ops.shifted_gram(X, y, 0.9, backend="gpu_interpret")
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, 0.9))
    np.testing.assert_allclose(
        np.asarray(K, np.float64), np.asarray(K_ref),
        atol=3e-6 * max(1.0, float(jnp.abs(K_ref).max())))


@pytest.mark.parametrize("backend", ["tpu_interpret", "gpu_interpret"])
@pytest.mark.parametrize("precision,tol", [("bf16", 3e-2), ("tf32", 3e-6)])
def test_gram_low_precision_storage(backend, precision, tol):
    # bf16 = reduced-precision storage with f32 accumulation; tf32 only
    # relaxes matmul precision on hardware that has the mode (on CPU and in
    # interpret mode it matches f32).
    X, y = _problem(96, 64)
    K = ops.shifted_gram(X, y, 1.1, backend=backend, precision=precision)
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, 1.1))
    np.testing.assert_allclose(
        np.asarray(K), np.asarray(K_ref),
        atol=tol * max(1.0, float(jnp.abs(K_ref).max())))


@settings(max_examples=10, deadline=None)
@given(st.integers(10, 140), st.integers(9, 140), st.floats(0.3, 4.0),
       st.integers(0, 99))
def test_gpu_gram_property(n, p, t, seed):
    X, y = _problem(n, p, seed=seed)
    K = ops.shifted_gram(X, y, t, backend="gpu_interpret")
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, t))
    np.testing.assert_allclose(
        np.asarray(K), np.asarray(K_ref),
        atol=1e-5 * max(1.0, float(jnp.abs(K_ref).max())))


# -- GPU hinge-stats body ---------------------------------------------------

@pytest.mark.parametrize("n,p", [(64, 64), (130, 96), (57, 33), (200, 40)])
def test_gpu_hinge_stats_parity(n, p):
    X, y = _problem(n, p)
    w = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32) * 0.1
    t, C = 1.3, 2.0
    margin, act, loss, galpha = ops.hinge_stats(
        X, y, t, w, C, backend="gpu_interpret")
    m_ref, a_ref, l_ref, g_ref = ref.hinge_stats_ref(X, y, t, w, C)
    scale = max(1.0, float(jnp.abs(m_ref).max()))
    np.testing.assert_allclose(np.asarray(margin), np.asarray(m_ref),
                               atol=3e-6 * scale)
    np.testing.assert_array_equal(np.asarray(act), np.asarray(a_ref))
    np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(galpha), np.asarray(g_ref),
                               atol=3e-6 * scale)


def test_gpu_vs_tpu_bodies_agree():
    X, y = _problem(128, 96)
    K_gpu = ops.shifted_gram(X, y, 1.7, backend="gpu_interpret")
    K_tpu = ops.shifted_gram(X, y, 1.7, backend="tpu_interpret")
    np.testing.assert_allclose(
        np.asarray(K_gpu), np.asarray(K_tpu),
        atol=3e-6 * max(1.0, float(jnp.abs(K_tpu).max())))


# -- bf16 + iterative refinement --------------------------------------------

def _check_bf16_refined(n, p, seed):
    """bf16-storage dual solve + one full-precision refinement re-solve
    lands within 1e-10 of the full-precision solve (the ISSUE gate)."""
    from repro.core.sven import SvenConfig, sven
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((n, p)) / np.sqrt(n))
    y = jnp.asarray(rng.standard_normal((n,)))
    t = 1.0 + 0.01 * seed
    beta_ref = sven(X, y, t, 0.5,
                    SvenConfig(mode="dual", backend="xla", tol=1e-12)).beta
    for backend in ("tpu_interpret", "gpu_interpret"):
        beta = sven(X, y, t, 0.5,
                    SvenConfig(mode="dual", backend=backend,
                               precision="bf16", tol=1e-12)).beta
        np.testing.assert_allclose(np.asarray(beta), np.asarray(beta_ref),
                                   atol=1e-10)


@pytest.mark.parametrize("n,p,seed", [(120, 16, 0), (200, 24, 7)])
def test_bf16_refined_solve_parity_fixed(n, p, seed):
    _check_bf16_refined(n, p, seed)


@settings(max_examples=6, deadline=None)
@given(st.integers(60, 200), st.integers(8, 24), st.integers(0, 99))
def test_bf16_refined_solve_parity(n, p, seed):
    _check_bf16_refined(n, p, seed)


def test_bf16_unrefined_would_fail_gate():
    """Sanity check the refinement is doing the work: the raw bf16 kernel
    deviates from f32 by far more than 1e-10, so a passing refined solve is
    evidence of refinement, not of bf16 being secretly exact."""
    X, y = _problem(128, 32)
    K16 = ops.shifted_gram(X, y, 1.0, backend="tpu_interpret",
                           precision="bf16")
    K32 = ops.shifted_gram(X, y, 1.0, backend="tpu_interpret")
    assert float(jnp.max(jnp.abs(K16 - K32))) > 1e-6


# -- deprecated two-flag shim -----------------------------------------------

def test_use_pallas_interpret_shim_warns_and_matches():
    X, y = _problem(64, 48)
    with pytest.warns(DeprecationWarning):
        K_old = ops.shifted_gram(X, y, 1.5, interpret=True)
    K_new = ops.shifted_gram(X, y, 1.5, backend="tpu_interpret")
    np.testing.assert_array_equal(np.asarray(K_old), np.asarray(K_new))
    with pytest.warns(DeprecationWarning):
        K_ref = ops.shifted_gram(X, y, 1.5, use_pallas=False)
    np.testing.assert_array_equal(
        np.asarray(K_ref), np.asarray(ops.shifted_gram(X, y, 1.5,
                                                       backend="ref")))


def test_sven_config_interpret_folds_into_enum():
    from repro.core.sven import SvenConfig, resolve_backend
    X, y = _problem(32, 16)
    a = resolve_backend(SvenConfig(backend="auto", interpret=True), X, y)
    b = resolve_backend(SvenConfig(backend="tpu_interpret"), X, y)
    assert a == b and a.interpret is None  # same jit key — no retrace


# -- autotune ---------------------------------------------------------------

def test_shape_bucket_pow2_and_caps():
    assert autotune.shape_bucket(100, 60) == (128, 64)
    assert autotune.shape_bucket(8, 8) == (8, 8)
    assert autotune.shape_bucket(10**6, 10**5) == (8192, 1024)


def test_resolve_tiles_interpret_gets_static_default():
    tiles, source = autotune.resolve_tiles("shifted_gram", "gpu_interpret",
                                           512, 256)
    assert source == "default"
    assert tiles == {"bm": 64, "bn": 64, "bk": 32}
    tiles_ref, source_ref = autotune.resolve_tiles("hinge_stats", "ref",
                                                   512, 256)
    assert source_ref == "default"


def test_resolve_tiles_clamps_to_tiny_problems():
    tiles, _ = autotune.resolve_tiles("shifted_gram", "gpu_interpret", 20, 10)
    assert tiles["bm"] >= 16 and tiles["bk"] >= 16  # Triton tl.dot floor
    tiles_t, _ = autotune.resolve_tiles("shifted_gram", "tpu_interpret",
                                        20, 10)
    assert tiles_t["bm"] <= 16 and tiles_t["bk"] >= 8


def test_resolve_tiles_measure_memory_disk(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_autotune_cache()
    calls = []

    def fake_measure(op, body, tiles, nb, pb, dtype):
        calls.append(tiles)
        return 1.0 if tiles != (32, 32, 32) else 0.1  # rig a winner

    tiles, source = autotune.resolve_tiles(
        "shifted_gram", "gpu", 200, 100, measure=fake_measure)
    assert source == "measured" and tiles == {"bm": 32, "bn": 32, "bk": 32}
    assert len(calls) == len(autotune.GRAM_CANDIDATES["gpu"])

    tiles2, source2 = autotune.resolve_tiles(
        "shifted_gram", "gpu", 200, 100, measure=fake_measure)
    assert source2 == "memory" and tiles2 == tiles
    assert len(calls) == len(autotune.GRAM_CANDIDATES["gpu"])  # no re-sweep

    autotune.clear_autotune_cache()
    tiles3, source3 = autotune.resolve_tiles(
        "shifted_gram", "gpu", 200, 100, measure=fake_measure)
    assert source3 == "disk" and tiles3 == tiles
    autotune.clear_autotune_cache()


def test_resolve_tiles_all_candidates_failing_degrades(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_autotune_cache()

    def exploding(op, body, tiles, nb, pb, dtype):
        raise RuntimeError("compiler rejected tile")

    tiles, source = autotune.resolve_tiles(
        "hinge_stats", "gpu", 300, 80, measure=exploding)
    assert source == "default"
    assert tiles == dict(zip(("bp", "bk"),
                             autotune._clamp((64, 128), "hinge_stats",
                                             *autotune.shape_bucket(300, 80),
                                             "gpu")))
    autotune.clear_autotune_cache()


# -- calibration disk cache -------------------------------------------------

def test_calibration_disk_roundtrip(tmp_path, monkeypatch):
    from repro.core import routing
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    routing.clear_calibration()
    cal = routing.calibrate(None, force=True)
    assert cal.kernel_backend in registry.RESOLVED_BACKENDS
    assert cal.gram_flops_per_s >= 0.0

    from repro import utils
    disk = utils.disk_cache_load("calibration")
    key = routing._disk_key(jax.default_backend(), 1)
    assert key in disk and set(disk[key]) == set(routing.Calibration._fields)

    # tamper the stored entry; a fresh in-process calibrate must read it
    # back from disk rather than re-measuring
    disk[key]["fanout_speedup"] = 123.5
    utils.disk_cache_update("calibration", {key: disk[key]})
    routing.clear_calibration()
    cal2 = routing.calibrate(None)
    assert cal2.fanout_speedup == 123.5
    routing.clear_calibration()


def test_solve_costs_price_gram_rate():
    from repro.core import routing
    cal = routing.Calibration(
        devices=8, backend="cpu", flops_per_s=1e9, psum_latency_s=1e-5,
        psum_per_byte_s=1e-10, fanout_speedup=4.0, replicated_slowdown=1.1,
        kernel_backend="gpu", gram_flops_per_s=4e9)
    costs = routing._solve_costs(10_000, 100, "dual", cal)
    # the data pass is priced at the measured gram kernel rate, not the
    # generic GEMM rate: a 4x slower kernel -> costlier single-device solve
    cal_slow = cal._replace(gram_flops_per_s=1e9)
    costs_slow = routing._solve_costs(10_000, 100, "dual", cal_slow)
    assert costs["single"] < costs_slow["single"]


# -- optional real-GPU job --------------------------------------------------

@pytest.mark.gpu
def test_gpu_compiled_parity():
    """Compiled Triton parity — runs only under the optional GPU CI job
    (`-m gpu`); auto-skips anywhere without a CUDA/ROCm device."""
    if jax.default_backend() not in ("gpu", "cuda", "rocm"):
        pytest.skip("no GPU present")
    X, y = _problem(512, 128)
    K = ops.shifted_gram(X, y, 1.3, backend="gpu")
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, 1.3))
    np.testing.assert_allclose(
        np.asarray(K), np.asarray(K_ref),
        atol=1e-4 * max(1.0, float(jnp.abs(K_ref).max())))
