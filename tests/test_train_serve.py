"""Training-loop and serving-path behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models import model as M
from repro.optim import adamw_init, warmup_cosine
from repro.optim.adamw import adamw_update
from repro.serve.engine import greedy_generate
from repro.train.step import make_train_step


def test_loss_decreases_tiny_model():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, microbatches=1, learning_rate=3e-3))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0)
    stream = SyntheticStream(dcfg)
    losses = []
    for _ in range(25):
        params, opt, metrics = step(params, opt, next(stream))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_adamw_matches_numpy_reference():
    """One AdamW step vs a hand-rolled numpy implementation."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)}
    state = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, new_state = adamw_update(g, state, p, lr=lr, b1=b1, b2=b2, eps=eps,
                                    weight_decay=wd)
    gw = np.asarray(g["w"], np.float64)
    m = (1 - b1) * gw
    v = (1 - b2) * gw * gw
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    want = np.asarray(p["w"], np.float64) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p["w"], np.float64))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)
    assert int(new_state.count) == 1


def test_warmup_cosine_schedule_shape():
    fn = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    vals = [float(fn(jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert vals[0] == 0.0
    assert vals[1] == pytest.approx(0.5)
    assert vals[2] == pytest.approx(1.0, abs=0.1)
    assert vals[3] < vals[2]
    assert vals[4] == pytest.approx(0.1, abs=0.02)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-130m", "mixtral-8x7b"])
def test_greedy_generate_runs(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
    out = greedy_generate(params, cfg, batch, steps=4, max_len=S + 8)
    assert out.shape == (B, 5)
    assert int(out.max()) < cfg.vocab_size
    # deterministic
    out2 = greedy_generate(params, cfg, batch, steps=4, max_len=S + 8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_swa_equals_full_when_window_covers_seq():
    """Mixtral attention with window >= seq length == full causal attention."""
    import dataclasses
    cfg = get_config("mixtral-8x7b", smoke=True)
    cfg_full = dataclasses.replace(cfg, swa_window=None)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16  # window in smoke cfg is 64 > 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
    l1, _ = M.forward(params, cfg, batch)
    l2, _ = M.forward(params, cfg_full, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_adafactor_reduces_loss_and_memory():
    from repro.optim.adafactor import adafactor_init, adafactor_update
    from repro.utils import tree_bytes
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    state = adafactor_init(params)
    # factored state is much smaller than AdamW's 2x f32 moments
    adamw_bytes = 2 * sum(np.prod(p.shape) * 4 for p in jax.tree.leaves(params))
    assert tree_bytes((state.v_row, state.v_col)) < 0.25 * adamw_bytes

    from repro.train.step import lm_loss
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0)
    stream = SyntheticStream(dcfg)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(params, cfg, batch)
        new_p, new_s = adafactor_update(grads, state, params, lr=3e-3)
        return new_p, new_s, loss

    losses = []
    for _ in range(20):
        params, state, loss = step(params, state, next(stream))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
