"""Unit tests of the dry-run analysis tooling itself: the HLO collective
parser (incl. tuple-result combined all-reduces and async -start forms) and
the roofline term arithmetic."""
import numpy as np

from repro.launch.dryrun import collective_bytes, _combine_probes
from repro.launch.roofline import roofline_terms

HLO = """
HloModule jit_step
%fused (a: bf16[4,128]) -> bf16[4,128] { ... }
%all-gather.5 = bf16[2,1024,512]{2,1,0} all-gather(%p0), dimensions={1}
%all-reduce = (f32[], f32[8192]{0}, f32[8192,8192]{1,0}) all-reduce(%a, %b, %c), to_apply=%add
%ar2 = bf16[1024]{0} all-reduce-start(%x), channel_id=3
%ar2d = bf16[1024]{0} all-reduce-done(%ar2)
%rs = f32[32,8192]{1,0} reduce-scatter(%g), dimensions={0}
%a2a = bf16[16,64,7168]{2,1,0} all-to-all(%buf), dimensions={0}
%cp = f32[256]{0} collective-permute(%h), source_target_pairs={{0,1}}
not_an_op_line
%dot = f32[128,128]{1,0} dot(%l, %r), lhs_contracting_dims={1}
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    assert out["all-gather"]["bytes"] == 2 * 1024 * 512 * 2
    assert out["all-gather"]["count"] == 1
    # tuple all-reduce: 4 + 8192*4 + 8192*8192*4 ; async start counted once
    ar = out["all-reduce"]
    assert ar["count"] == 2
    assert ar["bytes"] == (4 + 8192 * 4 + 8192 * 8192 * 4) + 1024 * 2
    assert out["reduce-scatter"]["bytes"] == 32 * 8192 * 4
    assert out["all-to-all"]["bytes"] == 16 * 64 * 7168 * 2
    assert out["collective-permute"]["bytes"] == 256 * 4
    assert "dot" not in out


def test_probe_combination_linear():
    rec = {}
    recA = {"flops": 100.0, "bytes_accessed": 10.0,
            "collectives": {"all-reduce": {"count": 2, "bytes": 8}}}
    recB = {"flops": 160.0, "bytes_accessed": 14.0,
            "collectives": {"all-reduce": {"count": 3, "bytes": 11}}}
    _combine_probes(rec, recA, recB, n_periods=5, mb=2)
    # per-period = 60 flops; total = 2*(100 + 4*60) = 680
    assert rec["corrected_flops"] == 680
    assert rec["corrected_bytes"] == 2 * (10 + 4 * 4)
    ar = rec["corrected_collectives"]["all-reduce"]
    assert ar["count"] == 2 * (2 + 4 * 1)
    assert ar["bytes"] == 2 * (8 + 4 * 3)


def test_roofline_terms_math():
    rec = {
        "chips": 256,
        "mesh": {"data": 16, "model": 16},
        "kind": "train",
        "corrected_flops": 197e12,          # exactly 1 second of compute
        "corrected_bytes": 819e9,           # exactly 1 second of HBM
        "corrected_collectives": {
            "all-reduce": {"count": 1, "bytes": 50e9},   # 2*(15/16)*50e9/50e9
        },
    }
    t = roofline_terms(rec)
    assert abs(t["t_compute_s"] - 1.0) < 1e-9
    assert abs(t["t_memory_s"] - 1.0) < 1e-9
    assert abs(t["t_collective_s"] - 2 * 15 / 16) < 1e-9
    assert t["bottleneck"] == "collective"
    # sven cells ring over the whole mesh
    rec["kind"] = "sven"
    t2 = roofline_terms(rec)
    assert abs(t2["t_collective_s"] - 2 * 255 / 256) < 1e-9
