"""Unit tests of the repro.dist layer itself: context/rule-table semantics,
spec resolution edge cases, ZeRO widening, compression edge cases, and the
sharding resolvers on model pytrees (single-device mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist
from repro.dist.zero import _widen_spec


class _MeshStub:
    """Only mesh.shape is consulted by _widen_spec/resolve_spec divisibility;
    a stub lets us test non-trivial axis sizes on a 1-device host."""

    def __init__(self, **shape):
        self.shape = shape


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_constrain_noop_outside_context():
    x = jnp.arange(12.0).reshape(3, 4)
    out = dist.constrain(x, "batch", "embed")
    assert out is x  # identity, not even a copy
    # and inside a context it still computes the same values
    with dist.mesh_context(_mesh11()):
        np.testing.assert_array_equal(
            np.asarray(dist.constrain(x, "batch", "embed")), np.asarray(x))


def test_constrain_rank_mismatch_raises():
    # arity bugs must surface even on the no-context (single-CPU test) path
    with pytest.raises(ValueError, match="rank"):
        dist.constrain(jnp.ones((2, 3)), "batch")
    with dist.mesh_context(_mesh11()):
        with pytest.raises(ValueError, match="rank"):
            dist.constrain(jnp.ones((2, 3)), "batch")


def test_mesh_context_rule_precedence():
    mesh = _mesh11()
    # partial override: passed entries win, untouched defaults survive
    with dist.mesh_context(mesh, rules={"mlp": "data", "my_axis": "model"}):
        _, rules = dist.current_context()
        assert rules["mlp"] == "data"
        assert rules["my_axis"] == "model"
        assert rules["heads"] == dist.DEFAULT_RULES["heads"]
        # nested contexts: innermost wins, outer restored on exit
        with dist.mesh_context(mesh, rules={"mlp": None}):
            assert dist.current_context()[1]["mlp"] is None
        assert dist.current_context()[1]["mlp"] == "data"
    assert dist.current_context() is None


def test_resolve_spec_skips_nondividing_and_reused_axes():
    mesh = _MeshStub(data=2, model=4)
    rules = {"batch": "data", "heads": "model", "kv_heads": "model"}
    # 7 % 4 != 0 -> heads dim falls back to None
    assert dist.resolve_spec(("batch", "heads"), (6, 7), mesh, rules) == P("data", None)
    # "model" already consumed by heads -> kv_heads resolves None
    assert dist.resolve_spec(("heads", "kv_heads"), (8, 8), mesh, rules) == \
        P("model", None)


def test_widen_spec_basic_and_nondivisible():
    mesh = _MeshStub(data=2, model=1)
    # widens the FIRST unsharded divisible dim only
    assert _widen_spec(P(None, None), (63, 8), "data", mesh) == P(None, "data")
    # nothing divides -> untouched
    assert _widen_spec(P(None, None), (63, 9), "data", mesh) == P(None, None)
    # spec already using the axis -> untouched
    assert _widen_spec(P("data", None), (64, 8), "data", mesh) == P("data", None)
    # sharded dims are never re-widened, even when divisible
    assert _widen_spec(P("model", None), (64, 9), "data", mesh) == P("model", None)


def test_topk_frac_one_roundtrips_exactly():
    from repro.dist.compress import (topk_compress, topk_decompress, topk_init)
    g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal((7, 5)),
                          jnp.float32),
         "b": jnp.linspace(-1, 1, 11).astype(jnp.float32)}
    state = topk_init(g)
    vals, idx, state = topk_compress(g, state, frac=1.0)
    out = topk_decompress(vals, idx, g)
    for k in g:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(g[k]))
        assert float(jnp.abs(state[k]).max()) == 0.0  # residual fully drained


def test_topk_residual_drains_after_full_emission():
    from repro.dist.compress import topk_compress, topk_init
    g = {"w": jnp.asarray([3.0, -2.0, 1.0, 0.5], jnp.float32)}
    state = topk_init(g)
    # frac=0.5 emits 2 entries/step; after one partial step the residual holds
    # exactly the un-emitted mass...
    _, _, state = topk_compress(g, state, frac=0.5)
    np.testing.assert_allclose(np.asarray(state["w"]), [0, 0, 1.0, 0.5])
    # ...and a follow-up full emission flushes it to zero
    _, _, state = topk_compress(jax.tree.map(jnp.zeros_like, g), state, frac=1.0)
    assert float(jnp.abs(state["w"]).max()) == 0.0


def test_params_shardings_requires_context():
    from repro.dist.shardings import params_shardings
    with pytest.raises(RuntimeError, match="mesh_context"):
        params_shardings({"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)})


def test_params_shardings_unknown_leaf_falls_back_replicated():
    from repro.dist.shardings import params_shardings
    with dist.mesh_context(_mesh11()):
        tree = params_shardings({"mystery": jax.ShapeDtypeStruct((3, 5), jnp.float32)})
    assert tree["mystery"].spec == P(None, None)


def test_batch_and_cache_shardings_resolve_model_trees():
    """Every leaf of a real smoke model's inputs + decode caches resolves."""
    from repro.configs import get_config, input_specs
    from repro.dist.shardings import batch_shardings, cache_shardings
    from repro.models import model as M
    cfg = get_config("jamba-v0.1-52b", smoke=True)   # attn + ssm + moe mix
    mesh = _mesh11()
    with dist.mesh_context(mesh, rules={**dist.DEFAULT_RULES, **cfg.rules_override}):
        b_sh = batch_shardings(input_specs(cfg, "train_4k"))
        cache = jax.eval_shape(lambda: M.init_cache(None, cfg, 2, 64))
        c_sh = cache_shardings(cache)
    for leaf in jax.tree.leaves(b_sh) + jax.tree.leaves(c_sh):
        assert isinstance(leaf, NamedSharding)
    assert b_sh["tokens"].spec[0] == "data"


def test_zero1_widens_over_data():
    from repro.dist.shardings import params_shardings
    from repro.dist.zero import zero1_shardings
    mesh = _mesh11()
    shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    with dist.mesh_context(mesh):
        p_sh = params_shardings(shapes)
    m_sh = zero1_shardings(p_sh, shapes)
    assert m_sh["w"].spec == P("data", None)
