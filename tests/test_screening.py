"""Gap-safe screening: safety (never discards true support) + effectiveness
(at the optimum, discards almost everything inactive) + end-to-end exactness."""
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.baselines import elastic_net_cd
from repro.core import sven
from repro.core.elastic_net import lambda1_max
from repro.core.screening import gap_safe_screen, sven_with_screening
from repro.data.synthetic import make_regression


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500), st.floats(0.15, 0.6), st.floats(0.1, 5.0))
def test_screening_is_safe(seed, l1_frac, lam2):
    """No feature in the exact solution's support is ever discarded — for an
    arbitrary (crude) warm point."""
    X, y, _ = make_regression(40, 120, k_true=8, seed=seed)
    l1 = l1_frac * float(lambda1_max(X, y))
    beta_star = elastic_net_cd(X, y, l1, lam2).beta
    support = np.asarray(jnp.abs(beta_star) > 1e-10)
    # crude warm point: half-converged FISTA
    from repro.baselines.fista import elastic_net_fista
    warm = elastic_net_fista(X, y, l1, lam2, max_iters=40).beta
    scr = gap_safe_screen(X, y, warm, l1, lam2)
    keep = np.asarray(scr.keep)
    assert (keep | ~support).all(), "screening discarded an active feature"


def test_screening_tight_at_optimum():
    X, y, _ = make_regression(50, 200, k_true=6, seed=1)
    l1 = 0.4 * float(lambda1_max(X, y))
    beta_star = elastic_net_cd(X, y, l1, 1.0).beta
    scr = gap_safe_screen(X, y, beta_star, l1, 1.0)
    n_support = int((jnp.abs(beta_star) > 1e-10).sum())
    # at the optimum the gap ~ 0 so the rule keeps ~ the support only
    assert int(scr.n_kept) <= max(2 * n_support, n_support + 5)
    assert float(scr.gap) < 1e-6


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 300), st.sampled_from(["exact", "crude", "none"]),
       st.floats(0.2, 0.5), st.floats(0.3, 3.0))
def test_screened_beta_matches_unscreened_sven(seed, warm_kind, l1_frac, lam2):
    """Scatter-back property: the screened-then-solved beta equals the
    UNSCREENED sven() beta (not just the CD baseline) — for every warm-start
    choice the driver supports, and with exact zeros on discarded columns."""
    X, y, _ = make_regression(36, 100, k_true=6, seed=seed)
    l1 = l1_frac * float(lambda1_max(X, y))
    beta_star = elastic_net_cd(X, y, l1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_star)))
    if t <= 1e-8:
        return  # degenerate draw: empty model, nothing to screen
    from repro.baselines.fista import elastic_net_fista
    warm = {"exact": beta_star,
            "crude": elastic_net_fista(X, y, l1, lam2, max_iters=40).beta,
            "none": None}[warm_kind]
    beta_scr, _, scr = sven_with_screening(X, y, t, lam2, warm_beta=warm)
    beta_full = sven(X, y, t, lam2).beta
    np.testing.assert_allclose(np.asarray(beta_scr), np.asarray(beta_full),
                               atol=1e-6)
    dropped = ~np.asarray(scr.keep)
    assert (np.asarray(beta_scr)[dropped] == 0.0).all(), \
        "scatter-back left a nonzero in a screened-out coordinate"


def test_sven_with_screening_exact():
    X, y, _ = make_regression(45, 160, k_true=7, seed=3)
    lam2 = 1.0
    l1 = 0.35 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    beta, sol, scr = sven_with_screening(X, y, t, lam2, warm_beta=beta_cd)
    np.testing.assert_allclose(np.asarray(beta), np.asarray(beta_cd), atol=1e-7)
    assert int(scr.n_kept) < 160  # actually shrank the problem
