"""SVEN vs coordinate descent: the paper's central correctness claim.

"Throughout all experiments and all settings of lambda2 and t we find that
glmnet and SVEN obtain identical results up to the tolerance level."
Our glmnet stand-in is the independently KKT-validated CD baseline.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import elastic_net_cd, elastic_net_fista
from repro.core import sven, sven_path, SvenConfig
from repro.core.elastic_net import kkt_violation, lambda1_max
from repro.data.synthetic import make_regression, prostate_like

ATOL = 1e-8


def _cd_then_sven(n, p, lam2, l1_frac, seed=0, **cfg_kw):
    X, y, _ = make_regression(n, p, k_true=min(10, p // 2), rho=0.3, seed=seed)
    l1 = l1_frac * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    if t <= 0:
        pytest.skip("degenerate: CD selected nothing")
    sol = sven(X, y, t, lam2, SvenConfig(**cfg_kw))
    return beta_cd, sol


@pytest.mark.parametrize("n,p", [(30, 150), (40, 400), (25, 64)])
@pytest.mark.parametrize("lam2", [0.1, 1.0, 10.0])
def test_pggn_dual_matches_cd(n, p, lam2):
    beta_cd, sol = _cd_then_sven(n, p, lam2, 0.3)
    assert sol.mode == "primal"  # 2p > n -> primal per Algorithm 1
    np.testing.assert_allclose(sol.beta, beta_cd, atol=ATOL)


@pytest.mark.parametrize("n,p", [(200, 30), (500, 50), (128, 12)])
@pytest.mark.parametrize("lam2", [0.1, 1.0, 10.0])
def test_nggp_matches_cd(n, p, lam2):
    beta_cd, sol = _cd_then_sven(n, p, lam2, 0.3)
    assert sol.mode == "dual"
    np.testing.assert_allclose(sol.beta, beta_cd, atol=ATOL)


@pytest.mark.parametrize("mode", ["primal", "dual"])
@pytest.mark.parametrize("matrix_free", [True, False])
def test_modes_and_materialization_agree(mode, matrix_free):
    """Forced primal/dual and explicit/matrix-free all give the same beta."""
    beta_cd, sol = _cd_then_sven(60, 80, 1.0, 0.4, mode=mode, matrix_free=matrix_free)
    np.testing.assert_allclose(sol.beta, beta_cd, atol=ATOL)


def test_dual_fista_matches_newton():
    beta_cd, sol_fista = _cd_then_sven(200, 30, 1.0, 0.3, solver="fista", tol=1e-10)
    np.testing.assert_allclose(sol_fista.beta, beta_cd, atol=1e-6)


def test_lasso_limit():
    """lambda2 -> 0 recovers the Lasso (paper: C -> inf, hard-margin link)."""
    X, y, _ = make_regression(50, 100, k_true=6, rho=0.2, seed=2)
    lam2 = 1e-7
    l1 = 0.4 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    sol = sven(X, y, t, lam2, SvenConfig(tol=1e-10))
    np.testing.assert_allclose(sol.beta, beta_cd, atol=1e-5)


def test_sparsity_pattern_is_support_vectors():
    """Selected features <-> support vectors (paper §'Feature selection')."""
    beta_cd, sol = _cd_then_sven(40, 200, 1.0, 0.3)
    p = 200
    sv = (sol.alpha[:p] + sol.alpha[p:]) > 1e-9
    selected = jnp.abs(sol.beta) > 1e-9
    assert bool(jnp.all(selected == sv))


def test_regularization_path_matches_cd_path():
    """Fig. 1: paths coincide point-for-point along the t grid."""
    X, y, _ = prostate_like()
    lam2 = 0.5
    l1max = float(lambda1_max(X, y))
    l1s = l1max * np.geomspace(0.9, 0.05, 8)
    ts, betas_cd = [], []
    for l1 in l1s:
        b = elastic_net_cd(X, y, float(l1), lam2).beta
        ts.append(float(jnp.sum(jnp.abs(b))))
        betas_cd.append(b)
    betas_sven = sven_path(X, y, ts, lam2)
    np.testing.assert_allclose(betas_sven, jnp.stack(betas_cd), atol=1e-7)


def test_kkt_of_sven_solution():
    _, sol = _cd_then_sven(35, 120, 2.0, 0.35)
    assert float(sol.kkt) < 1e-8


def test_fista_baseline_agrees_with_cd():
    X, y, _ = make_regression(100, 40, seed=5)
    l1 = 0.3 * float(lambda1_max(X, y))
    b_cd = elastic_net_cd(X, y, l1, 1.0).beta
    b_f = elastic_net_fista(X, y, l1, 1.0).beta
    np.testing.assert_allclose(b_f, b_cd, atol=1e-7)


@pytest.mark.parametrize("mode", ["primal", "dual"])
def test_pallas_backend_matches_xla(mode):
    """End-to-end SVEN with Pallas kernels (interpret mode on CPU) agrees with
    the XLA path. f32 kernels => looser tolerance than the f64 XLA tests."""
    X, y, _ = make_regression(60, 80, k_true=8, rho=0.3, seed=11)
    l1 = 0.35 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, 1.0).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    sol = sven(X, y, t, 1.0, SvenConfig(mode=mode, backend="pallas", tol=1e-6))
    np.testing.assert_allclose(sol.beta, beta_cd, atol=5e-4 * max(1.0, float(jnp.abs(beta_cd).max())))
