"""End-to-end fault tolerance: the training launcher survives an injected
node failure (supervisor restores + retries) and restart-resumes exactly."""
import os
import subprocess
import sys

import pytest


def _run_train(tmp, extra):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # never inherit forced host-device counts
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "internlm2-1.8b", "--smoke", "--batch", "4", "--seq", "64",
           "--ckpt-dir", os.path.join(tmp, "ckpt"), "--ckpt-every", "5",
           "--log-every", "5"] + extra
    return subprocess.run(cmd, cwd=os.getcwd(), env=env, capture_output=True,
                          text=True, timeout=900)


@pytest.mark.slow
def test_supervisor_recovers_from_injected_failure(tmp_path):
    r = _run_train(str(tmp_path), ["--steps", "15", "--inject-fault-at", "8"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[supervisor] step 8 failed" in r.stdout
    assert "done at step 15" in r.stdout


@pytest.mark.slow
def test_restart_resumes_from_checkpoint(tmp_path):
    r1 = _run_train(str(tmp_path), ["--steps", "10"])
    assert r1.returncode == 0, r1.stdout + r1.stderr
    r2 = _run_train(str(tmp_path), ["--steps", "20"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 10" in r2.stdout
    assert "done at step 20" in r2.stdout

    # determinism: an uninterrupted 20-step run lands on the same loss
    r3 = _run_train(str(tmp_path) + "_b", ["--steps", "20"])
    loss_resumed = r2.stdout.strip().splitlines()[-1].split("loss")[-1].strip()
    loss_straight = r3.stdout.strip().splitlines()[-1].split("loss")[-1].strip()
    assert abs(float(loss_resumed) - float(loss_straight)) < 1e-4, (
        loss_resumed, loss_straight, r2.stdout, r3.stdout)
