"""Fault injection, end to end (ISSUE 8).

Training side (the seed tests): the launcher's supervisor survives an
injected node failure and restart-resumes exactly.

Serving side: the multi-host runtime's failure contract — a worker host
SIGKILLed mid-drain loses ZERO admitted requests (each ends in a terminal
`EnResult.status`: re-solved to "ok", or "deadline_exceeded" /" aborted"
explicitly — never silence), a corrupt or truncated spilled cache entry
degrades to a miss instead of an exception on the serving path, and a
restarted engine recovers its warm-start hit rate from the persistent
spill tier (DESIGN.md §11).
"""
import numpy as np
import pytest

from _subprocess import run_python


def _run_train(tmp, extra):
    import os
    return run_python(
        ["-m", "repro.launch.train", "--arch", "internlm2-1.8b", "--smoke",
         "--batch", "4", "--seq", "64", "--ckpt-dir",
         os.path.join(tmp, "ckpt"), "--ckpt-every", "5", "--log-every", "5"]
        + extra, timeout=900)


@pytest.mark.slow
def test_supervisor_recovers_from_injected_failure(tmp_path):
    r = _run_train(str(tmp_path), ["--steps", "15", "--inject-fault-at", "8"])
    assert "[supervisor] step 8 failed" in r.stdout
    assert "done at step 15" in r.stdout


@pytest.mark.slow
def test_restart_resumes_from_checkpoint(tmp_path):
    r1 = _run_train(str(tmp_path), ["--steps", "10"])
    assert r1.returncode == 0
    r2 = _run_train(str(tmp_path), ["--steps", "20"])
    assert "resumed from step 10" in r2.stdout
    assert "done at step 20" in r2.stdout

    # determinism: an uninterrupted 20-step run lands on the same loss
    r3 = _run_train(str(tmp_path) + "_b", ["--steps", "20"])
    loss_resumed = r2.stdout.strip().splitlines()[-1].split("loss")[-1].strip()
    loss_straight = r3.stdout.strip().splitlines()[-1].split("loss")[-1].strip()
    assert abs(float(loss_resumed) - float(loss_straight)) < 1e-4, (
        loss_resumed, loss_straight, r2.stdout, r3.stdout)


# ---------------------------------------------------------------------------
# serving: multi-host coordinator fault injection
# ---------------------------------------------------------------------------

TERMINAL = {"ok", "deadline_exceeded", "aborted"}


def _problem(seed=0, n=40, p=20):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, p)), rng.normal(size=n)


@pytest.fixture
def coordinator_factory():
    """Build MultiHostCoordinators and guarantee their worker processes are
    reaped even when the test body fails mid-flight."""
    from repro.runtime.multihost import MultiHostCoordinator

    coords = []

    def make(**kw):
        c = MultiHostCoordinator(**kw)
        coords.append(c)
        return c

    yield make
    for c in coords:
        c.shutdown()


@pytest.mark.slow
def test_kill_host_mid_drain_loses_nothing(coordinator_factory):
    """The headline contract: SIGKILL a worker while it holds dispatched
    batches; every admitted request must still complete "ok" (no deadlines
    here, so the requeue path re-solves the dead host's work)."""
    X, y = _problem(0)
    coord = coordinator_factory(n_hosts=2, max_batch=4)
    ids = [coord.submit(X + 0.01 * k, y, t=1.0) for k in range(8)]
    coord.flush()                      # both hosts now hold in-flight work
    coord.kill_host(0)
    out = coord.drain()
    assert sorted(out) == sorted(ids), "silent request drop on host kill"
    assert {r.status for r in out.values()} == {"ok"}
    assert coord.hosts_lost == 1
    assert coord.requeued_batches >= 1, "kill was not detected as a failure"
    for r in out.values():             # re-solved results are real solutions
        assert r.beta is not None and np.all(np.isfinite(np.asarray(r.beta)))


@pytest.mark.slow
def test_kill_host_with_deadlines_terminal_statuses(coordinator_factory):
    """With deadlines armed, a killed host's requeued work whose deadline
    already passed must terminate explicitly as deadline_exceeded — the
    PR 6 contract, now across processes. Either way: a terminal status for
    every admitted request, solutions only for status == "ok"."""
    X, y = _problem(1)
    coord = coordinator_factory(n_hosts=2, max_batch=4, max_wait=1e-3)
    ids = [coord.submit(X + 0.01 * k, y, t=1.0) for k in range(8)]
    coord.flush()
    coord.kill_host(0)
    out = coord.drain()
    assert sorted(out) == sorted(ids), "silent request drop on host kill"
    statuses = {rid: out[rid].status for rid in ids}
    assert set(statuses.values()) <= {"ok", "deadline_exceeded"}, statuses
    for rid in ids:
        if out[rid].status == "ok":
            assert np.all(np.isfinite(np.asarray(out[rid].beta)))
        else:
            assert out[rid].beta is None


@pytest.mark.slow
def test_all_hosts_dead_aborts_explicitly(coordinator_factory):
    """When NO host survives, pending requests must terminate as "aborted"
    (and drain must return, not hang)."""
    X, y = _problem(2)
    coord = coordinator_factory(n_hosts=1, max_batch=4)
    ids = [coord.submit(X, y + 0.1 * k, t=1.0) for k in range(4)]
    coord.kill_host(0)
    out = coord.drain(timeout=60.0)
    assert sorted(out) == sorted(ids)
    assert {r.status for r in out.values()} == {"aborted"}
    assert all(out[rid].beta is None for rid in ids)


@pytest.mark.slow
def test_multihost_shared_spill_survives_host_loss(coordinator_factory,
                                                   tmp_path):
    """Work a dead host completed before dying must warm-start the
    survivors through the shared persistent spill tier."""
    X, y = _problem(3)
    coord = coordinator_factory(n_hosts=2, max_batch=4,
                                cache_dir=str(tmp_path / "spill"))
    first = [coord.submit(X, y, t=0.8 + 0.05 * k) for k in range(8)]
    out = coord.drain()
    assert {out[r].status for r in first} == {"ok"}
    coord.kill_host(0)                 # the half that solved some of wave 1
    again = [coord.submit(X, y, t=0.8 + 0.05 * k) for k in range(8)]
    out = coord.drain()
    assert sorted(out) == sorted(again)
    assert {out[r].status for r in again} == {"ok"}
    stats = coord.shutdown()
    # only the survivor reports; repeat traffic must have warm-started,
    # including from points the dead host spilled
    assert sum(s["cache_hits"] for s in stats) > 0


def test_corrupt_spill_entry_degrades_to_miss(tmp_path):
    """Flip bytes / truncate / garbage a spilled entry: lookups report a
    miss, the bad file is removed, nothing raises."""
    from repro.runtime.cache import TieredSolutionCache, WarmEntry

    def entry(lam):
        return WarmEntry(lam=lam, lambda2=1.0, alpha=np.ones(8),
                         w=np.ones(6), beta=np.ones(4), t=lam, nu=0.1)

    root = tmp_path / "spill"
    cache = TieredSolutionCache(spill_dir=root)
    cache.insert("fp0", "constrained", entry(1.0))
    cache.insert("fp1", "constrained", entry(2.0))
    files = sorted(root.glob("*.npz"))
    assert len(files) == 2

    files[0].write_bytes(b"\x00garbage, not a zipfile")   # corrupt
    with open(files[1], "r+b") as f:                       # truncate
        f.truncate(8)

    fresh = TieredSolutionCache(spill_dir=root)            # empty memory tier
    assert fresh.lookup("fp0", "constrained", 1.0, 1.0) is None
    assert fresh.lookup("fp1", "constrained", 2.0, 1.0) is None
    assert fresh.spill.corrupt_dropped == 2
    assert list(root.glob("*.npz")) == [], "bad entries must be removed"
    # and the tier still works after dropping the corruption
    fresh.insert("fp0", "constrained", entry(1.0))
    assert fresh.lookup("fp0", "constrained", 1.0, 1.0) is not None


def test_wrong_fingerprint_spill_never_served(tmp_path):
    """A spilled file renamed onto another problem's key (the on-disk
    analogue of a hash collision / tampering) must NOT be served: the
    stored fingerprint is verified against the query."""
    from repro.runtime.cache import PersistentCacheTier, WarmEntry

    tier = PersistentCacheTier(tmp_path / "spill")
    e = WarmEntry(lam=1.0, lambda2=1.0, alpha=np.ones(8), w=np.ones(6),
                  beta=np.ones(4), t=1.0, nu=0.0)
    assert tier.insert("aaaa", "constrained", e)
    (path,) = tier.root.glob("aaaa.*.npz")
    stolen = tier.root / path.name.replace("aaaa", "bbbb")
    path.rename(stolen)
    assert tier.lookup("bbbb", "constrained", 1.0, 1.0) is None
    assert tier.corrupt_dropped == 1
    assert not stolen.exists()


@pytest.mark.slow
def test_engine_restart_recovers_warm_hit_rate(tmp_path):
    """An engine restarted onto the same cache_dir must serve warm starts
    from the persistent tier: hit rate >= 0.5 on repeat traffic (ISSUE 8
    acceptance), with solutions unchanged."""
    from repro.serve import ElasticNetEngine

    X, y = _problem(4, n=24, p=10)
    lams = [0.6 + 0.1 * k for k in range(6)]
    spill = str(tmp_path / "warm")

    # max_batch=8 keeps each session to ONE batch: every lookup happens
    # before any insert, so the first session is provably all-miss
    engine1 = ElasticNetEngine(max_batch=8, cache_dir=spill)
    ids1 = [engine1.submit(X, y, t=lam, lambda2=1.0) for lam in lams]
    out1 = engine1.drain()
    assert engine1.cache.hits == 0     # cold process, cold disk

    del engine1                        # restart: fresh process state
    engine2 = ElasticNetEngine(max_batch=8, cache_dir=spill)
    assert len(engine2.cache) == 0, "memory tier must start empty"
    ids2 = [engine2.submit(X, y, t=lam, lambda2=1.0) for lam in lams]
    out2 = engine2.drain()
    cache = engine2.cache
    rate = cache.hits / max(cache.hits + cache.misses, 1)
    assert rate >= 0.5, (cache.hits, cache.misses)
    assert cache.spill_hits > 0, "hits must come from the persistent tier"
    for r1, r2 in zip(ids1, ids2):
        np.testing.assert_allclose(np.asarray(out2[r2].beta),
                                   np.asarray(out1[r1].beta), atol=1e-8)
