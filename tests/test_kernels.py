"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.data.synthetic import make_regression
from repro.kernels import ref
from repro.kernels.ops import hinge_hessian_matvec, shifted_gram


def _problem(n, p, dtype, seed=0):
    X, y, _ = make_regression(n, p, k_true=min(5, p), seed=seed, dtype=jnp.float32)
    return X.astype(dtype), y.astype(dtype)


GRAM_SHAPES = [(64, 64), (128, 96), (96, 130), (33, 57), (130, 150), (256, 64)]


@pytest.mark.parametrize("n,p", GRAM_SHAPES)
@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 3e-6), (jnp.bfloat16, 2e-2)])
def test_gram_kernel_sweep(n, p, dtype, rtol):
    X, y = _problem(n, p, dtype)
    t = 0.9
    K = shifted_gram(X, y, t, bm=32, bn=32, bk=32)
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X.astype(jnp.float32), y.astype(jnp.float32), t))
    scale = float(jnp.abs(K_ref).max())
    np.testing.assert_allclose(np.asarray(K, np.float32), np.asarray(K_ref), atol=rtol * scale)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (64, 64, 64)])
def test_gram_kernel_block_shapes(blocks):
    bm, bn, bk = blocks
    X, y = _problem(96, 64, jnp.float32)
    K = shifted_gram(X, y, 1.7, bm=bm, bn=bn, bk=bk)
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, 1.7))
    np.testing.assert_allclose(np.asarray(K), np.asarray(K_ref),
                               atol=3e-6 * float(jnp.abs(K_ref).max()))


def test_gram_block_layout_output():
    X, y = _problem(64, 48, jnp.float32)
    Kb = shifted_gram(X, y, 2.0, bm=16, bn=16, bk=16, flatten=False)
    assert Kb.shape == (2, 2, 48, 48)
    np.testing.assert_allclose(np.asarray(ref.flatten_gram(Kb)),
                               np.asarray(shifted_gram(X, y, 2.0, bm=16, bn=16, bk=16)),
                               atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(10, 140), st.integers(9, 140), st.floats(0.3, 4.0), st.integers(0, 99))
def test_gram_kernel_property(n, p, t, seed):
    X, y = _problem(n, p, jnp.float32, seed)
    K = shifted_gram(X, y, t, bm=32, bn=32, bk=32)
    K_ref = ref.flatten_gram(ref.gram_blocks_ref(X, y, t))
    np.testing.assert_allclose(np.asarray(K), np.asarray(K_ref),
                               atol=1e-5 * max(1.0, float(jnp.abs(K_ref).max())))


HINGE_SHAPES = [(64, 64), (130, 150), (57, 33), (200, 40), (48, 256)]


@pytest.mark.parametrize("n,p", HINGE_SHAPES)
@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
def test_hinge_matvec_sweep(n, p, dtype, rtol):
    X, y = _problem(n, p, dtype)
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (n,), jnp.float32)
    at = (jax.random.uniform(jax.random.PRNGKey(1), (p,)) > 0.4).astype(jnp.float32)
    ab = (jax.random.uniform(jax.random.PRNGKey(2), (p,)) > 0.6).astype(jnp.float32)
    hv = hinge_hessian_matvec(X, y, 1.1, 2.5, at, ab, v, bp=32, bn=32, bk=32)
    Xf = X.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    hv_ref = ref.hessian_matvec_ref(Xf, yf, 1.1, 2.5, at, ab, v)
    scale = max(1.0, float(jnp.abs(hv_ref).max()))
    np.testing.assert_allclose(np.asarray(hv), np.asarray(hv_ref), atol=rtol * scale)


@settings(max_examples=12, deadline=None)
@given(st.integers(9, 150), st.integers(8, 150), st.integers(0, 99))
def test_hinge_matvec_property(n, p, seed):
    X, y = _problem(n, p, jnp.float32, seed)
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (n,), jnp.float32)
    at = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (p,)) > 0.5).astype(jnp.float32)
    ab = 1.0 - at  # complementary masks (the realistic SV pattern)
    hv = hinge_hessian_matvec(X, y, 0.8, 4.0, at, ab, v, bp=32, bn=32, bk=32)
    hv_ref = ref.hessian_matvec_ref(X, y, 0.8, 4.0, at, ab, v)
    np.testing.assert_allclose(np.asarray(hv), np.asarray(hv_ref),
                               atol=1e-5 * max(1.0, float(jnp.abs(hv_ref).max())))


def test_oracle_matches_reduction_module():
    """ref.gram_blocks_ref agrees with core.reduction.gram_reference."""
    from repro.core.reduction import gram_reference
    X, y, _ = make_regression(50, 40, seed=3)
    K1 = ref.flatten_gram(ref.gram_blocks_ref(X, y, 1.5))
    K2 = gram_reference(X, y, 1.5)
    np.testing.assert_allclose(np.asarray(K1), np.asarray(K2), atol=1e-9)


HSTAT_SHAPES = [(64, 64), (130, 150), (57, 33), (200, 40)]


@pytest.mark.parametrize("n,p", HSTAT_SHAPES)
def test_hinge_stats_sweep(n, p):
    from repro.kernels.ops import hinge_stats
    X, y = _problem(n, p, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32) * 0.1
    t, C = 1.3, 2.0
    margin, act, loss, galpha = hinge_stats(X, y, t, w, C, bp=32, bk=32)
    m_ref, a_ref, l_ref, g_ref = ref.hinge_stats_ref(X, y, t, w, C)
    scale = max(1.0, float(jnp.abs(m_ref).max()))
    np.testing.assert_allclose(np.asarray(margin), np.asarray(m_ref), atol=3e-6 * scale)
    np.testing.assert_array_equal(np.asarray(act), np.asarray(a_ref))
    np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(galpha), np.asarray(g_ref), atol=3e-6 * scale)


@settings(max_examples=10, deadline=None)
@given(st.integers(9, 120), st.integers(8, 120), st.integers(0, 99))
def test_hinge_stats_property(n, p, seed):
    from repro.kernels.ops import hinge_stats
    X, y = _problem(n, p, jnp.float32, seed)
    w = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32) * 0.2
    margin, act, loss, galpha = hinge_stats(X, y, 0.9, w, 1.5, bp=32, bk=32)
    m_ref, a_ref, l_ref, g_ref = ref.hinge_stats_ref(X, y, 0.9, w, 1.5)
    scale = max(1.0, float(jnp.abs(m_ref).max()))
    np.testing.assert_allclose(np.asarray(margin), np.asarray(m_ref), atol=1e-5 * scale)
    np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(galpha), np.asarray(g_ref), atol=1e-5 * scale)
