"""Unified telemetry layer (ISSUE 9, DESIGN.md §12).

Pillar-by-pillar: the metrics registry (labeled instruments, exponential
histograms, the consuming delta protocol), the structured tracer (span
taxonomy, Chrome-trace round trip, zero recording when disabled), the
bounded event ring, and their integration into the serving runtime — the
stats/cache shims stay equal to the registry they now read through, every
admitted request lands in exactly one terminal-status counter, and the
fleet-merged worker counters survive a SIGKILL without double counting.
The timing-discipline lint (reprolint rule TIM001, formerly
tools/check_timing.py) runs as a test so a bare ``time.time()`` in
runtime/ fails here before it fails CI.
"""
import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.data.synthetic import make_regression
from repro.obs import (EventLog, MetricsRegistry, SolveLog, SolveRecord,
                       Tracer, default_registry, disable_tracing,
                       enable_tracing, get_tracer)
from repro.obs.metrics import ExponentialHistogram
from repro.runtime import ContinuousScheduler, LoadSpec, make_workload, \
    run_open_loop


def _problem(n, p, seed=0):
    X, y, _ = make_regression(n, p, k_true=max(3, p // 6), rho=0.3, seed=seed)
    import jax.numpy as jnp
    t_scale = 0.2 * float(jnp.sum(jnp.abs(X.T @ y))) / n
    return X, y, t_scale


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    c = reg.counter("requests_terminal_total", "t", ("status",))
    c.inc(status="ok")
    c.inc(2, status="ok")
    c.inc(status="aborted")
    assert c.value(status="ok") == 3
    assert c.total() == 4
    assert c.series() == {("ok",): 3.0, ("aborted",): 1.0}
    with pytest.raises(ValueError):
        c.inc(wrong="label")
    with pytest.raises(ValueError):          # same name, different labels
        reg.counter("requests_terminal_total", "t", ("reason",))
    with pytest.raises(ValueError):          # same name, different kind
        reg.gauge("requests_terminal_total")


def test_exponential_histogram_quantiles():
    h = ExponentialHistogram()
    vals = [10 ** (-6 + 5 * i / 999) for i in range(1000)]   # 1us .. 100ms
    for v in vals:
        h.observe(v)
    ref = sorted(vals)
    for q in (50, 90, 99):
        exact = ref[int(q / 100 * (len(ref) - 1))]
        assert abs(h.quantile(q) - exact) / exact < 0.09, (q, h.quantile(q))
    assert h.count == 1000
    assert h.quantile(0) == h.min and h.quantile(100) == h.max


def test_histogram_merge_matches_union():
    a, b = ExponentialHistogram(), ExponentialHistogram()
    for i in range(100):
        a.observe(1e-4 * (i + 1))
        b.observe(1e-2 * (i + 1))
    union = ExponentialHistogram()
    for i in range(100):
        union.observe(1e-4 * (i + 1))
        union.observe(1e-2 * (i + 1))
    a.merge(b)
    assert a.count == union.count and a.max == union.max
    assert a.quantile(50) == union.quantile(50)


def test_counter_deltas_consume_and_merge():
    """The multihost piggyback protocol: deltas are consumed by the snapshot
    (second call empty), merge reconstructs totals, and a reset clears the
    watermark so no negative delta is ever shipped."""
    reg = MetricsRegistry()
    c = reg.counter("runtime_requests_total", "r")
    c.inc(5)
    d1 = reg.counter_deltas()
    assert d1["runtime_requests_total"]["deltas"] == [[[], 5.0]]
    assert reg.counter_deltas() == {}            # consumed
    c.inc(2)
    fleet = MetricsRegistry()
    fleet.merge_counter_deltas(d1)
    fleet.merge_counter_deltas(reg.counter_deltas())
    assert fleet.counter("runtime_requests_total").total() == 7
    reg.reset_instrument("runtime_requests_total")
    c.inc(1)
    d3 = reg.counter_deltas()                    # post-reset: +1, never -6
    assert d3["runtime_requests_total"]["deltas"] == [[[], 1.0]]


def test_snapshot_and_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("launches_total", "n", ("reason",)).inc(reason="full")
    reg.histogram("latency_seconds", "lat").observe(0.25)
    snap = reg.snapshot()
    assert snap["launches_total"]["values"]['reason="full"'] == 1.0
    assert snap["latency_seconds"]["values"]["_"]["count"] == 1
    json.dumps(snap)                             # plain-JSON by construction
    text = reg.to_prometheus()
    assert '# TYPE launches_total counter' in text
    assert 'launches_total{reason="full"} 1' in text
    assert 'latency_seconds_bucket{le="+Inf"} 1' in text
    assert text.count("latency_seconds_sum") == 1


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_spans_nest_and_export(tmp_path):
    tr = Tracer()
    tr.enabled = True
    with tr.span("outer", bucket=(64, 32)):
        with tr.span("inner"):
            pass
    tr.instant("mark", k=1)
    assert tr.counts() == {"outer": 1, "inner": 1, "mark": 1}
    out = tmp_path / "trace.json"
    tr.export(str(out))
    doc = json.loads(out.read_text())
    events = {e["name"]: e for e in doc["traceEvents"]}
    assert events["outer"]["ph"] == "X" and events["outer"]["dur"] >= 0
    assert events["mark"]["ph"] == "i"
    # nesting: inner starts at/after outer and ends at/before outer's end
    o, i = events["outer"], events["inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]
    assert i["args"]["parent"] == "outer"
    assert o["args"]["bucket"] == [64, 32]


def test_tracer_disabled_records_nothing():
    tr = Tracer()
    assert not tr.enabled
    with tr.span("ghost"):
        tr.instant("ghost2")
    assert tr.spans() == [] and tr.counts() == {}


def test_tracer_ring_is_bounded():
    tr = Tracer(capacity=8)
    tr.enabled = True
    for i in range(50):
        tr.instant(f"e{i}")
    assert len(tr.spans()) == 8
    assert tr.spans()[-1][1] == "e49"            # newest survive
    assert sum(tr.counts().values()) == 50       # counts keep the true total


# ---------------------------------------------------------------------------
# event ring
# ---------------------------------------------------------------------------

def test_event_ring_bounded_and_jsonl(tmp_path):
    ev = EventLog(capacity=4)
    for i in range(9):
        ev.emit("requeue", host=i)
    recs = ev.records()
    assert len(recs) == 4 and recs[-1]["host"] == 8
    assert ev.counts() == {"requeue": 9}         # rolled-off still counted
    assert ev.emitted == 9
    out = tmp_path / "events.jsonl"
    ev.dump(str(out))
    lines = out.read_text().splitlines()
    assert len(lines) == 4
    for line in lines:
        rec = json.loads(line)
        assert "ts" in rec and rec["kind"] == "requeue"


# ---------------------------------------------------------------------------
# solve log
# ---------------------------------------------------------------------------

def test_solve_log_residual_report():
    log = SolveLog()
    for i in range(4):
        log.add(SolveRecord(bucket=(64, 32), form="constrained", batch=4,
                            b_real=3, route_path="single", modeled_s=0.01,
                            actual_s=0.02, blocked_s=0.001, iters_max=7,
                            iters_mean=5.0, kkt_max=1e-8, keep_fraction=0.4))
    log.add(SolveRecord(bucket=(64, 32), form="constrained", batch=4,
                        b_real=4, route_path="batch", modeled_s=0.0,
                        actual_s=0.05, blocked_s=0.0, iters_max=3,
                        iters_mean=3.0, kkt_max=0.0, keep_fraction=1.0))
    rep = log.residual_report()
    assert rep["n_records"] == 5 and rep["n_unmodeled"] == 1
    single = rep["by_path"]["single"]
    assert single["n"] == 4
    assert abs(single["log10_ratio_mean"] - np.log10(2.0)) < 1e-12


# ---------------------------------------------------------------------------
# runtime integration: shims, span taxonomy, terminal accounting
# ---------------------------------------------------------------------------

def test_scheduler_shims_read_registry_and_spans_cover_lifecycle():
    X, y, t = _problem(32, 16)
    sched = ContinuousScheduler(max_batch=2, max_wait=None)
    tracer = get_tracer()
    n0 = len(tracer.spans())
    enable_tracing()
    try:
        for i in range(4):
            sched.submit(X, y, t=t * (1 + 0.05 * i), lambda2=1.0)
        out = sched.drain()
    finally:
        disable_tracing()
    assert len(out) == 4

    # shim == registry: the legacy attributes are views, not copies
    reg = sched.registry
    assert sched.stats.requests == 4
    assert sched.stats.requests == int(
        reg.counter("runtime_requests_total").total())
    assert sched.cache.hits + sched.cache.misses == int(
        reg.counter("cache_lookups_total", labelnames=("result",)).total())
    term = reg.counter("requests_terminal_total", labelnames=("status",))
    assert term.value(status="ok") == 4          # exactly one terminal each

    # span taxonomy: the full request lifecycle appears in the trace
    names = {s[1] for s in tracer.spans()[n0:]}
    for expected in ("admit", "launch", "warm_start", "harvest.block",
                     "complete"):
        assert expected in names, (expected, names)

    # pillar 3: every dispatch priced and logged
    rep = sched.solve_log.residual_report()
    assert rep["n_records"] >= 2 and rep["n_unmodeled"] == 0
    assert "single" in rep["by_path"]


def test_trace_counts_reads_default_registry():
    from repro.core import reset_trace_counts, trace_counts
    reset_trace_counts()
    assert trace_counts() == {}
    default_registry().counter(
        "solver_traces_total", labelnames=("entry",)).inc(entry="sven")
    assert trace_counts() == {"sven": 1}
    reset_trace_counts()
    assert trace_counts() == {}


# ---------------------------------------------------------------------------
# multihost: fleet merge under host kill — no double counting
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multihost_metric_merge_survives_kill():
    """SIGKILL one worker mid-drain: the coordinator's books must stay
    balanced (each admitted request in exactly one terminal-status series),
    the fleet merge must show the re-solve work WITHOUT double-counting
    delivered requests, and host death must appear in coordinator
    counters + the structured event ring."""
    from repro.obs import default_events
    from repro.runtime.multihost import MultiHostCoordinator

    rng = np.random.default_rng(3)
    X, y = rng.normal(size=(40, 20)), rng.normal(size=40)
    deaths0 = default_events().counts().get("host_death", 0)
    coord = MultiHostCoordinator(n_hosts=2, max_batch=4)
    try:
        ids = [coord.submit(X + 0.01 * k, y, t=1.0) for k in range(8)]
        coord.flush()
        coord.kill_host(0)
        out = coord.drain()
        assert sorted(out) == sorted(ids)
        assert {r.status for r in out.values()} == {"ok"}

        acct = coord.accounting()
        assert acct["admitted"] == 8
        assert acct["terminals"] == {"ok": 8}    # one terminal per request
        assert acct["balanced"] and acct["outstanding"] == 0

        # fleet merge: every DELIVERED result rode in with its host's
        # deltas, so the fleet saw at least the admitted requests. The dead
        # host's unshipped deltas are dropped (never salvaged twice), so
        # the total exceeds admitted only if it shipped before dying —
        # which is exactly the no-double-counting property: requeues
        # change who solved, not how many results were delivered.
        fleet_reqs = int(coord.fleet.counter("runtime_requests_total",
                                             labelnames=()).total())
        assert fleet_reqs >= 8
        assert coord.requeued_batches >= 1

        assert coord.hosts_lost == 1
        assert int(coord.registry.counter("hosts_lost_total").total()) == 1
        snap = coord.metrics_snapshot()
        assert set(snap) == {"coordinator", "fleet", "hosts"}
        assert default_events().counts().get("host_death", 0) == deaths0 + 1
    finally:
        coord.shutdown()


# ---------------------------------------------------------------------------
# overhead guard (gated: timing assertions flake on loaded CI machines)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("REPRO_OVERHEAD_GUARD"),
                    reason="wall-clock gate; set REPRO_OVERHEAD_GUARD=1 "
                           "(CI runs the same gate via bench_obs)")
def test_tracing_overhead_within_budget():
    spec = LoadSpec(n_requests=16, n_datasets=2, penalized_fraction=0.0,
                    pattern="adjacent", seed=5)
    workload = make_workload(spec)
    sched = ContinuousScheduler(max_batch=8, max_wait=None)
    run_open_loop(sched, workload)               # compile + warm
    best = {False: float("inf"), True: float("inf")}
    p99 = {False: float("inf"), True: float("inf")}
    try:
        for _ in range(3):
            for enabled in (False, True):
                (enable_tracing if enabled else disable_tracing)()
                out = run_open_loop(sched, workload)
                if out["wall_seconds"] < best[enabled]:
                    best[enabled] = out["wall_seconds"]
                    p99[enabled] = out["p99_latency_s"]
    finally:
        disable_tracing()
    assert best[True] <= 1.10 * best[False], (best, p99)
    assert p99[True] <= 1.10 * p99[False], (best, p99)


# ---------------------------------------------------------------------------
# timing-discipline lint as a test
# ---------------------------------------------------------------------------

def test_runtime_has_no_bare_clock_reads():
    from pathlib import Path

    from tools.reprolint import load_config, run_paths
    root = Path(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    res = run_paths(root, ["src/repro/runtime"], load_config(root),
                    select=("TIM001",))
    assert res.findings == [], (
        "bare time.time()/time.perf_counter() in src/repro/runtime/ — "
        f"route clock reads through repro.obs.clock: {res.findings}")
