"""Per-architecture smoke tests on reduced configs (CPU): one forward/train
step asserting shapes + no NaNs, plus prefill->decode consistency against the
full forward (validates KV caches, MLA absorbed decode and SSD recurrence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, input_specs
from repro.models import model as M


def _batch_for(cfg, B, S, key):
    ks = jax.random.split(key, 2)
    if cfg.frontend == "codebooks":
        return {"tokens": jax.random.randint(ks[0], (B, S, cfg.n_codebooks), 0, cfg.vocab_size)}
    if cfg.frontend == "patches":
        P = cfg.vision_tokens
        return {"tokens": jax.random.randint(ks[0], (B, S - P), 0, cfg.vocab_size),
                "patch_embeds": jax.random.normal(ks[1], (B, P, cfg.d_model), cfg.dtype)}
    return {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux = M.forward(params, cfg, batch)
    if cfg.frontend == "codebooks":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    from repro.train.step import make_train_step
    from repro.optim.adamw import adamw_init
    cfg = get_config(arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    step_fn = make_train_step(cfg, microbatches=2, learning_rate=1e-3)
    batch = _batch_for(cfg, 4, 32, jax.random.PRNGKey(1))
    new_params, new_opt, metrics = step_fn(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, new_params))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """logits(decode(last token | prefill(S-1))) == logits(forward(S))[:, -1]."""
    cfg = get_config(arch, smoke=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    full_logits, _ = M.forward(params, cfg, batch)

    if cfg.frontend == "patches":
        # split: prefill sees patches + all but last text token
        pre_batch = {"tokens": batch["tokens"][:, :-1], "patch_embeds": batch["patch_embeds"]}
        last_tok = batch["tokens"][:, -1]
    elif cfg.frontend == "codebooks":
        pre_batch = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1]
    else:
        pre_batch = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1]

    _, caches = M.prefill(params, cfg, pre_batch, max_len=S + 4)
    step_logits, _ = M.decode_step(params, cfg, last_tok, caches)

    want = full_logits[:, -1]
    got = step_logits
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step h_t = exp(dt A) h + dt B x; y = C h + D x."""
    from repro.models.ssm import SSMConfig, _ssd_scan
    B, S, H, P, ds = 2, 24, 3, 8, 5
    cfg = SSMConfig(d_state=ds, head_dim=P, chunk=8)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    a = dtv * A
    Bm = jax.random.normal(ks[3], (B, S, H, ds))
    Cm = jax.random.normal(ks[4], (B, S, H, ds))

    y_chunked, h_final = _ssd_scan(xh, a, dtv, Bm, Cm, cfg)

    h = jnp.zeros((B, H, ds, P))
    ys = []
    for t_ in range(S):
        dec = jnp.exp(a[:, t_])[:, :, None, None]
        h = dec * h + jnp.einsum("bh,bhd,bhp->bhdp", dtv[:, t_], Bm[:, t_], xh[:, t_])
        ys.append(jnp.einsum("bhd,bhdp->bhp", Cm[:, t_], h))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(h), atol=1e-4, rtol=1e-4)


def test_chunked_attention_matches_dense():
    from repro.models.attention import _sdpa, sdpa_chunked
    B, S, H, D = 2, 64, 4, 16
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, D)) for i in range(3))
    pos = jnp.arange(S)
    mask = (pos[None, :] <= pos[:, None])[None, None]
    dense = _sdpa(q, k, v, mask, D)
    chunked = sdpa_chunked(q, k, v, scale=D ** -0.5, chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), atol=1e-5)
    # with sliding window
    maskw = mask & ((pos[:, None] - pos[None, :]) < 24)[None, None]
    dense_w = _sdpa(q, k, v, maskw, D)
    chunked_w = sdpa_chunked(q, k, v, scale=D ** -0.5, window=24, chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(chunked_w), np.asarray(dense_w), atol=1e-5)


def test_moe_matches_dense_reference():
    """With ample capacity the scatter-dispatch MoE equals the per-token mix."""
    from repro.models.moe import MoEConfig, apply_moe, init_moe
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=4.0)
    d = 16
    params = init_moe(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y, aux = apply_moe(params, x, cfg)

    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(x)
    for b in range(2):
        for s in range(8):
            acc = jnp.zeros((d,))
            for kk in range(2):
                e = int(top_e[b, s, kk])
                h = jax.nn.silu(x[b, s] @ params["w_gate"][e]) * (x[b, s] @ params["w_up"][e])
                acc = acc + float(top_p[b, s, kk]) * (h @ params["w_down"][e])
            y_ref = y_ref.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
