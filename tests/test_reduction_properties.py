"""Property-based tests (hypothesis) of the reduction's invariants."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.baselines import elastic_net_cd
from repro.core import SvenOperator, build_svm_dataset, gram_blocks, gram_reference, sven
from repro.core.elastic_net import kkt_violation, lambda1_max, smooth_grad
from repro.data.synthetic import make_regression

prob = st.tuples(
    st.integers(min_value=5, max_value=60),     # n
    st.integers(min_value=3, max_value=60),     # p
    st.integers(min_value=0, max_value=10_000), # seed
    st.floats(min_value=0.2, max_value=8.0),    # t
)


@settings(max_examples=25, deadline=None)
@given(prob)
def test_operator_identities(args):
    """Matrix-free products == explicit products for random problems."""
    n, p, seed, t = args
    X, y, _ = make_regression(n, p, k_true=min(5, p), seed=seed)
    op = SvenOperator(X=X, y=y, t=t)
    Xhat, yhat = build_svm_dataset(X, y, t)
    Zhat = (yhat[:, None] * Xhat).T
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n,), X.dtype)
    v = jax.random.normal(key, (2 * p,), X.dtype)
    scale = max(1.0, float(jnp.abs(Xhat).max()) ** 2 * p)
    np.testing.assert_allclose(op.xhat_matvec(w), Xhat @ w, atol=1e-9 * scale)
    np.testing.assert_allclose(op.xhat_rmatvec(v), Xhat.T @ v, atol=1e-9 * scale)
    np.testing.assert_allclose(op.kernel_matvec(v), Zhat.T @ (Zhat @ v), atol=1e-8 * scale)


@settings(max_examples=15, deadline=None)
@given(prob)
def test_gram_block_assembly(args):
    n, p, seed, t = args
    X, y, _ = make_regression(n, p, k_true=min(5, p), seed=seed)
    K_blocks = gram_blocks(X, y, t)
    K_ref = gram_reference(X, y, t)
    scale = max(1.0, float(jnp.abs(K_ref).max()))
    np.testing.assert_allclose(K_blocks, K_ref, atol=1e-10 * scale)
    # kernel must be PSD (it is a Gram matrix)
    eigs = jnp.linalg.eigvalsh(K_ref)
    assert float(eigs.min()) > -1e-7 * scale


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=1000), st.floats(min_value=0.1, max_value=5.0))
def test_sven_solution_invariants(seed, lam2):
    """For any solvable instance: |beta|_1 == t (tight), KKT ~ 0, and the
    recovered beta has the sign-split property beta+ .* beta- == 0."""
    X, y, _ = make_regression(40, 70, k_true=8, seed=seed)
    l1 = 0.35 * float(lambda1_max(X, y))
    beta_cd = elastic_net_cd(X, y, l1, lam2).beta
    t = float(jnp.sum(jnp.abs(beta_cd)))
    hypothesis.assume(t > 1e-6)
    sol = sven(X, y, t, lam2)
    p = X.shape[1]
    # tight L1 constraint
    np.testing.assert_allclose(float(jnp.sum(jnp.abs(sol.beta))), t, rtol=1e-6)
    # alpha+ and alpha- are complementary per coordinate (unique EN solution)
    overlap = float(jnp.max(sol.alpha[:p] * sol.alpha[p:]))
    assert overlap < 1e-8 * (1 + float(sol.alpha.max()) ** 2)
    assert float(sol.kkt) < 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_cd_satisfies_penalized_kkt(seed):
    """Independent validation of the ground-truth CD solver: subgradient
    optimality of the penalized objective."""
    X, y, _ = make_regression(60, 30, k_true=6, seed=seed)
    lam1 = 0.3 * float(lambda1_max(X, y))
    lam2 = 1.0
    beta = elastic_net_cd(X, y, lam1, lam2).beta
    g = smooth_grad(X, y, beta, lam2)
    active = jnp.abs(beta) > 1e-10
    # active: g_j + lam1 sign(beta_j) == 0 ; inactive: |g_j| <= lam1
    act_res = jnp.where(active, jnp.abs(g + lam1 * jnp.sign(beta)), 0.0)
    inact_res = jnp.where(~active, jnp.maximum(jnp.abs(g) - lam1, 0.0), 0.0)
    assert float(jnp.max(act_res)) < 1e-6 * (1 + lam1)
    assert float(jnp.max(inact_res)) < 1e-6 * (1 + lam1)
