"""reprolint static-analysis suite (ISSUE 10, DESIGN.md §13).

Per-rule fixture pairs (a known-bad snippet flagged with the right rule id
and line, a known-good idiom that passes), suppression-comment semantics,
pyproject per-directory scoping, the JSON report schema, the CLI
exit-code contract, and the whole-repo "lint is clean" gate that keeps
pytest and CI enforcing the same contract.

Fixtures run through `lint_source` with an explicit relpath, so a snippet
can live "inside" src/repro/runtime/ without touching disk and without
depending on the repo's own pyproject scoping.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, str(REPO_ROOT))

from tools.reprolint import (LintConfig, RuleOverride, all_rules,  # noqa: E402
                             lint_source, load_config, render_json, run_paths)

CORE = "src/repro/core/svm/solver.py"
RUNTIME = "src/repro/runtime/scheduler.py"
ANY = "src/repro/anything.py"


def lint(src, relpath=ANY, select=None, cfg=LintConfig()):
    res = lint_source(textwrap.dedent(src), relpath, cfg,
                      tuple(select) if select else None)
    return res


def rule_hits(src, rule, relpath=ANY):
    return [f for f in lint(src, relpath, select=[rule]).findings
            if f.rule == rule]


# ---------------------------------------------------------------------------
# fixture pairs, one per rule
# ---------------------------------------------------------------------------

class TestTRC001ImportTimeJnp:
    def test_flags_module_level_jnp_work(self):
        bad = """\
        import jax.numpy as jnp
        LOOKUP = jnp.arange(128)
        """
        hits = rule_hits(bad, "TRC001")
        assert [h.line for h in hits] == [2]

    def test_flags_default_arg_and_class_body(self):
        bad = """\
        import jax.numpy as jnp
        def solve(x, init=jnp.zeros(3)):
            return x + init
        class Cfg:
            table = jnp.ones((4, 4))
        """
        assert sorted(h.line for h in rule_hits(bad, "TRC001")) == [2, 5]

    def test_clean_lazy_and_guarded(self):
        good = """\
        import jax.numpy as jnp
        import numpy as np
        HOST_CONST = np.arange(128)          # numpy at import is fine
        DTYPE = jnp.float32                  # attribute ref, not a call
        def solve(x):
            return x + jnp.arange(128)       # built at call time
        if __name__ == "__main__":
            print(jnp.zeros(3))              # script body, not import
        """
        assert rule_hits(good, "TRC001") == []


class TestTRC002TracedPythonBranch:
    def test_flags_if_on_traced_param_in_jit(self):
        bad = """\
        import jax
        @jax.jit
        def step(x, tol):
            if tol > 0:
                return x
            return -x
        """
        hits = rule_hits(bad, "TRC002", relpath=CORE)
        assert [h.line for h in hits] == [4]

    def test_flags_coercion_in_loop_body(self):
        bad = """\
        import jax
        import jax.numpy as jnp
        def run(state):
            def body(s):
                r = float(jnp.linalg.norm(s))
                return s - r
            return jax.lax.while_loop(lambda s: True, body, state)
        """
        hits = rule_hits(bad, "TRC002", relpath=CORE)
        assert [h.line for h in hits] == [5]

    def test_clean_static_branch_and_structure_check(self):
        good = """\
        import jax
        from functools import partial
        @partial(jax.jit, static_argnames=("config",))
        def step(x, K, config):
            if config.solver == "newton":    # static arg: legal branch
                x = 2 * x
            if K is None:                    # pytree structure: jit key
                K = x @ x.T
            return K
        """
        assert rule_hits(good, "TRC002", relpath=CORE) == []

    def test_out_of_scope_module_not_linted_by_default(self):
        bad = """\
        import jax
        @jax.jit
        def step(x):
            if x > 0:
                return x
            return -x
        """
        assert lint(bad, relpath="src/repro/launch/driver.py",
                    select=["TRC002"]).findings == []


class TestTRC003JitStaticConfig:
    def test_flags_traced_config_param(self):
        bad = """\
        import jax
        @jax.jit
        def solve(X, y, config):
            return X @ y
        """
        hits = rule_hits(bad, "TRC003")
        assert len(hits) == 1 and "config" in hits[0].message

    def test_clean_with_static_argnames(self):
        good = """\
        import jax
        from functools import partial
        @partial(jax.jit, static_argnames=("config", "mesh"))
        def solve(X, y, config, mesh):
            return X @ y
        """
        assert rule_hits(good, "TRC003") == []


class TestSYN001HostSync:
    def test_flags_item_and_device_get(self):
        bad = """\
        import jax
        def drain(beta):
            n = beta.sum().item()
            host = jax.device_get(beta)
            return n, host
        """
        hits = rule_hits(bad, "SYN001", relpath=RUNTIME)
        assert sorted(h.line for h in hits) == [3, 4]

    def test_flags_float_of_jnp_result(self):
        bad = """\
        import jax.numpy as jnp
        def admit(x):
            return float(jnp.max(x))
        """
        assert [h.line for h in rule_hits(bad, "SYN001", relpath=RUNTIME)] == [3]

    def test_clean_numpy_staging(self):
        good = """\
        import numpy as np
        def stage(reqs, dtype):
            return np.asarray([r.lam for r in reqs], dtype)
        """
        assert rule_hits(good, "SYN001", relpath=RUNTIME) == []

    def test_benchmarks_out_of_scope(self):
        ok = """\
        import jax.numpy as jnp
        def measure(x):
            return float(jnp.max(x))    # benchmarks harvest freely
        """
        assert lint(ok, relpath="benchmarks/bench_x.py",
                    select=["SYN001"]).findings == []


class TestSYN002UnsanctionedBlock:
    def test_flags_block_in_runtime(self):
        bad = """\
        import jax
        def poll(beta):
            jax.block_until_ready(beta)
        """
        assert [h.line for h in rule_hits(bad, "SYN002", relpath=RUNTIME)] == [3]

    def test_suppressed_harvest_site_passes(self):
        good = """\
        import jax
        def harvest(inf):
            # reprolint: disable=SYN002 -- the sanctioned harvest barrier
            jax.block_until_ready(inf.beta)
        """
        res = lint(good, relpath=RUNTIME, select=["SYN002"])
        assert res.findings == [] and len(res.suppressed) == 1


class TestCOL001CollectiveInLoopBody:
    def test_flags_psum_in_fori_body_lambda_and_def(self):
        bad = """\
        import jax
        from jax import lax
        def run(x, axes):
            def body(i, c):
                return c + lax.psum(x, axes)
            r = lax.fori_loop(0, 8, body, x)
            return lax.while_loop(lambda s: True,
                                  lambda s: s + lax.psum(s, axes), r)
        """
        hits = rule_hits(bad, "COL001")
        assert sorted(h.line for h in hits) == [5, 8]
        assert "~60x" in hits[0].message

    def test_clean_collective_outside_loop(self):
        good = """\
        import jax
        from jax import lax
        def run(x, axes):
            total = lax.psum(x, axes)            # hoisted: once per call
            return lax.fori_loop(0, 8, lambda i, c: c + total, x)
        """
        assert rule_hits(good, "COL001") == []

    def test_audited_module_default_exclude(self):
        bad = """\
        from jax import lax
        def cg(x, axes):
            return lax.fori_loop(0, 8, lambda i, c: c + lax.psum(x, axes), x)
        """
        assert lint(bad, relpath="src/repro/core/distributed.py",
                    select=["COL001"]).findings == []


class TestCOL002ShardMapNeedsMesh:
    def test_flags_meshless_shard_map(self):
        bad = """\
        from jax.experimental.shard_map import shard_map
        def f(local):
            return shard_map(local)
        """
        assert [h.line for h in rule_hits(bad, "COL002")] == [3]

    def test_clean_with_mesh(self):
        good = """\
        from jax.experimental.shard_map import shard_map
        def f(local, mesh, P):
            return shard_map(local, mesh=mesh, in_specs=P, out_specs=P)
        """
        assert rule_hits(good, "COL002") == []


class TestATM001AtomicWrites:
    def test_flags_bare_write_in_persistence_module(self):
        bad = """\
        import json
        def spill(path, entry):
            with open(path, "w") as f:
                json.dump(entry, f)
        """
        assert [h.line for h in rule_hits(bad, "ATM001",
                                          relpath="src/repro/runtime/cache.py")] == [3]

    def test_clean_tmp_plus_rename(self):
        good = """\
        import json, os, tempfile
        def spill(d, name, entry):
            fd, tmp = tempfile.mkstemp(dir=d)
            with os.fdopen(fd, "w") as f:
                json.dump(entry, f)
            os.replace(tmp, os.path.join(d, name))
        """
        assert rule_hits(good, "ATM001",
                         relpath="src/repro/runtime/cache.py") == []

    def test_reads_and_out_of_scope_writes_clean(self):
        ok = """\
        import json
        def load(path):
            with open(path) as f:          # read mode: not a write site
                return json.load(f)
        """
        assert rule_hits(ok, "ATM001", relpath="src/repro/runtime/cache.py") == []
        write_elsewhere = """\
        def export(path, doc):
            with open(path, "w") as f:     # launch/ is not a persistence module
                f.write(doc)
        """
        assert lint(write_elsewhere, relpath="src/repro/launch/report.py",
                    select=["ATM001"]).findings == []


class TestRES001OpenWithoutContext:
    def test_flags_leaked_handle(self):
        bad = """\
        import json
        def load(path):
            return json.load(open(path))
        """
        assert [h.line for h in rule_hits(bad, "RES001")] == [3]

    def test_clean_with_and_explicit_close(self):
        good = """\
        import json
        def load(path):
            with open(path) as f:
                a = json.load(f)
            f2 = open(path)
            try:
                b = json.load(f2)
            finally:
                f2.close()
            return a, b
        """
        assert rule_hits(good, "RES001") == []


class TestDET001GlobalRng:
    def test_flags_legacy_np_random_and_stdlib_random(self):
        bad = """\
        import random
        import numpy as np
        def sample(n):
            return np.random.rand(n) + random.random()
        """
        hits = rule_hits(bad, "DET001")
        assert len(hits) == 2 and all(h.line == 4 for h in hits)

    def test_clean_seeded_generator(self):
        good = """\
        import numpy as np
        def sample(n, seed=0):
            rng = np.random.default_rng(seed)
            return rng.standard_normal(n)
        """
        assert rule_hits(good, "DET001") == []


class TestDET002UnseededRng:
    def test_flags_unseeded_and_clock_seeded(self):
        bad = """\
        import time
        import numpy as np
        def make():
            a = np.random.default_rng()
            b = np.random.default_rng(int(time.time()))
            return a, b
        """
        assert sorted(h.line for h in rule_hits(bad, "DET002")) == [4, 5]

    def test_clean_explicit_seed(self):
        good = """\
        import numpy as np
        def make(seed):
            return np.random.default_rng(seed)
        """
        assert rule_hits(good, "DET002") == []


class TestTIM001BareClock:
    def test_flags_bare_clock_reads_in_runtime(self):
        bad = """\
        import time
        def admit(req):
            req.t0 = time.perf_counter()
            req.wall = time.time()
        """
        assert sorted(h.line for h in rule_hits(bad, "TIM001",
                                                relpath=RUNTIME)) == [3, 4]

    def test_clean_obs_aliases_and_docstring_mentions(self):
        good = '''\
        from repro.obs import clock
        def admit(req):
            """Uses clock.monotonic, never bare time.time()."""
            req.t0 = clock.monotonic()
            return clock.walltime()
        '''
        assert rule_hits(good, "TIM001", relpath=RUNTIME) == []

    def test_out_of_runtime_clock_reads_allowed(self):
        ok = """\
        import time
        def calibrate():
            return time.perf_counter()    # measurement code outside runtime/
        """
        assert lint(ok, relpath="src/repro/core/routing.py",
                    select=["TIM001"]).findings == []


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------

class TestSuppressions:
    BAD = """\
    import time
    def admit(req):
        req.t0 = time.perf_counter(){trailer}
    """

    def test_same_line_suppression(self):
        src = self.BAD.format(
            trailer="  # reprolint: disable=TIM001 -- injected-clock test shim")
        res = lint(src, relpath=RUNTIME, select=["TIM001"])
        assert res.findings == [] and [f.rule for f in res.suppressed] == ["TIM001"]

    def test_standalone_suppression_covers_next_code_line(self):
        src = """\
        import time
        def admit(req):
            # reprolint: disable=TIM001 -- first line of a justification
            # that continues on a second comment line
            req.t0 = time.perf_counter()
        """
        res = lint(src, relpath=RUNTIME, select=["TIM001"])
        assert res.findings == [] and len(res.suppressed) == 1

    def test_wrong_rule_id_does_not_suppress(self):
        src = self.BAD.format(
            trailer="  # reprolint: disable=SYN001 -- not the right rule")
        res = lint(src, relpath=RUNTIME, select=["TIM001"])
        assert [f.rule for f in res.findings] == ["TIM001"]

    def test_missing_justification_is_its_own_finding(self):
        src = self.BAD.format(trailer="  # reprolint: disable=TIM001")
        res = lint(src, relpath=RUNTIME)
        assert [f.rule for f in res.findings] == ["SUP001"]
        assert [f.rule for f in res.suppressed] == ["TIM001"]

    def test_multi_rule_suppression(self):
        src = """\
        import jax
        def poll(beta):
            jax.block_until_ready(beta).sum().item()  # reprolint: disable=SYN001,SYN002 -- drain_reference: the deliberately synchronous oracle
        """
        res = lint(src, relpath=RUNTIME, select=["SYN001", "SYN002"])
        assert res.findings == [] and len(res.suppressed) == 2

    def test_suppressions_recorded_with_reason(self):
        src = self.BAD.format(
            trailer="  # reprolint: disable=TIM001 -- injected clock")
        res = lint(src, relpath=RUNTIME)
        (path, sup), = res.suppressions
        assert sup.rules == ("TIM001",) and sup.reason == "injected clock"

    def test_directive_quoted_in_docstring_is_not_live(self):
        src = '''\
        """Docs may QUOTE a directive without activating it:

            x = risky()  # reprolint: disable=TIM001 -- example only
        """
        import time
        def admit(req):
            req.t0 = time.perf_counter()
        '''
        res = lint(src, relpath=RUNTIME, select=["TIM001"])
        assert [f.rule for f in res.findings] == ["TIM001"]
        assert res.suppressions == []


# ---------------------------------------------------------------------------
# pyproject per-directory scoping
# ---------------------------------------------------------------------------

class TestConfigScoping:
    BAD_CLOCK = ("import time\n"
                 "def f():\n"
                 "    return time.perf_counter()\n")

    def test_rule_override_narrows_include(self, tmp_path):
        (tmp_path / "pyproject.toml").write_text(textwrap.dedent("""\
            [tool.reprolint]
            exclude = ["vendored"]

            [tool.reprolint.rules.TIM001]
            include = ["pkg/hot"]
        """))
        cfg = load_config(tmp_path)
        for rel, expect in [("pkg/hot/loop.py", 1),      # in override scope
                            ("src/repro/runtime/x.py", 0),  # default replaced
                            ("pkg/cold/loop.py", 0)]:
            res = lint_source(self.BAD_CLOCK, rel, cfg, select=("TIM001",))
            assert len(res.findings) == expect, rel

    def test_global_exclude_skips_path_entirely(self, tmp_path):
        (tmp_path / "pyproject.toml").write_text(textwrap.dedent("""\
            [tool.reprolint]
            exclude = ["vendored"]
        """))
        cfg = load_config(tmp_path)
        bad = "f = open('x')\n"
        assert lint_source(bad, "vendored/leak.py", cfg).findings == []
        assert lint_source(bad, "src/leak.py", cfg).findings != []

    def test_rule_exclude_carves_out_audited_file(self, tmp_path):
        (tmp_path / "pyproject.toml").write_text(textwrap.dedent("""\
            [tool.reprolint.rules.RES001]
            exclude = ["src/audited.py"]
        """))
        cfg = load_config(tmp_path)
        bad = "f = open('x')\n"
        assert lint_source(bad, "src/audited.py", cfg,
                           select=("RES001",)).findings == []
        assert lint_source(bad, "src/other.py", cfg,
                           select=("RES001",)).findings != []

    def test_missing_pyproject_is_all_defaults(self, tmp_path):
        cfg = load_config(tmp_path)
        assert cfg == LintConfig()

    def test_api_override_object(self):
        cfg = LintConfig(rules={"TIM001": RuleOverride(include=("elsewhere",))})
        assert lint_source(self.BAD_CLOCK, RUNTIME, cfg,
                           select=("TIM001",)).findings == []

    def test_repo_pyproject_carries_audited_collective_exclude(self):
        cfg = load_config(REPO_ROOT)
        assert "src/repro/core/distributed.py" in \
            cfg.rules["COL001"].exclude


# ---------------------------------------------------------------------------
# toml subset fallback parser (used only when tomllib AND tomli are absent)
# ---------------------------------------------------------------------------

def test_toml_subset_parser_matches_real_parser():
    from tools.reprolint.config import _load_toml, _parse_toml_subset
    text = (REPO_ROOT / "pyproject.toml").read_text()
    real = _load_toml(text)["tool"]["reprolint"]
    subset = _parse_toml_subset(text)["tool"]["reprolint"]
    assert subset == real


# ---------------------------------------------------------------------------
# JSON output schema + CLI exit-code contract
# ---------------------------------------------------------------------------

class TestOutputAndExitCodes:
    def run_cli(self, *argv, cwd=REPO_ROOT):
        return subprocess.run([sys.executable, "-m", "tools.reprolint", *argv],
                              cwd=cwd, capture_output=True, text=True,
                              timeout=120)

    def test_json_schema(self, tmp_path):
        report = tmp_path / "reprolint.json"
        proc = self.run_cli("src", "benchmarks", "tools",
                            "--format", "json", "--output", str(report))
        doc = json.loads(proc.stdout)
        assert doc == json.loads(report.read_text())
        assert doc["version"] == 1 and doc["tool"] == "reprolint"
        for key in ("root", "paths", "rules", "files_scanned", "ok",
                    "counts", "findings", "suppressed", "suppressions"):
            assert key in doc, key
        assert len([r for r in doc["rules"] if r != "SUP001"]) >= 6, (
            "acceptance: >= 6 rules active")
        for f in doc["findings"]:
            assert set(f) == {"path", "line", "col", "rule", "message"}
        for s in doc["suppressions"]:
            assert s["reason"], (
                "acceptance: every suppression carries a justification", s)

    def test_exit_zero_on_clean_tree_and_one_on_findings(self, tmp_path):
        proc = self.run_cli("src", "benchmarks", "tools")
        assert proc.returncode == 0, proc.stdout + proc.stderr
        bad = tmp_path / "bad.py"
        bad.write_text("import json\n"
                       "def f(p):\n"
                       "    return json.load(open(p))\n")
        proc = self.run_cli(str(bad), "--root", str(tmp_path))
        assert proc.returncode == 1
        assert "RES001" in proc.stdout

    def test_exit_two_on_unparseable_file(self, tmp_path):
        (tmp_path / "broken.py").write_text("def f(:\n")
        proc = self.run_cli("broken.py", "--root", str(tmp_path))
        assert proc.returncode == 2
        assert "cannot parse" in proc.stderr

    def test_select_limits_rules(self, tmp_path):
        (tmp_path / "bad.py").write_text(
            "import time\nf = open('x')\nt = time.time()\n")
        proc = self.run_cli("bad.py", "--root", str(tmp_path),
                            "--select", "RES001")
        assert "RES001" in proc.stdout and "TIM001" not in proc.stdout

    def test_list_rules(self):
        proc = self.run_cli("--list-rules")
        assert proc.returncode == 0
        for rid in ("TRC001", "TRC002", "TRC003", "SYN001", "SYN002",
                    "COL001", "COL002", "ATM001", "RES001", "DET001",
                    "DET002", "TIM001", "SUP001"):
            assert rid in proc.stdout, rid


# ---------------------------------------------------------------------------
# whole-repo gates: pytest enforces the same contract as CI
# ---------------------------------------------------------------------------

def test_whole_repo_lint_is_clean():
    res = run_paths(REPO_ROOT, ["src", "benchmarks", "tools"],
                    load_config(REPO_ROOT))
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
    # every live suppression carries its justification (SUP001 would have
    # fired above otherwise, but keep the direct assertion for the report)
    for path, sup in res.suppressions:
        assert sup.reason, (path, sup)


def test_check_timing_shim_still_works():
    sys.path.insert(0, str(REPO_ROOT / "tools"))
    try:
        import check_timing
        assert check_timing.find_violations(REPO_ROOT) == []
    finally:
        sys.path.pop(0)
    proc = subprocess.run([sys.executable, "tools/check_timing.py"],
                          cwd=REPO_ROOT, capture_output=True, text=True,
                          timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "deprecated" in proc.stderr


def test_rule_metadata_complete():
    rules = all_rules()
    assert len(rules) >= 6
    for rid, rule in rules.items():
        assert rule.meta.id == rid
        assert rule.meta.summary and rule.meta.name
        assert rule.__doc__ and rid in rule.__doc__.partition(":")[0], (
            "rule docstring must lead with its id")
