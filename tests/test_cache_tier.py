"""Two-tier warm-start cache: property tests + disk-isolation regression.

The persistent spill tier (DESIGN.md §11.2) has three invariants no
interleaving of inserts / lookups / clock advances may break:

    bound    on-disk bytes never exceed `max_bytes` after any operation;
    identity a lookup never returns an entry inserted under a DIFFERENT
             fingerprint (cross-problem contamination would warm-start one
             problem from another's iterate — slow at best, and a silent
             correctness hazard for screening state);
    ttl      an entry older than `ttl_s` is never served, no matter how
             recently its mtime was refreshed by LRU bookkeeping.

The Hypothesis machine drives a `TieredSolutionCache` through random op
sequences seeded with the PR 5 lambda = 0 EDGE keys (lambda1 = 0 is pure
ridge, lambda2 = 0 the Lasso: form boundaries, not small lambdas — they
must never warm-start, or be warm-started by, positive-lambda traffic).
Deterministic counterparts pin each invariant individually so the suite
still checks them when hypothesis isn't installed (the @given tests skip).

The bottom pair is a regression test for test ISOLATION: conftest.py
points `REPRO_CACHE_DIR` at a per-test tmp dir precisely so back-to-back
sessions cannot see each other's persisted tiles/calibrations/spills.
"""
import tempfile
from pathlib import Path

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.runtime.cache import (PersistentCacheTier, SolutionCache,
                                 TieredSolutionCache, WarmEntry)


class FakeClock:
    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t


def make_entry(lam, lambda2, tag=0.0):
    """A geometry-consistent entry; `tag` is stamped into beta[0] so a
    served entry can be traced back to the exact insert that produced it."""
    beta = np.full(4, tag)
    return WarmEntry(lam=lam, lambda2=lambda2, alpha=np.zeros(8),
                     w=np.zeros(6), beta=beta, t=lam, nu=0.0)


# -- the op-sequence property machine ---------------------------------------

#: Small universes keep collisions (same fp, same point, overwrites) likely.
FPS = ("fp-a", "fp-b", "fp-c")
#: Lambda points INCLUDING the PR 5 edges: 0.0 on either axis is a form
#: boundary (pure ridge / pure lasso) with +inf log-distance to any
#: positive lambda.
LAMS = (0.0, 1e-3, 0.5, 1.0, 2.7)
LAM2S = (0.0, 0.1, 1.0)
TTL = 60.0
MAX_BYTES = 6 << 10       # a handful of entries — evictions happen often

_op = st.one_of(
    st.tuples(st.just("insert"), st.sampled_from(FPS), st.sampled_from(LAMS),
              st.sampled_from(LAM2S)),
    st.tuples(st.just("lookup"), st.sampled_from(FPS), st.sampled_from(LAMS),
              st.sampled_from(LAM2S)),
    st.tuples(st.just("tick"), st.sampled_from((1.0, 30.0, 61.0))),
)


def _check_invariants(cache, model, clock, fp, lam, lam2, got, *,
                      check_ttl=False):
    """`got` was served for (fp, lam, lam2): trace it to its insert.

    `check_ttl` applies only when the serve MUST have come off disk (a
    fresh process): the memory tier is deliberately TTL-free — an iterate
    this process computed stays warm for its lifetime; `ttl_s` bounds the
    staleness only of what a RESTARTED or sibling process inherits."""
    assert (got.lam, got.lambda2) in model.get(fp, {}), (
        f"served a point never inserted under {fp}")
    tag, t_ins = model[fp][(got.lam, got.lambda2)]
    assert got.beta[0] == tag, (
        f"served fingerprint-mismatched payload for {fp}")
    if check_ttl:
        assert clock() - t_ins <= TTL, (
            f"served an entry {clock() - t_ins:.0f}s old (ttl {TTL}s)")
    # the lambda = 0 edges never cross-serve a positive-lambda query
    if lam == 0.0 or got.lam == 0.0:
        assert lam == got.lam, "lambda1=0 edge crossed the form boundary"
    if lam2 == 0.0 or got.lambda2 == 0.0:
        assert lam2 == got.lambda2, "lambda2=0 edge crossed the boundary"


@settings(max_examples=40, deadline=None)
@given(st.lists(_op, min_size=1, max_size=40))
def test_tiered_cache_invariants_under_interleavings(ops):
    # NOT tmp_path: each hypothesis example needs a FRESH spill dir (a
    # function-scoped fixture is shared across examples — stale entries
    # from the previous example would fail the identity check spuriously)
    with tempfile.TemporaryDirectory() as td:
        clock = FakeClock()
        cache = TieredSolutionCache(spill_dir=Path(td) / "spill",
                                    max_bytes=MAX_BYTES, ttl_s=TTL,
                                    clock=clock)
        model = {}                   # fp -> {(lam, lam2): (tag, t_insert)}
        tag = 0.0
        for op in ops:
            if op[0] == "insert":
                _, fp, lam, lam2 = op
                tag += 1.0
                cache.insert(fp, "constrained", make_entry(lam, lam2, tag))
                model.setdefault(fp, {})[(lam, lam2)] = (tag, clock())
            elif op[0] == "lookup":
                _, fp, lam, lam2 = op
                got = cache.lookup(fp, "constrained", lam, lam2)
                if got is not None:
                    _check_invariants(cache, model, clock, fp, lam, lam2, got)
            else:
                clock.t += op[1]
            assert cache.spill.total_bytes() <= MAX_BYTES, (
                f"spill grew past its bound after {op}")


@settings(max_examples=25, deadline=None)
@given(st.lists(_op, min_size=1, max_size=30))
def test_fresh_process_sees_only_valid_spill(ops):
    """Whatever an op sequence leaves on disk, a FRESH cache on the same
    spill dir (the restarted-host view) still upholds identity + ttl."""
    with tempfile.TemporaryDirectory() as td:
        clock = FakeClock()
        first = TieredSolutionCache(spill_dir=Path(td) / "spill",
                                    max_bytes=MAX_BYTES, ttl_s=TTL,
                                    clock=clock)
        model = {}
        tag = 0.0
        for op in ops:
            if op[0] == "insert":
                _, fp, lam, lam2 = op
                tag += 1.0
                first.insert(fp, "constrained", make_entry(lam, lam2, tag))
                model.setdefault(fp, {})[(lam, lam2)] = (tag, clock())
            elif op[0] == "tick":
                clock.t += op[1]
        fresh = TieredSolutionCache(spill_dir=Path(td) / "spill",
                                    max_bytes=MAX_BYTES, ttl_s=TTL,
                                    clock=clock)
        for fp in FPS:
            for lam in LAMS:
                for lam2 in LAM2S:
                    got = fresh.lookup(fp, "constrained", lam, lam2)
                    if got is not None:
                        _check_invariants(fresh, model, clock, fp, lam,
                                          lam2, got, check_ttl=True)


# -- deterministic pins (run even without hypothesis) ------------------------

def test_size_bound_never_exceeded(tmp_path):
    tier = PersistentCacheTier(tmp_path, max_bytes=6 << 10)
    for k in range(32):
        tier.insert(f"fp{k}", "constrained", make_entry(1.0, 1.0, float(k)))
        assert tier.total_bytes() <= tier.max_bytes
    assert tier.evicted > 0, "bound this tight must have evicted"
    assert len(tier) >= 1, "eviction must not empty a hot tier"


def test_ttl_expired_never_served(tmp_path):
    clock = FakeClock()
    tier = PersistentCacheTier(tmp_path, ttl_s=60.0, clock=clock)
    tier.insert("fp", "constrained", make_entry(1.0, 1.0))
    clock.t += 59.0
    assert tier.lookup("fp", "constrained", 1.0, 1.0) is not None
    # NOTE the hit above refreshed the file MTIME (the LRU clock) — age is
    # judged by the stored creation stamp, so the entry still expires:
    clock.t += 2.0
    assert tier.lookup("fp", "constrained", 1.0, 1.0) is None
    assert tier.expired_dropped == 1
    assert len(tier) == 0, "expired entries are dropped, not kept"


def test_expire_sweep_counts(tmp_path):
    clock = FakeClock()
    tier = PersistentCacheTier(tmp_path, ttl_s=60.0, clock=clock)
    tier.insert("fp0", "constrained", make_entry(1.0, 1.0))
    clock.t += 100.0
    tier.insert("fp1", "constrained", make_entry(1.0, 1.0))
    assert tier.expire() == 1
    assert len(tier) == 1


@pytest.mark.parametrize("cache_factory", [
    lambda tmp: SolutionCache(),
    lambda tmp: TieredSolutionCache(spill_dir=tmp / "spill"),
], ids=["memory", "tiered"])
def test_lambda_zero_edges_never_cross(tmp_path, cache_factory):
    """PR 5 edge semantics, now on every tier: lambda = 0 is a FORM
    boundary. Ridge-edge entries serve only ridge-edge queries; lasso-edge
    (lambda2 = 0) entries serve only lasso queries — tiny positive lambdas
    are NOT adjacent to zero."""
    cache = cache_factory(tmp_path)
    cache.insert("fp", "constrained", make_entry(1.0, 1.0, tag=1.0))
    cache.insert("fp", "constrained", make_entry(0.0, 1.0, tag=2.0))
    cache.insert("fp", "constrained", make_entry(1.0, 0.0, tag=3.0))

    assert cache.lookup("fp", "constrained", 0.0, 1.0).beta[0] == 2.0
    assert cache.lookup("fp", "constrained", 1.0, 0.0).beta[0] == 3.0
    assert cache.lookup("fp", "constrained", 1e-12, 1.0) is None, (
        "a tiny positive lambda must not hit the lambda=0 edge entry")
    assert cache.lookup("fp", "constrained", 1.0, 1e-12) is None
    assert cache.lookup("fp", "constrained", 1.1, 1.0).beta[0] == 1.0


def test_spill_hit_promotes_to_memory(tmp_path):
    cache = TieredSolutionCache(spill_dir=tmp_path / "spill")
    cache.insert("fp", "constrained", make_entry(1.0, 1.0, tag=7.0))
    fresh = TieredSolutionCache(spill_dir=tmp_path / "spill")
    assert fresh.lookup("fp", "constrained", 1.0, 1.0).beta[0] == 7.0
    assert fresh.spill_hits == 1
    for f in (tmp_path / "spill").glob("*.npz"):
        f.unlink()                   # memory must now serve alone
    assert fresh.lookup("fp", "constrained", 1.0, 1.0).beta[0] == 7.0
    assert fresh.spill_hits == 1, "second hit must come from memory"


# -- disk-cache isolation regression (the conftest autouse fixture) ----------
#
# Ordered pair sharing a module global: the first test persists state
# through `utils.cache_dir()` (exactly where autotuned tiles, routing
# calibrations and default spill tiers land); the second asserts a later
# test session sees a DIFFERENT directory and none of the first's state.
# Before the fixture existed, both resolved to ~/.cache/repro-sven and the
# second test would read the first's "tiles".

_leaked = {}


def test_disk_cache_isolation_writer():
    from repro import utils

    d = utils.cache_dir()
    assert d is not None
    (d / "tiles.json").write_text('{"leak": true}')
    _leaked["dir"] = d


def test_disk_cache_isolation_reader():
    from repro import utils

    assert "dir" in _leaked, "writer half must run first (file order)"
    d = utils.cache_dir()
    assert d is not None
    assert d != _leaked["dir"], (
        "REPRO_CACHE_DIR must differ per test — the conftest autouse "
        "fixture is broken or gone")
    assert not (d / "tiles.json").exists(), (
        "a previous test's persisted tiles leaked into this session")
