"""Optional-hypothesis shim: property tests skip cleanly when hypothesis is
not installed (it is dev-only, see requirements-dev.txt) while the plain
parametrized tests in the same modules keep running."""
import pytest

try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _skip = pytest.mark.skip(
        reason="needs hypothesis (pip install -r requirements-dev.txt)")

    def given(*_args, **_kwargs):
        return lambda f: _skip(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
